"""Ablation beyond the paper: probe sample count N and probe-model
choice (§3.2.3 'Why N=3?' — the paper asserts, we measure).

sigma generalises to (|{a_1..a_N}|-1)/(N-1); the router maps
sigma=0 -> single, sigma=1 -> full, else arena_lite. Larger N buys a
finer difficulty signal at linear probe cost; a stronger probe model
shifts the sigma=0 mass up (more consensus) but costs more per probe.
"""
from __future__ import annotations

from pathlib import Path

import numpy as np

from benchmarks.common import ARENA3, csv_line, write_json
from repro.configs.acar import ACARConfig
from repro.core.backends import paper_backends
from repro.core.orchestrator import ACAROrchestrator
from repro.data.tasks import paper_suite

OUT = Path("experiments/bench/ablation_probe.json")


def run(seed: int = 0, verbose: bool = True,
        n_values=(1, 2, 3, 5, 7),
        probes=("gemini-2.0-flash", "gpt-4o")) -> dict:
    tasks = paper_suite(seed=seed)
    backs = paper_backends()
    out = {"by_n": {}, "by_probe": {}}
    for n in n_values:
        acfg = ACARConfig(seed=seed, n_probe_samples=n)
        orch = ACAROrchestrator(acfg, backs["gemini-2.0-flash"],
                                {m: backs[m] for m in ARENA3},
                                run_id=f"ablate_n{n}")
        outs = orch.run_suite(tasks)
        acc = float(np.mean([o.correct for o in outs]))
        cost = float(sum(o.trace.cost for o in outs))
        full = np.mean([o.trace.mode == "full_arena" for o in outs])
        out["by_n"][str(n)] = {"accuracy": acc, "cost": cost,
                               "full_arena_rate": float(full)}
    for probe in probes:
        acfg = ACARConfig(seed=seed)
        orch = ACAROrchestrator(acfg, backs[probe],
                                {m: backs[m] for m in ARENA3},
                                run_id=f"ablate_probe_{probe}")
        outs = orch.run_suite(tasks)
        out["by_probe"][probe] = {
            "accuracy": float(np.mean([o.correct for o in outs])),
            "cost": float(sum(o.trace.cost for o in outs)),
        }
    write_json(OUT, out)
    if verbose:
        for n, r in out["by_n"].items():
            print(f"  N={n}: acc {r['accuracy']:.3f} "
                  f"cost ${r['cost']:.2f} "
                  f"full-arena {r['full_arena_rate']:.2f}")
        for p, r in out["by_probe"].items():
            print(f"  probe={p}: acc {r['accuracy']:.3f} "
                  f"cost ${r['cost']:.2f}")
    return out


def main() -> str:
    t = run(verbose=False)
    accs = {n: r["accuracy"] for n, r in t["by_n"].items()}
    best = max(accs, key=accs.get)
    return csv_line("ablation_probe", 0.0, f"best_N={best}")


if __name__ == "__main__":
    run()
