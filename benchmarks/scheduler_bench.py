"""Continuous-batching scheduler benchmark.

Drives the same seeded synthetic workload through the sequential
``ACAROrchestrator`` and the ``ContinuousBatchingScheduler`` (calibrated
synthetic backends) and reports task throughput for both paths on the
deterministic virtual clock — the calibrated per-call latency model the
simulator exposes — plus host wall time and the equivalence digest.

The virtual clock is the honest metric here: synthetic backends return
instantly, so wall time measures Python overhead, while the virtual
makespan measures what batching + the two-stage probe/ensemble pipeline
buy at the modeled provider latencies (the paper's regime).

The compaction section reports what escalated-subset wave planning
buys, twice: at the **calibrated** routing distribution this
reproduction's synthetic backends produce over the paper mix (~68%
escalated — honest but pessimistic for compaction), and at the
**paper's published rate** (sigma-routing avoids ensemble work on
54.2% of tasks, i.e. 45.8% escalate) via a scripted-sigma workload.
Both report ensemble decode row reduction vs the masked full-batch
path and the shared-prefix probe prefill reduction (~N x). Results are
persisted to ``BENCH_scheduler.json`` (repo root, uploaded nightly by
CI) and ``experiments/bench/scheduler.json``.

    PYTHONPATH=src:tests python -m benchmarks.scheduler_bench [--smoke]
"""
from __future__ import annotations

import argparse
import sys
import time
from dataclasses import dataclass

import numpy as np

from benchmarks.common import PAPER_RATE_BLOCK, csv_line, persist_bench
from repro.configs.acar import ACARConfig
from repro.core.backends import GenResult, paper_backends
from repro.core.orchestrator import ACAROrchestrator
from repro.data.tasks import Task, paper_suite
from repro.serving.queue import MicroBatchPolicy
from repro.serving.scheduler import ContinuousBatchingScheduler

PROBE = "gemini-2.0-flash"


@dataclass
class _SigmaScriptedBackend:
    """Probe whose N=3 samples realise a scripted sigma per task id;
    as an ensemble member it always answers 'a'."""
    name: str
    sigma_class: dict            # task_id -> 0 | 1 | 2
    latency_ms: float = 100.0

    _ANSWERS = {0: ("a", "a", "a"), 1: ("a", "a", "b"),
                2: ("a", "b", "c")}

    def generate(self, task: Task, prompt: str, *, temperature: float,
                 sample_idx: int = 0, seed: int = 0, **_kw) -> GenResult:
        cls = self.sigma_class.get(task.task_id, 0)
        ans = self._ANSWERS[cls][sample_idx % 3]
        return GenResult(response=f"answer: {ans}",
                         semantic_answer=ans, cost=0.001,
                         latency_ms=self.latency_ms, score=0.0)


def paper_rate_run(n_tasks: int, batch_size: int, seed: int) -> dict:
    """Compaction accounting at the paper's published routing rates."""
    rng = np.random.default_rng(seed + 0x45A)
    classes = []
    while len(classes) < n_tasks:
        block = list(PAPER_RATE_BLOCK)
        rng.shuffle(block)
        classes.extend(block)
    classes = classes[:n_tasks]
    tasks = [Task(task_id=f"pr-{i:05d}", benchmark="paper_rate",
                  kind="reasoning", text=f"paper rate task {i}",
                  gold="a", difficulty=0.0)
             for i in range(n_tasks)]
    sigma_class = {t.task_id: c for t, c in zip(tasks, classes)}
    probe = _SigmaScriptedBackend("probe", sigma_class)
    ensemble = {n: _SigmaScriptedBackend(n, {})
                for n in ("m1", "m2", "m3")}
    sched = ContinuousBatchingScheduler(
        ACARConfig(seed=seed), probe, ensemble, run_id="paper-rate",
        policy=MicroBatchPolicy(max_batch_size=batch_size))
    sched.serve(tasks)
    st = sched.stats
    return {
        "paper_rate_n_tasks": n_tasks,
        "paper_rate_escalation_rate": st.escalated_rows / n_tasks,
        "paper_rate_ensemble_decode_rows": st.ensemble_decode_rows,
        "paper_rate_ensemble_decode_rows_saved":
            st.ensemble_decode_rows_saved,
        "paper_rate_ensemble_decode_row_reduction":
            st.ensemble_decode_row_reduction,
        "paper_rate_probe_prefill_reduction":
            st.probe_prefill_reduction,
    }


def sample_workload(n_tasks: int, seed: int):
    """Seeded sample spread across the whole paper mix. The suite is
    ordered by benchmark, so taking its head would over-represent the
    high-escalation benchmarks and misstate the routing distribution."""
    pool = paper_suite(seed=seed)
    rng = np.random.default_rng(seed + 0xBE7C)
    idx = rng.permutation(len(pool))[:n_tasks]
    return [pool[int(i)] for i in idx]


def run(n_tasks: int = 200, batch_size: int = 8, seed: int = 0,
        verbose: bool = True) -> dict:
    tasks = sample_workload(n_tasks, seed)
    acfg = ACARConfig(seed=seed)

    backs = paper_backends()
    t0 = time.perf_counter()
    seq = ACAROrchestrator(acfg, backs[PROBE], backs,
                           run_id="bench").run_suite(tasks)
    seq_wall_ms = (time.perf_counter() - t0) * 1e3
    seq_makespan_ms = sum(o.latency_ms for o in seq)

    backs2 = paper_backends()
    sched = ContinuousBatchingScheduler(
        acfg, backs2[PROBE], backs2, run_id="bench",
        policy=MicroBatchPolicy(max_batch_size=batch_size))
    bat = sched.serve(tasks)
    st = sched.stats

    identical = (
        [o.trace.record_hash() for o in seq]
        == [o.trace.record_hash() for o in bat])
    seq_tps = n_tasks / (seq_makespan_ms / 1e3)
    out = {
        "n_tasks": n_tasks,
        "batch_size": batch_size,
        "identical_traces": identical,
        "sequential_makespan_ms": seq_makespan_ms,
        "scheduler_pipeline_makespan_ms": st.pipeline_makespan_ms,
        "scheduler_serial_batch_makespan_ms":
            st.serial_batch_makespan_ms,
        "throughput_sequential_tasks_per_s": seq_tps,
        "throughput_scheduler_tasks_per_s": st.throughput_tasks_per_s,
        "throughput_speedup": st.speedup_vs_sequential,
        "probe_cache_hits": st.probe_cache_hits,
        "ensemble_calls_saved": st.ensemble_calls_saved,
        "sequential_wall_ms": seq_wall_ms,
        "scheduler_wall_ms": st.wall_ms,
        # escalated-subset compaction (wave planning) accounting
        "escalation_rate": st.escalated_rows / n_tasks,
        "full_arena_rate": st.full_arena_rows / n_tasks,
        "ensemble_decode_rows": st.ensemble_decode_rows,
        "ensemble_decode_rows_saved": st.ensemble_decode_rows_saved,
        "ensemble_decode_row_reduction":
            st.ensemble_decode_row_reduction,
        "probe_prefill_tokens": st.probe_prefill_tokens,
        "probe_prefill_tokens_saved": st.probe_prefill_tokens_saved,
        "probe_prefill_reduction": st.probe_prefill_reduction,
        # paged-KV page-budget planning (virtual; the measured pool
        # numbers live in BENCH_kv.json from benchmarks/kv_bench.py)
        "kv_pages_allocated": st.kv_pages_allocated,
        "kv_pages_highwater": st.kv_pages_highwater,
        "kv_prefill_tokens_reused": st.kv_prefill_tokens_reused,
    }
    out.update(paper_rate_run(max(n_tasks, 192), batch_size, seed))
    persist_bench("scheduler", out)
    if verbose:
        print(f"tasks={n_tasks} batch={batch_size} "
              f"identical_traces={identical}")
        print(f"sequential : {seq_makespan_ms / 1e3:9.1f} s virtual "
              f"({seq_tps:6.2f} tasks/s)")
        print(f"scheduler  : {st.pipeline_makespan_ms / 1e3:9.1f} s "
              f"virtual ({st.throughput_tasks_per_s:6.2f} tasks/s)")
        print(f"speedup    : {st.speedup_vs_sequential:9.2f}x "
              f"(no-overlap batching alone: "
              f"{seq_makespan_ms / st.serial_batch_makespan_ms:.2f}x)")
        print(f"compaction : escalation={out['escalation_rate']:.1%} "
              f"decode-rows {st.ensemble_decode_rows} vs "
              f"{st.ensemble_decode_rows + st.ensemble_decode_rows_saved}"
              f" masked "
              f"({out['ensemble_decode_row_reduction']:.2f}x fewer), "
              f"probe prefill {out['probe_prefill_reduction']:.2f}x "
              f"fewer tokens")
        print(f"paper rate : escalation="
              f"{out['paper_rate_escalation_rate']:.1%} decode-rows "
              f"{out['paper_rate_ensemble_decode_rows']} vs "
              f"{out['paper_rate_ensemble_decode_rows'] + out['paper_rate_ensemble_decode_rows_saved']}"
              f" masked "
              f"({out['paper_rate_ensemble_decode_row_reduction']:.2f}x"
              f" fewer)")
        print(sched.render_metrics())
    return out


def main() -> str:
    t = run(verbose=False)
    us = t["scheduler_wall_ms"] * 1e3 / t["n_tasks"]
    return csv_line(
        "scheduler_bench", us,
        f"speedup={t['throughput_speedup']:.2f}x;"
        f"identical={t['identical_traces']};"
        f"decode_reduction={t['ensemble_decode_row_reduction']:.2f}x")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tasks", type=int, default=200)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run for CI artifact tracking")
    args = ap.parse_args()
    n = 60 if args.smoke else args.tasks
    out = run(n_tasks=n, batch_size=args.batch_size, seed=args.seed)
    # the prefill-reduction figures are modeled (the scheduler's host
    # backends fix them at N by construction), so they are reported
    # but not gated — the measured guard for shared-prefix prefill is
    # the engine-side equivalence suite (tests/test_engine_compaction
    # + tests/test_sampling_shared_prefix)
    gates = {
        "identical_traces": out["identical_traces"],
        "throughput_speedup >= 2.0": out["throughput_speedup"] >= 2.0,
        "paper_rate_ensemble_decode_row_reduction >= 2.0":
            out["paper_rate_ensemble_decode_row_reduction"] >= 2.0,
    }
    for name, passed in gates.items():
        if not passed:
            print(f"GATE FAILED: {name}", file=sys.stderr)
    sys.exit(0 if all(gates.values()) else 1)
