"""Continuous-batching scheduler benchmark.

Drives the same seeded synthetic workload through the sequential
``ACAROrchestrator`` and the ``ContinuousBatchingScheduler`` (calibrated
synthetic backends) and reports task throughput for both paths on the
deterministic virtual clock — the calibrated per-call latency model the
simulator exposes — plus host wall time and the equivalence digest.

The virtual clock is the honest metric here: synthetic backends return
instantly, so wall time measures Python overhead, while the virtual
makespan measures what batching + the two-stage probe/ensemble pipeline
buy at the modeled provider latencies (the paper's regime).

    PYTHONPATH=src:tests python -m benchmarks.scheduler_bench
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

from benchmarks.common import csv_line, write_json
from repro.configs.acar import ACARConfig
from repro.core.backends import paper_backends
from repro.core.orchestrator import ACAROrchestrator
from repro.data.tasks import paper_suite
from repro.serving.queue import MicroBatchPolicy
from repro.serving.scheduler import ContinuousBatchingScheduler

OUT = Path("experiments/bench/scheduler.json")
PROBE = "gemini-2.0-flash"


def run(n_tasks: int = 200, batch_size: int = 8, seed: int = 0,
        verbose: bool = True) -> dict:
    tasks = paper_suite(seed=seed)[:n_tasks]
    acfg = ACARConfig(seed=seed)

    backs = paper_backends()
    t0 = time.perf_counter()
    seq = ACAROrchestrator(acfg, backs[PROBE], backs,
                           run_id="bench").run_suite(tasks)
    seq_wall_ms = (time.perf_counter() - t0) * 1e3
    seq_makespan_ms = sum(o.latency_ms for o in seq)

    backs2 = paper_backends()
    sched = ContinuousBatchingScheduler(
        acfg, backs2[PROBE], backs2, run_id="bench",
        policy=MicroBatchPolicy(max_batch_size=batch_size))
    bat = sched.serve(tasks)
    st = sched.stats

    identical = (
        [o.trace.record_hash() for o in seq]
        == [o.trace.record_hash() for o in bat])
    seq_tps = n_tasks / (seq_makespan_ms / 1e3)
    out = {
        "n_tasks": n_tasks,
        "batch_size": batch_size,
        "identical_traces": identical,
        "sequential_makespan_ms": seq_makespan_ms,
        "scheduler_pipeline_makespan_ms": st.pipeline_makespan_ms,
        "scheduler_serial_batch_makespan_ms":
            st.serial_batch_makespan_ms,
        "throughput_sequential_tasks_per_s": seq_tps,
        "throughput_scheduler_tasks_per_s": st.throughput_tasks_per_s,
        "throughput_speedup": st.speedup_vs_sequential,
        "probe_cache_hits": st.probe_cache_hits,
        "ensemble_calls_saved": st.ensemble_calls_saved,
        "sequential_wall_ms": seq_wall_ms,
        "scheduler_wall_ms": st.wall_ms,
    }
    write_json(OUT, out)
    if verbose:
        print(f"tasks={n_tasks} batch={batch_size} "
              f"identical_traces={identical}")
        print(f"sequential : {seq_makespan_ms / 1e3:9.1f} s virtual "
              f"({seq_tps:6.2f} tasks/s)")
        print(f"scheduler  : {st.pipeline_makespan_ms / 1e3:9.1f} s "
              f"virtual ({st.throughput_tasks_per_s:6.2f} tasks/s)")
        print(f"speedup    : {st.speedup_vs_sequential:9.2f}x "
              f"(no-overlap batching alone: "
              f"{seq_makespan_ms / st.serial_batch_makespan_ms:.2f}x)")
        print(sched.render_metrics())
    return out


def main() -> str:
    t = run(verbose=False)
    us = t["scheduler_wall_ms"] * 1e3 / t["n_tasks"]
    return csv_line(
        "scheduler_bench", us,
        f"speedup={t['throughput_speedup']:.2f}x;"
        f"identical={t['identical_traces']}")


if __name__ == "__main__":
    out = run()
    sys.exit(0 if out["identical_traces"]
             and out["throughput_speedup"] >= 2.0 else 1)
