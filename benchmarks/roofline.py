"""Roofline analysis (deliverable g): three terms per (arch x shape)
from the dry-run's compiled artifacts.

    compute    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
    memory     = HLO_bytes / (chips x 819 GB/s HBM)
    collective = collective_bytes / (chips x 50 GB/s ICI)

``compiled.cost_analysis()`` (and the HLO text the collective bytes are
parsed from) is the per-partition program, so per-device quantities are
multiplied by the chip count to recover the global numerators; the two
conventions cancel. The scan-corrected costs from launch/dryrun.py are
used (XLA counts while bodies once — 'raw' would undercount ~L-fold).

MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (fwd-only); the
ratio MODEL_FLOPS / HLO_FLOPs exposes remat recompute + redundant
(replicated) compute.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

from benchmarks.common import csv_line, write_json

PEAK_FLOPS = 197e12          # bf16 per chip (TPU v5e-like)
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

DRYRUN_DIR = Path("experiments/dryrun")
OUT = Path("experiments/bench/roofline.json")


def _advice(dom: str, rec: dict) -> str:
    arch, shape = rec["arch"], rec["shape"]
    if dom == "compute":
        ratio = rec["useful_flops_ratio"]
        if ratio < 0.4:
            return ("compute-bound but only "
                    f"{ratio:.0%} of HLO FLOPs are model FLOPs — cut "
                    "remat recompute / replicated matmuls (sharding "
                    "that actually splits contractions) before chasing "
                    "utilisation")
        return ("compute-bound near useful peak — only larger "
                "per-chip batch or lower-precision matmuls move this")
    if dom == "memory":
        return ("HBM-bound — raise arithmetic intensity: fuse "
                "elementwise chains, keep KV/state in-register across "
                "steps, batch more requests per weight read"
                f" ({arch} {shape})")
    return ("collective-bound — reshard to cut cross-chip traffic "
            "(fewer all-gathers of replicated weights), overlap "
            "collectives with compute, or move the axis the traffic "
            "crosses" f" ({arch} {shape})")


def analyse_record(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    costs = rec.get("corrected") or rec["raw"]
    chips = rec["chips"]
    # per-partition numbers x chips = global
    flops_g = costs["hlo_flops"] * chips
    bytes_g = costs["hlo_bytes"] * chips
    coll_g = costs["collective"]["total"] * chips
    terms = {
        "compute_s": flops_g / (chips * PEAK_FLOPS),
        "memory_s": bytes_g / (chips * HBM_BW),
        "collective_s": coll_g / (chips * ICI_BW),
    }
    dom = max(terms, key=terms.get).replace("_s", "")
    model_flops = rec["model_flops"]
    out = {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": rec["mesh"], "chips": chips,
        **{k: float(v) for k, v in terms.items()},
        "bottleneck": dom,
        "model_flops": model_flops,
        "hlo_flops_global": flops_g,
        "useful_flops_ratio": (model_flops / flops_g) if flops_g else 0.0,
        "step_time_lower_bound_s": max(terms.values()),
    }
    out["advice"] = _advice(dom, out)
    return out


def run(dryrun_dir: Path = DRYRUN_DIR, mesh: str = "single",
        verbose: bool = True) -> dict:
    rows: List[dict] = []
    skips: List[dict] = []
    for f in sorted(dryrun_dir.glob(f"*__{mesh}.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") == "skipped":
            skips.append({"arch": rec["arch"], "shape": rec["shape"],
                          "reason": rec["reason"]})
            continue
        row = analyse_record(rec)
        if row:
            rows.append(row)
    out = {"rows": rows, "skipped": skips, "mesh": mesh,
           "constants": {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW,
                         "ici_bw": ICI_BW}}
    write_json(OUT, out)
    if verbose:
        hdr = (f"{'arch':24s} {'shape':12s} {'compute':>10s} "
               f"{'memory':>10s} {'collect':>10s} {'bound':>8s} "
               f"{'useful':>7s}")
        print(hdr)
        for r in rows:
            print(f"{r['arch']:24s} {r['shape']:12s} "
                  f"{r['compute_s']:10.3e} {r['memory_s']:10.3e} "
                  f"{r['collective_s']:10.3e} {r['bottleneck']:>8s} "
                  f"{r['useful_flops_ratio']:7.2%}")
        for s in skips:
            print(f"{s['arch']:24s} {s['shape']:12s} SKIPPED")
    return out


def main() -> str:
    t = run(verbose=False)
    n = len(t["rows"])
    return csv_line("roofline", 0.0, f"combos={n}")


if __name__ == "__main__":
    run()
