"""Paper Table 2 + §6.1: ACAR-UJ vs ACAR-U per benchmark (retrieval
augmentation hurts), plus the similarity-threshold study backing the
paper's ">0.7 required" recommendation."""
from __future__ import annotations

from pathlib import Path

import numpy as np

from benchmarks.common import (
    ARENA3, PROBE, cached_runs, csv_line, experience_store, write_json)
from repro.configs.acar import ACARConfig
from repro.core.backends import paper_backends
from repro.core.orchestrator import ACAROrchestrator
from repro.data.tasks import PAPER_MIX, paper_suite

PAPER_TABLE2 = {           # ACAR-U vs ACAR-UJ accuracy (paper)
    "overall": (0.556, 0.524),
    "supergpqa": (0.605, 0.573),
    "livecodebench": (0.515, 0.475),
    "reasoning_gym": (0.460, 0.440),
    "matharena": (0.267, 0.217),
}
OUT = Path("experiments/bench/table2.json")


def threshold_study(seed: int = 0, thresholds=(0.0, 0.3, 0.5, 0.7)):
    """Re-run ACAR-UJ at increasing similarity thresholds: the paper's
    recommendation is that only aligned (>0.7) exemplars are safe."""
    tasks = paper_suite(seed=seed)
    backs = paper_backends()
    store = experience_store()
    out = {}
    for th in thresholds:
        acfg = ACARConfig(seed=seed, retrieval_enabled=True,
                          retrieval_threshold=th)
        orch = ACAROrchestrator(acfg, backs[PROBE],
                                {m: backs[m] for m in ARENA3},
                                experience=store,
                                run_id=f"uj_th{th}")
        outs = orch.run_suite(tasks)
        out[str(th)] = float(np.mean([o.correct for o in outs]))
    return out


def run(seed: int = 0, verbose: bool = True) -> dict:
    runs = cached_runs(seed)
    u, uj = runs["acar_u"], runs["acar_uj"]
    per_u = u.accuracy_by_benchmark()
    per_uj = uj.accuracy_by_benchmark()
    table = {"overall": {
        "acar_u": u.accuracy, "acar_uj": uj.accuracy,
        "delta": uj.accuracy - u.accuracy,
        "paper_delta": PAPER_TABLE2["overall"][1]
        - PAPER_TABLE2["overall"][0]}}
    for bench in PAPER_MIX:
        pu, puj = PAPER_TABLE2[bench]
        table[bench] = {
            "acar_u": per_u[bench], "acar_uj": per_uj[bench],
            "delta": per_uj[bench] - per_u[bench],
            "paper_delta": puj - pu,
        }
    table["retrieval_hurts_overall"] = table["overall"]["delta"] < 0
    table["threshold_study"] = threshold_study(seed)
    ths = table["threshold_study"]
    table["aligned_threshold_recovers"] = ths["0.7"] >= ths["0.0"]
    write_json(OUT, table)
    if verbose:
        for k in ("overall", *PAPER_MIX):
            t = table[k]
            print(f"  {k:14s} U {t['acar_u']:.3f} UJ {t['acar_uj']:.3f} "
                  f"delta {t['delta']:+.3f} (paper {t['paper_delta']:+.3f})")
        print(f"  threshold study: {ths}")
    return table


def main() -> str:
    t = run(verbose=False)
    return csv_line("table2_retrieval", 0.0,
                    f"delta={t['overall']['delta']:+.3f}")


if __name__ == "__main__":
    run()
