"""Heterogeneous paged-state benchmark: quant-KV capacity, the ring
window cap, and a live mixed Mamba+quant+SWA+dense serving leg.

Three perf claims ride on the heterogeneous page layouts
(bit-equivalence is proved by ``tests/harness/simulate.py --hetero``;
this benchmark gates the capacity wins):

* **int8 quant pages** — codes plus per-vector f32 scale planes cost
  ``Dh + 4`` bytes per KV vector against bf16's ``2*Dh``: at
  head_dim=64 a fixed per-device HBM budget holds ~1.88x the decode
  rows (gate: >= 1.8x vs the member's bf16 twin, measured on the
  actually-allocated page pools).
* **ring pages** — a sliding-window member's per-row pages cap at
  ``ceil(window/page)`` no matter how long the prompt runs, so the KV
  high-water for long-prompt SWA streams is window-bound while the
  dense twin's grows with the prompt (the dense/ring high-water ratio
  is reported and must exceed the window's share of the prompt).
* **recurrent-state lanes** — an SSM member serves from O(1)-per-lane
  conv+SSM state pages; the live leg proves a Mamba member admits,
  forks and retires lanes inside the stepped engine alongside quant
  and ring members (its lane high-water must be > 0).

Gates (persisted via ``persist_bench`` to ``BENCH_hetero.json`` +
``experiments/bench/hetero.json``, uploaded nightly by CI):

* quant rows-per-device >= 1.8x the bf16 twin at head_dim=64;
* ring per-row pages == the window cap, dense/ring KV high-water
  ratio > 2x on long prompts;
* the live hetero fleet finishes with lanes high-water > 0.

    PYTHONPATH=src:tests python -m benchmarks.hetero_bench [--smoke]
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import csv_line, persist_bench
from benchmarks.serving_bench import (
    bursty_tasks, forced_modes, index_route_fn)
from repro.configs.acar import ACARConfig
from repro.configs.registry import get_config
from repro.serving import BatchedACAREngine, MicroBatchPolicy
from repro.serving.kv_pool import PagedKVServer, pages_for

PAGE = 8


def _bytes_per_page(cfg) -> int:
    """Bytes per page of a member's actually-allocated pool (all
    leaves: codes + scale planes for quant, conv+h for lanes)."""
    srv = PagedKVServer(cfg, page_size=PAGE, prefix_cache_entries=0)
    srv.ensure_capacity_stream(2, 32, 2, 8)
    total = sum(int(leaf.nbytes) for leaf in srv.pages.values())
    return total // srv.pool.num_pages


def _quant_capacity_leg(prompt_len: int = 128,
                        max_new_tokens: int = 16) -> dict:
    """Decode rows a fixed HBM budget affords: int8+scales pages vs
    the same member's bf16 twin. Geometry (pages per row) is layout-
    independent here, so the row ratio is the page-byte ratio."""
    bf16 = get_config("smollm-135m", reduced=True)
    quant = bf16.replace(kv_quant=True)
    assert bf16.dtype == "bfloat16" and bf16.resolved_head_dim == 64
    b_bf16 = _bytes_per_page(bf16)
    b_quant = _bytes_per_page(quant)

    srv = PagedKVServer(bf16, page_size=PAGE, prefix_cache_entries=0)
    g = srv.row_geometry(prompt_len, max_new_tokens)
    row_pages = g.nbp + 2 * g.n_tail             # 2 probe lanes/row
    budget = b_bf16 * 4096                       # bf16 4096-page pool
    rows_bf16 = (budget // b_bf16) // row_pages
    rows_quant = (budget // b_quant) // row_pages
    return {
        "page_bytes_bf16": b_bf16,
        "page_bytes_quant": b_quant,
        "rows_per_device_bf16": int(rows_bf16),
        "rows_per_device_quant": int(rows_quant),
        "quant_rows_ratio": rows_quant / rows_bf16,
    }


def _window_leg(window: int = 16, prompt_len: int = 96,
                max_new_tokens: int = 8, rows: int = 8) -> dict:
    """KV high-water for long-prompt SWA streams: the ring server's
    per-row pages cap at ceil(window/page); the dense twin's grow with
    prompt_len + max_new. Both pools are really allocated and walked
    through a rows-deep admission to read the measured high-water."""
    base = get_config("smollm-135m", reduced=True)
    swa = base.replace(window=window)

    def highwater_bytes(cfg):
        srv = PagedKVServer(cfg, page_size=PAGE,
                            prefix_cache_entries=0)
        srv.ensure_capacity_stream(rows, prompt_len, 1,
                                   max_new_tokens)
        g = srv.row_geometry(prompt_len, max_new_tokens)
        held = [srv._alloc_retry(g.nbp + g.n_tail)
                for _ in range(rows)]
        hw = srv.stats.pages_highwater * _bytes_per_page(cfg)
        for pages in held:
            srv.pool.release(pages)
        return g, int(hw)

    g_dense, hw_dense = highwater_bytes(base)
    g_ring, hw_ring = highwater_bytes(swa)
    return {
        "window": window,
        "swa_prompt_len": prompt_len,
        "ring_row_pages": int(g_ring.nb),
        "ring_row_pages_cap": int(pages_for(
            min(prompt_len + max_new_tokens, window), PAGE)),
        "dense_row_pages": int(g_dense.nb),
        "kv_highwater_bytes_dense": hw_dense,
        "kv_highwater_bytes_ring": hw_ring,
        "swa_highwater_ratio": hw_dense / max(hw_ring, 1),
    }


def _live_leg(n_tasks: int, seed: int, max_new_tokens: int) -> dict:
    """Stepped serving of the mixed hetero fleet (Mamba lanes + SWA
    ring + quant probe/member) at the paper's forced escalation rate:
    proves all three layouts admit/fork/retire through one step loop
    and reports their measured page high-waters."""
    from harness.simulate import hetero_zoo
    from repro.models.transformer import resolve_layout
    tasks, _ = bursty_tasks(n_tasks, 24, seed, burst=n_tasks, gap=0)
    modes = forced_modes(n_tasks, seed)
    probe, ensemble = hetero_zoo(seed)
    acfg = ACARConfig(probe_temperature=0.9, seed=seed)
    eng = BatchedACAREngine(
        acfg, probe, ensemble, max_new_tokens=max_new_tokens,
        route_fn=index_route_fn(modes))
    t0 = time.perf_counter()
    res = eng.run_stepped(
        list(tasks), MicroBatchPolicy(max_batch_size=8,
                                      max_batch_tokens=1 << 20),
        chunk_tokens=8, max_active_rows=8)
    wall_ms = (time.perf_counter() - t0) * 1e3
    layouts = {m.name: (resolve_layout(m.cfg) or "dense*")
               for m in [probe] + list(ensemble)}
    highwater = {name: int(st.pages_highwater)
                 for name, st in eng.kv_stats().items()}
    lanes_hw = sum(hw for name, hw in highwater.items()
                   if layouts.get(name) == "lanes")
    return {
        "n_tasks": n_tasks,
        "escalation_rate": float(np.mean(modes >= 1)),
        "fleet_layouts": layouts,
        "pages_highwater": highwater,
        "lanes_pages_highwater": lanes_hw,
        "ticks": res.step.ticks,
        "launches": res.step.launches,
        "wall_ms": wall_ms,
    }


def run(n_tasks: int = 48, max_new_tokens: int = 6, seed: int = 0,
        verbose: bool = True) -> dict:
    out = {}
    out.update(_quant_capacity_leg())
    out.update(_window_leg())
    out.update(_live_leg(n_tasks, seed, max_new_tokens))
    persist_bench("hetero", out)
    if verbose:
        for k, v in out.items():
            print(f"  {k}: {v}")
    return out


def check(out: dict) -> list:
    failures = []
    if out["quant_rows_ratio"] < 1.8:
        failures.append(
            f"quant rows-per-device {out['quant_rows_ratio']:.2f}x "
            "< 1.8x gate vs the bf16 twin (int8 codes + f32 scale "
            "planes must halve page bytes at head_dim=64)")
    if out["ring_row_pages"] != out["ring_row_pages_cap"]:
        failures.append(
            f"ring row pages {out['ring_row_pages']} != window cap "
            f"{out['ring_row_pages_cap']} (SWA pages must not grow "
            "with prompt length)")
    if out["swa_highwater_ratio"] < 2.0:
        failures.append(
            f"SWA KV high-water only {out['swa_highwater_ratio']:.2f}x "
            "below dense on long prompts (< 2x gate)")
    if out["lanes_pages_highwater"] <= 0:
        failures.append(
            "live fleet's Mamba member held no lanes (lanes "
            "high-water 0 — SSM member never admitted)")
    return failures


def main() -> str:
    t = run(n_tasks=24, verbose=False)
    us = t["wall_ms"] * 1e3 / t["n_tasks"]
    return csv_line(
        "hetero_bench", us,
        f"quant={t['quant_rows_ratio']:.2f}x;"
        f"swa={t['swa_highwater_ratio']:.1f}x;"
        f"lanes_hw={t['lanes_pages_highwater']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="smaller stream for CI")
    args = ap.parse_args()
    out = run(n_tasks=24 if args.smoke else 48, verbose=True)
    failures = check(out)
    for f in failures:
        print(f"GATE FAILED: {f}", file=sys.stderr)
    sys.exit(1 if failures else 0)
