"""Paper Table 1 + Figures 2/3: overall and per-benchmark accuracy and
cost for the five configurations, regenerated from the substrate runs.

Paper claims (1,510 tasks): Single 45.4% / Arena-2 54.4% / ACAR-U 55.6%
/ Arena-3 63.6%; ACAR-U cheaper than Arena-2.
"""
from __future__ import annotations

from pathlib import Path

from benchmarks.common import cached_runs, csv_line, write_json

PAPER_TABLE1 = {
    "single_model": 0.454,
    "arena_2": 0.544,
    "acar_u": 0.556,
    "arena_3": 0.636,
}
OUT = Path("experiments/bench/table1.json")


def run(seed: int = 0, verbose: bool = True) -> dict:
    runs = cached_runs(seed)
    table = {}
    for name in ("single_model", "arena_2", "acar_u", "arena_3"):
        r = runs[name]
        table[name] = {
            "accuracy": r.accuracy,
            "correct": int(r.accuracy * len(r.outcomes) + 0.5),
            "total": len(r.outcomes),
            "cost": r.cost,
            "paper_accuracy": PAPER_TABLE1[name],
            "delta_vs_paper": r.accuracy - PAPER_TABLE1[name],
            "per_benchmark": r.accuracy_by_benchmark(),   # Fig. 3
            "wall_s": r.wall_s,
        }
    # the paper's two ordering claims
    table["claims"] = {
        "acar_u_exceeds_arena2":
            table["acar_u"]["accuracy"] > table["arena_2"]["accuracy"],
        "arena3_is_ceiling":
            table["arena_3"]["accuracy"]
            >= max(table[n]["accuracy"]
                   for n in ("single_model", "arena_2", "acar_u")),
        "acar_u_cheaper_than_arena2":
            table["acar_u"]["cost"] < table["arena_2"]["cost"],
        "single_cheapest":
            table["single_model"]["cost"]
            < min(table["arena_2"]["cost"], table["acar_u"]["cost"]),
    }
    write_json(OUT, table)
    if verbose:
        for n in ("single_model", "arena_2", "acar_u", "arena_3"):
            t = table[n]
            print(f"  {n:13s} acc {t['accuracy']:.3f} "
                  f"(paper {t['paper_accuracy']:.3f}) "
                  f"cost ${t['cost']:.2f}")
        print(f"  claims: {table['claims']}")
    return table


def main() -> str:
    t = run(verbose=False)
    us = t["acar_u"]["wall_s"] / t["acar_u"]["total"] * 1e6
    return csv_line("table1_overall", us,
                    f"acar_u_acc={t['acar_u']['accuracy']:.3f}")


if __name__ == "__main__":
    run()
