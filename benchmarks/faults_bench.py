"""Fault-tolerance benchmark: injection-hook overhead and journal
recovery speed.

Two measurements over the single-device step loop (real micro models,
duplicate-bearing long-prompt stream — the crash-recovery harness
regime):

* **hook overhead** — the fault-injection hooks are attribute checks
  that must cost nothing when no fault fires. Serve the same stream
  with ``faults=None`` and with an *armed but never-firing* plan (one
  spec at a far-future tick, so the injector and every per-group gate
  run on the hot path); min-of-``--repeats`` wall clock each. Gate:
  the armed run is within 2% of the plain run.
* **recovery speed** — journal a run, kill it at 90% of its ticks,
  then time ``BatchedACAREngine.recover()`` against a full journaled
  re-run (both on a warm jit cache). Recovery restores retired rows
  verbatim and re-executes only the tail, so it must be >= 5x faster
  than re-serving the whole stream.

Gates persist via ``persist_bench`` to ``BENCH_faults.json`` +
``experiments/bench/faults.json`` (uploaded nightly by CI).

    PYTHONPATH=src:tests python -m benchmarks.faults_bench [--smoke]
        [--repeats 3]
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import csv_line, persist_bench
from repro.configs.acar import ACARConfig
from repro.serving import BatchedACAREngine, MicroBatchPolicy
from repro.serving.faults import FaultPlan, FaultSpec, SimulatedCrash


def _zoo():
    from harness.simulate import paged_zoo
    return paged_zoo(seed=0)


def _engine(zoo, max_new_tokens):
    probe, ensemble = zoo
    return BatchedACAREngine(ACARConfig(probe_temperature=0.9, seed=0),
                             probe, ensemble,
                             max_new_tokens=max_new_tokens)


def _serve(zoo, tasks, policy, *, max_new_tokens, chunk_tokens,
           **kw):
    eng = _engine(zoo, max_new_tokens)
    t0 = time.perf_counter()
    if "recover" in kw:
        res = eng.recover(tasks, policy, journal_path=kw["recover"],
                          chunk_tokens=chunk_tokens)
    else:
        res = eng.run_stepped(tasks, policy,
                              chunk_tokens=chunk_tokens, **kw)
    return res, time.perf_counter() - t0


def run(n_tasks: int = 32, batch_size: int = 8,
        prompt_chars: int = 24, max_new_tokens: int = 4,
        chunk_tokens: int = 8, repeats: int = 3, seed: int = 0,
        verbose: bool = True) -> dict:
    import tempfile
    from pathlib import Path

    from harness.simulate import long_prompt_workload

    tasks = long_prompt_workload(n_tasks, prompt_chars, seed=seed,
                                 duplicate_rate=0.15)
    zoo = _zoo()
    policy = MicroBatchPolicy(max_batch_size=batch_size,
                              max_batch_tokens=1 << 20)
    kw = dict(max_new_tokens=max_new_tokens,
              chunk_tokens=chunk_tokens)
    # an armed plan that never fires: the injector and every
    # per-tick / per-group fault gate run, but no fault path executes
    armed = FaultPlan(specs=(
        FaultSpec(tick=1 << 30, site="admit_alloc"),))

    base_res, _ = _serve(zoo, tasks, policy, **kw)   # warmup (jit)
    plain_wall = min(_serve(zoo, tasks, policy, **kw)[1]
                     for _ in range(repeats))
    armed_wall = min(_serve(zoo, tasks, policy, faults=armed, **kw)[1]
                     for _ in range(repeats))

    workdir = Path(tempfile.mkdtemp(prefix="acar-faults-bench-"))
    jp = workdir / "journal.jsonl"
    crash_tick = max(1, base_res.step.ticks * 9 // 10)
    try:
        _serve(zoo, tasks, policy,
               faults=FaultPlan.crash_at(crash_tick),
               journal_path=jp, **kw)
        raise RuntimeError("crash fault never fired")
    except SimulatedCrash:
        pass
    rec_res, rec_wall = _serve(zoo, tasks, policy, recover=jp, **kw)
    full_wall = min(
        _serve(zoo, tasks, policy,
               journal_path=workdir / f"full-{i}.jsonl", **kw)[1]
        for i in range(repeats))
    if rec_res.final_answers != base_res.final_answers:
        raise RuntimeError("recovered run diverged from baseline")

    out = {
        "n_tasks": n_tasks,
        "repeats": repeats,
        "ticks": base_res.step.ticks,
        "crash_tick": crash_tick,
        "plain_wall_s": plain_wall,
        "armed_wall_s": armed_wall,
        "hook_overhead": armed_wall / plain_wall,
        "restored_rows": rec_res.restored_rows,
        "recover_wall_s": rec_wall,
        "full_rerun_wall_s": full_wall,
        "recovery_speedup": full_wall / rec_wall,
    }
    persist_bench("faults", out)
    if verbose:
        for k, v in out.items():
            print(f"  {k}: {v}")
    return out


def check(out: dict) -> list:
    """Perf gates: never-firing fault hooks within 2% of the
    hook-free run; journal recovery >= 5x faster than a full
    re-serve of the stream."""
    failures = []
    if out["hook_overhead"] > 1.02:
        failures.append(
            f"armed-but-idle fault hooks cost "
            f"{(out['hook_overhead'] - 1) * 100:.2f}% > 2% gate")
    if out["recovery_speedup"] < 5.0:
        failures.append(
            f"journal recovery only {out['recovery_speedup']:.2f}x "
            f"faster than a full re-run (< 5x gate)")
    if out["restored_rows"] <= 0:
        failures.append("recovery restored no rows from the journal")
    return failures


def main() -> str:
    t = run(verbose=False)
    us = t["recover_wall_s"] * 1e6 / t["n_tasks"]
    return csv_line(
        "faults_bench", us,
        f"overhead={(t['hook_overhead'] - 1) * 100:.2f}%;"
        f"recovery={t['recovery_speedup']:.1f}x")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="smaller stream for CI")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    out = run(n_tasks=12 if args.smoke else 32,
              repeats=args.repeats, verbose=True)
    failures = check(out)
    for f in failures:
        print(f"GATE FAILED: {f}", file=sys.stderr)
    sys.exit(1 if failures else 0)
