"""Benchmark driver — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run

Prints ``name,us_per_call,derived`` CSV (plus detailed per-benchmark
sections) and writes JSON artifacts under experiments/bench/.
"""
from __future__ import annotations

import time
import traceback


def main() -> None:
    from benchmarks import (
        ablation_probe, attribution_bench, figures, kernels_micro,
        roofline, table1_overall, table2_retrieval)
    from benchmarks import (
        scheduler_bench, serving_bench, sharding_bench)

    sections = [
        ("table1_overall (paper Table 1, Figs 2/3)", table1_overall),
        ("table2_retrieval (paper Table 2, §6.1)", table2_retrieval),
        ("figures (paper Figs 1/5/6/7/8/9)", figures),
        ("attribution (paper §6.3)", attribution_bench),
        ("roofline (deliverable g — reads experiments/dryrun)",
         roofline),
        ("kernels_micro", kernels_micro),
        ("ablation_probe (beyond-paper: N and probe choice)",
         ablation_probe),
        ("serving_bench (batched ACAR engine over JAX zoo)",
         serving_bench),
        ("scheduler_bench (continuous batching vs sequential)",
         scheduler_bench),
        # needs >= 4 devices (run standalone: it forces the host
        # device count itself; here it reports the skip cleanly)
        ("sharding_bench (mesh-sharded step loop vs single device)",
         sharding_bench),
    ]
    csv_lines = []
    for title, mod in sections:
        print(f"\n== {title} ==")
        try:
            t0 = time.perf_counter()
            mod.run(verbose=True)
            csv_lines.append(mod.main())
            print(f"  [{time.perf_counter() - t0:.1f}s]")
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            csv_lines.append(f"{title.split()[0]},0.0,ERROR:{e}")

    print("\n# name,us_per_call,derived")
    for line in csv_lines:
        print(line)


if __name__ == "__main__":
    main()
