"""Observability benchmark: armed-tracer overhead and lineage-walk
verification over a full serve.

Two measurements over the single-device step loop (real micro models,
duplicate-bearing long-prompt stream — the obs harness regime):

* **tracer overhead** — span instrumentation must be effectively
  free. Serve the same stream untraced (``tracer=None`` — every hook
  is one attribute check) and with an armed ``SpanTracer`` recording
  the full lifecycle plus on-capacity leave-one-out attribution;
  min-of-``--repeats`` wall clock each. Gate: the armed run is within
  3% of the untraced run (the span chain hashes in memory and flushes
  once — no fsync ever enters the serving loop).
* **lineage verification** — over the traced run, build the PROV
  graph and walk the lineage of every distinct task, re-verifying the
  content hash of every span each walk touches, and audit the flushed
  span JSONL with the ArtifactStore verifier. Gate: every hash
  verifies (zero failures) and the file audit is clean.

Gates persist via ``persist_bench`` to ``BENCH_obs.json`` +
``experiments/bench/obs.json`` (uploaded nightly by CI).

    PYTHONPATH=src:tests python -m benchmarks.obs_bench [--smoke]
        [--repeats 3]
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import csv_line, persist_bench
from repro.configs.acar import ACARConfig
from repro.serving import BatchedACAREngine, MicroBatchPolicy
from repro.serving.tracing import SpanTracer


def _zoo():
    from harness.simulate import paged_zoo
    return paged_zoo(seed=0)


def _engine(zoo, max_new_tokens):
    probe, ensemble = zoo
    return BatchedACAREngine(ACARConfig(probe_temperature=0.9, seed=0),
                             probe, ensemble,
                             max_new_tokens=max_new_tokens)


def _serve(zoo, tasks, policy, *, max_new_tokens, chunk_tokens,
           tracer=None):
    eng = _engine(zoo, max_new_tokens)
    t0 = time.perf_counter()
    res = eng.run_stepped(tasks, policy, chunk_tokens=chunk_tokens,
                          tracer=tracer)
    return res, time.perf_counter() - t0


def run(n_tasks: int = 200, batch_size: int = 8,
        prompt_chars: int = 24, max_new_tokens: int = 4,
        chunk_tokens: int = 8, repeats: int = 3, seed: int = 0,
        verbose: bool = True) -> dict:
    import tempfile
    from pathlib import Path

    from harness.simulate import long_prompt_workload
    from repro.teamllm.prov import lineage, verify_span_file

    tasks = long_prompt_workload(n_tasks, prompt_chars, seed=seed,
                                 duplicate_rate=0.15)
    zoo = _zoo()
    policy = MicroBatchPolicy(max_batch_size=batch_size,
                              max_batch_tokens=1 << 20)
    kw = dict(max_new_tokens=max_new_tokens,
              chunk_tokens=chunk_tokens)

    base_res, _ = _serve(zoo, tasks, policy, **kw)   # warmup (jit)
    plain_wall = min(_serve(zoo, tasks, policy, **kw)[1]
                     for _ in range(repeats))
    workdir = Path(tempfile.mkdtemp(prefix="acar-obs-bench-"))
    span_path = workdir / "spans.jsonl"
    traced_res = None
    armed_wall = float("inf")
    for i in range(repeats):
        res, wall = _serve(
            zoo, tasks, policy,
            tracer=SpanTracer(span_path if i == 0 else None), **kw)
        if i == 0:
            traced_res = res
        armed_wall = min(armed_wall, wall)
    if traced_res.final_answers != base_res.final_answers:
        raise RuntimeError("traced run diverged from baseline")

    t0 = time.perf_counter()
    audit = verify_span_file(span_path)
    walked = 0
    verified = 0
    failures = []
    for tid in sorted({t.task_id for t in tasks}):
        lin = lineage(traced_res.spans, tid)
        walked += 1
        verified += lin["verified"]
        failures.extend(f"{tid}: {f}" for f in lin["hash_failures"])
    lineage_wall = time.perf_counter() - t0

    out = {
        "n_tasks": n_tasks,
        "repeats": repeats,
        "ticks": base_res.step.ticks,
        "plain_wall_s": plain_wall,
        "armed_wall_s": armed_wall,
        "tracer_overhead": armed_wall / plain_wall,
        "span_records": len(traced_res.spans),
        "span_file_ok": bool(audit["ok"]),
        "span_head": traced_res.span_head,
        "lineage_tasks": walked,
        "lineage_hashes_verified": verified,
        "lineage_failures": len(failures),
        "lineage_wall_s": lineage_wall,
    }
    persist_bench("obs", out)
    if verbose:
        for k, v in out.items():
            print(f"  {k}: {v}")
        for f in failures[:10]:
            print(f"  lineage failure: {f}")
    return out


def check(out: dict) -> list:
    """Perf + integrity gates: armed tracer within 3% of the untraced
    run; the flushed span chain audits clean; every span hash on
    every task's lineage walk verifies."""
    failures = []
    if out["tracer_overhead"] > 1.03:
        failures.append(
            f"armed tracer costs "
            f"{(out['tracer_overhead'] - 1) * 100:.2f}% > 3% gate")
    if not out["span_file_ok"]:
        failures.append("flushed span chain failed ArtifactStore "
                        "audit")
    if out["lineage_failures"]:
        failures.append(
            f"{out['lineage_failures']} lineage hash verifications "
            f"failed")
    if out["lineage_hashes_verified"] <= 0:
        failures.append("lineage walk verified no span hashes")
    return failures


def main() -> str:
    t = run(verbose=False)
    us = t["armed_wall_s"] * 1e6 / t["n_tasks"]
    return csv_line(
        "obs_bench", us,
        f"overhead={(t['tracer_overhead'] - 1) * 100:.2f}%;"
        f"lineage={t['lineage_hashes_verified']}hashes")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="smaller stream for CI")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    out = run(n_tasks=24 if args.smoke else 200,
              repeats=args.repeats, verbose=True)
    failures = check(out)
    for f in failures:
        print(f"GATE FAILED: {f}", file=sys.stderr)
    sys.exit(1 if failures else 0)
