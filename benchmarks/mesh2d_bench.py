"""2-D ("data", "model") serving-mesh benchmark: MoE compaction win
and tensor-parallel KV capacity scaling.

Two perf claims ride on the 2-D mesh (bit-equivalence is proved by
``tests/harness/simulate.py --mesh2d``; this benchmark gates the
performance):

* **MoE compaction** — capacity-free gather-dispatch MoE members are
  batch-composition invariant, so they qualify for the escalated-subset
  compacted path exactly like dense members. At the paper's published
  45.8% escalation rate the ensemble decodes the escalated subset
  instead of the full masked batch: decode rows serving requests drop
  >= 2x for a mixed dense+MoE fleet (the bucket-padded device-token
  ratio is reported alongside).
* **KV capacity** — with a "model" axis each model column holds only
  its kv-head slice of every page, so per-device page bytes shrink by
  the model-axis size: for a fixed per-device HBM budget, the page
  pool each member can afford grows ~model-x (gate: >= 1.8x at
  model=2).

A short 2-D step-loop serving leg (mixed dense + gather-MoE fleet,
``megastep="auto"``) runs on the same mesh to report live tick /
launch / placement / steal numbers alongside the measured gates.

Gates (persisted via ``persist_bench`` to ``BENCH_mesh2d.json`` +
``experiments/bench/mesh2d.json``, uploaded nightly by CI):

* ensemble decode-row reduction (masked / compacted) >= 2x with the
  gather-MoE member on the compacted path;
* per-member page capacity in a fixed device byte budget >= 1.8x at
  model=2.

    PYTHONPATH=src:tests python -m benchmarks.mesh2d_bench [--smoke]
        [--data 2] [--model 2]
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import csv_line, persist_bench
from benchmarks.serving_bench import (
    bursty_tasks, forced_modes, index_route_fn)
from repro.configs.acar import ACARConfig
from repro.serving import BatchedACAREngine, MicroBatchPolicy


def _engine(modes, seed, max_new_tokens):
    from harness.simulate import mesh2d_zoo
    probe, ensemble = mesh2d_zoo(seed)
    acfg = ACARConfig(probe_temperature=0.9, seed=seed)
    return BatchedACAREngine(
        acfg, probe, ensemble, max_new_tokens=max_new_tokens,
        route_fn=index_route_fn(modes), kv_prefix_cache=8)


def _compaction_leg(tasks, modes, seed, max_new_tokens):
    """Wave-mode run of the mixed dense+MoE fleet at the paper rate:
    the engine's own CompactionStats carry the masked-vs-compacted
    decode-token accounting; the gather-MoE member must be on the
    compacted path for the ratio to clear the gate (a masked MoE
    member contributes full-batch rows and drags it below 2x)."""
    from repro.sampling import batch_invariant
    eng = _engine(modes, seed, max_new_tokens)
    moe = [zm for zm in eng.ensemble if zm.cfg.moe is not None]
    assert moe and all(batch_invariant(zm.cfg) for zm in moe)
    res = eng.run_batch(list(tasks))
    cs = res.compaction
    # row accounting: rows serving escalated requests vs the masked
    # full batch every member would otherwise decode. Bucket padding
    # (power-of-two jit shapes) is reported separately via the token
    # ratio — padded rows burn device work but serve no request.
    compacted_rows = int(sum(cs.bucket_rows))
    masked_rows = cs.batch * len(eng.ensemble)
    return {
        "escalation_rate": float(np.mean(modes >= 1)),
        "escalated_rows": cs.escalated_rows,
        "ensemble_decode_rows": compacted_rows,
        "ensemble_decode_rows_masked": masked_rows,
        "decode_row_reduction": masked_rows / max(compacted_rows, 1),
        "ensemble_decode_tokens": cs.ensemble_decode_tokens,
        "ensemble_decode_tokens_saved":
            cs.ensemble_decode_tokens_saved,
        "decode_token_reduction":
            float(cs.ensemble_decode_token_reduction),
        "moe_members_compacted": len(moe),
    }


def _capacity_leg(data: int, model: int):
    """Per-device page bytes of one member's sharded KV pool, model=1
    vs model=m on the same data extent: the model columns slice
    kv-heads within each page, so a fixed per-device byte budget
    affords ~m-x the pages."""
    from harness.simulate import mesh2d_zoo
    from repro.serving.mesh import ServingMesh, ShardedPagedKVServer

    cfg = mesh2d_zoo(0)[1][1].cfg                # the gather-MoE member
    num_pages = 64

    def device_page_bytes(m: int) -> int:
        smesh = ServingMesh(data=data, model=m)
        srv = ShardedPagedKVServer(cfg, smesh, page_size=8)
        srv._rebuild_all(num_pages, 2, key=(1, 1, 1, 1))
        shard_bytes = srv.k_pages.addressable_shards[0].data.nbytes \
            + srv.v_pages.addressable_shards[0].data.nbytes
        return shard_bytes // num_pages

    bytes_1 = device_page_bytes(1)
    bytes_m = device_page_bytes(model)
    budget = bytes_1 * num_pages                 # model=1 pool footprint
    return {
        "device_page_bytes_model1": int(bytes_1),
        f"device_page_bytes_model{model}": int(bytes_m),
        "pages_in_budget_model1": int(budget // bytes_1),
        f"pages_in_budget_model{model}": int(budget // bytes_m),
        "capacity_ratio": (budget // bytes_m) / (budget // bytes_1),
    }


def _serving_leg(tasks, modes, seed, max_new_tokens, data, model):
    """Live 2-D step-loop leg: mixed fleet, auto megastep."""
    eng = _engine(modes, seed, max_new_tokens)
    t0 = time.perf_counter()
    res = eng.run_stepped(
        list(tasks), MicroBatchPolicy(max_batch_size=8,
                                      max_batch_tokens=1 << 20),
        chunk_tokens=4, max_active_rows=8, data_shards=data,
        model_shards=model, megastep="auto")
    wall_ms = (time.perf_counter() - t0) * 1e3
    placements = [int(res.metrics.get("acar_shard_placements_total",
                                      shard=str(k)))
                  for k in range(data)]
    steals = sum(
        int(res.metrics.get("acar_shard_steals_total",
                            src=str(a), dst=str(b)))
        for a in range(data) for b in range(data) if a != b)
    return {
        "ticks": res.step.ticks,
        "launches": res.step.launches,
        "masked_decode_steps": res.step.masked_decode_steps,
        "shard_placements": placements,
        "shard_steals": steals,
        "wall_ms": wall_ms,
    }


def run(n_tasks: int = 48, prompt_chars: int = 24,
        max_new_tokens: int = 4, data: int = 2, model: int = 2,
        seed: int = 0, verbose: bool = True) -> dict:
    tasks, _ = bursty_tasks(n_tasks, prompt_chars, seed,
                            burst=n_tasks, gap=0)
    modes = forced_modes(n_tasks, seed)
    out = {"n_tasks": n_tasks, "data_shards": data,
           "model_shards": model,
           "max_new_tokens": max_new_tokens}
    out.update(_compaction_leg(tasks, modes, seed, max_new_tokens))
    out.update(_capacity_leg(data, model))
    out.update(_serving_leg(tasks, modes, seed, max_new_tokens,
                            data, model))
    persist_bench("mesh2d", out)
    if verbose:
        for k, v in out.items():
            print(f"  {k}: {v}")
    return out


def check(out: dict) -> list:
    failures = []
    if out["decode_row_reduction"] < 2.0:
        failures.append(
            f"ensemble decode-row reduction "
            f"{out['decode_row_reduction']:.2f}x < 2x gate at "
            f"{out['escalation_rate']:.1%} escalation (MoE members "
            "must take the compacted escalated-subset path)")
    if out["capacity_ratio"] < 1.8:
        failures.append(
            f"KV capacity {out['capacity_ratio']:.2f}x < 1.8x gate "
            f"at model={out['model_shards']} (pages must shard "
            "kv-heads over the model axis)")
    if not out["moe_members_compacted"]:
        failures.append("fleet carried no compactable MoE member")
    return failures


def main() -> str:
    t = run(n_tasks=24, verbose=False)
    us = t["wall_ms"] * 1e3 / t["n_tasks"]
    return csv_line(
        "mesh2d_bench", us,
        f"compaction={t['decode_row_reduction']:.2f}x;"
        f"capacity={t['capacity_ratio']:.1f}x")


def _maybe_reexec() -> None:
    """Re-exec under a forced host device count when the 2-D mesh
    needs more devices than jax would otherwise expose (same contract
    as tests/harness/simulate.py: a user-set count always wins)."""
    from repro.xla_flags import argv_int, reexec_with_host_devices
    argv = sys.argv[1:]
    need = argv_int(argv, "--data", 2) * argv_int(argv, "--model", 2)
    reexec_with_host_devices(
        need, ["-m", "benchmarks.mesh2d_bench"] + argv)


if __name__ == "__main__":
    _maybe_reexec()
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="smaller stream for CI")
    ap.add_argument("--data", type=int, default=2)
    ap.add_argument("--model", type=int, default=2)
    args = ap.parse_args()
    out = run(n_tasks=24 if args.smoke else 48, data=args.data,
              model=args.model, verbose=True)
    failures = check(out)
    for f in failures:
        print(f"GATE FAILED: {f}", file=sys.stderr)
    sys.exit(1 if failures else 0)
