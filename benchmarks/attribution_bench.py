"""Paper §6.3: attribution proxies vs ground-truth counterfactuals.

For every full-arena task: ground-truth leave-one-out + exact Shapley
(2^3 coalitions, explicit counterfactual judge re-runs) vs the three
proxy signals. The paper's finding: proxies correlate weakly; practical
attribution requires the counterfactual computation."""
from __future__ import annotations

from pathlib import Path

import numpy as np

from benchmarks.common import cached_runs, csv_line, write_json
from repro.core.attribution import (
    leave_one_out, proxy_agreement, proxy_entropy, proxy_similarity,
    proxy_vs_truth_correlation, shapley)
from repro.data.tasks import paper_suite

OUT = Path("experiments/bench/attribution.json")
# "weak" = practically unusable for credit assignment: |r| < 0.45
# (R^2 < 0.2 — the proxy explains <20% of ground-truth variance). The
# similarity proxy lands ~0.4 here: mechanically correlated with LOO
# because a response matching the (often-correct) final answer gets LOO
# credit by construction — exactly the paper's point that observational
# proxies cannot replace counterfactual computation.
WEAK_CORRELATION = 0.45


def _gold_in_answer_space(task) -> str:
    """Task gold mapped into EXTRACT's canonical answer space."""
    if task.kind == "reasoning":
        return task.gold.lower()
    return task.gold


def run(seed: int = 0, verbose: bool = True) -> dict:
    u = cached_runs(seed)["acar_u"]
    gold_map = {t.task_id: _gold_in_answer_space(t)
                for t in paper_suite(seed=seed)}
    # code responses are non-canonical (nonce formatting) — the
    # extracted-answer space cannot match gold; attribution uses the
    # other three benchmarks (the paper's setting is the same judge).
    full = [o for o in u.outcomes if o.trace.mode == "full_arena"
            and len(o.trace.responses) == 3
            and o.trace.benchmark != "livecodebench"]
    loo_rows, shap_rows = [], []
    prox = {"similarity": [], "entropy": [], "agreement": []}
    golds = 0
    for o in full:
        tr = o.trace
        gold = gold_map[tr.task_id]
        loo_rows.append(leave_one_out(tr.responses, tr.task_id, gold))
        shap_rows.append(shapley(tr.responses, tr.task_id, gold))
        prox["similarity"].append(
            proxy_similarity(tr.responses, tr.final_answer))
        prox["entropy"].append(proxy_entropy(tr.responses))
        prox["agreement"].append(proxy_agreement(tr.responses))
        golds += o.correct

    out = {"n_full_arena": len(full), "n_correct": golds}
    for name, rows in prox.items():
        out[f"corr_loo_{name}"] = proxy_vs_truth_correlation(
            loo_rows, rows)
        out[f"corr_shapley_{name}"] = proxy_vs_truth_correlation(
            shap_rows, rows)
    out["corr_loo_shapley"] = proxy_vs_truth_correlation(
        loo_rows, shap_rows)
    out["all_proxies_weak"] = all(
        abs(out[f"corr_shapley_{n}"]) < WEAK_CORRELATION
        for n in prox)
    # sanity: the two ground truths agree with each other strongly
    out["ground_truths_agree"] = out["corr_loo_shapley"] > 0.7
    write_json(OUT, out)
    if verbose:
        for name in prox:
            print(f"  shapley vs {name:10s}: "
                  f"r={out[f'corr_shapley_{name}']:+.3f}")
        print(f"  loo vs shapley        : "
              f"r={out['corr_loo_shapley']:+.3f}")
        print(f"  all proxies weak      : {out['all_proxies_weak']}")
    return out


def main() -> str:
    t = run(verbose=False)
    worst = max(abs(t[f"corr_shapley_{n}"])
                for n in ("similarity", "entropy", "agreement"))
    return csv_line("attribution", 0.0, f"max_proxy_r={worst:.3f}")


if __name__ == "__main__":
    run()
