"""Paper Figures 1, 5, 6, 7, 8, 9 — regenerated from the decision
traces of the cached runs (all data, no plotting backend needed; each
figure's numbers are written to experiments/bench/)."""
from __future__ import annotations

from pathlib import Path
from typing import Dict, List

import numpy as np

from benchmarks.common import (
    cached_runs, csv_line, experience_store, write_json)
from repro.core.sigma import MODE_NAMES
from repro.data.tasks import PAPER_MIX, paper_suite

BENCH_DIR = Path("experiments/bench")

PAPER_FIG1 = {"0.0": 0.329, "0.5": 0.213, "1.0": 0.458}
PAPER_FIG5 = {"supergpqa_single": 0.42, "matharena_full": 0.93,
              "livecodebench_full": 0.96}
PAPER_FIG6_FULL_ARENA_AVOIDED = 0.542
PAPER_FIG9_MEDIAN_SIM = 0.167


# ----------------------------------------------------------------------
def fig1_sigma_dist(seed: int = 0) -> dict:
    """Fig. 1: distribution of sigma across 1,510 tasks (bimodal)."""
    u = cached_runs(seed)["acar_u"]
    sig = np.array([o.trace.sigma for o in u.outcomes])
    out = {
        "histogram": {s: float((sig == float(s)).mean())
                      for s in ("0.0", "0.5", "1.0")},
        "paper": PAPER_FIG1,
        "bimodal": bool(
            (sig == 0.0).mean() > (sig == 0.5).mean()
            and (sig == 1.0).mean() > (sig == 0.5).mean()),
    }
    write_json(BENCH_DIR / "fig1_sigma_dist.json", out)
    return out


def fig5_escalation(seed: int = 0) -> dict:
    """Fig. 5: escalation distribution by benchmark."""
    u = cached_runs(seed)["acar_u"]
    out: Dict[str, Dict[str, float]] = {}
    for bench in PAPER_MIX:
        sel = [o.trace.mode for o in u.outcomes
               if o.trace.benchmark == bench]
        out[bench] = {m: sel.count(m) / len(sel) for m in MODE_NAMES}
    out["paper_anchors"] = PAPER_FIG5
    write_json(BENCH_DIR / "fig5_escalation.json", out)
    return out


def fig6_cumulative(seed: int = 0) -> dict:
    """Fig. 6: cumulative full-arena usage; ACAR avoids full
    ensembling on the majority of tasks (paper: 54.2%)."""
    u = cached_runs(seed)["acar_u"]
    full = np.array([o.trace.mode == "full_arena" for o in u.outcomes])
    cum = np.cumsum(full) / (np.arange(len(full)) + 1)
    avoided = float(1.0 - full.mean())
    out = {
        "full_arena_rate": float(full.mean()),
        "avoided_fraction": avoided,
        "paper_avoided": PAPER_FIG6_FULL_ARENA_AVOIDED,
        "cumulative_curve_every_100": [float(c) for c in cum[::100]],
        "majority_avoided": avoided > 0.5,
    }
    write_json(BENCH_DIR / "fig6_cumulative.json", out)
    return out


def fig7_latency(seed: int = 0) -> dict:
    """Fig. 7: latency distribution by configuration (calibrated
    latency model; single < ACAR-U < full ensembling)."""
    runs = cached_runs(seed)
    out = {}
    for name in ("single_model", "arena_2", "acar_u", "arena_3"):
        lat = np.array([o.latency_ms for o in runs[name].outcomes])
        out[name] = {
            "p50_ms": float(np.percentile(lat, 50)),
            "p90_ms": float(np.percentile(lat, 90)),
            "mean_ms": float(lat.mean()),
        }
    # "intermediate latency" (paper Fig. 7) is a statement about the
    # distribution mass: single < ACAR-U < Arena-3 in the MEAN (ACAR's
    # sigma=0 tasks skip the ensemble entirely; escalated tasks pay
    # probe + ensemble, so the p50 sits near Arena-3's).
    out["ordering_holds"] = (
        out["single_model"]["mean_ms"] < out["acar_u"]["mean_ms"] <
        out["arena_3"]["mean_ms"] + 1e-9)
    write_json(BENCH_DIR / "fig7_latency.json", out)
    return out


def fig8_fig9_retrieval(seed: int = 0) -> dict:
    """Figs. 8/9: hit rate by benchmark + similarity distribution.
    High hit rates, low similarity (paper median 0.167)."""
    store = experience_store()
    tasks = paper_suite(seed=seed)
    out: Dict[str, dict] = {"per_benchmark": {}}
    sims_all: List[float] = []
    for bench in PAPER_MIX:
        qs = [t.text for t in tasks if t.benchmark == bench]
        stats = store.similarity_stats(qs)
        out["per_benchmark"][bench] = {
            "hit_rate": stats["hit_rate"],
            "median_similarity": stats["median_similarity"],
        }
        sims_all.extend(stats["similarities"])
    sims = np.array(sims_all)
    out["median_similarity"] = float(np.median(sims))
    out["paper_median"] = PAPER_FIG9_MEDIAN_SIM
    out["hist"] = {f"{lo:.1f}-{lo + 0.1:.1f}":
                   float(((sims >= lo) & (sims < lo + 0.1)).mean())
                   for lo in np.arange(0.0, 1.0, 0.1)}
    out["low_similarity_regime"] = out["median_similarity"] < 0.3
    write_json(BENCH_DIR / "fig9_similarity.json", out)
    return out


def run(seed: int = 0, verbose: bool = True) -> dict:
    out = {
        "fig1": fig1_sigma_dist(seed),
        "fig5": fig5_escalation(seed),
        "fig6": fig6_cumulative(seed),
        "fig7": fig7_latency(seed),
        "fig9": fig8_fig9_retrieval(seed),
    }
    if verbose:
        print(f"  fig1 sigma hist: {out['fig1']['histogram']} "
              f"(paper {PAPER_FIG1})")
        print(f"  fig5 supergpqa: {out['fig5']['supergpqa']}")
        print(f"  fig6 avoided: {out['fig6']['avoided_fraction']:.3f} "
              f"(paper {PAPER_FIG6_FULL_ARENA_AVOIDED})")
        print(f"  fig7 p50: single "
              f"{out['fig7']['single_model']['p50_ms']:.0f}ms acar "
              f"{out['fig7']['acar_u']['p50_ms']:.0f}ms arena3 "
              f"{out['fig7']['arena_3']['p50_ms']:.0f}ms")
        print(f"  fig9 median sim: "
              f"{out['fig9']['median_similarity']:.3f} "
              f"(paper {PAPER_FIG9_MEDIAN_SIM})")
    return out


def main() -> str:
    out = run(verbose=False)
    return csv_line(
        "figures", 0.0,
        f"avoided={out['fig6']['avoided_fraction']:.3f}")


if __name__ == "__main__":
    run()
