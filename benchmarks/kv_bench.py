"""Paged KV-cache benchmark: probe-KV memory high-water and prefill
reuse at the paper's escalation rate.

Drives a duplicate-bearing stream of uniform long prompts through the
real-model ``BatchedACAREngine`` twice — dense ``tile_cache`` baseline
vs the paged KV subsystem (serving/kv_pool.py) — with routing forced to
the paper's published 45.8% escalation, and measures:

* **probe-KV memory high-water** — pages referenced by the largest
  probe wave (shared prompt pages + COW tails + sample-private decode
  pages) vs the ``B*N*(prompt+new)`` slots ``tile_cache`` materialises.
  The N probe samples share the read-only prompt pages, so the paged
  working set approaches ``prompt + N*new`` per task; the gate asserts
  >= 2x reduction at the benchmark's prompt/decode shape.
* **prefill tokens reused** — prompt prefills served from retained
  pages instead of recomputation: ensemble members that are the probe
  model seed their prefill from the probe's pages the route decision
  kept alive, and duplicate requests hit the prompt prefix cache. The
  gate asserts the probe->ensemble counter is nonzero at the paper
  rate (escalated rows exist, and the arena's third member is the
  probe model, mirroring the paper's ARENA3).

Both engines must produce identical answers (the bit-equivalence
contract is enforced in depth by ``tests/harness/simulate.py
--paged-kv``; here it is a cheap sanity gate). Results persist to
``BENCH_kv.json`` + ``experiments/bench/kv.json`` via
``benchmarks.common.persist_bench``.

    PYTHONPATH=src python -m benchmarks.kv_bench [--smoke]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import PAPER_RATE_BLOCK, csv_line, persist_bench
from repro.configs.acar import ACARConfig
from repro.configs.registry import get_config
from repro.data import tokenizer as tok
from repro.data.tasks import Task
from repro.models import params as params_lib
from repro.serving import (
    BatchedACAREngine, MicroBatchPolicy, ZooModel, dense_tile_slots)


def paper_rate_route_fn(seed: int):
    """route_fn realising the paper's 45.8% escalation rate per wave,
    deterministically shuffled so waves mix modes."""
    rng = np.random.default_rng(seed + 0x45A)

    def route(sig):
        b = int(sig.shape[0])
        block: list = []
        while len(block) < b:
            chunk = list(PAPER_RATE_BLOCK)
            rng.shuffle(chunk)
            block.extend(chunk)
        return jnp.asarray(np.asarray(block[:b], np.int32))
    return route


def bench_zoo(seed: int = 0):
    """Tiny dense zoo; the arena's third member IS the probe model
    (the paper's ARENA3 contains the probe), so probe->ensemble
    prefill-page reuse is sound and exercised."""
    zoo = []
    for i in range(3):
        cfg = get_config("smollm-135m", reduced=True).replace(
            vocab_size=tok.VOCAB_SIZE, dtype="float32",
            tie_embeddings=True)
        prm = params_lib.init_params(cfg, jax.random.PRNGKey(seed + i))
        zoo.append(ZooModel(name=f"m{i}", cfg=cfg, params=prm))
    probe = zoo[0]
    ensemble = [zoo[1], zoo[2],
                ZooModel(name="m3-probe", cfg=probe.cfg,
                         params=probe.params)]
    return probe, ensemble


def long_prompt_tasks(n_tasks: int, prompt_chars: int, seed: int,
                      duplicate_rate: float = 0.15):
    """Uniform long arithmetic-surface prompts (the memory regime where
    prefix sharing matters: prompt >> decode), with duplicate
    resubmissions exercising the prompt prefix cache."""
    rng = np.random.default_rng(seed + 0xA11)
    tasks = []
    for i in range(n_tasks):
        if tasks and rng.random() < duplicate_rate:
            tasks.append(tasks[int(rng.integers(len(tasks)))])
            continue
        digits = "".join(str(rng.integers(10))
                         for _ in range(prompt_chars - 8))
        tasks.append(Task(
            task_id=f"kv-{i:05d}", benchmark="kv_bench",
            kind="arithmetic", text=f"{digits} + 1 = ", gold="0",
            difficulty=0.0))
    return tasks


def run(n_tasks: int = 96, batch_size: int = 8,
        prompt_chars: int = 56, max_new_tokens: int = 8,
        page_size: int = 8, seed: int = 0,
        verbose: bool = True) -> dict:
    tasks = long_prompt_tasks(n_tasks, prompt_chars, seed)
    probe, ensemble = bench_zoo(seed)
    acfg = ACARConfig(probe_temperature=0.9, seed=seed)
    policy = MicroBatchPolicy(max_batch_size=batch_size,
                              max_batch_tokens=1 << 20)
    s = tok.encode_aligned([tasks[0].text]).shape[1]
    n = acfg.n_probe_samples

    dense_eng = BatchedACAREngine(
        acfg, probe, ensemble, max_new_tokens=max_new_tokens,
        compact=True, shared_prefix=True, paged=False,
        route_fn=paper_rate_route_fn(seed))
    paged_eng = BatchedACAREngine(
        acfg, probe, ensemble, max_new_tokens=max_new_tokens,
        compact=True, shared_prefix=True, paged=True,
        kv_page_size=page_size,
        route_fn=paper_rate_route_fn(seed))

    t0 = time.perf_counter()
    res_d = dense_eng.run_queued(tasks, policy)
    dense_wall = (time.perf_counter() - t0) * 1e3
    t0 = time.perf_counter()
    res_p = paged_eng.run_queued(tasks, policy)
    paged_wall = (time.perf_counter() - t0) * 1e3

    identical = (list(res_d.final_answers) == list(res_p.final_answers)
                 and np.array_equal(res_d.modes, res_p.modes)
                 and res_d.member_answers == res_p.member_answers)

    kv = paged_eng.kv_stats()
    probe_kv = kv[probe.name]
    token_bytes = probe_kv.page_bytes / probe_kv.page_size
    dense_probe_bytes = dense_tile_slots(
        batch_size, n, s, max_new_tokens) * token_bytes
    paged_probe_bytes = probe_kv.probe_highwater_bytes
    reduction = dense_probe_bytes / max(paged_probe_bytes, 1)
    reused_probe = sum(st.prefill_tokens_reused_probe
                       for st in kv.values())
    reused_prefix = sum(st.prefill_tokens_reused_prefix
                        for st in kv.values())
    metric_reused = sum(
        res_p.metrics.get("acar_kv_prefill_tokens_reused_total",
                          model=name, source=source)
        for name in kv for source in ("probe", "prefix_cache"))

    out = {
        "n_tasks": n_tasks,
        "batch_size": batch_size,
        "prompt_len": s,
        "max_new_tokens": max_new_tokens,
        "n_probe_samples": n,
        "page_size": page_size,
        "escalation_rate": float(np.mean(np.asarray(res_p.modes) >= 1)),
        "identical_answers": identical,
        # probe-KV memory high-water: tile_cache vs paged working set
        "dense_probe_kv_bytes": dense_probe_bytes,
        "paged_probe_kv_bytes": paged_probe_bytes,
        "probe_kv_memory_reduction": reduction,
        "kv_pool_pages": probe_kv.pool_pages,
        "kv_pages_highwater": probe_kv.pages_highwater,
        # prefill reuse at the paper rate
        "prefill_tokens_reused_probe": reused_probe,
        "prefill_tokens_reused_prefix_cache": reused_prefix,
        "prefill_tokens_reused_total_metric": metric_reused,
        "prefill_tokens_computed": sum(
            st.prefill_tokens_computed for st in kv.values()),
        "cow_forks": sum(st.cow_forks for st in kv.values()),
        "dense_wall_ms": dense_wall,
        "paged_wall_ms": paged_wall,
    }
    persist_bench("kv", out)
    if verbose:
        print(f"tasks={n_tasks} batch={batch_size} prompt={s} "
              f"new={max_new_tokens} page={page_size} "
              f"escalation={out['escalation_rate']:.1%} "
              f"identical={identical}")
        print(f"probe KV high-water: dense {dense_probe_bytes/1e3:.1f}"
              f" kB vs paged {paged_probe_bytes/1e3:.1f} kB "
              f"({reduction:.2f}x smaller)")
        print(f"prefill reuse: probe->ensemble {reused_probe} tok, "
              f"prefix cache {reused_prefix} tok, computed "
              f"{out['prefill_tokens_computed']} tok")
    return out


def main() -> str:
    t = run(n_tasks=48, verbose=False)
    us = t["paged_wall_ms"] * 1e3 / t["n_tasks"]
    return csv_line(
        "kv_bench", us,
        f"mem_reduction={t['probe_kv_memory_reduction']:.2f}x;"
        f"reused={t['prefill_tokens_reused_probe']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tasks", type=int, default=96)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--prompt-chars", type=int, default=56)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run for CI artifact tracking")
    args = ap.parse_args()
    n = 48 if args.smoke else args.tasks
    out = run(n_tasks=n, batch_size=args.batch_size,
              prompt_chars=args.prompt_chars,
              page_size=args.page_size, seed=args.seed)
    gates = {
        "identical_answers": out["identical_answers"],
        "probe_kv_memory_reduction >= 2.0":
            out["probe_kv_memory_reduction"] >= 2.0,
        "prefill_tokens_reused_probe > 0":
            out["prefill_tokens_reused_probe"] > 0,
        "reuse counter exported":
            out["prefill_tokens_reused_total_metric"] > 0,
    }
    for name, passed in gates.items():
        if not passed:
            print(f"GATE FAILED: {name}", file=sys.stderr)
    sys.exit(0 if all(gates.values()) else 1)
