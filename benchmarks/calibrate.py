"""Calibration report: every paper target vs the simulator's output.

Used while fitting the SyntheticBackend profile + task-suite difficulty
constants; re-run after any constant change:

    PYTHONPATH=src:. python -m benchmarks.calibrate
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_all_configs
from repro.data.tasks import PAPER_MIX

TARGETS = {
    "acc/single_model": 0.454,
    "acc/arena_2": 0.544,
    "acc/acar_u": 0.556,
    "acc/arena_3": 0.636,
    "acc/acar_uj": 0.524,
    "sigma0/overall": 0.329,
    "sigma05/overall": 0.213,
    "sigma1/overall": 0.458,
    "sigma0/supergpqa": 0.42,
    "full_arena/matharena": 0.93,
    "full_arena/livecodebench": 0.96,
    "acar_u/supergpqa": 0.605,
    "acar_u/livecodebench": 0.515,
    "acar_u/reasoning_gym": 0.46,
    "acar_u/matharena": 0.267,
    "retrieval_delta": -0.034,
}


def report(seed: int = 0) -> dict:
    runs = run_all_configs(seed=seed)
    out = {}
    for name in ("single_model", "arena_2", "acar_u", "arena_3",
                 "acar_uj"):
        out[f"acc/{name}"] = runs[name].accuracy
    u = runs["acar_u"].outcomes
    sig = np.array([o.trace.sigma for o in u])
    out["sigma0/overall"] = float((sig == 0.0).mean())
    out["sigma05/overall"] = float((sig == 0.5).mean())
    out["sigma1/overall"] = float((sig == 1.0).mean())
    for bench in PAPER_MIX:
        sel = [o for o in u if o.trace.benchmark == bench]
        s = np.array([o.trace.sigma for o in sel])
        out[f"sigma0/{bench}"] = float((s == 0.0).mean())
        out[f"full_arena/{bench}"] = float((s == 1.0).mean())
        out[f"acar_u/{bench}"] = float(
            np.mean([o.correct for o in sel]))
    out["retrieval_delta"] = runs["acar_uj"].accuracy \
        - runs["acar_u"].accuracy
    out["cost/single"] = runs["single_model"].cost
    out["cost/arena_2"] = runs["arena_2"].cost
    out["cost/acar_u"] = runs["acar_u"].cost
    out["cost/arena_3"] = runs["arena_3"].cost
    return out


def main():
    got = report()
    print(f"{'metric':26s} {'got':>8s} {'target':>8s} {'diff':>8s}")
    for k, t in TARGETS.items():
        g = got.get(k, float("nan"))
        print(f"{k:26s} {g:8.3f} {t:8.3f} {g - t:+8.3f}")
    print("\nextra:")
    for k in sorted(got):
        if k not in TARGETS:
            print(f"  {k:24s} {got[k]:8.3f}")


if __name__ == "__main__":
    main()
