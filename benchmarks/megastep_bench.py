"""Megastep decode benchmark: wall-clock decode throughput of K fused
device-resident ticks vs the per-tick step loop.

Drives a saturated (all arrivals at tick 0) stream of short prompts
with routing forced to mode 0 (probe-only — decode-dominated) through
the mesh-sharded step loop (data=--shards forced host devices) twice:
once with megastep K=1 (the per-tick baseline: one shard_map'd decode
launch + one host logits round-trip per tick) and once with
K=--megastep fused ticks (one launch per megastep, lane state
device-resident, only (K, B) token ids + done bits crossing back).
Each configuration runs twice — an untimed warmup to populate the
jit cache, then the measured run — so the gate measures steady-state
launch/transfer overhead, not compilation.

The two runs serve bit-identical token streams (proved by
``tests/harness/simulate.py --megastep``); this benchmark gates the
wall-clock win that motivates the fusion.

Gates (persisted via ``persist_bench`` to ``BENCH_megastep.json`` +
``experiments/bench/megastep.json``, uploaded nightly by CI):

* wall-clock decode tokens/s at K=16 must be >= 2x the per-tick loop;
* both runs must emit the same decode-token count (same streams — a
  mismatch means the fusion changed semantics, not just speed);
* host<->device transfer events per emitted token must drop by at
  least K/2 (the per-tick logits round-trip really is gone).

    PYTHONPATH=src:tests python -m benchmarks.megastep_bench [--smoke]
        [--shards 4] [--megastep 16]
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import csv_line, persist_bench
from benchmarks.serving_bench import (
    bench_zoo, bursty_tasks, index_route_fn)
from repro.configs.acar import ACARConfig
from repro.data import tokenizer as tok
from repro.serving import AdmissionQueue, MicroBatchPolicy
from repro.serving.scheduler import StepPlanner
from repro.serving.step_loop import ShardedStepLoopRunner


def _run_loop(tasks, modes, *, megastep, shards, chunk_tokens,
              max_new_tokens, active_rows, batch_size, seed):
    """One mesh-sharded step-loop run over a saturated queue.
    Returns (runner, wall_s)."""
    from repro.serving import BatchedACAREngine
    from repro.serving.mesh import ServingMesh
    probe, ensemble = bench_zoo(seed)
    acfg = ACARConfig(probe_temperature=0.9, seed=seed)
    eng = BatchedACAREngine(
        acfg, probe, ensemble, max_new_tokens=max_new_tokens,
        route_fn=index_route_fn(modes), kv_prefix_cache=0)
    queue = AdmissionQueue(MicroBatchPolicy(
        max_batch_size=batch_size, max_batch_tokens=1 << 20))
    for t in tasks:
        queue.submit(t, arrival_time=0)
    planner = StepPlanner(chunk_tokens=chunk_tokens,
                          max_active_rows=active_rows,
                          megastep=megastep)
    runner = ShardedStepLoopRunner(eng, queue, planner,
                                   ServingMesh(data=shards))
    t0 = time.perf_counter()
    runner.run()
    return runner, time.perf_counter() - t0


def _measure(tasks, modes, **kw):
    """Warmup (jit-cache fill) + measured run; returns the measured
    runner's stats and decode tokens/s."""
    _run_loop(tasks, modes, **kw)                  # warmup, untimed
    runner, wall_s = _run_loop(tasks, modes, **kw)
    st = runner.stats
    return st, st.decode_tokens / wall_s, wall_s


def run(n_tasks: int = 32, batch_size: int = 8,
        prompt_chars: int = 16, max_new_tokens: int = 16,
        chunk_tokens: int = 8, active_rows: int = 4,
        shards: int = 4, megastep: int = 16, seed: int = 0,
        verbose: bool = True) -> dict:
    """Mode 0 everywhere keeps the run decode-dominated (no member
    prefills), short prompts keep the prefill phase negligible — the
    measured quantity is decode launch + transfer overhead."""
    tasks, _ = bursty_tasks(n_tasks, prompt_chars, seed,
                            burst=n_tasks, gap=0)
    modes = np.zeros(n_tasks, np.int64)
    prompt_len = int(tok.encode_aligned([tasks[0].text]).shape[1])

    kw = dict(shards=shards, chunk_tokens=chunk_tokens,
              max_new_tokens=max_new_tokens, active_rows=active_rows,
              batch_size=batch_size, seed=seed)
    st_1, tps_1, wall_1 = _measure(tasks, modes, megastep=1, **kw)
    st_k, tps_k, wall_k = _measure(tasks, modes, megastep=megastep,
                                   **kw)

    def per_token(st):
        return (st.decode_h2d + st.decode_d2h) \
            / max(st.decode_tokens, 1)

    out = {
        "n_tasks": n_tasks,
        "shards": shards,
        "megastep": megastep,
        "prompt_len": prompt_len,
        "max_new_tokens": max_new_tokens,
        "active_rows_per_shard": active_rows,
        "decode_tokens_per_tick": st_1.decode_tokens,
        "decode_tokens_megastep": st_k.decode_tokens,
        "wall_s_per_tick": wall_1,
        "wall_s_megastep": wall_k,
        "decode_tps_per_tick": tps_1,
        "decode_tps_megastep": tps_k,
        "decode_tps_speedup": tps_k / tps_1,
        "launches_per_tick": st_1.launches,
        "launches_megastep": st_k.launches,
        "masked_decode_steps": st_k.masked_decode_steps,
        "transfers_per_token_per_tick": per_token(st_1),
        "transfers_per_token_megastep": per_token(st_k),
        "transfer_drop": per_token(st_1) / max(per_token(st_k), 1e-9),
    }
    persist_bench("megastep", out)
    if verbose:
        for k, v in out.items():
            print(f"  {k}: {v}")
    return out


def check(out: dict) -> list:
    """Perf gates: >=2x wall-clock decode tokens/s at the configured
    megastep, same decode-token count (stream equality sanity), and
    >= K/2 fewer transfer events per emitted token."""
    k = out["megastep"]
    failures = []
    if out["decode_tps_speedup"] < 2.0:
        failures.append(
            f"megastep K={k} decode throughput "
            f"{out['decode_tps_speedup']:.2f}x < 2x wall-clock gate")
    if out["decode_tokens_megastep"] != out["decode_tokens_per_tick"]:
        failures.append(
            f"decode token counts diverge: "
            f"{out['decode_tokens_per_tick']} per-tick vs "
            f"{out['decode_tokens_megastep']} megastep")
    if out["transfer_drop"] < k / 2:
        failures.append(
            f"transfers per token dropped only "
            f"{out['transfer_drop']:.2f}x < {k / 2:g}x gate at K={k}")
    return failures


def main() -> str:
    t = run(verbose=False)
    us = t["wall_s_megastep"] * 1e6 / t["n_tasks"]
    return csv_line(
        "megastep_bench", us,
        f"decode_tps={t['decode_tps_speedup']:.2f}x;"
        f"transfers={t['transfer_drop']:.1f}x")


def _maybe_reexec() -> None:
    """Re-exec under a forced host device count when the mesh needs
    more devices than jax would otherwise expose (same contract as
    tests/harness/simulate.py: a user-set count always wins)."""
    from repro.xla_flags import argv_int, reexec_with_host_devices
    argv = sys.argv[1:]
    reexec_with_host_devices(
        argv_int(argv, "--shards", 4),
        ["-m", "benchmarks.megastep_bench"] + argv)


if __name__ == "__main__":
    _maybe_reexec()
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="smaller stream for CI")
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--megastep", type=int, default=16)
    args = ap.parse_args()
    out = run(n_tasks=16 if args.smoke else 32, shards=args.shards,
              megastep=args.megastep, verbose=True)
    failures = check(out)
    for f in failures:
        print(f"GATE FAILED: {f}", file=sys.stderr)
    sys.exit(1 if failures else 0)
