"""Shared benchmark runner.

Runs the paper's five configurations (Single-Model / Arena-2 / Arena-3 /
ACAR-U / ACAR-UJ) over the 1,510-task synthetic suite through the real
orchestrator + TEAMLLM substrate, writing immutable runs.jsonl artifacts
(paper Appendix B layout) and caching summarised outcomes so every
table/figure benchmark reads the same runs.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs.acar import ACAR_U, ACAR_UJ, ACARConfig
from repro.core.backends import paper_backends
from repro.core.orchestrator import ACAROrchestrator, TaskOutcome, \
    run_fixed_mode
from repro.core.retrieval import Experience, ExperienceStore
from repro.data.tasks import PAPER_MIX, Task, paper_suite
from repro.teamllm.artifacts import ArtifactStore

ART_DIR = Path("experiments/artifacts")
PROBE = "gemini-2.0-flash"
ARENA2 = ["claude-sonnet-4", "gpt-4o"]
ARENA3 = ["claude-sonnet-4", "gpt-4o", "gemini-2.0-flash"]

# paper's experience store: 837 entries, built from held-out history
STORE_SIZE = 837

# 24-task repeating block hitting the paper's published routing rates
# exactly: 13 sigma=0 (54.2% single_agent), 4 sigma=0.5 (arena_lite),
# 7 sigma=1 (full_arena) -> 45.8% escalated. Shared by the scheduler
# and kv benchmarks so both measure the same regime.
PAPER_RATE_BLOCK = [0] * 13 + [1] * 4 + [2] * 7


@dataclass
class ConfigRun:
    name: str
    outcomes: List[TaskOutcome]
    wall_s: float

    @property
    def accuracy(self) -> float:
        return float(np.mean([o.correct for o in self.outcomes]))

    @property
    def cost(self) -> float:
        return float(sum(o.trace.cost for o in self.outcomes))

    def accuracy_by_benchmark(self) -> Dict[str, float]:
        by: Dict[str, List[bool]] = {}
        for o in self.outcomes:
            by.setdefault(o.trace.benchmark, []).append(o.correct)
        return {k: float(np.mean(v)) for k, v in by.items()}


def experience_store(seed: int = 1) -> ExperienceStore:
    """837-entry store built from a held-out pseudo-history (different
    task seed -> weakly related texts, the paper's low-similarity
    regime)."""
    store = ExperienceStore()
    hist = paper_suite(seed=seed)
    rng = np.random.default_rng(seed)
    idx = rng.choice(len(hist), size=STORE_SIZE, replace=False)
    for i in idx:
        t = hist[i]
        store.add(Experience(t.text, t.gold, bool(rng.random() < 0.6),
                             t.benchmark))
    return store


def run_all_configs(tasks: Optional[Sequence[Task]] = None,
                    seed: int = 0,
                    art_dir: Path = ART_DIR) -> Dict[str, ConfigRun]:
    tasks = list(tasks if tasks is not None else paper_suite(seed=seed))
    backs = paper_backends()
    art_dir.mkdir(parents=True, exist_ok=True)
    runs: Dict[str, ConfigRun] = {}

    def timed(name, fn):
        t0 = time.perf_counter()
        out = fn()
        runs[name] = ConfigRun(name, out, time.perf_counter() - t0)

    def store_for(name):
        p = art_dir / name / "runs.jsonl"
        if p.exists():
            p.unlink()
        return ArtifactStore(p)

    timed("single_model", lambda: run_fixed_mode(
        tasks, backs, ["claude-sonnet-4"], store=store_for("single"),
        seed=seed, run_id="single"))
    timed("arena_2", lambda: run_fixed_mode(
        tasks, backs, ARENA2, store=store_for("arena2"), seed=seed,
        run_id="arena2"))
    timed("arena_3", lambda: run_fixed_mode(
        tasks, backs, ARENA3, store=store_for("arena3"), seed=seed,
        run_id="arena3"))

    acfg_u = ACARConfig(seed=seed)
    orch_u = ACAROrchestrator(
        acfg_u, backs[PROBE],
        {m: backs[m] for m in ARENA3},
        store=store_for("phase22_acar_u"), run_id="acar_u")
    timed("acar_u", lambda: orch_u.run_suite(tasks))

    acfg_uj = ACARConfig(seed=seed, retrieval_enabled=True,
                         retrieval_threshold=0.0)
    orch_uj = ACAROrchestrator(
        acfg_uj, backs[PROBE],
        {m: backs[m] for m in ARENA3},
        store=store_for("phase22_acar_uj"),
        experience=experience_store(), run_id="acar_uj")
    timed("acar_uj", lambda: orch_uj.run_suite(tasks))
    return runs


_CACHE: Dict[int, Dict[str, ConfigRun]] = {}


def cached_runs(seed: int = 0) -> Dict[str, ConfigRun]:
    if seed not in _CACHE:
        _CACHE[seed] = run_all_configs(seed=seed)
    return _CACHE[seed]


def csv_line(name: str, us_per_call: float, derived) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def write_json(path: Path, obj) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(obj, indent=1, default=float))


def persist_bench(name: str, payload: dict) -> None:
    """Write a benchmark's dual artifacts in one place: the CI-uploaded
    ``BENCH_<name>.json`` at the repo root and the experiment-tracking
    ``experiments/bench/<name>.json`` — one helper so the two copies
    cannot drift."""
    write_json(Path(f"BENCH_{name}.json"), payload)
    write_json(Path("experiments/bench") / f"{name}.json", payload)
