"""Step-level serving benchmark: p50/p95 virtual-clock task latency
and KV-page high-water, step loop vs wave-lockstep.

Drives a bursty, duplicate-bearing stream of uniform long prompts
through the real-model engine's step-level loop (``run_stepped``) with
routing forced to the paper's published 45.8% escalation rate, and
compares its virtual-clock task latencies against a simulated
wave-lockstep timeline over the *same* arrivals, modes and cost model.

The virtual clock counts **device-program launches**: one decode step
of any bucketed group costs 1, one prefill chunk of ``chunk_tokens``
costs 1. Each model server is an independent executor (ACAR's
ensemble members are separate services in the paper's deployment), so
the step loop's tick advance is the *max* programs any one server
launched that tick — same-server programs serialize, cross-server
ones overlap. The wave timeline is charged in the same units but is
serial by construction (that is what lockstep means — ``run_batch``
drains the probe wave, then each member wave one after another, idling
every other server): a one-shot prefill of an S-token prompt costs
ceil(S/C), each member wave costs its own prefill (twin members reuse
the probe's pages for free) plus ``max_new`` decode steps, and waves
serialize with each other. Prefix-cache hits skip prefill charges on
both sides, tracked with the same seen-prompt logic.

Gates (persisted via ``persist_bench`` to ``BENCH_serving.json`` +
``experiments/bench/serving.json``, uploaded nightly by CI):

* p95 virtual-clock task latency must improve >= 1.5x over
  wave-lockstep at the paper's 45.8% escalation with bursty arrivals
  (the step loop retires single-agent rows while the wave would still
  be draining its slowest full-arena member);
* the step loop's measured probe-server KV-page high-water must not
  regress vs the wave baseline recorded in ``BENCH_kv.json``
  (mid-stream retirement must not cost memory).

    PYTHONPATH=src:tests python -m benchmarks.serving_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import PAPER_RATE_BLOCK, csv_line, persist_bench
from repro.configs.acar import ACARConfig
from repro.configs.registry import get_config
from repro.data import tokenizer as tok
from repro.data.tasks import Task
from repro.models import params as params_lib
from repro.serving import (
    BatchedACAREngine, MicroBatchPolicy, ZooModel)

BENCH_KV = Path("BENCH_kv.json")


def bench_zoo(seed: int = 0):
    """Tiny dense zoo mirroring kv_bench: the arena's third member IS
    the probe model (paper ARENA3), so twin reuse is exercised."""
    zoo = []
    for i in range(3):
        cfg = get_config("smollm-135m", reduced=True).replace(
            vocab_size=tok.VOCAB_SIZE, dtype="float32",
            tie_embeddings=True)
        prm = params_lib.init_params(cfg, jax.random.PRNGKey(seed + i))
        zoo.append(ZooModel(name=f"m{i}", cfg=cfg, params=prm))
    probe = zoo[0]
    ensemble = [zoo[1], zoo[2],
                ZooModel(name="m3-probe", cfg=probe.cfg,
                         params=probe.params)]
    return probe, ensemble


def bursty_tasks(n_tasks: int, prompt_chars: int, seed: int,
                 burst: int, gap: int, duplicate_rate: float = 0.15):
    """Uniform long prompts arriving in bursts of ``burst`` every
    ``gap`` virtual ticks. Returns (tasks, arrivals)."""
    rng = np.random.default_rng(seed + 0xB0B5)
    tasks, arrivals = [], []
    for i in range(n_tasks):
        if tasks and rng.random() < duplicate_rate:
            tasks.append(tasks[int(rng.integers(len(tasks)))])
        else:
            digits = "".join(str(rng.integers(10))
                             for _ in range(prompt_chars - 8))
            tasks.append(Task(
                task_id=f"serve-{i:05d}", benchmark="serving_bench",
                kind="math", text=f"{digits} + 1 = ", gold="0",
                difficulty=0.0))
        arrivals.append((i // burst) * gap)
    return tasks, arrivals


def forced_modes(n_tasks: int, seed: int) -> np.ndarray:
    """Per-task modes realising the paper's 45.8% escalation,
    deterministically shuffled and keyed by admission index so wave
    and step execution force identical routes."""
    rng = np.random.default_rng(seed + 0x45A)
    modes: list = []
    while len(modes) < n_tasks:
        block = list(PAPER_RATE_BLOCK)
        rng.shuffle(block)
        modes.extend(block)
    return np.asarray(modes[:n_tasks], np.int32)


def index_route_fn(modes: np.ndarray):
    def route(sig, indices):
        return jnp.asarray(modes[np.asarray(indices, np.int64)])
    return route


def wave_lockstep_latencies(arrivals, modes, *, batch_size: int,
                            max_wait: int, prompt_len: int,
                            chunk_tokens: int, max_new: int,
                            n_members: int, arena_lite: int,
                            twin_members, prompts) -> np.ndarray:
    """Virtual-clock completion simulation of the wave-lockstep engine
    over the same arrivals/modes, in device-program units (see module
    docstring). Batches form fill-or-timeout (``AdmissionQueue.ready``
    semantics) and execute strictly one after another."""
    n = len(arrivals)
    prefill_units = -(-prompt_len // chunk_tokens)
    seen_probe: set = set()
    seen_member = [set() for _ in range(n_members)]
    latencies = np.zeros(n, float)
    i = 0
    busy = 0.0
    while i < n:
        # fill-or-timeout, matching AdmissionQueue.next_ready_at:
        # whichever fires first — the arrival of the batch-size-th
        # request, or the head's wait budget — and only requests that
        # have arrived by the formation instant join the batch
        timeout = arrivals[i] + max_wait
        if i + batch_size <= n:
            formed = min(arrivals[i + batch_size - 1], timeout)
        else:
            formed = timeout
        j = i
        while (j < n and j - i < batch_size
               and arrivals[j] <= formed):
            j += 1
        start = max(busy, formed)
        # probe stage: one (bucketed) prefill over the cache-missed
        # rows + the fixed-length decode scan
        miss = any(prompts[r] not in seen_probe for r in range(i, j))
        seen_probe.update(prompts[r] for r in range(i, j))
        dur = (prefill_units if miss else 0) + max_new
        # member waves, serial (run_batch loops members)
        for mi in range(n_members):
            rows = [r for r in range(i, j)
                    if modes[r] >= (1 if mi < arena_lite else 2)]
            if not rows:
                continue
            if mi in twin_members:
                dur += max_new                # seeded: no prefill
            else:
                mmiss = any(prompts[r] not in seen_member[mi]
                            for r in rows)
                seen_member[mi].update(prompts[r] for r in rows)
                dur += (prefill_units if mmiss else 0) + max_new
        end = start + dur
        for r in range(i, j):
            latencies[r] = end - arrivals[r]
        busy = end
        i = j
    return latencies


def run(n_tasks: int = 48, batch_size: int = 8,
        prompt_chars: int = 56, max_new_tokens: int = 8,
        chunk_tokens: int = 8, burst: int = 8, gap: int = 24,
        active_rows: int = 16, prefix_cache: int = 24,
        seed: int = 0, verbose: bool = True) -> dict:
    """``active_rows`` is the step loop's admission cap: twice the
    wave's batch size, because streaming admission is not bound to
    batch formation — rows join whenever the page budget is open.
    ``prefix_cache`` is smaller than the wave baseline's 32 entries:
    cost-aware eviction (prefill-tokens-saved per page held) keeps the
    valuable prompts cached, so the step loop serves 2x the concurrent
    rows inside a *lower* page high-water than ``BENCH_kv.json``'s
    wave measurement — the gate below is what proves the extra
    concurrency is paid for by shorter page lifetimes (mid-stream
    retirement + chunked prefill), not by more memory."""
    tasks, arrivals = bursty_tasks(n_tasks, prompt_chars, seed, burst,
                                   gap)
    modes = forced_modes(n_tasks, seed)
    probe, ensemble = bench_zoo(seed)
    acfg = ACARConfig(probe_temperature=0.9, seed=seed)
    policy = MicroBatchPolicy(max_batch_size=batch_size,
                              max_batch_tokens=1 << 20)
    prompt_len = int(tok.encode_aligned([tasks[0].text]).shape[1])
    prompts = [t.text for t in tasks]

    eng = BatchedACAREngine(
        acfg, probe, ensemble, max_new_tokens=max_new_tokens,
        route_fn=index_route_fn(modes), kv_prefix_cache=prefix_cache)
    t0 = time.perf_counter()
    # real run: the step loop's own tick accounting is the measurement
    queue_submit = [(t, a) for t, a in zip(tasks, arrivals)]
    from repro.serving import AdmissionQueue
    from repro.serving.scheduler import StepPlanner
    from repro.serving.step_loop import StepLoopRunner
    queue = AdmissionQueue(policy)
    for t, a in queue_submit:
        queue.submit(t, arrival_time=a)
    runner = StepLoopRunner(
        eng, queue, StepPlanner(chunk_tokens=chunk_tokens,
                                max_active_rows=active_rows))
    stats = runner.run()
    wall_ms = (time.perf_counter() - t0) * 1e3

    step_lat = np.asarray(
        [stats.timeline[i][2] - stats.timeline[i][0]
         for i in range(n_tasks)], float)
    twin = {mi for mi, zm in enumerate(ensemble)
            if zm.params is probe.params}
    wave_lat = wave_lockstep_latencies(
        arrivals, modes, batch_size=batch_size,
        max_wait=policy.max_wait_ticks, prompt_len=prompt_len,
        chunk_tokens=chunk_tokens, max_new=max_new_tokens,
        n_members=len(ensemble), arena_lite=acfg.arena_lite_size,
        twin_members=twin, prompts=prompts)

    probe_kv = eng.kv_stats()[probe.name]
    kv_baseline = None
    if BENCH_KV.exists():
        kv_baseline = json.loads(BENCH_KV.read_text()).get(
            "kv_pages_highwater")

    out = {
        "n_tasks": n_tasks,
        "batch_size": batch_size,
        "active_rows": active_rows,
        "prompt_len": prompt_len,
        "chunk_tokens": chunk_tokens,
        "max_new_tokens": max_new_tokens,
        "burst": burst,
        "gap": gap,
        "escalation_rate": float(np.mean(modes >= 1)),
        "step_ticks": stats.ticks,
        "step_invocations": stats.invocations,
        "step_prefill_chunks": stats.prefill_chunks,
        "step_p50_latency": float(np.percentile(step_lat, 50)),
        "step_p95_latency": float(np.percentile(step_lat, 95)),
        "wave_p50_latency": float(np.percentile(wave_lat, 50)),
        "wave_p95_latency": float(np.percentile(wave_lat, 95)),
        "p95_speedup": float(np.percentile(wave_lat, 95)
                             / np.percentile(step_lat, 95)),
        "p50_speedup": float(np.percentile(wave_lat, 50)
                             / np.percentile(step_lat, 50)),
        "kv_pages_highwater_step": probe_kv.pages_highwater,
        "kv_pages_highwater_baseline": kv_baseline,
        "prefix_evictions": probe_kv.prefix_evictions,
        "wall_ms": wall_ms,
    }
    persist_bench("serving", out)
    if verbose:
        for k, v in out.items():
            print(f"  {k}: {v}")
    return out


def check(out: dict) -> list:
    """Perf gates: p95 >= 1.5x over wave-lockstep at the paper's
    escalation; KV high-water no worse than the BENCH_kv baseline."""
    failures = []
    if out["p95_speedup"] < 1.5:
        failures.append(
            f"p95 speedup {out['p95_speedup']:.2f}x < 1.5x gate")
    base = out.get("kv_pages_highwater_baseline")
    if base is not None and out["kv_pages_highwater_step"] > base:
        failures.append(
            f"step KV high-water {out['kv_pages_highwater_step']} "
            f"regressed vs BENCH_kv baseline {base}")
    return failures


def main() -> str:
    t = run(verbose=False)
    us = t["wall_ms"] * 1e3 / t["n_tasks"]
    return csv_line(
        "serving_bench", us,
        f"p95_speedup={t['p95_speedup']:.2f}x;"
        f"kv_hw={t['kv_pages_highwater_step']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="smaller stream for CI")
    args = ap.parse_args()
    out = run(n_tasks=32 if args.smoke else 48,
              verbose=True)
    failures = check(out)
    for f in failures:
        print(f"GATE FAILED: {f}", file=sys.stderr)
    sys.exit(1 if failures else 0)
