"""Serving-path benchmark: the batched ACAR engine over real (tiny,
arithmetic-trained) JAX zoo models — measures end-to-end routed-batch
wall time and the ensemble calls saved by sigma routing."""
from __future__ import annotations

from pathlib import Path

from benchmarks.common import csv_line, write_json
from repro.configs.acar import ACARConfig
from repro.data.tasks import arithmetic_suite
from repro.launch.serve import build_zoo, serve

OUT = Path("experiments/bench/serving.json")


def run(n_tasks: int = 32, train_steps: int = 500,
        verbose: bool = True) -> dict:
    archs = ["smollm-135m", "llama3-8b", "deepseek-7b",
             "recurrentgemma-2b"]
    zoo = build_zoo(archs, train_steps, seed=0, verbose=verbose)
    acfg = ACARConfig(probe_model=archs[0],
                      ensemble_models=tuple(archs[1:]),
                      probe_temperature=0.7, seed=0)
    tasks = arithmetic_suite(n_tasks, seed=99)
    out = serve(tasks, zoo[0], zoo[1:], acfg, verbose=verbose)
    write_json(OUT, out)
    return out


def main() -> str:
    t = run(verbose=False)
    us = t["wall_ms"] * 1e3 / 32
    return csv_line("serving_bench", us,
                    f"acc={t['accuracy']:.3f};"
                    f"saved={t['ensemble_calls_saved']}")


if __name__ == "__main__":
    run()
