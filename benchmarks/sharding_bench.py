"""Sharded-serving benchmark: virtual-clock throughput and aggregate
KV page capacity, mesh-sharded step loop (data=N) vs single device.

Drives a saturated (all arrivals at tick 0), duplicate-bearing stream
of uniform long prompts through the step-level loop twice — once on a
single device and once on a ``ServingMesh`` with ``--shards`` data
shards — with routing forced to the paper's published 45.8% escalation
rate and the *same per-shard resources* (``active_rows`` is the
per-shard admission cap on both sides, so the sharded run serves
N x the concurrent rows out of N independent per-shard page pools).

The virtual clock is the step loop's own (device-program launches,
max over independent per-server executors per tick — see
serving/step_loop.py). A tick's group structure is identical on both
sides (groups key on (server, temperature, cache_len), and the
shard_map'd program advances every shard in one launch), so the
sharded run drains the same stream in ~1/N the ticks: throughput
scales with the mesh while per-row results stay bit-identical
(``tests/harness/simulate.py --sharded`` proves the equivalence; this
benchmark gates the performance).

Gates (persisted via ``persist_bench`` to ``BENCH_sharding.json`` +
``experiments/bench/sharding.json``, uploaded nightly by CI):

* virtual-clock throughput (tasks per virtual tick) at data=N must be
  >= 2x the single-device loop;
* aggregate KV page capacity must scale: the sharded pools' summed
  capacity >= 3x the single pool (exactly N x by construction — the
  gate catches accidental pool-sharing regressions), and the summed
  page high-water >= 2x the single high-water (the extra concurrency
  really does spread resident rows across shards).

    PYTHONPATH=src:tests python -m benchmarks.sharding_bench [--smoke]
        [--shards 4]
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import csv_line, persist_bench
from benchmarks.serving_bench import (
    bench_zoo, bursty_tasks, forced_modes, index_route_fn)
from repro.configs.acar import ACARConfig
from repro.data import tokenizer as tok
from repro.serving import AdmissionQueue, MicroBatchPolicy
from repro.serving.scheduler import StepPlanner
from repro.serving.step_loop import (
    ShardedStepLoopRunner, StepLoopRunner)


def _run_loop(tasks, modes, *, chunk_tokens, max_new_tokens,
              active_rows, prefix_cache, batch_size, seed,
              shards=None):
    """One step-loop run over a saturated queue (every request arrives
    at tick 0). Returns (runner, makespan, wall_ms)."""
    from repro.serving import BatchedACAREngine
    probe, ensemble = bench_zoo(seed)
    acfg = ACARConfig(probe_temperature=0.9, seed=seed)
    eng = BatchedACAREngine(
        acfg, probe, ensemble, max_new_tokens=max_new_tokens,
        route_fn=index_route_fn(modes), kv_prefix_cache=prefix_cache)
    queue = AdmissionQueue(MicroBatchPolicy(
        max_batch_size=batch_size, max_batch_tokens=1 << 20))
    for t in tasks:
        queue.submit(t, arrival_time=0)
    planner = StepPlanner(chunk_tokens=chunk_tokens,
                          max_active_rows=active_rows)
    t0 = time.perf_counter()
    if shards is None:
        runner = StepLoopRunner(eng, queue, planner)
    else:
        from repro.serving.mesh import ServingMesh
        runner = ShardedStepLoopRunner(eng, queue, planner,
                                       ServingMesh(data=shards))
    stats = runner.run()
    wall_ms = (time.perf_counter() - t0) * 1e3
    makespan = max(t[2] for t in stats.timeline.values())
    return runner, makespan, wall_ms


def run(n_tasks: int = 48, batch_size: int = 8,
        prompt_chars: int = 40, max_new_tokens: int = 6,
        chunk_tokens: int = 8, active_rows: int = 4,
        prefix_cache: int = 4, shards: int = 4,
        seed: int = 0, verbose: bool = True) -> dict:
    """``prefix_cache`` is deliberately small (4 entries/shard): the
    high-water gate measures *resident-row* pages spreading across
    shards, and a large prefix cache would dominate the single-device
    high-water with retained cache pages instead."""
    tasks, _ = bursty_tasks(n_tasks, prompt_chars, seed,
                            burst=n_tasks, gap=0)
    modes = forced_modes(n_tasks, seed)
    prompt_len = int(tok.encode_aligned([tasks[0].text]).shape[1])
    probe_name = bench_zoo(seed)[0].name

    kw = dict(chunk_tokens=chunk_tokens,
              max_new_tokens=max_new_tokens, active_rows=active_rows,
              prefix_cache=prefix_cache, batch_size=batch_size,
              seed=seed)
    single, span_1, wall_1 = _run_loop(tasks, modes, **kw)
    sharded, span_n, wall_n = _run_loop(tasks, modes, shards=shards,
                                        **kw)

    kv_1 = single.kv_stats()[probe_name]
    kv_n = sharded.kv_stats()[probe_name]
    tp_1 = n_tasks / span_1
    tp_n = n_tasks / span_n
    placements = [
        int(sharded.metrics.get("acar_shard_placements_total",
                                shard=str(k)))
        for k in range(shards)]

    out = {
        "n_tasks": n_tasks,
        "shards": shards,
        "prompt_len": prompt_len,
        "chunk_tokens": chunk_tokens,
        "max_new_tokens": max_new_tokens,
        "active_rows_per_shard": active_rows,
        "escalation_rate": float(np.mean(modes >= 1)),
        "single_makespan": int(span_1),
        "sharded_makespan": int(span_n),
        "single_ticks": single.stats.ticks,
        "sharded_ticks": sharded.stats.ticks,
        "single_throughput": tp_1,
        "sharded_throughput": tp_n,
        "throughput_speedup": tp_n / tp_1,
        "single_pool_pages": kv_1.pool_pages,
        "aggregate_pool_pages": kv_n.pool_pages,
        "pool_capacity_ratio": kv_n.pool_pages
        / max(kv_1.pool_pages, 1),
        "single_kv_highwater": kv_1.pages_highwater,
        "aggregate_kv_highwater": kv_n.pages_highwater,
        "kv_highwater_ratio": kv_n.pages_highwater
        / max(kv_1.pages_highwater, 1),
        "shard_placements": placements,
        "wall_ms_single": wall_1,
        "wall_ms_sharded": wall_n,
    }
    persist_bench("sharding", out)
    if verbose:
        for k, v in out.items():
            print(f"  {k}: {v}")
    return out


def check(out: dict) -> list:
    """Perf gates, scaled to the configured shard count (at the
    default data=4: >=2x virtual-clock throughput, >=3x aggregate
    page capacity, >=2x aggregate high-water — capacity is exactly
    N x by construction, so its gate mainly catches accidental
    pool-sharing regressions)."""
    n = out["shards"]
    tp_gate = min(2.0, 0.5 * n)
    cap_gate = 0.75 * n
    hw_gate = min(2.0, 0.5 * n)
    failures = []
    if out["throughput_speedup"] < tp_gate:
        failures.append(
            f"sharded throughput {out['throughput_speedup']:.2f}x "
            f"< {tp_gate:g}x gate at data={n}")
    if out["pool_capacity_ratio"] < cap_gate:
        failures.append(
            f"aggregate pool capacity {out['pool_capacity_ratio']:.2f}x"
            f" < {cap_gate:g}x gate (per-shard pools must not share)")
    if out["kv_highwater_ratio"] < hw_gate:
        failures.append(
            f"aggregate KV high-water {out['kv_highwater_ratio']:.2f}x "
            f"< {hw_gate:g}x gate (resident rows must spread across "
            "shards)")
    return failures


def main() -> str:
    t = run(verbose=False)
    us = t["wall_ms_sharded"] * 1e3 / t["n_tasks"]
    return csv_line(
        "sharding_bench", us,
        f"throughput={t['throughput_speedup']:.2f}x;"
        f"capacity={t['pool_capacity_ratio']:.1f}x")


def _maybe_reexec() -> None:
    """Re-exec under a forced host device count when the mesh needs
    more devices than jax would otherwise expose (same contract as
    tests/harness/simulate.py: a user-set count always wins)."""
    from repro.xla_flags import argv_int, reexec_with_host_devices
    argv = sys.argv[1:]
    reexec_with_host_devices(
        argv_int(argv, "--shards", 4),
        ["-m", "benchmarks.sharding_bench"] + argv)


if __name__ == "__main__":
    _maybe_reexec()
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="smaller stream for CI")
    ap.add_argument("--shards", type=int, default=4)
    args = ap.parse_args()
    out = run(n_tasks=24 if args.smoke else 48, shards=args.shards,
              verbose=True)
    failures = check(out)
    for f in failures:
        print(f"GATE FAILED: {f}", file=sys.stderr)
    sys.exit(1 if failures else 0)
