"""Kernel micro-benchmarks: wall-time of the jnp reference paths on CPU
(the Pallas kernels target TPU; interpret mode is a correctness tool,
not a perf proxy) at serving-relevant shapes."""
from __future__ import annotations

import time
from pathlib import Path

import jax
import jax.numpy as jnp

from benchmarks.common import csv_line, write_json
from repro.kernels import ref

OUT = Path("experiments/bench/kernels_micro.json")


def _time(fn, *args, iters: int = 10) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) \
        else fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6   # us


def run(verbose: bool = True) -> dict:
    key = jax.random.PRNGKey(0)
    out = {}

    # decode attention: llama3-8b decode_32k-like per-chip slice
    b, h, kv, dk, s = 8, 32, 8, 128, 4096
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, dk), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, dk), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, dk), jnp.float32)
    fn = jax.jit(lambda q, k, v: ref.decode_attention_ref(
        q, k, v, jnp.int32(s)))
    out["decode_attention_us"] = _time(fn, q, k, v)

    # selective scan: falcon-mamba chunk
    b, s2, d, n = 2, 1024, 512, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s2, d)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s2, d))) * 0.1
    alog = jax.random.normal(ks[2], (d, n)) * 0.3
    bi = jax.random.normal(ks[3], (b, s2, n))
    ci = jax.random.normal(ks[4], (b, s2, n))
    fn = jax.jit(ref.selective_scan_ref)
    out["selective_scan_us"] = _time(fn, x, dt, alog, bi, ci)

    # rglru scan
    a = jax.random.uniform(ks[0], (2, 1024, 512), minval=.8, maxval=.99)
    u = jax.random.normal(ks[1], (2, 1024, 512)) * 0.1
    fn = jax.jit(ref.rglru_scan_ref)
    out["rglru_scan_us"] = _time(fn, a, u)

    # fused swiglu
    x = jax.random.normal(ks[0], (1024, 1024), jnp.float32) * 0.5
    wg = jax.random.normal(ks[1], (1024, 2816)) * 0.02
    wu = jax.random.normal(ks[2], (1024, 2816)) * 0.02
    wd = jax.random.normal(ks[3], (2816, 1024)) * 0.02
    fn = jax.jit(ref.fused_swiglu_ref)
    out["fused_swiglu_us"] = _time(fn, x, wg, wu, wd)

    write_json(OUT, out)
    if verbose:
        for k_, v_ in out.items():
            print(f"  {k_:24s} {v_:10.1f}")
    return out


def main() -> str:
    t = run(verbose=False)
    return csv_line("kernels_micro", t["decode_attention_us"],
                    "ref_paths_cpu")


if __name__ == "__main__":
    run()
