"""Regenerate the data-driven sections of EXPERIMENTS.md from
experiments/dryrun/*.json and experiments/bench/*.json.

    PYTHONPATH=src:. python -m benchmarks.report_experiments

Writes experiments/generated/{dryrun.md,roofline.md,paper.md} — the
EXPERIMENTS.md tables are copies of these (regenerable from artifacts,
the paper's own reproducibility bar).
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.roofline import analyse_record

DRY = Path("experiments/dryrun")
BENCH = Path("experiments/bench")
OUT = Path("experiments/generated")


def _fmt_bytes(b: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PiB"


def dryrun_table() -> str:
    rows = []
    for f in sorted(DRY.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("rules", "default") != "default":
            continue      # SPerf variants live in experiments/perf
        arch, shape, mesh = rec["arch"], rec["shape"], rec["mesh"]
        if rec["status"] == "skipped":
            rows.append(f"| {arch} | {shape} | {mesh} | SKIP | "
                        f"{rec['reason'][:58]} |")
            continue
        if rec["status"] != "ok":
            rows.append(f"| {arch} | {shape} | {mesh} | FAIL | "
                        f"{rec['error'][:58]} |")
            continue
        mem = rec.get("memory", {})
        per_dev = (mem.get("argument_bytes", 0)
                   + mem.get("temp_bytes", 0)
                   + mem.get("output_bytes", 0))
        costs = rec.get("corrected") or rec["raw"]
        coll = costs["collective"]
        counts = rec["raw"]["collective"]["counts"]
        abbrev = {"all-reduce": "ar", "all-gather": "ag",
                  "reduce-scatter": "rs", "all-to-all": "a2a",
                  "collective-permute": "cp"}
        sched = "+".join(f"{abbrev[k]}:{v}"
                         for k, v in counts.items() if v)
        rows.append(
            f"| {arch} | {shape} | {mesh} | ok | "
            f"{_fmt_bytes(per_dev)}/dev, "
            f"{costs['hlo_flops'] * rec['chips']:.2e} FLOP, "
            f"coll {_fmt_bytes(coll['total'] * rec['chips'])} "
            f"[{sched or 'none'}], compile {rec['compile_s']}s |")
    hdr = ("| arch | shape | mesh | status | "
           "bytes/device · global FLOPs · collective schedule |\n"
           "|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def roofline_table() -> str:
    rows = []
    for f in sorted(DRY.glob("*__single.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok" \
                or rec.get("rules", "default") != "default":
            continue
        r = analyse_record(rec)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['bottleneck']}** | {r['useful_flops_ratio']:.2%} | "
            f"{r['advice'][:90]} |")
    hdr = ("| arch | shape | compute (s) | memory (s) | collective (s)"
           " | bottleneck | useful FLOPs | what moves it |\n"
           "|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def paper_tables() -> str:
    out = []
    t1 = json.loads((BENCH / "table1.json").read_text())
    out.append("### Table 1 (ours vs paper)\n")
    out.append("| configuration | accuracy (ours) | accuracy (paper) |"
               " cost (ours) |\n|---|---|---|---|")
    for n in ("single_model", "arena_2", "acar_u", "arena_3"):
        r = t1[n]
        out.append(f"| {n} | {r['accuracy']:.3f} | "
                   f"{r['paper_accuracy']:.3f} | ${r['cost']:.2f} |")
    out.append(f"\nclaims: {t1['claims']}\n")
    t2 = json.loads((BENCH / "table2.json").read_text())
    out.append("### Table 2 — retrieval (ACAR-UJ − ACAR-U)\n")
    out.append("| benchmark | delta (ours) | delta (paper) |\n"
               "|---|---|---|")
    for b in ("overall", "supergpqa", "livecodebench",
              "reasoning_gym", "matharena"):
        r = t2[b]
        out.append(f"| {b} | {r['delta']:+.3f} | "
                   f"{r['paper_delta']:+.3f} |")
    out.append(f"\nthreshold study: {t2['threshold_study']}\n")
    for name in ("fig1_sigma_dist", "fig5_escalation",
                 "fig6_cumulative", "fig7_latency", "fig9_similarity",
                 "attribution"):
        p = BENCH / f"{name}.json"
        if p.exists():
            out.append(f"### {name}\n```json\n"
                       f"{p.read_text()[:1200]}\n```\n")
    return "\n".join(out)


def main():
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "dryrun.md").write_text(dryrun_table() + "\n")
    (OUT / "roofline.md").write_text(roofline_table() + "\n")
    try:
        (OUT / "paper.md").write_text(paper_tables() + "\n")
    except FileNotFoundError as e:
        print(f"paper tables incomplete: {e}")
    print(f"wrote {OUT}/dryrun.md, roofline.md, paper.md")


if __name__ == "__main__":
    main()
