from repro.serving.compaction import (
    CompactionPlan, CompactionStats, MemberPlan, bucket_size,
    plan_compaction)
from repro.serving.engine import (
    BatchedACAREngine, BatchResult, QueuedServeResult, ZooModel,
    intern_answers, judge_batch)
from repro.serving.jax_backend import JaxModelBackend
from repro.serving.metrics import PromCounters
from repro.serving.queue import (
    AdmissionQueue, MicroBatch, MicroBatchPolicy, Request)
from repro.serving.scheduler import (
    ContinuousBatchingScheduler, ProbeCache, SchedulerStats)

__all__ = [
    "AdmissionQueue", "BatchedACAREngine", "BatchResult",
    "CompactionPlan", "CompactionStats", "ContinuousBatchingScheduler",
    "JaxModelBackend", "MemberPlan", "MicroBatch", "MicroBatchPolicy",
    "ProbeCache", "PromCounters", "QueuedServeResult", "Request",
    "SchedulerStats", "ZooModel", "bucket_size", "intern_answers",
    "judge_batch", "plan_compaction",
]
