from repro.serving.engine import (
    BatchedACAREngine, BatchResult, ZooModel, intern_answers,
    judge_batch)
from repro.serving.jax_backend import JaxModelBackend

__all__ = [
    "BatchedACAREngine", "BatchResult", "JaxModelBackend", "ZooModel",
    "intern_answers", "judge_batch",
]
