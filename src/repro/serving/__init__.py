from repro.serving.compaction import (
    CompactionPlan, CompactionStats, MemberPlan, bucket_size,
    plan_compaction)
from repro.serving.engine import (
    BatchedACAREngine, BatchResult, QueuedServeResult, ZooModel,
    intern_answers, judge_batch)
from repro.serving.jax_backend import JaxModelBackend
from repro.serving.kv_pool import (
    KVStats, PageAccountingError, PagePool, PagePoolError,
    PagedKVServer, PoolExhausted, ProbeHandle, dense_tile_slots,
    pages_for)
from repro.serving.mesh import ServingMesh, ShardedPagedKVServer
from repro.serving.metrics import PromCounters
from repro.serving.queue import (
    AdmissionQueue, MicroBatch, MicroBatchPolicy, Request)
from repro.serving.scheduler import (
    ContinuousBatchingScheduler, ProbeCache, SchedulerStats,
    StepPlanner)
from repro.serving.step_loop import (
    ShardedStepLoopRunner, StepLoopRunner, StepStats)
from repro.serving.tracing import NullTracer, SpanTracer

__all__ = [
    "AdmissionQueue", "BatchedACAREngine", "BatchResult",
    "CompactionPlan", "CompactionStats", "ContinuousBatchingScheduler",
    "JaxModelBackend", "KVStats", "MemberPlan", "MicroBatch",
    "MicroBatchPolicy", "NullTracer", "PageAccountingError",
    "PagePool", "PagePoolError", "PagedKVServer", "PoolExhausted",
    "ProbeCache", "ProbeHandle", "PromCounters", "QueuedServeResult",
    "Request", "SchedulerStats", "ServingMesh", "ShardedPagedKVServer",
    "ShardedStepLoopRunner", "SpanTracer", "StepLoopRunner",
    "StepPlanner", "StepStats", "ZooModel", "bucket_size",
    "dense_tile_slots", "intern_answers", "judge_batch", "pages_for",
    "plan_compaction",
]
