from repro.serving.engine import (
    BatchedACAREngine, BatchResult, QueuedServeResult, ZooModel,
    intern_answers, judge_batch)
from repro.serving.jax_backend import JaxModelBackend
from repro.serving.metrics import PromCounters
from repro.serving.queue import (
    AdmissionQueue, MicroBatch, MicroBatchPolicy, Request)
from repro.serving.scheduler import (
    ContinuousBatchingScheduler, ProbeCache, SchedulerStats)

__all__ = [
    "AdmissionQueue", "BatchedACAREngine", "BatchResult",
    "ContinuousBatchingScheduler", "JaxModelBackend", "MicroBatch",
    "MicroBatchPolicy", "ProbeCache", "PromCounters",
    "QueuedServeResult", "Request", "SchedulerStats", "ZooModel",
    "intern_answers", "judge_batch",
]
