"""Escalated-subset compaction for batched ensemble decodes.

ACAR's routing decision says most rows of a micro-batch need *no*
ensemble work (the paper's sigma=0 rate is 54.2%), yet a masked decode
pays for every row anyway. Compaction makes decode cost proportional to
what the router escalated: the ``sigma>0`` rows are gathered into a
dense sub-batch, padded up to a **power-of-two shape bucket** (so XLA
compiles at most log2(B)+1 decode shapes per member instead of one per
escalated-count), decoded, and the answers scattered back to their
full-batch positions. The judge sees bit-identical inputs: the same
rows produce the same answers (greedy decode is batch-composition
invariant for dense configs and for MoE configs using the
capacity-free gather dispatch — ``sampling.batch_invariant``), and
rows the mask would have discarded are simply never decoded.

This module is pure host-side planning + accounting, shared by the
real-model engine (serving/engine.py) and the scheduler's wave planner
(serving/scheduler.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np


def bucket_size(k: int, cap: Optional[int] = None) -> int:
    """Smallest power of two >= k (0 stays 0), clipped to ``cap`` —
    but never below k itself (callers pass cap >= k, e.g. the batch
    size when k counts escalated rows of that batch)."""
    if k <= 0:
        return 0
    b = 1 << (int(k) - 1).bit_length()
    if cap is not None and b > cap:
        b = max(cap, int(k))
    return b


@dataclass(frozen=True)
class MemberPlan:
    """Decode plan for one ensemble member over one micro-batch."""
    member: int
    rows: np.ndarray          # int64 indices of escalated rows
    bucket: int               # padded sub-batch size (0 = skip decode)

    @property
    def n_rows(self) -> int:
        return int(self.rows.size)

    @property
    def occupancy(self) -> float:
        return self.n_rows / self.bucket if self.bucket else 0.0

    def padded_rows(self) -> np.ndarray:
        """Gather indices padded to the bucket by replicating the first
        escalated row (a valid prompt, so padding rows decode real —
        discarded — work with no risk of degenerate inputs)."""
        if self.n_rows == 0:
            return self.rows
        pad = np.full(self.bucket - self.n_rows, self.rows[0],
                      self.rows.dtype)
        return np.concatenate([self.rows, pad])


@dataclass
class CompactionPlan:
    """Per-member decode plans plus the savings accounting."""
    batch: int
    members: List[MemberPlan]
    escalated_rows: int       # rows with modes >= 1 (arena_lite+)
    full_arena_rows: int      # rows with modes >= 2

    # -- decode accounting (row-steps; multiply by max_new_tokens for
    # tokens) -----------------------------------------------------------
    @property
    def compacted_decode_rows(self) -> int:
        return sum(m.bucket for m in self.members)

    @property
    def masked_decode_rows(self) -> int:
        """What the masked path decodes: the full batch for every
        member that has at least one escalated row."""
        return sum(self.batch for m in self.members if m.n_rows)

    @property
    def decode_rows_saved(self) -> int:
        return self.masked_decode_rows - self.compacted_decode_rows

    def decode_tokens(self, max_new_tokens: int) -> int:
        return self.compacted_decode_rows * max_new_tokens

    def decode_tokens_saved(self, max_new_tokens: int) -> int:
        return self.decode_rows_saved * max_new_tokens


def plan_compaction(modes: Sequence[int], n_members: int,
                    arena_lite_size: int,
                    max_bucket: Optional[int] = None) -> CompactionPlan:
    """Plan the escalated-subset decode for one micro-batch.

    modes: per-row mode ids (0=single_agent, 1=arena_lite,
    2=full_arena). Member ``mi`` decodes the rows with
    ``modes >= 1`` when it belongs to the arena-lite pair
    (mi < arena_lite_size) and the ``modes >= 2`` subset otherwise —
    the same predicate the masked path applies after decoding.
    """
    modes = np.asarray(modes)
    b = int(modes.shape[0])
    cap = b if max_bucket is None else min(max_bucket, b)
    members = []
    for mi in range(n_members):
        needed = modes >= (1 if mi < arena_lite_size else 2)
        rows = np.nonzero(needed)[0]
        members.append(MemberPlan(
            member=mi, rows=rows,
            bucket=bucket_size(int(rows.size), cap)))
    return CompactionPlan(
        batch=b, members=members,
        escalated_rows=int(np.sum(modes >= 1)),
        full_arena_rows=int(np.sum(modes >= 2)))


@dataclass
class CompactionStats:
    """Savings record for one served batch (engine) or wave (scheduler).

    Token counts are real decode-token units; FLOP figures use the
    2 * active_params * tokens dense-transformer estimate — the honest
    per-decode accounting the Unsolvability Ceiling study calls for.
    """
    batch: int = 0
    escalated_rows: int = 0
    full_arena_rows: int = 0
    ensemble_decode_tokens: int = 0
    ensemble_decode_tokens_saved: int = 0
    probe_prefill_tokens: int = 0
    probe_prefill_tokens_saved: int = 0
    probe_prefill_flops_saved: float = 0.0
    bucket_rows: List[int] = field(default_factory=list)
    bucket_sizes: List[int] = field(default_factory=list)

    def merge(self, other: "CompactionStats") -> None:
        self.batch += other.batch
        self.escalated_rows += other.escalated_rows
        self.full_arena_rows += other.full_arena_rows
        self.ensemble_decode_tokens += other.ensemble_decode_tokens
        self.ensemble_decode_tokens_saved += \
            other.ensemble_decode_tokens_saved
        self.probe_prefill_tokens += other.probe_prefill_tokens
        self.probe_prefill_tokens_saved += \
            other.probe_prefill_tokens_saved
        self.probe_prefill_flops_saved += other.probe_prefill_flops_saved
        self.bucket_rows.extend(other.bucket_rows)
        self.bucket_sizes.extend(other.bucket_sizes)

    @property
    def ensemble_decode_token_reduction(self) -> float:
        """masked / compacted decode-token ratio (>= 1)."""
        if self.ensemble_decode_tokens <= 0:
            return float("inf") if self.ensemble_decode_tokens_saved \
                else 1.0
        return (self.ensemble_decode_tokens
                + self.ensemble_decode_tokens_saved) \
            / self.ensemble_decode_tokens

    @property
    def probe_prefill_reduction(self) -> float:
        if self.probe_prefill_tokens <= 0:
            return 1.0
        return (self.probe_prefill_tokens
                + self.probe_prefill_tokens_saved) \
            / self.probe_prefill_tokens
