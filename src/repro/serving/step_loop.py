"""Step-level continuous batching for the ACAR serving engine.

The wave engine (serving/engine.py ``run_batch``/``run_queued``) is
lockstep: a micro-batch prefills in one shot, probe-decodes as one
fixed-length scan, and every ensemble wave stalls the batch until its
slowest member finishes — tail latency and the KV-page high-water are
set by the worst row, not by the router. This module replaces the
lockstep with an iteration-level loop: one logical tick advances a
*mixed* set of rows where each row is independently in

    prefill-chunk -> probe-decode -> route-pending -> ensemble-decode
                                                          -> done

Rows are admitted from ``AdmissionQueue.ready()`` the moment the page
budget opens (``StepPlanner.may_admit``), long prompts prefill in
fixed-size chunks appended to the paged KV pool
(``sampler.prefill_chunk_paged``), decodes of any phase mix into one
bucketed ``decode_megastep_rows`` program per (server, temperature)
that fuses up to ``StepPlanner.megastep`` ticks in a single launch,
and a finished row retires — and frees its pages — mid-stream,
without waiting for its batch.

Megastep decode: lane state (pending logits, positions, step
indices, done bits, key streams, block tables) stays device-resident
between launches — the only arrays pulled back per megastep are the
(K, B) emitted-token-id and done-bit stacks, which the host replays
lane by lane (a lane that finished or exhausted its budget at offset
t < K burns the remaining ticks as *masked* steps, counted in
``StepStats.masked_decode_steps``). Because sampling draws from
per-row key streams indexed by the per-row step counter, K is a pure
performance knob: K=1 *is* the per-tick baseline and any K emits
bit-identical token streams (``tests/harness/simulate.py
--megastep`` proves it for K in {1, 4, 16}, single-device and
sharded). Route-time sigma/judge extracts remain the only other host
touchpoint.

Determinism / auditability: the loop is bit-equivalent to the wave
engine, proven the same way PRs 1-3 proved their refactors
(``tests/harness/simulate.py --step-loop``: identical record hashes
and artifact-chain heads over a duplicate-bearing 200-task stream).
Three properties carry the proof:

* chunked prefill composes bit-identically with one-shot prefill
  (fixed key-axis reduction length — see
  ``models.transformer.prefill_chunk_paged``);
* sampling uses per-row key streams (``sample_token_rows``) keyed by
  admission index, so a row's draws are independent of which rows
  share its step batch — the wave path uses the same streams;
* every host decision (grouping, bucketing, admission, retirement)
  is a deterministic function of the admission order.

The virtual clock: one unit is one logical tick of device work (one
fused decode-tick iteration — a megastep launch charges its K fused
iterations, so the virtual clock measures device occupancy and stays
comparable across K; the launch-overhead win shows up in wall-clock,
gated by ``benchmarks/megastep_bench.py`` — or one prefill chunk of
``chunk_tokens`` tokens). Each model server is its own executor — ACAR's ensemble members are
independent services in the paper's deployment, and the wave engine
keeping them idle while it drains one member at a time is precisely
the lockstep cost this loop removes — so a tick advances the clock by
the *maximum* programs any single server launched, while programs on
the same server serialize. ``benchmarks/serving_bench.py`` charges
the simulated wave-lockstep timeline in the same units (its stages
are serial by construction: sum of per-stage program counts), so
step-vs-wave latency comparisons are apples to apples.
"""
from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.extract import extract, extract_batch
from repro.core.routing import degrade_mode
from repro.core.sigma import majority_vote_batch, sigma_batch
from repro.data import tokenizer as tok
from repro.sampling import sampler as S
from repro.serving.faults import FaultInjector, SimulatedCrash
from repro.serving.kv_pool import (
    PagedKVServer, PagePoolError, PoolExhausted, pages_for)
from repro.serving.metrics import (
    FAULTS_INJECTED, MEMBER_QUARANTINED, MEMBER_RETRIES, PromCounters,
    RECOVERY_ROWS_RESTORED, ROUTES_DEGRADED, ROW_DEADLINE_ABORTS,
    SHARD_STEALS, STEP_REQUEUES)
from repro.serving.queue import AdmissionQueue, Request
from repro.serving.scheduler import StepPlanner
from repro.teamllm.spans import make_trace_id
from repro.teamllm.trace import fault_record

PHASES = ("prefill", "probe_decode", "route_pending",
          "ensemble_decode", "done")


# ----------------------------------------------------------------------
# per-row state
# ----------------------------------------------------------------------
@dataclass
class _Lane:
    """One decode stream: a probe sample or one member's answer."""
    block_table: np.ndarray            # (NB,) page ids
    row_key: np.ndarray                # (2,) uint32 sampling stream
    # (V,) pending next-token logits — device-resident in the model's
    # native dtype (bf16 stays bf16; no host round-trip between ticks)
    logits: jax.Array
    tag: int = 0                       # deterministic within-row order
    steps: int = 0
    done: bool = False
    tokens: List[int] = field(default_factory=list)
    length: int = 0                    # live (pre-EOS) steps

    def harvest(self, max_new: int, pad_id: int) -> np.ndarray:
        out = np.full(max_new, pad_id, np.int32)
        out[:len(self.tokens)] = self.tokens
        return out


@dataclass
class _MemberExec:
    """One (row, member) ensemble execution."""
    member: int
    server: Optional[PagedKVServer]
    reuse: bool                        # seeded from the row's pages
    prefill_pos: int = 0
    from_cache: bool = False
    shared: Optional[np.ndarray] = None   # own prompt pages (non-reuse)
    tail: Optional[int] = None
    logits0: Optional[np.ndarray] = None
    tails: Optional[np.ndarray] = None    # decode tail pages
    lane: Optional[_Lane] = None
    answer: Optional[str] = None


@dataclass
class _Row:
    request: Request
    ids: np.ndarray                    # (S,) prompt token ids
    phase: str = "prefill"
    # probe-server prompt pages
    shared: Optional[np.ndarray] = None
    tail: Optional[int] = None
    from_cache: bool = False
    prefill_pos: int = 0
    logits0: Optional[np.ndarray] = None
    sample_tails: Optional[np.ndarray] = None     # (N, n_tail)
    lanes: List[_Lane] = field(default_factory=list)
    probe_texts: Optional[List[str]] = None
    probe_answers: Optional[List[str]] = None
    sigma: float = 0.0
    mode: int = 0
    members: List[_MemberExec] = field(default_factory=list)
    member_answers: Optional[List[Optional[str]]] = None
    final_answer: Optional[str] = None
    aborted: Optional[str] = None      # traced abort reason, or None
    admitted_at: int = 0
    retired_at: int = 0
    reserved: int = 0                  # probe-server pages still owed
    shard: int = 0                     # mesh shard hosting this row

    @property
    def admission(self) -> int:
        return self.request.admission_index

    @property
    def s(self) -> int:
        return int(self.ids.shape[0])


@dataclass
class StepStats:
    """Step-loop accounting. The virtual clock charges one unit per
    fused decode-tick iteration (a K-tick megastep launch costs K) or
    prefill chunk; ``launches`` counts actual device programs, and the
    ``decode_*`` transfer counters are the hook the megastep tests use
    to prove host<->device traffic per emitted token drops K-fold."""
    ticks: int = 0
    invocations: int = 0               # virtual-clock units charged
    launches: int = 0                  # device programs launched
    admissions: int = 0
    prefill_chunks: int = 0
    retired: int = 0
    # megastep accounting: ticks a lane sat masked because it finished
    # (or exhausted its budget) mid-megastep — the <=K-1 burn per row
    masked_decode_steps: int = 0
    decode_tokens: int = 0             # live tokens emitted by decode
    decode_h2d: int = 0                # host->device arrays per launch
    decode_d2h: int = 0                # device->host pulls per launch
    # fault-tolerance accounting
    restored: int = 0                  # rows restored from the journal
    requeues: int = 0                  # admissions requeued (alloc)
    retries: int = 0                   # member launch retries
    aborted: int = 0                   # rows retired with a null answer
    # per admission index: (arrival_tick, admitted_tick, retired_tick)
    timeline: Dict[int, Tuple[int, int, int]] = field(
        default_factory=dict)

    def latencies(self) -> np.ndarray:
        """Virtual-clock task latency (retire - arrival) per task."""
        return np.asarray([t[2] - t[0]
                           for t in self.timeline.values()], float)


class StepLoopRunner:
    """Executes the step-level loop over a ``BatchedACAREngine``'s
    models and paged-KV servers. One-shot: construct, ``run``."""

    def __init__(self, engine, queue: AdmissionQueue,
                 planner: StepPlanner,
                 metrics: Optional[PromCounters] = None, *,
                 faults: Optional[FaultInjector] = None,
                 journal=None,
                 recovered: Optional[Dict[int, dict]] = None,
                 tracer=None):
        self.eng = engine
        self.queue = queue
        self.planner = planner
        self.metrics = metrics if metrics is not None else PromCounters()
        self.stats = StepStats()
        self.acfg = engine.acfg
        self.n = engine.acfg.n_probe_samples
        self.max_new = engine.max_new_tokens
        self.megastep = planner.megastep
        self.base_key = jax.random.PRNGKey(engine.acfg.seed)
        # fault tolerance: every hook below is a single attribute
        # check when disabled, so the fault-free path pays nothing
        # (benchmarks/faults_bench.py gates the overhead)
        self.injector = faults
        self.journal = journal              # StepJournal, duck-typed
        self.recovered = dict(recovered) if recovered else None
        self.fault_events: List[dict] = []
        self._quarantined: set = set()
        self._displaced: List[_Row] = []
        # span tracing rides the same zero-cost discipline: a
        # disarmed or absent tracer normalises to None, so every
        # instrumentation site below is one attribute check
        # (benchmarks/obs_bench.py gates the armed overhead at <=3%)
        self.tracer = tracer if (tracer is not None
                                 and getattr(tracer, "armed", False)) \
            else None
        # escalated full-arena rows awaiting on-capacity
        # leave-one-out attribution (drained on idle ticks)
        self._attrib_queue: List[_Row] = []
        self._init_servers()
        self._reserved = 0                 # pages admitted rows may yet take
        self.active: List[_Row] = []
        self.done_rows: Dict[int, _Row] = {}
        self.now = 0
        # per-tick virtual-clock charges for work outside the grouped
        # device programs (dense-fallback members run whole
        # generations on their own executor)
        self._tick_extra: Dict[object, int] = {}
        self._routed_this_tick = 0

    def _init_servers(self) -> None:
        """Resolve the paged servers the loop allocates against. The
        sharded runner overrides this to build mesh-partitioned
        servers; everything downstream goes through the per-row
        ``_probe_server``/``_member_server`` hooks."""
        engine = self.eng
        self.probe_srv: PagedKVServer = \
            engine._stepped_server(engine.probe)
        if self.probe_srv is None:
            raise ValueError(
                "run_stepped requires a paged-capable probe model "
                "(models.transformer.resolve_layout)")
        self.page_size = self.probe_srv.page_size
        # one ensure_capacity_stream per distinct server; twin members
        # (same params as the probe) decode on the probe's server, so
        # its per-row worst case carries their seeded decode tails too
        self._servers: List[PagedKVServer] = [self.probe_srv]
        self._twins = 0
        for zm in engine.ensemble:
            srv = engine._stepped_server(zm)
            if srv is self.probe_srv and zm is not engine.probe:
                self._twins += 1
            elif srv is not None and srv not in self._servers:
                self._servers.append(srv)

    # -- placement hooks (the sharded runner overrides these) ----------
    def _probe_server(self, row: _Row) -> PagedKVServer:
        """The probe-model server hosting ``row``'s pages."""
        return self.probe_srv

    def _member_server(self, zm, row: _Row) -> Optional[PagedKVServer]:
        """The server a (row, member) execution allocates against.
        The stepped engine speaks every page layout (dense, quant,
        ring, lanes), so quantised-KV, sliding-window and recurrent
        members all get paged servers here."""
        return self.eng._stepped_server(zm)

    def _reuse_member(self, zm, row: _Row) -> bool:
        """Whether this member seeds its decode from the row's probe
        pages (twin params + compactable decode)."""
        return (self.eng._kv_reuse_member(zm, self.probe_srv)
                and self.eng._member_compactable(zm))

    def _group_key(self, srv) -> int:
        """Executor identity for device-program grouping and the
        virtual clock; the sharded runner collapses a server's shard
        views into one executor (one shard_map launch serves all)."""
        return id(srv)

    # -- geometry ------------------------------------------------------
    def _geometry(self, srv, s: int):
        """Per-layout page accounting for one row on ``srv`` (ring
        rows cap their snapshot at the window; a lanes row is one
        recurrent-state lane)."""
        return srv.row_geometry(s, self.max_new)

    def _row_need(self, s: int) -> int:
        """Worst-case probe-server pages one row may still allocate."""
        return self.probe_srv.stream_row_pages(
            s, self.n + max(self._twins, 1), self.max_new)

    def _unreserve(self, row: _Row, pages: int) -> None:
        pages = min(pages, row.reserved)
        row.reserved -= pages
        self._reserved -= pages

    # -- span tracing --------------------------------------------------
    def _trace_id(self, row: _Row) -> str:
        return make_trace_id(row.request.request_id, row.admission)

    def _kv_reuse_span(self, model: str, row: _Row, kind: str,
                       key=None) -> None:
        """PROV raw material: a ``wasDerivedFrom`` edge — KV state
        seeded from retained pages instead of recomputation.
        ``kind='prefix'`` names the donor trace whose prefill
        populated the cache entry (recorded at insert, first writer in
        admission order); ``kind='probe'`` marks a member decode
        seeded from the row's own probe prompt pages."""
        trace = self._trace_id(row)
        if kind == "probe":
            src, src_span = trace, None
        else:
            owner = self.tracer.kv_source(
                model, hashlib.sha256(row.ids.tobytes()).hexdigest())
            src = owner[0] if owner else None
            src_span = owner[1] if owner else None
        self.tracer.span("kv_reuse", trace, self.now, key=key,
                         kind=kind, model=model, source=src,
                         source_span=src_span)

    # -- fault handling ------------------------------------------------
    def _fired(self, site: str, **match) -> bool:
        """Did an injected fault fire at this site this step? Every
        firing is counted and traced (and journaled when a journal is
        attached). Fault coordinates match on the loop's iteration
        counter, so a replayed run fires identically."""
        if self.injector is None:
            return False
        if self.injector.fire(site, self.stats.ticks, **match) is None:
            return False
        self.metrics.inc(FAULTS_INJECTED, site=site,
                         help="injected faults fired, by site")
        self._trace_fault("injected", site=site, **match)
        return True

    def _trace_fault(self, kind: str, **fields) -> None:
        """Record a fault-path event: collected on the runner (the
        engine appends them to the decision-trace artifact chain as
        fully-hashed records) and mirrored into the journal."""
        rec = fault_record(kind, self.now, **fields)
        self.fault_events.append(rec)
        if self.journal is not None:
            self.journal.fault(rec, self.now)

    def _fault_tick(self) -> None:
        """Tick-boundary fault checks: process kill, shard loss, and
        per-row SLO deadlines. Runs right after admission so a crash
        tick is a clean transaction boundary."""
        if self._fired("crash"):
            raise SimulatedCrash(
                f"injected process kill at step-loop tick "
                f"{self.stats.ticks}")
        self._shard_faults()
        ddl = self.injector.plan.slo_deadline
        if ddl is not None:
            for row in list(self.active):
                if self.now - row.request.arrival_time > ddl:
                    self._abort_row(row, "slo_deadline")

    def _shard_faults(self) -> None:
        """Shard-loss checks — meaningful only on the sharded runner."""

    def _member_fault_gate(self, items) -> int:
        """Pre-launch injected faults for one member decode group:
        bounded retries with exponential virtual-clock backoff, then
        quarantine on exhaustion or injected NaN logits. Faults fire
        *before* the real launch (which has no side effects yet), so a
        retried group re-launches bit-identically — fault handling
        never moves token streams. Returns the backoff penalty in
        virtual-clock units, or -1 when the group was quarantined (the
        launch must be skipped)."""
        if not all(it[2].tag >= 100 for it in items):
            return 0                   # probe lanes mixed in: not a
        model = items[0][0].stats.model  # member group
        plan = self.injector.plan
        penalty = 0
        retries = 0
        while self._fired("member_launch", model=model):
            retries += 1
            self.stats.retries += 1
            self.metrics.inc(MEMBER_RETRIES, model=model,
                             help="member decode-group launch retries")
            penalty += plan.backoff_base << (retries - 1)
            self._trace_fault("member_retry", model=model,
                              attempt=retries)
            if self.tracer is not None:
                for it in items:
                    self.tracer.span(
                        "member_retry", self._trace_id(it[1]),
                        self.now, key=("m", it[2].tag - 100),
                        model=model, attempt=retries)
            if retries > plan.max_retries:
                self._quarantine_group(items, model,
                                       "launch_retries_exhausted")
                return -1
        if self._fired("member_nan", model=model):
            self._quarantine_group(items, model, "nan_logits")
            return -1
        return penalty

    def _quarantine_group(self, items, model: str, reason: str) -> None:
        """Quarantine every ensemble member decoding in this group and
        degrade all in-flight routes over the remaining healthy
        members. Completed answers are kept; only unanswered
        executions are dropped."""
        members = sorted({it[2].tag - 100 for it in items})
        for mi in members:
            if mi in self._quarantined:
                continue
            self._quarantined.add(mi)
            self.metrics.set_gauge(
                MEMBER_QUARANTINED, 1.0,
                model=self.eng.ensemble[mi].name,
                help="1 while an ensemble member is quarantined")
            self._trace_fault("member_quarantined", member=mi,
                              model=self.eng.ensemble[mi].name,
                              reason=reason)
            if self.tracer is not None:
                # fleet-scoped span: quarantine is not row state
                self.tracer.span(
                    "member_quarantined", "fleet", self.now,
                    member=mi, model=self.eng.ensemble[mi].name,
                    reason=reason)
        for row in list(self.active):
            if row.phase == "ensemble_decode":
                self._degrade_row(row)

    def _apply_degraded_mode(self, row: _Row) -> None:
        """Degrade a row's route over the healthy members (the
        routing ladder in ``core.routing.degrade_mode``); a row left
        with no members falls back to the probe consensus."""
        healthy = [mi not in self._quarantined
                   for mi in range(len(self.eng.ensemble))]
        new_mode = degrade_mode(row.mode, healthy,
                                self.acfg.arena_lite_size)
        if row.phase == "ensemble_decode" and not row.members:
            new_mode = 0
        if new_mode != row.mode:
            self.metrics.inc(
                ROUTES_DEGRADED, 1.0,
                help="routes degraded over quarantined members",
                **{"from": str(row.mode), "to": str(new_mode)})
            self._trace_fault("route_degraded",
                              admission=row.admission,
                              **{"from": row.mode, "to": new_mode})
            if self.tracer is not None:
                self.tracer.span("route_degraded",
                                 self._trace_id(row), self.now,
                                 **{"from": row.mode, "to": new_mode})
            row.mode = new_mode

    def _degrade_row(self, row: _Row) -> None:
        """Drop a row's unanswered executions on quarantined members
        and re-judge under the degraded mode."""
        dropped = [mx for mx in row.members
                   if mx.member in self._quarantined
                   and mx.answer is None]
        if not dropped:
            return
        for mx in dropped:
            self._abort_member_exec(row, mx)
            row.members.remove(mx)
        self._apply_degraded_mode(row)
        if not row.members:
            # every member dropped: the probe consensus is final
            self._release_prompt(self._probe_server(row), row)
            self._judge(row)
            self._retire(row)
        else:
            self._finish_members(row)

    def _abort_member_exec(self, row: _Row, mx: _MemberExec) -> None:
        """Release one (row, member) execution's pages mid-flight. The
        lane object may still sit in this tick's precomputed decode
        groups; marking it done masks it in any launch that follows."""
        srv = self._probe_server(row) if mx.reuse else mx.server
        if mx.lane is not None:
            mx.lane.done = True
            mx.lane = None
        if mx.tails is not None:
            srv.pool.release(mx.tails)
            mx.tails = None
        if not mx.reuse and mx.shared is not None:
            self._release_prompt(srv, mx)
        if srv is not None:
            srv._sample_usage()

    def _abort_row(self, row: _Row, reason: str) -> None:
        """Retire a row with a null answer and a traced abort reason,
        releasing everything it holds (SLO deadline, dead fleet)."""
        srv = self._probe_server(row)
        if row.sample_tails is not None:
            srv.pool.release(row.sample_tails.reshape(-1))
            row.sample_tails = None
        for lane in row.lanes:
            lane.done = True
        row.lanes = []
        for mx in row.members:
            if mx.answer is None:
                self._abort_member_exec(row, mx)
        row.members = []
        self._release_prompt(srv, row)
        row.probe_texts = row.probe_texts or []
        row.probe_answers = row.probe_answers or []
        if row.member_answers is None:
            row.member_answers = [None] * len(self.eng.ensemble)
        row.final_answer = None
        row.aborted = reason
        self.stats.aborted += 1
        if reason == "slo_deadline":
            self.metrics.inc(ROW_DEADLINE_ABORTS,
                             help="rows aborted past their SLO "
                                  "deadline")
        self._trace_fault("row_aborted", admission=row.admission,
                          reason=reason)
        if self.tracer is not None:
            self.tracer.span("abort", self._trace_id(row), self.now,
                             reason=reason)
        self._retire(row)

    def _rollback_admission(self, row: _Row) -> None:
        """Undo a partially-allocated admission (``PoolExhausted``
        mid ``_begin_prefill``): release whatever was retained or
        allocated and return the row's page reservation."""
        srv = self._probe_server(row)
        if row.sample_tails is not None:
            srv.pool.release(row.sample_tails.reshape(-1))
            row.sample_tails = None
        row.lanes = []
        self._release_prompt(srv, row)
        row.from_cache = False
        row.prefill_pos = 0
        row.logits0 = None
        row.phase = "prefill"
        self._unreserve(row, row.reserved)
        self.stats.timeline.pop(row.admission, None)

    def _try_begin_prefill(self, row: _Row) -> bool:
        """Admission-time allocation with ``PoolExhausted`` rollback:
        the row is requeued at the head of the queue *keeping its
        admission index*, so its sampling key streams — and therefore
        its tokens — are unchanged when it re-admits."""
        try:
            if self._fired("admit_alloc"):
                raise PoolExhausted(
                    "injected admission-time pool exhaustion")
            self._begin_prefill(row)
            return True
        except PoolExhausted:
            self._rollback_admission(row)
            self.queue.requeue(row.request)
            self.stats.requeues += 1
            self.metrics.inc(
                STEP_REQUEUES,
                help="admissions requeued on PoolExhausted")
            self._trace_fault("requeued", admission=row.admission)
            if self.tracer is not None:
                # the re-admission's admit span parents on this one:
                # one trace spans the requeue
                self.tracer.span("requeued", self._trace_id(row),
                                 self.now)
            return False

    def _restore_head(self) -> bool:
        """Crash recovery: restore the queue head verbatim from its
        journaled retirement. Retired rows are *not* a prefix of the
        admission order (later rows retire first all the time), so
        this is checked per-head inside the admission loop, bypassing
        the ready()/arrival gating — restoration is instantaneous
        host work."""
        head = self.queue.peek()
        idx = head.admission_index
        if idx is None:
            idx = self.queue.next_admission_index
        rec = self.recovered.get(idx)
        if rec is None:
            return False
        del self.recovered[idx]
        req = self.queue.pop()
        row = _Row(request=req, ids=np.zeros(0, np.int32),
                   phase="done", sigma=float(rec["sigma"]),
                   mode=int(rec["mode"]),
                   probe_texts=list(rec["probe_texts"]),
                   probe_answers=list(rec["probe_answers"]),
                   member_answers=list(rec["member_answers"]),
                   final_answer=rec["final_answer"],
                   aborted=rec.get("aborted"))
        self.stats.timeline[idx] = tuple(rec["timeline"])
        self.stats.retired += 1
        self.stats.restored += 1
        self.done_rows[idx] = row
        self.metrics.inc(
            RECOVERY_ROWS_RESTORED,
            help="rows restored verbatim from the step journal")
        if self.tracer is not None:
            # span continuity across crash->recover: the restored
            # trace re-materialises from its journaled retirement (a
            # restore span parenting a retire span), so every admitted
            # task still ends in a retire span after a journal replay
            trace = self._trace_id(row)
            self.tracer.span("restore", trace, self.now,
                             task_id=req.task.task_id,
                             sigma=row.sigma, mode=row.mode)
            self.tracer.span("retire", trace, self.now,
                             task_id=req.task.task_id,
                             final_answer=row.final_answer,
                             sigma=row.sigma, mode=row.mode,
                             aborted=row.aborted, restored=1)
            if (getattr(self.tracer, "attribution", False)
                    and row.mode >= 2 and row.aborted is None
                    and row.member_answers is not None):
                self._attrib_queue.append(row)
        return True

    # -- admission -----------------------------------------------------
    def _admit_ready(self) -> None:
        while len(self.queue):
            if self.recovered and self._restore_head():
                continue
            if not self.queue.ready(self.now):
                break
            head = self.queue.peek()
            if head.arrival_time > self.now:
                break
            ids = tok.encode_aligned([head.task.text])[0]
            s = int(ids.shape[0])
            try:
                self.probe_srv.ensure_capacity_stream(
                    self.planner.max_active_rows, s,
                    self.n + max(self._twins, 1), self.max_new)
                for srv in self._servers[1:]:
                    srv.ensure_capacity_stream(
                        self.planner.max_active_rows, s, 1,
                        self.max_new)
            except PagePoolError:
                # a longer prompt needs a bigger pool, which can only
                # rebuild while no pages are held: defer admission
                # until the active rows drain instead of failing the
                # stream (progress is guaranteed — retirement frees
                # pages every tick, and an idle pool always rebuilds)
                if self.active:
                    break
                raise
            if not self.planner.may_admit(
                    len(self.active), self.probe_srv.pool.free_pages,
                    self._reserved, self._row_need(s)):
                break
            req = self.queue.pop()
            row = _Row(request=req, ids=ids, admitted_at=self.now,
                       reserved=self._row_need(s))
            self._reserved += row.reserved
            self.stats.timeline[row.admission] = (
                req.arrival_time, self.now, -1)
            if self.tracer is not None:
                self.tracer.span("admit", self._trace_id(row),
                                 self.now, prompt_tokens=s,
                                 arrival=req.arrival_time)
            if not self._try_begin_prefill(row):
                break
            self.active.append(row)
            self.stats.admissions += 1
            self.metrics.inc("acar_step_admissions_total",
                             help="rows admitted into the step loop")
            if self.journal is not None:
                self.journal.admit(row.admission, req.request_id,
                                   self.now)

    def _begin_prefill(self, row: _Row) -> None:
        srv = self._probe_server(row)
        s = row.s
        g = self._geometry(srv, s)
        entry = srv._prefix_lookup(row.ids.tobytes())
        if entry is not None:
            srv.pool.retain(entry.shared)
            if entry.tail is not None:
                srv.pool.retain([entry.tail])
            row.shared = entry.shared.copy()
            row.tail = entry.tail
            row.logits0 = entry.logits0.copy()
            row.from_cache = True
            row.prefill_pos = s
            srv.stats.prefill_tokens_reused_prefix += s
            if self.tracer is not None:
                self._kv_reuse_span(srv.stats.model, row, "prefix")
            self._unreserve(row, g.nbp)
            self._begin_probe_decode(row)
            return
        pages = srv._alloc_retry(g.nbp)
        if g.n_shared or g.tail_tokens:
            row.shared = pages[:g.n_shared]
            row.tail = int(pages[g.n_shared]) if g.tail_tokens \
                else None
        else:
            # ring / lanes: the whole allocation is this row's private
            # snapshot — there is no read-only shared prefix to alias
            row.shared = pages
            row.tail = None
        self._unreserve(row, g.nbp)

    def _begin_probe_decode(self, row: _Row) -> None:
        srv = self._probe_server(row)
        s = row.s
        g = self._geometry(srv, s)
        row.sample_tails = srv._alloc_retry(
            self.n * g.n_tail).reshape(self.n, g.n_tail)
        self._unreserve(row, self.n * g.n_tail)
        keys = np.asarray(S.probe_row_keys(
            self.base_key, [row.admission], self.n))
        for j in range(self.n):
            table = np.empty(g.nb, np.int32)
            if g.n_shared:
                table[:g.n_shared] = row.shared
            table[g.n_shared:] = row.sample_tails[j]
            row.lanes.append(_Lane(block_table=table, row_key=keys[j],
                                   logits=row.logits0.copy(), tag=j))
        if g.tail_tokens:
            self._fork(srv, [row.tail] * self.n,
                       row.sample_tails[:, 0].tolist())
            srv.stats.cow_forks += self.n
        elif g.n_shared == 0:
            # ring / lanes: every page of the prompt snapshot is
            # written during decode (ring wraps in place, lane state
            # mutates every tick), so each probe sample forks the
            # whole snapshot into its private pages
            src = np.repeat(row.shared[None], self.n,
                            axis=0).reshape(-1)
            self._fork(srv, src.tolist(),
                       row.sample_tails.reshape(-1).tolist())
            srv.stats.cow_forks += self.n * g.nbp
        row.phase = "probe_decode"
        srv._sample_usage()

    # -- page plumbing -------------------------------------------------
    def _fork(self, srv: PagedKVServer, src: Sequence[int],
              dst: Sequence[int]) -> None:
        import jax.numpy as jnp
        srv.pages = S.fork_pages(
            srv.pages,
            jnp.asarray(np.asarray(src, np.int32)),
            jnp.asarray(np.asarray(dst, np.int32)))

    def _release_prompt(self, srv: PagedKVServer, row_or_mx) -> None:
        if row_or_mx.shared is not None:
            srv.pool.release(row_or_mx.shared)
            if row_or_mx.tail is not None:
                srv.pool.release([row_or_mx.tail])
            row_or_mx.shared = None
            row_or_mx.tail = None
        srv._sample_usage()

    # -- prefill step --------------------------------------------------
    def _prefill_groups(self):
        """Group rows/member-execs needing prefill work by
        (server, chunk_len, prompt_len). Per-row start offsets are
        *traced* in the chunk program, so rows at different prefill
        depths — freshly admitted rows next to members that escalated
        ticks ago — share one device launch. Servers whose layout
        cannot compose chunk-by-chunk (quant re-reads quantised
        prefixes, ring wraps in place, lanes is one recurrent scan —
        ``PagedKVServer.chunked``) group under the ``c == -1``
        sentinel and prefill one-shot instead."""
        groups: Dict[tuple, list] = {}
        for row in self.active:
            if row.phase == "prefill":
                srv = self._probe_server(row)
                c = self.planner.chunk_span(row.prefill_pos, row.s) \
                    if srv.chunked else -1
                key = (self._group_key(srv), c, row.s)
                groups.setdefault(key, []).append((srv, row, None))
            elif row.phase == "ensemble_decode":
                for mx in row.members:
                    if (mx.answer is None and not mx.reuse
                            and mx.lane is None and not mx.from_cache
                            and mx.prefill_pos < row.s):
                        c = self.planner.chunk_span(
                            mx.prefill_pos, row.s) \
                            if mx.server.chunked else -1
                        key = (self._group_key(mx.server), c, row.s)
                        groups.setdefault(key, []).append(
                            (mx.server, row, mx))
        return groups

    def _run_prefill_group(self, key, items) -> int:
        import jax.numpy as jnp
        _, c, s = key
        if c < 0:
            return self._run_one_shot_prefill_group(key, items)
        t0 = time.perf_counter() if self.tracer is not None else 0.0
        srv = items[0][0]
        ps = srv.page_size
        nbp = pages_for(s, ps)
        rows = sorted(items, key=lambda it: it[1].admission)
        bucket = self.planner.decode_bucket(len(rows))
        tokens = np.empty((bucket, c), np.int32)
        tables = np.empty((bucket, nbp), np.int32)
        starts = np.zeros(bucket, np.int32)
        for i in range(bucket):
            srv_i, row, mx = rows[min(i, len(rows) - 1)]
            target = mx if mx is not None else row
            starts[i] = target.prefill_pos
            tokens[i] = row.ids[starts[i]:starts[i] + c]
            if i < len(rows):
                tables[i, :target.shared.size] = target.shared
                if target.tail is not None:
                    tables[i, -1] = target.tail
            else:
                tables[i] = srv._scratch[:nbp]
        zm = self._server_model(srv)
        lg, srv.pages = S.prefill_chunk_paged(
            zm.cfg, zm.params, jnp.asarray(tokens), srv.pages,
            jnp.asarray(tables), jnp.asarray(starts),
            prompt_len=s)
        srv.stats.prefill_tokens_computed += bucket * c
        srv.stats.prefill_chunks += 1
        self.stats.prefill_chunks += 1
        self.metrics.inc("acar_prefill_chunks_total",
                         model=srv.stats.model,
                         help="chunked-prefill device programs run")
        self.stats.launches += 1
        # chunk-final logits stay on device in the model's native
        # dtype (a bf16 member's lane state is bf16 end-to-end; the
        # old np.float32 host cast silently widened it while the
        # device path stayed bf16)
        for i, (srv_i, row, mx) in enumerate(rows):
            target = mx if mx is not None else row
            start0 = int(starts[i])
            target.prefill_pos = start0 + c
            sid = None
            if self.tracer is not None:
                sid = self.tracer.span(
                    "prefill_chunk", self._trace_id(row), self.now,
                    key=None if mx is None else ("m", mx.member),
                    model=srv.stats.model, start=start0, tokens=c)
            if target.prefill_pos == s:
                target.logits0 = lg[i]
                # publish to the server's prefix cache (cost-aware
                # eviction keys off tokens-saved-per-page)
                srv._prefix_insert(row.ids.tobytes(), target.shared,
                                   target.tail, lg[i], tokens=s)
                if sid is not None:
                    self.tracer.kv_insert(
                        srv.stats.model,
                        hashlib.sha256(row.ids.tobytes()).hexdigest(),
                        self._trace_id(row), sid)
        if self.tracer is not None:
            self.metrics.observe(
                "acar_span_duration", time.perf_counter() - t0,
                phase="prefill",
                help="host wall seconds per traced lifecycle phase")
        return 1

    def _run_one_shot_prefill_group(self, key, items) -> int:
        """One whole-prompt prefill launch for a non-chunkable layout
        (quant / ring / lanes). The prompt math is the dense
        ``T.prefill`` scan bit-for-bit — only the state parking
        differs — and the virtual clock is charged the same
        ``chunk_count(s)`` units the chunked path would pay, so
        layout choice never moves the latency accounting."""
        import jax.numpy as jnp
        _, _, s = key
        t0 = time.perf_counter() if self.tracer is not None else 0.0
        srv = items[0][0]
        g = self._geometry(srv, s)
        rows = sorted(items, key=lambda it: it[1].admission)
        bucket = self.planner.decode_bucket(len(rows))
        tokens = np.zeros((bucket, s), np.int32)
        tables = np.empty((bucket, g.nbp), np.int32)
        for i in range(bucket):
            if i < len(rows):
                srv_i, row, mx = rows[i]
                target = mx if mx is not None else row
                tokens[i] = row.ids
                tables[i, :target.shared.size] = target.shared
                if target.tail is not None:
                    tables[i, -1] = target.tail
            else:
                # pad rows prefill zeros into scratch pages
                tables[i] = srv._scratch[:g.nbp]
        zm = self._server_model(srv)
        if srv.layout == "lanes":
            lg, srv.pages = S.prefill_lanes(
                zm.cfg, zm.params, jnp.asarray(tokens), srv.pages,
                jnp.asarray(tables[:, 0]))
        else:
            cl = g.cache_len if srv.layout == "ring" else None
            lg, srv.pages = S.prefill_paged(
                zm.cfg, zm.params, jnp.asarray(tokens), srv.pages,
                jnp.asarray(tables), cache_len=cl)
        srv.stats.prefill_tokens_computed += bucket * s
        self.metrics.inc("acar_prefill_oneshot_total",
                         model=srv.stats.model,
                         help="one-shot prefill device programs run "
                              "for non-chunkable page layouts")
        self.stats.launches += 1
        for i, (srv_i, row, mx) in enumerate(rows):
            target = mx if mx is not None else row
            target.prefill_pos = s
            target.logits0 = lg[i]
            srv._prefix_insert(row.ids.tobytes(), target.shared,
                               target.tail, lg[i], tokens=s)
            if self.tracer is not None:
                sid = self.tracer.span(
                    "prefill_chunk", self._trace_id(row), self.now,
                    key=None if mx is None else ("m", mx.member),
                    model=srv.stats.model, start=0, tokens=s,
                    oneshot=1)
                self.tracer.kv_insert(
                    srv.stats.model,
                    hashlib.sha256(row.ids.tobytes()).hexdigest(),
                    self._trace_id(row), sid)
        if self.tracer is not None:
            self.metrics.observe(
                "acar_span_duration", time.perf_counter() - t0,
                phase="prefill",
                help="host wall seconds per traced lifecycle phase")
        return self.planner.chunk_count(s)

    def _server_model(self, srv: PagedKVServer):
        if srv is self.probe_srv:
            return self.eng.probe
        for zm in self.eng.ensemble:
            if self.eng._stepped_server(zm) is srv:
                return zm
        raise KeyError("server has no model")

    # -- decode step ---------------------------------------------------
    def _decode_groups(self):
        """Group live lanes by (server, temperature, cache_len)."""
        groups: Dict[tuple, list] = {}
        for row in self.active:
            cache_len = row.s + self.max_new
            if row.phase == "probe_decode":
                srv = self._probe_server(row)
                for lane in row.lanes:
                    if not lane.done and lane.steps < self.max_new:
                        key = (self._group_key(srv),
                               self.acfg.probe_temperature, cache_len)
                        groups.setdefault(key, []).append(
                            (srv, row, lane))
            elif row.phase == "ensemble_decode":
                for mx in row.members:
                    lane = mx.lane
                    if (lane is not None and not lane.done
                            and lane.steps < self.max_new):
                        srv = self._probe_server(row) if mx.reuse \
                            else mx.server
                        key = (self._group_key(srv),
                               self.acfg.ensemble_temperature,
                               cache_len)
                        groups.setdefault(key, []).append(
                            (srv, row, lane))
        return groups

    def _megastep_span(self, lanes) -> int:
        """Fused ticks for one decode group. Fixed-K mode caps the
        planner's K by the group's longest remaining budget so no
        launch runs ticks *every* lane would mask. Auto mode
        (``StepPlanner.megastep_auto``) caps by the *shortest*
        remaining budget instead: no lane can overrun its budget
        mid-launch, so the masked-step burn from budget exhaustion
        drops to zero (only early EOS still masks — unknowable before
        the launch). Any deterministic span emits bit-identical
        tokens: sampling keys are (row_key, step)-indexed, so K is a
        pure performance knob. Every grouped lane is live
        (steps < max_new), so the span is always >= 1."""
        budgets = [self.max_new - l.steps for l in lanes]
        cap = min(budgets) if self.planner.megastep_auto \
            else max(budgets)
        return max(1, min(self.megastep, cap))

    def _replay_megastep(self, lane: _Lane, emits, dones, kl: int,
                         i: int) -> None:
        """Host replay of one lane's (K,) emit/done columns — exactly
        the per-tick group-membership rule: a lane already done (or
        past its budget) at offset t would not have been launched at
        tick t, so its emission is masked and counted."""
        for t in range(kl):
            if lane.done or lane.steps >= self.max_new:
                self.stats.masked_decode_steps += 1
                continue
            lane.tokens.append(int(emits[t, i]))
            lane.length += 1
            lane.steps += 1
            lane.done = bool(dones[t, i])
            self.stats.decode_tokens += 1

    def _run_decode_group(self, key, items) -> int:
        import jax.numpy as jnp
        _, temperature, cache_len = key
        t0 = time.perf_counter() if self.tracer is not None else 0.0
        srv = items[0][0]
        nb = srv.table_width(cache_len - self.max_new, self.max_new)
        ordered = sorted(items, key=lambda it: (it[1].admission,
                                                it[2].tag))
        lanes = [it[2] for it in ordered]
        penalty = 0
        if self.injector is not None:
            penalty = self._member_fault_gate(ordered)
            if penalty < 0:
                return 0               # group quarantined pre-launch
        tok0 = [len(l.tokens) for l in lanes] \
            if self.journal is not None else None
        bucket = self.planner.decode_bucket(len(lanes))
        k = len(lanes)
        kl = self._megastep_span(lanes)
        tables = np.empty((bucket, nb), np.int32)
        pos = np.empty(bucket, np.int32)
        keys = np.empty((bucket, 2), np.uint32)
        steps = np.empty(bucket, np.int32)
        done = np.zeros(bucket, bool)
        for i in range(bucket):
            lane = lanes[min(i, k - 1)]
            tables[i] = lane.block_table if i < k else srv._scratch[:nb]
            pos[i] = cache_len - self.max_new + lane.steps
            keys[i] = lane.row_key
            steps[i] = lane.steps
            # pad rows emit pads into scratch; a lane a quarantine
            # dropped earlier this tick decodes masked (its pages are
            # already released)
            done[i] = i >= k or lane.done
        # lane logits never left the device: stacking slices of the
        # previous megastep's next_logits is a device-side gather
        logits = jnp.stack([lanes[min(i, k - 1)].logits
                            for i in range(bucket)])
        zm = self._server_model(srv)
        (emits, dones, next_logits,
         srv.pages) = S.decode_megastep_rows(
            zm.cfg, zm.params, logits, srv.pages,
            jnp.asarray(tables), jnp.asarray(pos), jnp.asarray(keys),
            jnp.asarray(steps), jnp.asarray(done), n_ticks=kl,
            cache_len=cache_len, temperature=temperature,
            eos_id=tok.EOS, pad_id=tok.PAD)
        # the megastep's only host pulls: (K, B) token ids + done bits
        emits = np.asarray(emits)
        dones = np.asarray(dones)
        self.stats.launches += 1
        self.stats.decode_h2d += 5     # tables, pos, keys, steps, done
        self.stats.decode_d2h += 2     # emits, dones
        if (self.injector is not None
                and all(l.tag >= 100 for l in lanes)
                and not np.isfinite(np.asarray(
                    next_logits[:k], np.float32)).all()):
            # genuine non-finite member logits: discard the launch
            # (lane state is untouched) and quarantine — only checked
            # while an injector is attached, so the fault-free path
            # never pays the extra device sync
            self._quarantine_group(ordered, srv.stats.model,
                                   "nan_logits")
            return kl + penalty
        for i, lane in enumerate(lanes):
            self._replay_megastep(lane, emits, dones, kl, i)
            lane.logits = next_logits[i]
        if self.tracer is not None:
            # one span per (row, lane) per megastep launch; lane
            # streams chain launch-to-launch, parented on the row
            # lifecycle (probe lanes) or the member launch (members)
            for it, lane in zip(ordered, lanes):
                probe = lane.tag < 100
                self.tracer.span(
                    "probe_decode" if probe else "member_decode",
                    self._trace_id(it[1]), self.now,
                    key=("p", lane.tag) if probe
                    else ("m", lane.tag - 100),
                    member=None if probe else lane.tag - 100,
                    model=srv.stats.model, ticks=kl,
                    steps=lane.steps, done=int(lane.done))
            d = time.perf_counter() - t0
            self.metrics.observe(
                "acar_span_duration", d,
                phase="probe_decode" if lanes[0].tag < 100
                else "ensemble_decode",
                help="host wall seconds per traced lifecycle phase")
            self.metrics.observe(
                "acar_decode_launch_seconds", d,
                server=srv.stats.model,
                help="wall seconds per megastep decode launch")
        if self.journal is not None:
            self.journal.emit(self.now, srv.stats.model, [
                [it[1].admission, lane.tag, lane.steps,
                 int(lane.done), lane.tokens[tok0[i]:]]
                for i, (it, lane) in enumerate(zip(ordered, lanes))])
        self.metrics.set_gauge(
            "acar_step_bucket_occupancy", k / bucket,
            server=srv.stats.model, bucket=str(bucket),
            help="live-lane fill of the last step-decode bucket")
        return kl + penalty

    # -- phase transitions ---------------------------------------------
    def _promote(self) -> None:
        """Host-side transitions after this tick's device work."""
        # prefill finished -> probe decode
        for row in self.active:
            if row.phase == "prefill" and row.prefill_pos == row.s:
                self._begin_probe_decode(row)
        # probe decode finished -> route
        resolved = [row for row in self.active
                    if row.phase == "probe_decode"
                    and all(l.done or l.steps >= self.max_new
                            for l in row.lanes)]
        if resolved:
            self._route(sorted(resolved, key=lambda r: r.admission))
        # member prefill finished or cache hit -> member decode lanes
        for row in self.active:
            if row.phase != "ensemble_decode":
                continue
            for mx in row.members:
                if (mx.lane is None and mx.answer is None
                        and not mx.reuse and mx.logits0 is not None):
                    self._begin_member_decode(row, mx)
            self._finish_members(row)

    def _route(self, rows: List[_Row]) -> None:
        import jax.numpy as jnp
        from repro.serving.engine import intern_answers
        t0 = time.perf_counter() if self.tracer is not None else 0.0
        n = self.n
        self._routed_this_tick += len(rows)
        # batched route-time extract: decode + extract every row
        # routing this tick in one call (duplicate probe texts are
        # extracted once) — element-wise identical to the old per-row
        # extract loop, so sigma/modes/answers cannot move
        texts_all: List[str] = []
        kinds_all: List[str] = []
        for row in rows:
            texts = [tok.decode(l.harvest(self.max_new, tok.PAD))
                     for l in row.lanes]
            row.probe_texts = texts
            texts_all.extend(texts)
            kinds_all.extend([row.request.task.kind] * len(texts))
        answers_all = extract_batch(texts_all, kinds_all)
        off = 0
        for row in rows:
            row.probe_answers = answers_all[off:off + len(row.lanes)]
            off += len(row.lanes)
            srv = self._probe_server(row)
            srv.pool.release(row.sample_tails.reshape(-1))
            row.sample_tails = None
            row.lanes = []
            srv._sample_usage()
        # per-row interning namespaces: sigma/majority/judge are
        # within-row functions, invariant to interning order
        ids = np.stack([intern_answers(row.probe_answers)
                        for row in rows]).reshape(len(rows), n)
        sig = sigma_batch(jnp.asarray(ids))
        modes = np.asarray(self.eng.route_modes(
            sig, [r.admission for r in rows]))
        for i, row in enumerate(rows):
            row.sigma = float(np.asarray(sig)[i])
            row.mode = int(modes[i])
            if self._quarantined:
                self._apply_degraded_mode(row)
            if self.tracer is not None:
                self.tracer.span("route", self._trace_id(row),
                                 self.now, sigma=row.sigma,
                                 mode=row.mode,
                                 n_samples=len(row.probe_answers))
            row.member_answers = [None] * len(self.eng.ensemble)
            self._spawn_members(row)
        if self.tracer is not None:
            self.metrics.observe(
                "acar_span_duration", time.perf_counter() - t0,
                phase="route",
                help="host wall seconds per traced lifecycle phase")

    def _member_needed(self, mode: int, mi: int) -> bool:
        if mi in self._quarantined:
            return False
        return mode >= (1 if mi < self.acfg.arena_lite_size else 2)

    def _spawn_members(self, row: _Row) -> None:
        eng = self.eng
        needed = [mi for mi in range(len(eng.ensemble))
                  if self._member_needed(row.mode, mi)]
        if not needed:
            self._release_prompt(self._probe_server(row), row)
            self._judge(row)       # mode 0: final = probe majority
            self._retire(row)
            return

        row.phase = "ensemble_decode"
        for mi in needed:
            zm = eng.ensemble[mi]
            srv_m = self._member_server(zm, row)
            reuse = self._reuse_member(zm, row)
            mx = _MemberExec(member=mi, server=srv_m, reuse=reuse)
            row.members.append(mx)
            if self.tracer is not None:
                self.tracer.span("member_launch", self._trace_id(row),
                                 self.now, key=("m", mi), member=mi,
                                 model=zm.name, reuse=int(reuse))
            if reuse:
                self._begin_member_decode(row, mx)
            elif srv_m is not None:
                entry = srv_m._prefix_lookup(row.ids.tobytes())
                if entry is not None:
                    srv_m.pool.retain(entry.shared)
                    if entry.tail is not None:
                        srv_m.pool.retain([entry.tail])
                    mx.shared = entry.shared.copy()
                    mx.tail = entry.tail
                    mx.logits0 = entry.logits0.copy()
                    mx.from_cache = True
                    mx.prefill_pos = row.s
                    srv_m.stats.prefill_tokens_reused_prefix += row.s
                    if self.tracer is not None:
                        self._kv_reuse_span(srv_m.stats.model, row,
                                            "prefix", key=("m", mi))
                    self._begin_member_decode(row, mx)
                else:
                    g = self._geometry(srv_m, row.s)
                    pages = srv_m._alloc_retry(g.nbp)
                    if g.n_shared or g.tail_tokens:
                        mx.shared = pages[:g.n_shared]
                        mx.tail = int(pages[g.n_shared]) \
                            if g.tail_tokens else None
                    else:
                        # ring / lanes member: the whole allocation is
                        # its private prompt snapshot
                        mx.shared = pages
                        mx.tail = None
            else:
                # non-paged member: dense one-shot fallback (still
                # row-keyed, so tokens match the wave path's dense
                # member decode bit-for-bit)
                self._dense_member(row, mx, zm)
        if not any(mx.reuse for mx in row.members):
            # no member seeds from the probe's pages: free them the
            # moment the route resolves, like the wave handle does
            self._release_prompt(self._probe_server(row), row)
        self._finish_members(row)

    def _begin_member_decode(self, row: _Row, mx: _MemberExec) -> None:
        srv = self._probe_server(row) if mx.reuse else mx.server
        s = row.s
        g = self._geometry(srv, s)
        tails = srv._alloc_retry(g.n_tail)
        if mx.reuse:
            self._unreserve(row, g.n_tail)
        mx.tails = tails
        table = np.empty(g.nb, np.int32)
        shared = row.shared if mx.reuse else mx.shared
        canon_tail = row.tail if mx.reuse else mx.tail
        if g.n_shared:
            table[:g.n_shared] = shared
        table[g.n_shared:] = tails
        if g.tail_tokens:
            self._fork(srv, [canon_tail], [int(tails[0])])
            srv.stats.cow_forks += 1
        elif g.n_shared == 0:
            # ring / lanes member: fork the whole prompt snapshot
            # into the decode lane's private pages
            self._fork(srv, [int(p) for p in shared],
                       [int(p) for p in tails])
            srv.stats.cow_forks += g.nbp
        key = np.asarray(S.member_row_keys(
            self.base_key, [row.admission], mx.member))[0]
        logits0 = row.logits0 if mx.reuse else mx.logits0
        mx.lane = _Lane(block_table=table, row_key=key,
                        logits=logits0.copy(), tag=100 + mx.member)
        if mx.reuse:
            srv.stats.prefill_tokens_reused_probe += s
            if self.tracer is not None:
                self._kv_reuse_span(srv.stats.model, row, "probe",
                                    key=("m", mx.member))

    def _dense_member(self, row: _Row, mx: _MemberExec, zm) -> None:
        import jax.numpy as jnp
        rk = S.member_row_keys(self.base_key, [row.admission],
                               mx.member)
        out = S.generate(
            zm.cfg, zm.params, jnp.asarray(row.ids[None]),
            max_new_tokens=self.max_new,
            temperature=self.acfg.ensemble_temperature,
            key=jax.random.fold_in(self.base_key, 1000 + mx.member),
            eos_id=tok.EOS, pad_id=tok.PAD, row_keys=jnp.asarray(rk))
        text = tok.decode(np.asarray(out.tokens)[0])
        mx.answer = extract(text, row.request.task.kind)
        # the whole prefill + decode ran as one dense program on this
        # member's executor: charge it to the virtual clock in the
        # same units the chunked/stepped paths pay
        cost = self.planner.chunk_count(row.s) + self.max_new
        key = ("dense", mx.member)
        self._tick_extra[key] = self._tick_extra.get(key, 0) + cost
        self.stats.launches += 1
        if self.tracer is not None:
            self.tracer.span("member_decode", self._trace_id(row),
                             self.now, key=("m", mx.member),
                             member=mx.member, model=zm.name, dense=1,
                             done=int(mx.answer is not None))

    def _finish_members(self, row: _Row) -> None:
        srv = self._probe_server(row)
        for mx in row.members:
            lane = mx.lane
            if (mx.answer is None and lane is not None
                    and (lane.done or lane.steps >= self.max_new)):
                text = tok.decode(lane.harvest(self.max_new, tok.PAD))
                mx.answer = extract(text, row.request.task.kind)
                dsrv = srv if mx.reuse else mx.server
                dsrv.pool.release(mx.tails)
                mx.tails = None
                mx.lane = None
                if not mx.reuse and mx.shared is not None:
                    self._release_prompt(dsrv, mx)
        if all(mx.answer is not None for mx in row.members):
            for mx in row.members:
                row.member_answers[mx.member] = mx.answer
            self._release_prompt(srv, row)
            self._judge(row)
            self._retire(row)

    def _judge(self, row: _Row) -> None:
        import jax.numpy as jnp
        from repro.serving.engine import intern_answers, judge_batch
        t0 = time.perf_counter() if self.tracer is not None else 0.0
        table: Dict[str, int] = {}
        probe_ids = intern_answers(row.probe_answers,
                                   table).reshape(1, self.n)
        col = np.full(len(self.eng.ensemble), -1, np.int32)
        for mi, a in enumerate(row.member_answers):
            if a is not None:
                col[mi] = table.setdefault(a, len(table))
        final = judge_batch(
            jnp.asarray(col[None]),
            majority_vote_batch(jnp.asarray(probe_ids)),
            jnp.asarray([row.mode], np.int32))
        rev = {v: k for k, v in table.items()}
        row.final_answer = rev[int(np.asarray(final)[0])]
        if self.tracer is not None:
            self.tracer.span(
                "judge", self._trace_id(row), self.now, mode=row.mode,
                members=[mi for mi, a
                         in enumerate(row.member_answers or [])
                         if a is not None])
            self.metrics.observe(
                "acar_span_duration", time.perf_counter() - t0,
                phase="judge",
                help="host wall seconds per traced lifecycle phase")

    def _retire(self, row: _Row) -> None:
        self._unreserve(row, row.reserved)
        row.phase = "done"
        row.retired_at = self.now
        arr, adm, _ = self.stats.timeline[row.admission]
        self.stats.timeline[row.admission] = (arr, adm, self.now)
        self.stats.retired += 1
        self.done_rows[row.admission] = row
        if self.journal is not None:
            self.journal.retire({
                "adm": row.admission,
                "task_id": row.request.task.task_id,
                "sigma": row.sigma, "mode": row.mode,
                "probe_texts": row.probe_texts,
                "probe_answers": row.probe_answers,
                "member_answers": row.member_answers,
                "final_answer": row.final_answer,
                "aborted": row.aborted,
                "timeline": list(self.stats.timeline[row.admission]),
            }, self.now)
        if self.tracer is not None:
            self.tracer.span("retire", self._trace_id(row), self.now,
                             task_id=row.request.task.task_id,
                             final_answer=row.final_answer,
                             sigma=row.sigma, mode=row.mode,
                             aborted=row.aborted)
            if (getattr(self.tracer, "attribution", False)
                    and row.mode >= 2 and row.aborted is None
                    and row.member_answers is not None):
                # full-arena row: schedule on-capacity leave-one-out
                # recomputation (drained on idle ticks; see run())
                self._attrib_queue.append(row)

    # -- on-capacity counterfactual attribution ------------------------
    def _attribute_row(self, row: _Row) -> None:
        """Recompute ground-truth leave-one-out judge counterfactuals
        for one escalated row and emit them as a hashed span. Uses the
        same ``core.attribution`` oracle the offline analysis calls, so
        the on-capacity values are numerically identical by
        construction (``simulate.py --obs`` asserts it row-by-row)."""
        from repro.core.attribution import leave_one_out
        from repro.teamllm.trace import ModelResponse
        task = row.request.task
        responses = [
            ModelResponse(model=self.eng.ensemble[mi].name,
                          response="", answer=a, cost=0.0)
            for mi, a in enumerate(row.member_answers)
            if a is not None]
        loo = leave_one_out(responses, task.task_id, task.gold)
        self.tracer.span(
            "attribution", self._trace_id(row), self.now,
            task_id=task.task_id, mode=row.mode,
            values={m: float(v) for m, v in loo.items()})

    def _drain_attribution(self, quota: int) -> None:
        while self._attrib_queue and quota > 0:
            self._attribute_row(self._attrib_queue.pop(0))
            quota -= 1
            self.metrics.inc(
                "acar_attribution_rows_total",
                help="escalated rows with on-capacity leave-one-out "
                     "attribution recomputed")

    def kv_stats(self):
        """Measured paged-KV accounting per model for this run."""
        return self.eng.kv_stats()

    # -- main loop -----------------------------------------------------
    def _emit_phase_gauges(self) -> None:
        counts = {p: 0 for p in PHASES}
        for row in self.active:
            counts[row.phase] += 1
        counts["done"] = self.stats.retired
        # route-pending is transient within a tick (routing resolves
        # on the host the same step probe decode finishes): report
        # the rows that passed through it this step
        counts["route_pending"] = self._routed_this_tick
        for phase, v in counts.items():
            self.metrics.set_gauge(
                "acar_step_rows_active", v, phase=phase,
                help="rows per lifecycle phase at the last step "
                     "(route_pending: resolved within this step)")

    def run(self) -> StepStats:
        while len(self.queue) or self.active or self._displaced:
            self._admit_ready()
            if self.injector is not None:
                self._fault_tick()
            per_server: Dict[object, int] = {}
            self._tick_extra = {}
            self._routed_this_tick = 0
            for key, items in sorted(self._prefill_groups().items(),
                                     key=lambda kv: kv[0][1:]):
                # a chunked launch charges one tick; a one-shot launch
                # (quant/ring/lanes) charges its dense-equivalent
                # chunk count, so layout choice never skews latency
                cost = self._run_prefill_group(key, items)
                per_server[key[0]] = per_server.get(key[0], 0) + cost
            for key, items in sorted(self._decode_groups().items(),
                                     key=lambda kv: (kv[0][1],
                                                     kv[0][2])):
                # a megastep launch charges its fused tick count: the
                # virtual clock measures device occupancy, not launch
                # overhead (that is megastep_bench's wall-clock gate)
                kl = self._run_decode_group(key, items)
                per_server[key[0]] = per_server.get(key[0], 0) + kl
            self._promote()
            # dense-fallback members ran whole generations on their
            # own executors during promotion
            for key, cost in self._tick_extra.items():
                per_server[key] = per_server.get(key, 0) + cost
            self.active = [r for r in self.active if r.phase != "done"]
            self._emit_phase_gauges()
            # servers are independent executors: the tick takes as
            # long as its busiest server; same-server programs
            # serialize. Idle ticks launch nothing (invocations stay
            # honest) but time still passes.
            tick_cost = max(per_server.values(), default=0)
            if self.tracer is not None and self._attrib_queue:
                # attribution is pure host recompute over retired
                # rows: spend idle device ticks on it, never busy ones
                self._drain_attribution(self.planner.attribution_quota(
                    tick_cost, len(self._attrib_queue)))
            self.stats.ticks += 1
            self.stats.invocations += sum(per_server.values())
            self.now += max(1, tick_cost)
            if tick_cost == 0 and not self.active and len(self.queue):
                # idle: jump the virtual clock to the next admission
                # event (a future arrival, or the oldest request's
                # fill-or-timeout instant)
                head = self.queue.peek()
                if head.arrival_time > self.now:
                    self.now = head.arrival_time
                elif not self.queue.ready(self.now):
                    nxt = self.queue.next_ready_at()
                    if nxt is not None:
                        self.now = max(self.now, nxt)
        if self.tracer is not None and self._attrib_queue:
            # the run drained before the queue did: flush the rest so
            # every escalated row gets its counterfactual events
            self._drain_attribution(len(self._attrib_queue))
        if self.stats.masked_decode_steps:
            self.metrics.inc(
                "acar_step_masked_decode_steps_total",
                self.stats.masked_decode_steps,
                help="decode ticks lanes sat masked because they "
                     "finished mid-megastep")
        return self.stats


# ----------------------------------------------------------------------
# mesh-sharded step loop (serving/mesh.py per-shard page pools)
# ----------------------------------------------------------------------
def _shard_rows(arr):
    """Per-shard device-local views of a P("data")-sharded launch
    output (leading axis = shard index). Indexing the global array
    instead (``arr[k, i]``) dispatches a tiny cross-device gather —
    an all-device collective per lane per tick — whose rendezvous can
    deadlock the CPU backend when fault handling perturbs the launch
    schedule mid-tick. A shard-local view costs nothing and never
    synchronises across devices."""
    out = [None] * arr.shape[0]
    for s in arr.addressable_shards:
        out[s.index[0].start or 0] = s.data
    return out


class ShardedStepLoopRunner(StepLoopRunner):
    """Step-level loop over a ``ServingMesh``: rows are placed on the
    least-loaded shard at admission (``StepPlanner.place_shard``),
    every shard keeps its own page pool / block tables / free list /
    prefix cache (``ShardedPagedKVServer``), and each tick's prefill
    and decode groups run as *one* shard_map'd program spanning every
    shard simultaneously (``sampler.decode_megastep_rows_sharded`` /
    ``prefill_chunk_paged_sharded``) — per-shard buckets, vector pos,
    per-row key streams keyed by global admission index, up to
    ``StepPlanner.megastep`` ticks fused per launch. Only the (K, B)
    emit and done stacks come back to the host per megastep — lane
    logits stay device-resident — and route-time extracts are batched
    per tick.

    Bit-equivalence with the single-device loop holds because every
    per-row computation is placement-independent: sampling keys derive
    from the global admission index, attention reads only the row's
    own shard-local pages, and all host decisions are deterministic
    functions of the admission order. ``tests/harness/simulate.py
    --sharded`` proves it on record hashes and artifact-chain heads.

    ``planner.max_active_rows`` is the *per-shard* cap here, so
    aggregate concurrency — and aggregate KV page capacity — scale
    with the mesh (``benchmarks/sharding_bench.py`` gates both).

    On a 2-D ``("data", "model")`` mesh every per-tick launch spans
    the full mesh: each data shard's program runs tensor-parallel
    across its model columns (params column-sharded, page kv-heads
    sharded — ``sharding/tp.py``), while row placement, lane
    assembly, and all host decisions stay keyed by the data axis
    alone. The decode tick path stays free of host-side collectives;
    the model-axis all-gathers live inside the device program.
    ``tests/harness/simulate.py --mesh2d`` proves (data=2, model=2)
    bit-identical to single-device for a mixed dense+MoE fleet;
    ``benchmarks/mesh2d_bench.py`` gates the per-member KV capacity
    scaling and the MoE compaction win.
    """

    def __init__(self, engine, queue: AdmissionQueue,
                 planner: StepPlanner, smesh,
                 metrics: Optional[PromCounters] = None, *,
                 faults: Optional[FaultInjector] = None,
                 journal=None,
                 recovered: Optional[Dict[int, dict]] = None,
                 tracer=None):
        self.smesh = smesh
        self._lost: set = set()            # shards marked lost
        super().__init__(engine, queue, planner, metrics,
                         faults=faults, journal=journal,
                         recovered=recovered, tracer=tracer)

    # -- server topology -----------------------------------------------
    def _init_servers(self) -> None:
        from repro.models.transformer import resolve_layout
        eng = self.eng
        if resolve_layout(eng.probe.cfg) not in ("dense", "quant"):
            raise ValueError(
                "sharded serving requires a dense- or quant-paged "
                "probe model (models.transformer.resolve_layout)")
        self._sharded: Dict[int, object] = {}      # id(params) -> server
        self._model_by_group: Dict[int, object] = {}
        self._params_repl: Dict[int, dict] = {}
        self.probe_sharded = self._sharded_server(eng.probe)
        self._member_sharded: List[object] = []
        self._twins = 0
        for zm in eng.ensemble:
            if resolve_layout(zm.cfg) not in ("dense", "quant"):
                # ring / lanes members stay single-device for now:
                # dense one-shot fallback (bit-identical tokens)
                continue
            if zm.params is eng.probe.params:
                if zm is not eng.probe:
                    self._twins += 1
            else:
                srv = self._sharded_server(zm)
                if srv not in self._member_sharded:
                    self._member_sharded.append(srv)
        self.page_size = self.probe_sharded.page_size
        # shard-0 view: page geometry only — allocation always goes
        # through the per-row _probe_server/_member_server hooks
        self.probe_srv = self.probe_sharded.shards[0]
        self._servers = [self.probe_srv]
        n = self.smesh.n_shards
        self._shard_active = [0] * n
        self._shard_reserved = [0] * n

    def _sharded_server(self, zm):
        from repro.serving.mesh import ShardedPagedKVServer
        key = id(zm.params)
        srv = self._sharded.get(key)
        if srv is None:
            srv = ShardedPagedKVServer(
                zm.cfg, self.smesh, page_size=self.eng.kv_page_size,
                prefix_cache_entries=self.eng.kv_prefix_cache)
            srv.set_model_name(zm.name)
            self._sharded[key] = srv
            self._model_by_group[id(srv)] = zm
            # replicated over "data"; on a 2-D mesh additionally
            # tensor-sharded column-parallel over "model"
            self._params_repl[id(srv)] = self.smesh.place_params(
                zm.cfg, zm.params)
        return srv

    # -- placement hooks -----------------------------------------------
    def _probe_server(self, row: _Row):
        return self.probe_sharded.shards[row.shard]

    def _member_server(self, zm, row: _Row):
        from repro.models.transformer import resolve_layout
        if resolve_layout(zm.cfg) not in ("dense", "quant"):
            return None                    # dense one-shot fallback
        srv = self._sharded_server(zm)
        home = row.shard
        if self._reuse_member(zm, row):
            # COW reuse seeds from the row's probe pages: shard-bound
            return srv.shards[home]
        # work stealing for escalation skew: a fresh (non-reuse)
        # member execution has no page affinity — its prompt prefills
        # into whatever pool hosts it and its tokens are keyed by
        # global admission index, so re-placing it moves bytes, never
        # math. When the home shard's pool cannot hold the full
        # execution (prompt + decode tail) and another healthy shard
        # can, steal to the freest such shard (lowest index breaks
        # ties) — deterministic, since free-page counts are a pure
        # function of the admission-ordered allocation history.
        g = self._geometry(srv.shards[home], row.s)
        need = g.nbp + g.n_tail
        home_ok = (home not in self._lost
                   and srv.shards[home].pool is not None
                   and srv.shards[home].pool.free_pages >= need)
        if home_ok:
            return srv.shards[home]
        best = None
        for k, sv in enumerate(srv.shards):
            if k == home or k in self._lost or sv.pool is None:
                continue
            f = sv.pool.free_pages
            if f >= need and (best is None or f > best[0]):
                best = (f, k)
        if best is None:
            return srv.shards[home]    # no roomier shard: retry path
        # metrics only, never the trace: steal placement is
        # sharded-only bookkeeping, and the artifact chain must stay
        # bit-identical to the single-device run
        self.metrics.inc(SHARD_STEALS, src=str(home),
                         dst=str(best[1]),
                         help="member executions stolen to a roomier "
                              "shard")
        return srv.shards[best[1]]

    def _reuse_member(self, zm, row: _Row) -> bool:
        eng = self.eng
        return (zm.cfg == eng.probe.cfg
                and zm.params is eng.probe.params
                and eng._member_compactable(zm))

    def _group_key(self, srv) -> int:
        return id(srv.parent)

    def _server_model(self, srv):
        return self._model_by_group[id(srv.parent)]

    # -- reservations / retirement (shard-local) -----------------------
    def _unreserve(self, row: _Row, pages: int) -> None:
        pages = min(pages, row.reserved)
        row.reserved -= pages
        self._shard_reserved[row.shard] -= pages

    def _retire(self, row: _Row) -> None:
        # rows retiring off a lost shard (displaced-row aborts) were
        # already struck from its zeroed occupancy counters
        if row.shard not in self._lost:
            self._shard_active[row.shard] -= 1
        super()._retire(row)

    def _rollback_admission(self, row: _Row) -> None:
        super()._rollback_admission(row)
        self._shard_active[row.shard] -= 1

    # -- admission: least-loaded shard placement -----------------------
    def _admit_ready(self) -> None:
        if self._displaced:
            self._replace_displaced()
        while len(self.queue):
            if self.recovered and self._restore_head():
                continue
            if not self.queue.ready(self.now):
                break
            head = self.queue.peek()
            if head.arrival_time > self.now:
                break
            ids = tok.encode_aligned([head.task.text])[0]
            s = int(ids.shape[0])
            try:
                self.probe_sharded.ensure_capacity_stream(
                    self.planner.max_active_rows, s,
                    self.n + max(self._twins, 1), self.max_new)
                for srv in self._member_sharded:
                    srv.ensure_capacity_stream(
                        self.planner.max_active_rows, s, 1,
                        self.max_new)
            except PagePoolError:
                # a longer prompt needs bigger per-shard pools, which
                # only rebuild while no shard holds pages: defer until
                # the active rows drain (see StepLoopRunner)
                if self.active:
                    break
                if self._lost:
                    # a lost shard is frozen in place, so pools can
                    # never rebuild again: admit-or-abort keeps the
                    # stream draining (traced, deterministic)
                    req = self.queue.pop()
                    row = _Row(request=req, ids=ids,
                               admitted_at=self.now,
                               shard=min(self._lost))
                    self.stats.timeline[row.admission] = (
                        req.arrival_time, self.now, -1)
                    self._abort_row(row, "capacity_rebuild_blocked")
                    continue
                raise
            need = self._row_need(s)
            shard = self.planner.place_shard(
                self._shard_active,
                [sv.pool.free_pages
                 for sv in self.probe_sharded.shards],
                self._shard_reserved, need, blocked=self._lost)
            if shard is None:
                break
            req = self.queue.pop()
            row = _Row(request=req, ids=ids, admitted_at=self.now,
                       reserved=need, shard=shard)
            self._shard_reserved[shard] += need
            self._shard_active[shard] += 1
            self.stats.timeline[row.admission] = (
                req.arrival_time, self.now, -1)
            if self.tracer is not None:
                self.tracer.span("admit", self._trace_id(row),
                                 self.now, prompt_tokens=s,
                                 arrival=req.arrival_time,
                                 shard=shard)
            if not self._try_begin_prefill(row):
                break
            self.active.append(row)
            self.stats.admissions += 1
            self.metrics.inc("acar_step_admissions_total",
                             help="rows admitted into the step loop")
            self.metrics.inc("acar_shard_placements_total",
                             shard=str(shard),
                             help="rows placed per mesh shard")
            if self.journal is not None:
                self.journal.admit(row.admission, req.request_id,
                                   self.now)

    # -- shard loss ----------------------------------------------------
    def _shard_faults(self) -> None:
        for k in range(self.smesh.n_shards):
            if k not in self._lost \
                    and self._fired("shard_loss", shard=k):
                self._lose_shard(k)

    def _lose_shard(self, k: int) -> None:
        """Simulated shard death: every server's shard-``k`` pool is
        abandoned (pages forfeited, never released — a dead host runs
        no release path), resident rows are displaced for re-placement
        on survivors, and the shard's occupancy counters zero out."""
        self._lost.add(k)
        self.probe_sharded.mark_shard_lost(k)
        for srv in self._member_sharded:
            srv.mark_shard_lost(k)
        self._trace_fault("shard_lost", shard=k)
        for row in [r for r in self.active if r.shard == k]:
            self._forfeit_row(row)
            self.active.remove(row)
            self._displaced.append(row)
            self._trace_fault("row_displaced",
                              admission=row.admission, shard=k)
            if self.tracer is not None:
                self.tracer.span("displaced", self._trace_id(row),
                                 self.now, shard=k)
        self._shard_active[k] = 0
        self._shard_reserved[k] = 0

    def _forfeit_row(self, row: _Row) -> None:
        """Strip a row of everything resident on its (lost) shard and
        reset it to re-prefill from step 0. No pages are released —
        the pool is abandoned with them. Admission-indexed key streams
        make the restart emit bit-identical tokens."""
        for lane in row.lanes:
            lane.done = True
        for mx in row.members:
            if mx.lane is not None:
                mx.lane.done = True
        row.shared = None
        row.tail = None
        row.from_cache = False
        row.prefill_pos = 0
        row.logits0 = None
        row.sample_tails = None
        row.lanes = []
        row.probe_texts = None
        row.probe_answers = None
        row.sigma = 0.0
        row.mode = 0
        row.members = []
        row.member_answers = None
        row.final_answer = None
        row.phase = "prefill"
        row.reserved = 0

    def _replace_displaced(self) -> None:
        """Re-place displaced rows on surviving shards (admission
        order, least-loaded placement over the healthy set). Rows that
        do not fit yet stay displaced — retirements free pages every
        tick, so placement is retried until they land. With no shard
        left the rows abort with a traced null-answer retirement."""
        if len(self._lost) >= self.smesh.n_shards:
            for row in self._displaced:
                self._abort_row(row, "no_healthy_shards")
            self._displaced = []
            return
        still: List[_Row] = []
        for row in sorted(self._displaced, key=lambda r: r.admission):
            need = self._row_need(row.s)
            shard = self.planner.place_shard(
                self._shard_active,
                [sv.pool.free_pages
                 for sv in self.probe_sharded.shards],
                self._shard_reserved, need, blocked=self._lost)
            if shard is None:
                still.append(row)
                continue
            row.shard = shard
            row.reserved = need
            self._shard_reserved[shard] += need
            self._shard_active[shard] += 1
            self._begin_prefill(row)
            self.active.append(row)
            self._trace_fault("row_replaced",
                              admission=row.admission, shard=shard)
            if self.tracer is not None:
                self.tracer.span("replaced", self._trace_id(row),
                                 self.now, shard=shard)
            self.metrics.inc("acar_shard_placements_total",
                             shard=str(shard),
                             help="rows placed per mesh shard")
        self._displaced = still

    # -- page plumbing: per-shard COW forks in one launch --------------
    def _fork(self, srv, src: Sequence[int],
              dst: Sequence[int]) -> None:
        parent = srv.parent
        src_a = parent.pad_fork_ids(len(src))
        dst_a = src_a.copy()
        src_a[srv.index] = src
        dst_a[srv.index] = dst
        parent.pages = S.fork_pages_sharded(
            parent.pages, src_a, dst_a, mesh=self.smesh.mesh)

    # -- device programs: one shard_map'd launch per group -------------
    def _run_prefill_group(self, key, items) -> int:
        _, c, s = key
        if c < 0:
            return self._run_one_shot_prefill_group(key, items)
        t0 = time.perf_counter() if self.tracer is not None else 0.0
        parent = items[0][0].parent
        nsh = parent.n_shards
        nbp = pages_for(s, self.page_size)
        per: List[list] = [[] for _ in range(nsh)]
        for srv, row, mx in items:
            per[srv.index].append((srv, row, mx))
        for k in range(nsh):
            per[k].sort(key=lambda it: it[1].admission)
        bucket = self.planner.decode_bucket(
            max(len(p) for p in per))
        tokens = np.zeros((nsh, bucket, c), np.int32)
        tables = np.empty((nsh, bucket, nbp), np.int32)
        starts = np.zeros((nsh, bucket), np.int32)
        for k in range(nsh):
            scratch = parent.shards[k]._scratch[:nbp]
            for i in range(bucket):
                if i < len(per[k]):
                    _, row, mx = per[k][i]
                    target = mx if mx is not None else row
                    starts[k, i] = target.prefill_pos
                    tokens[k, i] = row.ids[
                        starts[k, i]:starts[k, i] + c]
                    tables[k, i, :target.shared.size] = target.shared
                    if target.tail is not None:
                        tables[k, i, -1] = target.tail
                else:
                    # pad rows prefill zeros into scratch pages
                    tables[k, i] = scratch
        zm = self._model_by_group[id(parent)]
        prm = self._params_repl[id(parent)]
        lg, parent.pages = S.prefill_chunk_paged_sharded(
            zm.cfg, prm, tokens, parent.pages,
            tables, starts, prompt_len=s, mesh=self.smesh.mesh)
        for sv in parent.shards:
            sv.stats.prefill_tokens_computed += bucket * c
            sv.stats.prefill_chunks += 1
        self.stats.prefill_chunks += 1
        self.metrics.inc("acar_prefill_chunks_total",
                         model=parent.model_name,
                         help="chunked-prefill device programs run")
        self.stats.launches += 1
        # native-dtype, device-resident chunk-final logits (see the
        # single-device runner), sliced shard-locally — never through
        # the global array, which would gather cross-device
        lg_local = _shard_rows(lg)
        for k in range(nsh):
            for i, (srv, row, mx) in enumerate(per[k]):
                target = mx if mx is not None else row
                target.prefill_pos = int(starts[k, i]) + c
                sid = None
                if self.tracer is not None:
                    sid = self.tracer.span(
                        "prefill_chunk", self._trace_id(row),
                        self.now,
                        key=None if mx is None else ("m", mx.member),
                        model=parent.model_name,
                        start=int(starts[k, i]), tokens=c)
                if target.prefill_pos == s:
                    target.logits0 = lg_local[k][0, i]
                    srv._prefix_insert(row.ids.tobytes(),
                                       target.shared, target.tail,
                                       target.logits0, tokens=s)
                    if sid is not None:
                        self.tracer.kv_insert(
                            parent.model_name,
                            hashlib.sha256(
                                row.ids.tobytes()).hexdigest(),
                            self._trace_id(row), sid)
        if self.tracer is not None:
            self.metrics.observe(
                "acar_span_duration", time.perf_counter() - t0,
                phase="prefill",
                help="host wall seconds per traced lifecycle phase")
        return 1

    def _run_one_shot_prefill_group(self, key, items) -> int:
        """Whole-prompt prefill for the quant layout, every shard in
        one shard_map'd launch (only dense/quant reach the sharded
        runner, and dense always chunks)."""
        import jax.numpy as jnp
        _, _, s = key
        t0 = time.perf_counter() if self.tracer is not None else 0.0
        parent = items[0][0].parent
        nsh = parent.n_shards
        g = self._geometry(items[0][0], s)
        per: List[list] = [[] for _ in range(nsh)]
        for srv, row, mx in items:
            per[srv.index].append((srv, row, mx))
        for k in range(nsh):
            per[k].sort(key=lambda it: it[1].admission)
        bucket = self.planner.decode_bucket(
            max(len(p) for p in per))
        tokens = np.zeros((nsh, bucket, s), np.int32)
        tables = np.empty((nsh, bucket, g.nbp), np.int32)
        for k in range(nsh):
            scratch = parent.shards[k]._scratch[:g.nbp]
            for i in range(bucket):
                if i < len(per[k]):
                    _, row, mx = per[k][i]
                    target = mx if mx is not None else row
                    tokens[k, i] = row.ids
                    tables[k, i, :target.shared.size] = target.shared
                    if target.tail is not None:
                        tables[k, i, -1] = target.tail
                else:
                    # pad rows prefill zeros into scratch pages
                    tables[k, i] = scratch
        zm = self._model_by_group[id(parent)]
        prm = self._params_repl[id(parent)]
        lg, parent.pages = S.prefill_paged_sharded(
            zm.cfg, prm, jnp.asarray(tokens), parent.pages,
            jnp.asarray(tables), mesh=self.smesh.mesh)
        for sv in parent.shards:
            sv.stats.prefill_tokens_computed += bucket * s
        self.metrics.inc("acar_prefill_oneshot_total",
                         model=parent.model_name,
                         help="one-shot prefill device programs run "
                              "for non-chunkable page layouts")
        self.stats.launches += 1
        lg_local = _shard_rows(lg)
        for k in range(nsh):
            for i, (srv, row, mx) in enumerate(per[k]):
                target = mx if mx is not None else row
                target.prefill_pos = s
                target.logits0 = lg_local[k][0, i]
                srv._prefix_insert(row.ids.tobytes(), target.shared,
                                   target.tail, target.logits0,
                                   tokens=s)
                if self.tracer is not None:
                    sid = self.tracer.span(
                        "prefill_chunk", self._trace_id(row),
                        self.now,
                        key=None if mx is None else ("m", mx.member),
                        model=parent.model_name, start=0, tokens=s,
                        oneshot=1)
                    self.tracer.kv_insert(
                        parent.model_name,
                        hashlib.sha256(row.ids.tobytes()).hexdigest(),
                        self._trace_id(row), sid)
        if self.tracer is not None:
            self.metrics.observe(
                "acar_span_duration", time.perf_counter() - t0,
                phase="prefill",
                help="host wall seconds per traced lifecycle phase")
        return self.planner.chunk_count(s)

    def _run_decode_group(self, key, items) -> int:
        import jax.numpy as jnp
        _, temperature, cache_len = key
        t0 = time.perf_counter() if self.tracer is not None else 0.0
        parent = items[0][0].parent
        nsh = parent.n_shards
        nb = items[0][0].table_width(cache_len - self.max_new,
                                     self.max_new)
        penalty = 0
        if self.injector is not None:
            penalty = self._member_fault_gate(items)
            if penalty < 0:
                return 0               # group quarantined pre-launch
        per: List[list] = [[] for _ in range(nsh)]
        for srv, row, lane in items:
            per[srv.index].append((row, lane))
        for k in range(nsh):
            per[k].sort(key=lambda rl: (rl[0].admission, rl[1].tag))
        tok0 = {id(lane): len(lane.tokens) for _, _, lane in items} \
            if self.journal is not None else None
        bucket = self.planner.decode_bucket(
            max(len(p) for p in per))
        # one fused span for the whole group: every shard advances in
        # the same shard_map'd megastep, so K must be uniform — take
        # it over all lanes across shards
        kl = self._megastep_span([lane for _, _, lane in items])
        tables = np.empty((nsh, bucket, nb), np.int32)
        pos = np.full((nsh, bucket), cache_len - self.max_new,
                      np.int32)
        keys = np.zeros((nsh, bucket, 2), np.uint32)
        steps = np.zeros((nsh, bucket), np.int32)
        done = np.ones((nsh, bucket), bool)
        filler = items[0][2].logits    # pad rows sample masked pads
        live_total = 0
        # assemble the logits operand shard-locally: each device
        # stacks its own lanes' rows (device_put is a no-op for a row
        # already resident; prefix-cache hits seeded on another shard
        # transfer point-to-point), and the pieces form the
        # P("data")-sharded global array the launch expects — no
        # cross-device gathers, no collective per lane. On a 2-D mesh
        # the spec is still P("data") — logits replicate over "model"
        # — so every model column of a data row needs its own
        # single-device copy of that row's piece (a point-to-point
        # broadcast, still no collective and no host round-trip).
        import jax
        from jax.sharding import NamedSharding, PartitionSpec
        mesh_devs = self.smesh.mesh.devices
        if mesh_devs.ndim == 1:
            mesh_devs = mesh_devs.reshape(-1, 1)
        nm = mesh_devs.shape[1]
        pieces = []
        for k in range(nsh):
            scratch = parent.shards[k]._scratch[:nb]
            rows_k = []
            for i in range(bucket):
                if i < len(per[k]):
                    row, lane = per[k][i]
                    rows_k.append(
                        jax.device_put(lane.logits, mesh_devs[k, 0]))
                    tables[k, i] = lane.block_table
                    pos[k, i] = cache_len - self.max_new + lane.steps
                    keys[k, i] = lane.row_key
                    steps[k, i] = lane.steps
                    # a lane dropped by a quarantine or shard loss
                    # earlier this tick decodes masked
                    done[k, i] = lane.done
                    live_total += 1
                else:
                    rows_k.append(
                        jax.device_put(filler, mesh_devs[k, 0]))
                    tables[k, i] = scratch
            piece = jnp.stack(rows_k)[None]
            pieces.append(piece)
            for j in range(1, nm):
                pieces.append(jax.device_put(piece, mesh_devs[k, j]))
        logits = jax.make_array_from_single_device_arrays(
            (nsh, bucket, int(filler.shape[-1])),
            NamedSharding(self.smesh.mesh, PartitionSpec("data")),
            pieces)
        zm = self._model_by_group[id(parent)]
        prm = self._params_repl[id(parent)]
        (emits, dones, next_logits,
         parent.pages) = S.decode_megastep_rows_sharded(
            zm.cfg, prm, logits, parent.pages,
            jnp.asarray(tables), jnp.asarray(pos), jnp.asarray(keys),
            jnp.asarray(steps), jnp.asarray(done), n_ticks=kl,
            cache_len=cache_len, temperature=temperature,
            eos_id=tok.EOS, pad_id=tok.PAD, mesh=self.smesh.mesh)
        emits = np.asarray(emits)      # (nsh, K, bucket)
        dones = np.asarray(dones)
        self.stats.launches += 1
        self.stats.decode_h2d += 5     # tables, pos, keys, steps, done
        self.stats.decode_d2h += 2     # emits, dones
        if (self.injector is not None
                and all(it[2].tag >= 100 for it in items)
                and not np.isfinite(np.asarray(
                    next_logits, np.float32)).all()):
            # genuine non-finite member logits (see StepLoopRunner)
            self._quarantine_group(items, parent.model_name,
                                   "nan_logits")
            return kl + penalty
        nl_local = _shard_rows(next_logits)
        for k in range(nsh):
            for i, (row, lane) in enumerate(per[k]):
                self._replay_megastep(lane, emits[k], dones[k], kl, i)
                lane.logits = nl_local[k][0, i]
        if self.tracer is not None:
            for k in range(nsh):
                for row, lane in per[k]:
                    probe = lane.tag < 100
                    self.tracer.span(
                        "probe_decode" if probe else "member_decode",
                        self._trace_id(row), self.now,
                        key=("p", lane.tag) if probe
                        else ("m", lane.tag - 100),
                        member=None if probe else lane.tag - 100,
                        model=parent.model_name, ticks=kl,
                        steps=lane.steps, done=int(lane.done))
            d = time.perf_counter() - t0
            self.metrics.observe(
                "acar_span_duration", d,
                phase="probe_decode" if items[0][2].tag < 100
                else "ensemble_decode",
                help="host wall seconds per traced lifecycle phase")
            self.metrics.observe(
                "acar_decode_launch_seconds", d,
                server=parent.model_name,
                help="wall seconds per megastep decode launch")
        if self.journal is not None:
            self.journal.emit(self.now, parent.model_name, [
                [row.admission, lane.tag, lane.steps, int(lane.done),
                 lane.tokens[tok0[id(lane)]:]]
                for k in range(nsh) for row, lane in per[k]])
        self.metrics.set_gauge(
            "acar_step_bucket_occupancy",
            live_total / (nsh * bucket), server=parent.model_name,
            bucket=str(bucket),
            help="live-lane fill of the last step-decode bucket")
        return kl + penalty

    # -- observability -------------------------------------------------
    def _emit_phase_gauges(self) -> None:
        super()._emit_phase_gauges()
        counts = [0] * self.smesh.n_shards
        for row in self.active:
            counts[row.shard] += 1
        for k, v in enumerate(counts):
            self.metrics.set_gauge(
                "acar_shard_rows_active", v, shard=str(k),
                help="active rows resident per mesh shard")
        for srv in [self.probe_sharded] + self._member_sharded:
            for k, used in srv.per_shard_pages_in_use().items():
                self.metrics.set_gauge(
                    "acar_shard_pages_in_use", used, shard=str(k),
                    model=srv.model_name,
                    help="KV pool pages in use per mesh shard")

    def kv_stats(self):
        return {srv.model_name: srv.aggregate_stats()
                for srv in self._sharded.values()}
