"""Write-ahead step journal: the step loop's transition log, doubling
as its crash-recovery log.

The journal is itself a hash-chained ``ArtifactStore`` — the same
append-only, fsync'd, torn-tail-recovering substrate the decision
traces use — so a kill at any instant leaves a verifiable prefix of
the run's history. Events:

* ``admit``  — a row entered the active set (admission index,
  request id, tick);
* ``emit``   — one decode-group launch's per-lane deltas (admission
  index, lane tag, step counter, done bit, emitted token ids) — the
  megastep offsets and emitted tokens of the tick;
* ``retire`` — a row's full judge-visible outcome (sigma, mode, probe
  texts/answers, member answers, final answer, abort reason,
  timeline). This is the only event recovery *needs*;
* ``fault``  — an injected fault or its consequence (retry,
  quarantine, degraded route, shard loss, abort), mirrored from the
  runner's fault-event stream.

Recovery contract (``BatchedACAREngine.recover``): rows with a
durable ``retire`` event are restored verbatim; everything else —
in-flight rows included — re-executes *from scratch* with its
original global admission index. Because sampling key streams are
keyed by admission index (and per-row step counters), re-execution
emits bit-identical tokens, so a run killed at any tick and recovered
produces byte-identical record hashes and artifact-chain heads to an
uninterrupted run (``tests/harness/simulate.py --crash-at`` proves it
single-device and sharded). Re-prefilling in-flight rows instead of
teacher-forcing KV from journaled tokens is deliberate: prefill and
decode logits at the same position are not bit-identical (different
matmul shapes regroup the float reductions), so only a clean restart
preserves the hashes.

Appends are stamped with the virtual-clock tick as their (non-hashed)
wall time, so a journal file is a deterministic function of the run.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional, Set, Union

from repro.serving.faults import FaultInjector, SimulatedCrash
from repro.teamllm.artifacts import ArtifactStore


@dataclass
class RecoveryState:
    """Parsed journal: everything ``recover()`` needs to resume."""
    retired: Dict[int, dict] = field(default_factory=dict)
    admitted: Set[int] = field(default_factory=set)
    faults: List[dict] = field(default_factory=list)
    records: int = 0
    torn_recovered: bool = False
    head: str = ""


class StepJournal:
    """Hash-chained write-ahead journal for one step-loop run."""

    def __init__(self, path: Union[str, Path],
                 injector: Optional[FaultInjector] = None):
        self.store = ArtifactStore(path)
        self.injector = injector

    @property
    def torn_recovered(self) -> bool:
        return self.store.torn_recovered

    @property
    def head(self) -> str:
        return self.store.head

    # -- event appends -------------------------------------------------
    def _append(self, event: Dict[str, Any], tick: int) -> str:
        if self.injector is not None:
            if self.injector.fire("artifact_append", tick) is not None:
                self._torn_append(event, tick)
        return self.store.append(event, wall_time=float(tick))

    def _torn_append(self, event: Dict[str, Any], tick: int) -> None:
        """Injected kill mid-append: write a strict prefix of the
        encoded line (no trailing newline) and die. The next open
        truncates the torn tail and the chain verifies at the previous
        head — the kill-mid-append regression path, end to end."""
        line, _ = self.store._encode(dict(event), wall_time=float(tick))
        with self.store.path.open("a") as f:
            f.write(line[:max(1, len(line) // 2)])
            f.flush()
            os.fsync(f.fileno())
        raise SimulatedCrash(
            f"injected kill mid-journal-append at tick {tick}")

    def admit(self, admission: int, request_id: str, tick: int) -> None:
        self._append({"ev": "admit", "adm": int(admission),
                      "request_id": request_id, "tick": int(tick)},
                     tick)

    def emit(self, tick: int, model: str, lanes: List[list]) -> None:
        """One decode-group launch: ``lanes`` rows are
        ``[admission, tag, steps_after, done, new_token_ids]``."""
        self._append({"ev": "emit", "tick": int(tick), "model": model,
                      "lanes": lanes}, tick)

    def retire(self, payload: Dict[str, Any], tick: int) -> None:
        self._append(dict(payload, ev="retire"), tick)

    def fault(self, rec: Dict[str, Any], tick: int) -> None:
        self._append(dict(rec, ev="fault"), tick)

    # -- recovery ------------------------------------------------------
    @staticmethod
    def load(path: Union[str, Path]) -> RecoveryState:
        """Open (recovering any torn tail), verify the chain, and fold
        the event stream into a ``RecoveryState``. A later ``retire``
        for an admission already seen wins — impossible in a single
        run, but harmless under journal concatenation."""
        store = ArtifactStore(path)
        state = RecoveryState(torn_recovered=store.torn_recovered,
                              head=store.head)
        for rec in store.records():
            state.records += 1
            ev = rec.get("ev")
            if ev == "admit":
                state.admitted.add(int(rec["adm"]))
            elif ev == "retire":
                state.retired[int(rec["adm"])] = rec
            elif ev == "fault":
                state.faults.append(rec)
        return state
