"""Continuous-batching ACAR scheduler.

The sequential orchestrator (core/orchestrator.py) routes one task at
a time. This scheduler serves a continuous request stream:

1. **Admission** — requests enter an ``AdmissionQueue`` with logical
   arrival ticks and are grouped into micro-batches under a joint
   size/token/wait budget (serving/queue.py).
2. **Probe wave** — per micro-batch, the N-sample probe decode runs for
   every request (skipping prompts already in the probe cache), answers
   are interned to int32 ids, and sigma/route are computed **on
   device** with ``sigma_batch`` / ``route_batch`` — one padded XLA
   program per wave instead of per-task host logic.
3. **Ensemble wave** — the routed ensemble members execute per request
   with per-mode masking (single_agent rows run nothing), and
   aggregation reuses the orchestrator's exact ``aggregate`` function.
4. **Pipelining** — the probe wave of micro-batch k+1 is prefetched on
   a worker thread while the ensemble wave of micro-batch k runs, so
   the two stages overlap; a deterministic virtual clock accounts the
   modeled makespan of the pipeline vs the sequential path.

Equivalence guarantee: every per-task phase (retrieval, probe
generation, extraction, aggregation, cost accounting, trace
construction) is the *same code* the sequential orchestrator runs, and
all seeds derive from (model, task, sample, seed) — so the scheduler
produces bit-identical modes, final answers, and record hashes, with
traces appended in admission order. Queue/batch provenance rides the
non-hashed ``schedule`` side channel of each TraceRecord.

Cost/latency accounting is exported as Prometheus-style counters
(``render_metrics``).
"""
from __future__ import annotations

import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import (
    AbstractSet, Any, Dict, List, Optional, Sequence, Tuple)

import jax.numpy as jnp
import numpy as np

from repro.configs.acar import ACARConfig
from repro.core.backends import GenResult, ModelBackend
from repro.core.orchestrator import (
    TaskOutcome, aggregate, build_trace, execute_ensemble, probe_task,
    retrieve_exemplar, task_cost_latency)
from repro.core.retrieval import ExperienceStore
from repro.core.routing import majority_vote, models_for_mode
from repro.core.sigma import (
    MODE_NAMES, route_batch, sigma as sigma_fn, sigma_batch)
from repro.data.tasks import Task
from repro.serving.compaction import (
    CompactionPlan, bucket_size, plan_compaction)
from repro.serving.kv_pool import pages_for
from repro.serving.metrics import PromCounters
from repro.serving.queue import AdmissionQueue, MicroBatch, \
    MicroBatchPolicy, Request
from repro.teamllm.artifacts import ArtifactStore
from repro.teamllm.fingerprint import render_prompt
from repro.teamllm.state_machine import RunState, RunStateMachine
from repro.teamllm.trace import ProbeSample


# ----------------------------------------------------------------------
# probe-result cache
# ----------------------------------------------------------------------
@dataclass
class _ProbeEntry:
    probe_samples: List[ProbeSample]
    probe_results: List[GenResult]
    probe_latency: float


class ProbeCache:
    """LRU cache of probe waves keyed by the full generation identity:
    (task_id, prompt, n_samples, temperature, seed). Deterministic
    backends make a hit byte-identical to recomputation, so cache reuse
    cannot perturb routing or trace hashes."""

    def __init__(self, capacity: int = 512):
        self.capacity = capacity
        self._data: Dict[Tuple, _ProbeEntry] = {}
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(task: Task, prompt: str, acfg: ACARConfig) -> Tuple:
        return (task.task_id, prompt, acfg.n_probe_samples,
                acfg.probe_temperature, acfg.seed)

    def lookup(self, key: Tuple) -> Optional[_ProbeEntry]:
        entry = self._data.get(key)
        if entry is not None:
            self.hits += 1
            self._data[key] = self._data.pop(key)    # refresh LRU slot
        else:
            self.misses += 1
        return entry

    def insert(self, key: Tuple, entry: _ProbeEntry) -> None:
        if self.capacity <= 0:
            return
        self._data[key] = entry
        while len(self._data) > self.capacity:
            self._data.pop(next(iter(self._data)))

    def __len__(self) -> int:
        return len(self._data)


# ----------------------------------------------------------------------
# step planner (step-level continuous batching policy)
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class StepPlanner:
    """Per-step scheduling policy for the step-level serving loop
    (serving/step_loop.py executes it over the real-model engine).

    The wave scheduler plans whole micro-batches; the step planner
    makes three smaller decisions every logical tick, layered on the
    same ``CompactionPlan``/bucket machinery:

    * **admission** — a queued request joins the active set only when
      the fill-or-timeout trigger (``AdmissionQueue.ready``) has fired,
      the active-row cap has room, and the page budget is open: the
      row's worst-case page need (prompt pages + sample/decode tails)
      must fit in the pool's free pages net of what already-admitted
      rows may still allocate. Reservation-based admission is what
      makes mid-stream retirement safe: a row that got in can always
      finish.
    * **chunk sizing** — prompts prefill in fixed ``chunk_tokens``
      slices (the last chunk takes the remainder), bounding the
      per-step prefill working set regardless of prompt length.
    * **bucket selection** — each step's mixed decode/prefill groups
      pad to power-of-two row buckets (``bucket_size``), so XLA
      compiles at most log2(rows)+1 shapes per (server, phase) instead
      of one per occupancy.
    * **shard placement** — on a sharded mesh (serving/mesh.py),
      ``place_shard`` puts an admitted row on the least-loaded shard
      by free pages net of outstanding reservations (ties break to the
      lowest shard index, deterministically); ``max_active_rows`` is
      then a *per-shard* cap, so aggregate concurrency scales with the
      mesh. Placement is pure load balancing: per-row sampling keys
      are derived from the global admission index, so the shard a row
      lands on can never change its tokens.
    * **megastep span** — decode groups fuse up to ``megastep`` ticks
      into one device launch (``sampler.decode_megastep_rows``); lane
      state stays device-resident between launches and only emitted
      token ids + done bits come back per megastep. Rows finishing
      mid-megastep burn <= K-1 masked steps (accounted in
      ``StepStats.masked_decode_steps``). Sampling keys derive from
      (admission index, per-row step counter), so K is a pure
      performance knob — any value emits bit-identical streams. With
      ``megastep_auto`` the span is additionally capped by the
      group's *shortest* remaining budget, so no lane can overrun its
      budget mid-launch and the masked-step burn from budget
      exhaustion drops to zero (``--megastep auto`` on the engine).
    """
    chunk_tokens: int = 8
    max_active_rows: int = 8
    megastep: int = 1
    megastep_auto: bool = False

    def __post_init__(self) -> None:
        if self.megastep < 1:
            raise ValueError(
                f"megastep must be >= 1, got {self.megastep}")

    def chunk_span(self, pos: int, prompt_len: int) -> int:
        """Tokens the next prefill step of a row at ``pos`` covers."""
        return min(self.chunk_tokens, prompt_len - pos)

    def chunk_count(self, prompt_len: int) -> int:
        """Prefill steps (virtual-clock units) a whole prompt costs."""
        return -(-prompt_len // self.chunk_tokens)

    def decode_bucket(self, rows: int, cap: Optional[int] = None) -> int:
        return bucket_size(rows, cap)

    def may_admit(self, active_rows: int, free_pages: int,
                  reserved_pages: int, row_need: int) -> bool:
        return (active_rows < self.max_active_rows
                and free_pages - reserved_pages >= row_need)

    def place_shard(self, active_rows: Sequence[int],
                    free_pages: Sequence[int],
                    reserved_pages: Sequence[int],
                    row_need: int,
                    blocked: Optional[AbstractSet[int]] = None
                    ) -> Optional[int]:
        """Least-loaded shard placement (free-pages-weighted): among
        shards that can admit (per-shard row cap and page budget, the
        exact ``may_admit`` predicate), pick the one with the most
        free pages net of its outstanding reservations; ties break to
        the lowest shard index. Returns None when no shard can admit
        — the caller defers the row until retirements free budget.
        ``blocked`` shards (lost to a simulated fault) are never
        candidates regardless of their stale accounting."""
        best = None
        best_headroom = -1
        for k in range(len(free_pages)):
            if blocked is not None and k in blocked:
                continue
            if not self.may_admit(active_rows[k], free_pages[k],
                                  reserved_pages[k], row_need):
                continue
            headroom = free_pages[k] - reserved_pages[k]
            if headroom > best_headroom:
                best, best_headroom = k, headroom
        return best

    def attribution_quota(self, tick_cost: int, pending: int) -> int:
        """On-capacity attribution budget for this tick: how many
        retired full-arena rows the step loop may recompute
        leave-one-out counterfactuals for (serving/step_loop.py drains
        its queue with it). The policy is strict idleness — a tick
        that launched any device program gets no budget, an idle tick
        drains everything pending. Attribution is host-side recompute
        over already-journaled answers, so the quota can never perturb
        the virtual clock or the decision trace; the remainder flushes
        after the stream drains."""
        return pending if tick_cost == 0 else 0


# ----------------------------------------------------------------------
# scheduler
# ----------------------------------------------------------------------
@dataclass
class _ProbedRequest:
    request: Request
    prompt: str
    retrieval_sim: Optional[float]
    ret_meta: Optional[Dict[str, Any]]
    probe_samples: List[ProbeSample]
    probe_results: List[GenResult]
    probe_latency: float
    cache_hit: bool
    sigma: float = 0.0
    mode: str = "single_agent"


@dataclass
class _ProbedBatch:
    batch: MicroBatch
    rows: List[_ProbedRequest]
    wave_latency_ms: float       # max over cache-missed rows
    # escalated-subset decode plan, computed on the (overlapped) probe
    # stage so the ensemble wave starts with its gather/bucket shapes
    # already known
    plan: Optional[CompactionPlan] = None
    # prompt pages held past the route decision for probe->ensemble
    # prefill seeding; released when this wave's ensemble completes
    kv_escalated_pages: int = 0


@dataclass
class SchedulerStats:
    tasks: int = 0
    batches: int = 0
    probe_cache_hits: int = 0
    probe_cache_misses: int = 0
    ensemble_calls_saved: int = 0
    total_cost: float = 0.0
    # compaction accounting (escalated-subset wave planning)
    escalated_rows: int = 0               # rows routed past single_agent
    full_arena_rows: int = 0              # rows routed to the full arena
    ensemble_decode_rows: int = 0         # compacted row-decodes issued
    ensemble_decode_rows_saved: int = 0   # full-batch masked rows elided
    probe_prefill_tokens: int = 0         # shared-prefix prefill tokens
    probe_prefill_tokens_saved: int = 0   # (N-1)x prompt tokens elided
    # paged KV-cache budget planning (virtual, page units): prompt
    # pages allocate once per cache-missed row (shared across the N
    # probe samples), sample pages free after the probe decode,
    # non-escalated rows free at the route decision, escalated rows'
    # prompt pages live until their ensemble wave finishes
    kv_pages_in_use: int = 0              # live pages, current
    kv_pages_highwater: int = 0           # peak live pages
    kv_pages_allocated: int = 0           # page allocations, total
    kv_prefill_tokens_reused: int = 0     # probe pages seeding ensemble
    # megastep accounting (step loop only; the wave path never masks):
    # decode ticks a lane sat masked because it finished mid-megastep
    masked_decode_steps: int = 0
    # deterministic virtual clock (the calibrated latency model)
    sequential_makespan_ms: float = 0.0   # sum of per-task latencies
    serial_batch_makespan_ms: float = 0.0  # batched, no overlap
    pipeline_makespan_ms: float = 0.0      # batched + stage overlap
    wall_ms: float = 0.0                   # host wall clock

    @property
    def speedup_vs_sequential(self) -> float:
        if self.pipeline_makespan_ms <= 0:
            return float("inf") if self.sequential_makespan_ms > 0 \
                else 1.0
        return self.sequential_makespan_ms / self.pipeline_makespan_ms

    @property
    def throughput_tasks_per_s(self) -> float:
        if self.pipeline_makespan_ms <= 0:
            return float("inf")
        return self.tasks / (self.pipeline_makespan_ms / 1e3)

    @property
    def ensemble_decode_row_reduction(self) -> float:
        """masked-path row-decodes / compacted row-decodes (>= 1)."""
        if self.ensemble_decode_rows <= 0:
            return float("inf") if self.ensemble_decode_rows_saved \
                else 1.0
        return (self.ensemble_decode_rows
                + self.ensemble_decode_rows_saved) \
            / self.ensemble_decode_rows

    @property
    def probe_prefill_reduction(self) -> float:
        """tiled-expansion prefill tokens / shared-prefix tokens."""
        if self.probe_prefill_tokens <= 0:
            return 1.0
        return (self.probe_prefill_tokens
                + self.probe_prefill_tokens_saved) \
            / self.probe_prefill_tokens


class ContinuousBatchingScheduler:
    """Continuous-batching, trace-equivalent ACAR serving scheduler."""

    def __init__(self, acfg: ACARConfig, probe: ModelBackend,
                 ensemble: Dict[str, ModelBackend],
                 store: Optional[ArtifactStore] = None,
                 experience: Optional[ExperienceStore] = None,
                 run_id: str = "acar",
                 policy: MicroBatchPolicy = MicroBatchPolicy(),
                 probe_cache_size: int = 512,
                 overlap: bool = True,
                 device_routing: bool = True,
                 kv_page_size: int = 8,
                 kv_decode_tokens: int = 8,
                 kv_layout: str = "dense",
                 kv_window: Optional[int] = None):
        self.acfg = acfg
        self.probe = probe
        self.ensemble = ensemble
        self.ensemble_order = list(ensemble)
        self.store = store
        self.experience = experience
        self.run_id = run_id
        self.policy = policy
        self.queue = AdmissionQueue(policy)
        self.cache = ProbeCache(probe_cache_size)
        self.overlap = overlap
        self.device_routing = device_routing
        # virtual paged-KV budget model (the engine measures the real
        # pool; the scheduler plans the same lifecycle in page units).
        # kv_layout prices the probe model's page layout: "dense" and
        # "quant" share the dense geometry (quant shrinks bytes per
        # page, not pages per row), "ring" caps a row's pages at the
        # window, "lanes" prices one recurrent-state lane per stream
        if kv_layout not in ("dense", "quant", "ring", "lanes"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}")
        if kv_layout == "ring" and not kv_window:
            raise ValueError("kv_layout='ring' needs kv_window")
        self.kv_page_size = kv_page_size
        self.kv_decode_tokens = kv_decode_tokens
        self.kv_layout = kv_layout
        self.kv_window = kv_window
        self.metrics = PromCounters()
        self.stats = SchedulerStats()

    # -- admission -----------------------------------------------------
    def submit(self, task: Task,
               arrival_time: Optional[int] = None) -> Request:
        req = self.queue.submit(task, arrival_time)
        self.metrics.inc("acar_sched_requests_total",
                         help="requests admitted to the queue")
        self.metrics.inc("acar_sched_tokens_admitted_total",
                         req.est_tokens,
                         help="estimated prompt tokens admitted")
        return req

    def submit_many(self, tasks: Sequence[Task]) -> List[Request]:
        return [self.submit(t) for t in tasks]

    # -- probe wave ----------------------------------------------------
    def _probe_wave(self, batch: MicroBatch) -> _ProbedBatch:
        rows: List[_ProbedRequest] = []
        wave_latency = 0.0
        for req in batch.requests:
            task = req.task
            exemplar, sim, ret_meta = retrieve_exemplar(
                self.acfg, self.experience, task)
            prompt = render_prompt(task.text, exemplar or "")
            key = ProbeCache.key(task, prompt, self.acfg)
            entry = self.cache.lookup(key)
            hit = entry is not None
            if entry is None:
                samples, results, lat = probe_task(
                    self.acfg, self.probe, task, prompt, sim)
                entry = _ProbeEntry(samples, results, lat)
                self.cache.insert(key, entry)
                wave_latency = max(wave_latency, lat)
            rows.append(_ProbedRequest(
                request=req, prompt=prompt, retrieval_sim=sim,
                ret_meta=ret_meta, probe_samples=entry.probe_samples,
                probe_results=entry.probe_results,
                probe_latency=entry.probe_latency, cache_hit=hit))

        self._route_rows(rows)
        # wave planning: the escalated-subset gather/bucket shapes are
        # decided here, on the prefetch thread, so the ensemble wave of
        # batch k pipelines against the probe wave of batch k+1 with no
        # planning work left on the critical path
        modes_np = np.asarray(
            [MODE_NAMES.index(r.mode) for r in rows], np.int32)
        plan = plan_compaction(modes_np, len(self.ensemble_order),
                               self.acfg.arena_lite_size)
        return _ProbedBatch(batch=batch, rows=rows,
                            wave_latency_ms=wave_latency, plan=plan)

    def _route_rows(self, rows: List[_ProbedRequest]) -> None:
        """sigma + mode per row. The routing decision runs on device
        over the whole wave (one padded XLA program); the recorded
        sigma uses the host Def. 1 value so trace hashes stay
        bit-identical with sequential execution (float32 vs float64
        rounding must not leak into the audit chain)."""
        if not rows:
            return
        answer_lists = [[p.answer for p in r.probe_samples]
                        for r in rows]
        for r, answers in zip(rows, answer_lists):
            r.sigma = sigma_fn(answers)
        if self.device_routing:
            n = self.acfg.n_probe_samples
            pad_b = self.policy.max_batch_size
            ids = np.zeros((pad_b, n), np.int32)
            for i, answers in enumerate(answer_lists):
                table: Dict[str, int] = {}
                for j, a in enumerate(answers):
                    ids[i, j] = table.setdefault(a, len(table))
            modes = np.asarray(
                route_batch(sigma_batch(jnp.asarray(ids))))
            for i, r in enumerate(rows):
                r.mode = MODE_NAMES[int(modes[i])]
        else:
            from repro.core.routing import execution_mode
            for r in rows:
                r.mode = execution_mode(r.sigma)

    # -- ensemble wave -------------------------------------------------
    def _ensemble_wave(self, probed: _ProbedBatch
                       ) -> Tuple[List[TaskOutcome], float]:
        outcomes: List[TaskOutcome] = []
        wave_latency = 0.0
        self._account_compaction(probed)
        for row in probed.rows:
            req, task = row.request, row.request.task
            sm = RunStateMachine(f"{self.run_id}/{task.task_id}")
            sm.advance(RunState.EXECUTING)
            probe_majority = majority_vote(
                [p.answer for p in row.probe_samples])
            executed = models_for_mode(row.mode, self.ensemble_order,
                                       self.acfg.arena_lite_size)
            responses, results, exec_latency = execute_ensemble(
                self.acfg, self.ensemble, executed, task, row.prompt,
                row.retrieval_sim)
            final_answer, semantic = aggregate(
                task, row.mode, probe_majority, row.probe_samples,
                row.probe_results, responses, results)
            sm.advance(RunState.VERIFYING)
            correct = semantic == task.gold
            cost, latency = task_cost_latency(
                row.probe_samples, responses, row.probe_latency,
                exec_latency)
            task_exec_latency = latency - row.probe_latency
            wave_latency = max(wave_latency, task_exec_latency)

            trace = build_trace(
                self.run_id, task, row.prompt, self.acfg.seed,
                row.sigma, row.mode, row.probe_samples, responses,
                final_answer, correct, cost, row.ret_meta,
                logical_time=req.admission_index,
                schedule={
                    "arrival": req.arrival_time,
                    "admitted": req.admission_index,
                    "batch_id": req.batch_id,
                    "batch_formed_at": probed.batch.formed_at,
                    "probe_cache_hit": row.cache_hit,
                })
            if self.store is not None:
                self.store.append(trace)
            sm.advance(RunState.COMPLETED)
            outcomes.append(TaskOutcome(
                trace=trace, latency_ms=latency,
                semantic_answer=semantic, correct=correct))

            saved = len(self.ensemble_order) - len(executed)
            self.stats.ensemble_calls_saved += saved
            self.stats.total_cost += cost
            self.stats.sequential_makespan_ms += latency
            self.metrics.inc("acar_sched_mode_total", mode=row.mode,
                             help="tasks routed per execution mode")
            self.metrics.inc("acar_sched_cost_total", cost,
                             mode=row.mode,
                             help="accumulated cost per execution mode")
            self.metrics.inc("acar_sched_task_latency_ms_total",
                             latency, mode=row.mode,
                             help="accumulated per-task latency "
                                  "(sequential-equivalent) per mode")
            self.metrics.inc("acar_sched_ensemble_calls_saved_total",
                             saved,
                             help="ensemble calls avoided vs full arena")
            if row.cache_hit:
                self.metrics.inc("acar_sched_probe_cache_hits_total",
                                 help="probe waves served from cache")
            else:
                self.metrics.inc("acar_sched_probe_cache_misses_total",
                                 help="probe waves decoded")
        self._release_kv_pages(probed)
        return outcomes, wave_latency

    def _account_compaction(self, probed: _ProbedBatch) -> None:
        """Record the wave's escalated-subset decode plan: how many
        rows escalated, how many row-decodes the compacted sub-batches
        issue vs the full-batch masked path, the shape-bucket occupancy
        (bounded XLA recompiles: one shape per power of two), and the
        shared-prefix probe prefill savings. Runs on the main thread —
        the probe wave may execute on the prefetch worker, so stats and
        metrics mutation stays out of ``_probe_wave``."""
        # shared-prefix probe: a cache-missed row prefills its prompt
        # once; the tiled (B*N) expansion would have prefilled it N
        # times
        n = self.acfg.n_probe_samples
        for row in probed.rows:
            if not row.cache_hit:
                est = row.request.est_tokens
                self.stats.probe_prefill_tokens += est
                self.stats.probe_prefill_tokens_saved += (n - 1) * est
                self.metrics.inc(
                    "acar_sched_probe_prefill_tokens_saved_total",
                    (n - 1) * est,
                    help="probe prefill tokens elided by shared-prefix "
                         "expansion")
        self._account_kv_pages(probed)
        plan = probed.plan
        if plan is None:
            return
        st = self.stats
        st.escalated_rows += plan.escalated_rows
        st.full_arena_rows += plan.full_arena_rows
        st.ensemble_decode_rows += plan.compacted_decode_rows
        st.ensemble_decode_rows_saved += plan.decode_rows_saved
        self.metrics.inc("acar_sched_escalated_rows_total",
                         plan.escalated_rows,
                         help="rows escalated past single_agent")
        self.metrics.inc("acar_sched_full_arena_rows_total",
                         plan.full_arena_rows,
                         help="rows escalated to the full arena")
        self.metrics.inc("acar_sched_ensemble_decode_rows_total",
                         plan.compacted_decode_rows,
                         help="row-decodes issued by compacted waves")
        self.metrics.inc(
            "acar_sched_ensemble_decode_rows_saved_total",
            plan.decode_rows_saved,
            help="row-decodes the masked full-batch path would have "
                 "issued but compaction elided")
        for mp in plan.members:
            if mp.bucket == 0:
                continue
            self.metrics.inc("acar_sched_bucket_waves_total",
                             bucket=str(mp.bucket),
                             help="member decode waves per shape bucket")
            self.metrics.set_gauge(
                "acar_sched_bucket_occupancy", mp.occupancy,
                bucket=str(mp.bucket),
                help="escalated-row fill of the last decode wave in "
                     "each shape bucket")

    def _row_page_plan(self, est_tokens: int) -> Tuple[int, int]:
        """(prompt pages, per-stream private pages) for one row under
        the planned layout — mirrors ``PagedKVServer.row_geometry``.
        Ring rows never hold more than the window's worth of pages
        however long the prompt runs (each stream rewrites its own
        capped snapshot); a lanes row is one fixed-size
        recurrent-state lane per stream regardless of length."""
        ps, e = self.kv_page_size, est_tokens
        if self.kv_layout == "ring":
            capped = pages_for(
                min(e + self.kv_decode_tokens, self.kv_window), ps)
            return capped, capped
        if self.kv_layout == "lanes":
            return 1, 1
        nbp = pages_for(e, ps)
        tail = pages_for(e + self.kv_decode_tokens, ps) - e // ps
        return nbp, tail

    def _account_kv_pages(self, probed: _ProbedBatch) -> None:
        """Virtual paged-KV lifecycle for one wave: prompt pages
        allocate once per cache-missed row (the N samples share them),
        sample-private pages free right after the probe decode,
        non-escalated rows free their prompt pages the moment the
        route resolves, and escalated rows keep theirs until the
        ensemble wave completes (``_release_kv_pages``) — seeding the
        prefill of any ensemble member that is the probe model, which
        is counted as reused prefill tokens."""
        n = self.acfg.n_probe_samples
        alloc = tails = esc_shared = resolved = reused = 0
        for row in probed.rows:
            if row.cache_hit:
                continue         # served from the probe cache: no KV
            e = row.request.est_tokens
            nbp, tail = self._row_page_plan(e)
            alloc += nbp + n * tail
            tails += n * tail
            if row.mode == "single_agent":
                resolved += nbp
            else:
                esc_shared += nbp
                executed = models_for_mode(
                    row.mode, self.ensemble_order,
                    self.acfg.arena_lite_size)
                if any(self.ensemble.get(m) is self.probe
                       for m in executed):
                    reused += e
        st = self.stats
        st.kv_pages_allocated += alloc
        st.kv_pages_in_use += alloc
        st.kv_pages_highwater = max(st.kv_pages_highwater,
                                    st.kv_pages_in_use)
        st.kv_pages_in_use -= tails + resolved
        st.kv_prefill_tokens_reused += reused
        probed.kv_escalated_pages = esc_shared
        if reused:
            self.metrics.inc(
                "acar_sched_kv_prefill_tokens_reused_total", reused,
                help="prompt prefill tokens ensemble members seed "
                     "from retained probe pages")
        self.metrics.set_gauge(
            "acar_sched_kv_pages_in_use", st.kv_pages_in_use,
            help="virtual KV pool pages live after wave planning")
        self.metrics.set_gauge(
            "acar_sched_kv_pages_highwater", st.kv_pages_highwater,
            help="virtual KV pool pages-in-use peak")

    def _release_kv_pages(self, probed: _ProbedBatch) -> None:
        self.stats.kv_pages_in_use -= probed.kv_escalated_pages
        probed.kv_escalated_pages = 0
        self.metrics.set_gauge(
            "acar_sched_kv_pages_in_use", self.stats.kv_pages_in_use,
            help="virtual KV pool pages live after wave planning")

    # -- main loop -----------------------------------------------------
    def run_until_idle(self) -> List[TaskOutcome]:
        """Drain the queue: form micro-batches, run the two-stage
        pipeline (probe wave of batch k+1 prefetched while the ensemble
        wave of batch k executes), emit traces in admission order."""
        t0 = time.perf_counter()
        batches = self.queue.drain_batches()
        outcomes: List[TaskOutcome] = []
        probe_end = 0.0          # virtual clock: probe stage frontier
        ens_end = 0.0            # virtual clock: ensemble stage frontier
        serial = 0.0

        executor: Optional[ThreadPoolExecutor] = None
        pending: Optional[Future] = None
        try:
            if self.overlap and len(batches) > 1:
                executor = ThreadPoolExecutor(max_workers=1)
            for k, batch in enumerate(batches):
                if pending is not None:
                    probed = pending.result()
                    pending = None
                else:
                    probed = self._probe_wave(batch)
                if executor is not None and k + 1 < len(batches):
                    pending = executor.submit(self._probe_wave,
                                              batches[k + 1])
                batch_outcomes, ens_latency = self._ensemble_wave(probed)
                outcomes.extend(batch_outcomes)

                # virtual two-stage pipeline bookkeeping: the probe
                # stage is serial with itself; an ensemble wave starts
                # once its probe wave AND the previous ensemble wave
                # are done
                probe_end = probe_end + probed.wave_latency_ms
                ens_end = max(probe_end, ens_end) + ens_latency
                serial += probed.wave_latency_ms + ens_latency

                self.stats.batches += 1
                self.stats.tasks += len(batch.requests)
                self.metrics.inc("acar_sched_batches_total",
                                 help="micro-batches executed")
                self.metrics.inc(
                    "acar_sched_probe_wave_ms_total",
                    probed.wave_latency_ms,
                    help="virtual probe-wave latency accumulated")
                self.metrics.inc(
                    "acar_sched_ensemble_wave_ms_total", ens_latency,
                    help="virtual ensemble-wave latency accumulated")
        finally:
            if pending is not None:
                pending.cancel()
            if executor is not None:
                executor.shutdown(wait=False)

        # each drain's virtual clock starts at 0, so successive drains
        # accumulate — keeping speedup/throughput honest for streaming
        # usage with repeated run_until_idle calls
        self.stats.serial_batch_makespan_ms += serial
        self.stats.pipeline_makespan_ms += ens_end
        self.stats.probe_cache_hits = self.cache.hits
        self.stats.probe_cache_misses = self.cache.misses
        self.stats.wall_ms += (time.perf_counter() - t0) * 1e3
        return outcomes

    def serve(self, tasks: Sequence[Task]) -> List[TaskOutcome]:
        """Convenience: submit every task, then drain."""
        self.submit_many(tasks)
        return self.run_until_idle()

    def render_metrics(self) -> str:
        return self.metrics.render()
