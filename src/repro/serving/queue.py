"""Admission queue + micro-batch formation for the ACAR scheduler.

Requests arrive with a logical arrival tick (deterministic: supplied by
the caller or auto-incremented), wait in FIFO order, and are admitted
into micro-batches under a joint budget:

* ``max_batch_size``   — at most B requests per micro-batch;
* ``max_batch_tokens`` — the summed prompt-token estimate must stay
  under the budget (the decode wave's memory/latency proxy);
* ``max_wait_ticks``   — a request older than this forces the batch to
  close even if under budget, bounding queueing latency.

Everything is host-side and deterministic — the queue introduces no
randomness, so batched execution stays replayable and auditable.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional

from repro.data.tasks import Task


def estimate_tokens(text: str) -> int:
    """Cheap prompt-length proxy (whitespace tokens, min 1)."""
    return max(1, len(text.split()))


@dataclass(frozen=True)
class MicroBatchPolicy:
    max_batch_size: int = 8
    max_batch_tokens: int = 4096
    max_wait_ticks: int = 16

    def __post_init__(self):
        if self.max_batch_size < 1:
            raise ValueError("max_batch_size must be >= 1")
        if self.max_batch_tokens < 1:
            raise ValueError("max_batch_tokens must be >= 1")


@dataclass
class Request:
    task: Task
    arrival_time: int
    request_id: str
    est_tokens: int
    admission_index: Optional[int] = None   # set when admitted
    batch_id: Optional[int] = None


@dataclass
class MicroBatch:
    batch_id: int
    requests: List[Request] = field(default_factory=list)
    formed_at: int = 0

    def __len__(self) -> int:
        return len(self.requests)

    @property
    def total_tokens(self) -> int:
        return sum(r.est_tokens for r in self.requests)


class AdmissionQueue:
    """FIFO admission queue with deterministic micro-batch formation."""

    def __init__(self, policy: MicroBatchPolicy = MicroBatchPolicy()):
        self.policy = policy
        self._pending: Deque[Request] = deque()
        self._tick = 0
        self._last_arrival = -1
        self._admitted = 0
        self._batches_formed = 0

    def __len__(self) -> int:
        return len(self._pending)

    @property
    def now(self) -> int:
        return self._tick

    def submit(self, task: Task,
               arrival_time: Optional[int] = None) -> Request:
        """Enqueue a task. ``arrival_time`` defaults to the next logical
        tick; explicit times must be monotone non-decreasing (FIFO)."""
        if arrival_time is None:
            arrival_time = self._tick
        # watermark check: the invariant must survive batch formation
        # draining the pending deque
        if arrival_time < self._last_arrival:
            raise ValueError(
                f"arrival_time {arrival_time} precedes the last "
                f"arrival ({self._last_arrival}); arrivals must be "
                "monotone")
        self._last_arrival = arrival_time
        self._tick = max(self._tick, arrival_time) + 1
        req = Request(task=task, arrival_time=arrival_time,
                      request_id=f"req-{arrival_time}-{task.task_id}",
                      est_tokens=estimate_tokens(task.text))
        self._pending.append(req)
        return req

    def ready(self, now: Optional[int] = None) -> bool:
        """Should a streaming loop close a micro-batch now? True when
        the requests that have *arrived by now* fill the size budget,
        or the oldest pending request has already waited
        ``max_wait_ticks`` — the standard fill-or-timeout
        continuous-batching trigger.

        Only arrived requests count toward the fill trigger: the
        pending deque may hold future arrivals (a stream is often
        submitted up front with explicit arrival ticks), and counting
        those fired ready() early — a burst whose last member lands
        exactly at the head's timeout instant (fill == timeout)
        admitted the head alone and the burst later, two batches where
        fill-or-timeout semantics demand one."""
        if not self._pending:
            return False
        if now is None:
            now = self._tick
        head = self._pending[0]
        if head.arrival_time > now:
            return False              # nothing has arrived yet
        arrived = 0
        for r in self._pending:
            if r.arrival_time > now:
                break
            arrived += 1
            if arrived >= self.policy.max_batch_size:
                return True
        return now - head.arrival_time >= self.policy.max_wait_ticks

    def peek(self) -> Optional[Request]:
        """Oldest pending request (not yet admitted), or None."""
        return self._pending[0] if self._pending else None

    def pop(self) -> Request:
        """Admit the single oldest pending request (FIFO). Admission
        indices are assigned from the same monotone counter
        ``form_batch`` uses, so row numbering is identical whether a
        stream is served wave-wise or one row at a time — the
        step-level loop's sampling key streams depend on that. A
        requeued request (see ``requeue``) keeps the index it already
        holds — the counter advanced at its first admission."""
        req = self._pending.popleft()
        if req.admission_index is None:
            req.admission_index = self._admitted
            self._admitted += 1
        return req

    def requeue(self, req: Request) -> None:
        """Return an admitted-but-unstarted request to the head of the
        queue (the step loop's admission-time ``PoolExhausted``
        rollback). The request keeps its already-assigned admission
        index, so its sampling key streams — and therefore its tokens
        — are unchanged when it re-admits."""
        self._pending.appendleft(req)

    @property
    def next_admission_index(self) -> int:
        """The admission index the next ``pop`` will return: a
        requeued head keeps the index it already holds, otherwise the
        monotone counter's next value. Crash recovery peeks this to
        restore already-retired rows without popping."""
        if self._pending and \
                self._pending[0].admission_index is not None:
            return self._pending[0].admission_index
        return self._admitted

    def form_batch(self, now: Optional[int] = None
                   ) -> Optional[MicroBatch]:
        """Admit the next micro-batch (FIFO) under the size/token
        budget; None when the queue is empty. A request is always
        admissible on its own even if it alone exceeds
        ``max_batch_tokens`` (oversized requests must not wedge the
        queue). Timing — *when* to close a batch — is ``ready``'s job;
        formation always packs up to the budget."""
        if not self._pending:
            return None
        if now is None:
            now = self._tick
        pol = self.policy
        batch = MicroBatch(batch_id=self._batches_formed, formed_at=now)
        tokens = 0
        while self._pending and len(batch) < pol.max_batch_size:
            head = self._pending[0]
            if head.arrival_time > now:
                break               # not yet arrived at this tick
            if batch.requests and \
                    tokens + head.est_tokens > pol.max_batch_tokens:
                break
            req = self.pop()
            req.batch_id = batch.batch_id
            tokens += req.est_tokens
            batch.requests.append(req)
        self._batches_formed += 1
        return batch

    def next_ready_at(self) -> Optional[int]:
        """Earliest tick at which ``ready`` will fire for the current
        pending set: when the size budget fills (the arrival of the
        batch-size-th pending request — the earliest tick at which
        ``max_batch_size`` requests have *arrived*, matching ready()'s
        arrived-only count) or when the oldest request's wait budget
        expires — whichever comes first.

        Boundary contract: an empty queue returns None (there is no
        meaningful instant after a drain — callers must not fast-
        forward a clock on it), an exactly-full queue returns
        ``min(fill, timeout)``, and when fill == timeout the two
        triggers coincide so the instant admits the whole burst as
        one batch (see ``ready``)."""
        if not self._pending:
            return None
        timeout = self._pending[0].arrival_time \
            + self.policy.max_wait_ticks
        if len(self._pending) >= self.policy.max_batch_size:
            fill = self._pending[
                self.policy.max_batch_size - 1].arrival_time
            return min(fill, timeout)
        return timeout

    def drain_batches(self) -> List[MicroBatch]:
        """Form micro-batches until the queue is empty, with
        ``ready()`` as the single admission trigger: the clock jumps
        to each batch's fill-or-timeout instant before it forms, so a
        drain is exactly the batch sequence a streaming loop ticking
        through the same arrivals would admit."""
        out = []
        now = self._tick
        while self._pending:
            # next_ready_at is never None here (pending is non-empty),
            # and the max() keeps the clock monotone when a batch was
            # already ready before the jump; at a fill == timeout
            # coincidence the instant admits the whole burst at once
            now = max(now, self.next_ready_at())
            assert self.ready(now)
            out.append(self.form_batch(now))
        return out
