"""Deterministic fault injection for the serving stack.

ACAR's determinism contract — per-row sampling key streams keyed by
global admission index, hash-chained decision traces — makes failure
handling *testable*: if faults fire at seeded, reproducible
coordinates, then every retry, quarantine, degraded route and crash
recovery is itself a deterministic function of (task stream, fault
plan), and the equivalence harness can hold fault-tolerant execution
to the same bit-identical standard as every other execution strategy
(``tests/harness/simulate.py --crash-at`` / ``--faults``).

A ``FaultPlan`` is a tuple of ``FaultSpec`` coordinates; the
``FaultInjector`` consumes them one firing at a time. Sites:

* ``admit_alloc``     — ``PoolExhausted`` during admission-time page
                        allocation (the step loop requeues the row,
                        preserving its admission index);
* ``member_launch``   — transient failure of a member decode-group
                        launch (bounded virtual-clock retries with
                        exponential backoff; exhausting
                        ``max_retries`` quarantines the member);
* ``member_nan``      — a member decode launch emits non-finite
                        logits (immediate quarantine + route
                        degradation over the healthy members);
* ``shard_loss``      — a mesh shard dies: its page pool is
                        abandoned and its resident rows are re-placed
                        on surviving shards, restarting from prefill
                        (admission-indexed keys make the restart
                        bit-identical);
* ``artifact_append`` — process kill mid-journal-append (a torn final
                        line, exercising ``ArtifactStore``'s
                        truncate-and-reverify recovery);
* ``crash``           — process kill at a tick boundary (recovery
                        replays the write-ahead journal:
                        ``BatchedACAREngine.recover``).

Injected faults fire *before* the real device launch they displace,
so a retried or fault-free run emits bit-identical token streams —
fault handling is an execution strategy, not a semantic change.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

SITES = ("admit_alloc", "member_launch", "member_nan", "shard_loss",
         "artifact_append", "crash")


class SimulatedCrash(RuntimeError):
    """Injected process kill. Escapes the step loop uncaught — exactly
    like a real SIGKILL, nothing downstream of the raise runs — so the
    journal holds only what was already fsync'd."""


@dataclass(frozen=True)
class FaultSpec:
    """One fault coordinate: fire ``count`` times at the first
    opportunity at-or-after step-loop tick ``tick`` (the loop's
    iteration counter, not the virtual clock). ``model``/``shard``
    narrow the match; ``None`` is a wildcard."""
    tick: int
    site: str
    model: Optional[str] = None
    shard: Optional[int] = None
    count: int = 1

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(
                f"unknown fault site {self.site!r}; one of {SITES}")
        if self.tick < 0:
            raise ValueError(f"fault tick must be >= 0, got {self.tick}")
        if self.count < 1:
            raise ValueError(
                f"fault count must be >= 1, got {self.count}")


@dataclass(frozen=True)
class FaultPlan:
    """A seeded, deterministic fault schedule plus the retry/SLO policy
    the step loop applies while the plan is active.

    * ``max_retries``   — member decode-group launch attempts beyond
      the first before the member is quarantined;
    * ``backoff_base``  — virtual-clock units the first retry waits;
      attempt ``k`` waits ``backoff_base << (k - 1)`` (exponential);
    * ``slo_deadline``  — optional per-row virtual-clock budget
      (retire within ``slo_deadline`` ticks of arrival or the row is
      aborted with a traced, null-answer retirement).
    """
    specs: Tuple[FaultSpec, ...] = ()
    seed: int = 0
    max_retries: int = 3
    backoff_base: int = 1
    slo_deadline: Optional[int] = None

    @classmethod
    def crash_at(cls, tick: int, *, torn: bool = False) -> "FaultPlan":
        """Kill the process at step-loop tick ``tick``; ``torn=True``
        kills mid-journal-append instead (a torn final line)."""
        site = "artifact_append" if torn else "crash"
        return cls(specs=(FaultSpec(tick=tick, site=site),))

    @classmethod
    def generate(cls, seed: int, *, n_faults: int = 4,
                 max_tick: int = 64,
                 models: Sequence[str] = (),
                 shards: int = 0,
                 sites: Optional[Sequence[str]] = None,
                 slo_deadline: Optional[int] = None) -> "FaultPlan":
        """Seeded random plan for chaos testing. Defaults exclude the
        terminal sites (``crash``/``artifact_append``) so a generated
        plan always drains; pass ``sites`` to include them."""
        rng = np.random.default_rng(seed)
        pool = list(sites) if sites is not None else [
            s for s in SITES if s not in ("crash", "artifact_append")]
        if not shards:
            pool = [s for s in pool if s != "shard_loss"]
        if not models:
            pool = [s for s in pool
                    if s not in ("member_launch", "member_nan")]
        specs: List[FaultSpec] = []
        for _ in range(n_faults):
            if not pool:
                break
            site = pool[int(rng.integers(len(pool)))]
            model = None
            shard = None
            if site in ("member_launch", "member_nan"):
                model = models[int(rng.integers(len(models)))]
            elif site == "shard_loss":
                shard = int(rng.integers(shards))
            specs.append(FaultSpec(
                tick=int(rng.integers(max_tick)), site=site,
                model=model, shard=shard))
        specs.sort(key=lambda sp: (sp.tick, sp.site, str(sp.model),
                                   -1 if sp.shard is None else sp.shard))
        return cls(specs=tuple(specs), seed=seed,
                   slo_deadline=slo_deadline)


class FaultInjector:
    """Consume-once firing engine for a ``FaultPlan``.

    ``fire(site, tick, ...)`` scans the plan in spec order and
    consumes the first spec matching (site, tick >= spec.tick,
    model/shard wildcards) with firings remaining. Everything is a
    pure function of the call sequence, so a replayed run fires every
    fault at identical coordinates — the property the chaos test and
    the degraded-fleet harness leg assert."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._remaining = [sp.count for sp in plan.specs]
        self.fired: List[dict] = []

    def fire(self, site: str, tick: int, *,
             model: Optional[str] = None,
             shard: Optional[int] = None) -> Optional[FaultSpec]:
        for i, sp in enumerate(self.plan.specs):
            if (self._remaining[i] <= 0 or sp.site != site
                    or tick < sp.tick):
                continue
            if sp.model is not None and sp.model != model:
                continue
            if sp.shard is not None and sp.shard != shard:
                continue
            self._remaining[i] -= 1
            self.fired.append({
                "site": site, "tick": int(tick), "model": model,
                "shard": shard, "spec_tick": sp.tick})
            return sp
        return None

    @property
    def exhausted(self) -> bool:
        """True once every planned firing has been consumed."""
        return all(r <= 0 for r in self._remaining)
