"""Sharded serving subsystem: a request-parallel device mesh over
per-shard paged KV pools.

Every serving path before this module executed on a single device; the
ROADMAP's north star wants multi-host sharded waves. This module adds
the data-parallel half of that story, exercised on CPU via
``--xla_force_host_platform_device_count``:

* **ServingMesh** — a ``("data",)`` or 2-D ``("data", "model")`` jax
  mesh (built by ``launch.mesh.make_serving_mesh``). Each *data* row
  is one *shard*: an independent serving executor with its own slice
  of every model's KV page pool. When the mesh carries a "model" axis
  each shard's program additionally runs tensor-parallel across its
  model columns: member params shard column-parallel per
  ``sharding.tp.tp_param_specs`` and each page array's kv-head axis
  shards over "model", so per-device page bytes — and therefore
  per-member pool capacity at fixed memory — scale with the
  model-axis size.
* **ShardedPagedKVServer** — one model's paged KV state partitioned
  across the mesh. The device page arrays are one global
  ``(n_shards, L, P, page, KV, Dh)`` array sharded over ``"data"``;
  the host-side allocation state is *per shard*: each shard has its
  own ``PagePool`` (shard-local page ids and LIFO free list), its own
  prompt prefix cache, its own scratch region, and its own ``KVStats``
  — exposed through ``_ShardView`` objects that present the exact
  ``PagedKVServer`` host interface, so the step loop's page plumbing
  (alloc/retain/release/prefix insert/evict-retry) runs unmodified
  against any shard.

Why this is bit-equivalent to single-device execution: a row's decode
is a pure function of (its prompt, its pages, its admission-indexed
sampling key stream). Pages never alias across shards (each shard's
block tables index only its own pool slice), the sampling keys are
keyed by *global* admission index (``sampler.probe_row_keys`` /
``member_row_keys``), and every host decision (placement, grouping,
retirement) is deterministic — so moving a row to a different shard
changes where its bytes live, never what tokens it samples.
``tests/harness/simulate.py --sharded`` proves it end to end:
identical record hashes and artifact-chain heads between data=4 and
single-device step execution over the 200-task duplicate-bearing
stream.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.serving.kv_pool import (
    KVStats, PagedKVServer, PagePool, PagePoolError)


class ServingMesh:
    """A ("data",) or ("data", "model") request-parallel serving mesh.

    Thin wrapper over the jax ``Mesh`` adding the placement helpers
    the sharded servers need: ``replicate`` / ``place_params`` (member
    weights) and ``shard_rows`` (per-shard operand stacks, leading
    axis mapped to ``"data"``).
    """

    def __init__(self, data: Optional[int] = None, mesh=None, *,
                 model: int = 1):
        if mesh is None:
            from repro.launch.mesh import make_serving_mesh
            mesh = make_serving_mesh(data, model=model)
        names = tuple(mesh.axis_names)
        if names not in (("data",), ("data", "model")):
            raise ValueError(
                f"serving mesh must be ('data',) or "
                f"('data', 'model'), got {mesh.axis_names}")
        self.mesh = mesh

    @property
    def n_shards(self) -> int:
        return int(self.mesh.shape["data"])

    @property
    def n_model(self) -> int:
        if "model" not in self.mesh.axis_names:
            return 1
        return int(self.mesh.shape["model"])

    def replicate(self, tree):
        """Place a pytree fully replicated across the mesh."""
        return jax.device_put(tree, NamedSharding(self.mesh, P()))

    def place_params(self, cfg: ModelConfig, params):
        """Place one member's params: replicated over "data", and —
        when the mesh carries a "model" axis — column-parallel
        tensor-sharded over it (``sharding.tp.tp_param_specs``; a
        leaf's spec is all-``None`` on the data axis, so replication
        over "data" composes for free). Validates divisibility up
        front so a bad fleet/mesh pairing fails at placement, not
        mid-trace."""
        if self.n_model == 1:
            return self.replicate(params)
        from repro.sharding import tp_check_cfg, tp_param_specs
        tp_check_cfg(cfg, self.n_model)
        specs = tp_param_specs(params)
        return jax.tree_util.tree_map(
            lambda leaf, spec: jax.device_put(
                leaf, NamedSharding(self.mesh, spec)),
            params, specs)

    def shard_rows(self, x):
        """Place an array with its leading axis sharded over "data"."""
        return jax.device_put(x, NamedSharding(self.mesh, P("data")))


class _ShardView(PagedKVServer):
    """One shard's host-side face of a ``ShardedPagedKVServer``.

    Inherits every allocation/prefix-cache/stats method from
    ``PagedKVServer`` — the pool, scratch, and prefix cache are
    genuinely shard-local — but never owns device arrays (``pages``
    stays ``None``; the parent holds the one global sharded pytree)
    and delegates capacity rebuilds to the parent, which must resize
    every shard in lockstep to keep the global arrays rectangular.
    """

    def __init__(self, parent: "ShardedPagedKVServer", index: int,
                 cfg: ModelConfig, **kw):
        self.parent = parent
        self.index = index
        super().__init__(cfg, **kw)

    def _rebuild(self, num_pages: int, scratch_pages: int, key) -> None:
        self.parent._rebuild_all(num_pages, scratch_pages, key)


class ShardedPagedKVServer:
    """Paged KV serving state for one model, partitioned over a
    ``ServingMesh``: shard-local pools/block-tables/free-lists on the
    host, one globally-sharded page array pair on the device mesh."""

    def __init__(self, cfg: ModelConfig, smesh: ServingMesh, *,
                 page_size: int = 8, prefix_cache_entries: int = 32):
        self.cfg = cfg
        self.smesh = smesh
        self.page_size = int(page_size)
        self.pages = None
        self.shards: List[_ShardView] = [
            _ShardView(self, i, cfg, page_size=page_size,
                       prefix_cache_entries=prefix_cache_entries)
            for i in range(smesh.n_shards)]
        self.layout = self.shards[0].layout
        if self.layout not in ("dense", "quant"):
            # ring arenas and recurrent lanes stay single-device for
            # now; ShardedStepLoopRunner routes those members to its
            # dense fallback instead
            raise ValueError(
                f"config {cfg.name!r} resolves to layout "
                f"{self.layout!r}; sharded paged serving supports "
                "'dense' and 'quant' only")

    @property
    def k_pages(self):
        """Global K code leaf (capacity probes read per-shard bytes off
        this); ``self.pages`` is the full layout pytree."""
        return None if self.pages is None else self.pages.get("k")

    @property
    def v_pages(self):
        return None if self.pages is None else self.pages.get("v")

    @property
    def n_shards(self) -> int:
        return self.smesh.n_shards

    @property
    def model_name(self) -> str:
        return self.shards[0].stats.model

    def set_model_name(self, name: str) -> None:
        for sv in self.shards:
            sv.stats.model = name

    # -- capacity ------------------------------------------------------
    def ensure_capacity_stream(self, max_rows_per_shard: int,
                               prompt_len: int, lanes_per_row: int,
                               max_new_tokens: int) -> None:
        """Size every shard for the step loop's per-shard steady state.
        All shards are always sized identically (the global page array
        is rectangular), so checking shard 0 suffices; a rebuild goes
        through ``_rebuild_all`` and resizes the whole set."""
        self.shards[0].ensure_capacity_stream(
            max_rows_per_shard, prompt_len, lanes_per_row,
            max_new_tokens)

    def _rebuild_all(self, num_pages: int, scratch_pages: int,
                     key) -> None:
        self._rebuild_host(num_pages, scratch_pages, key)
        self._rebuild_device(num_pages)

    def _rebuild_host(self, num_pages: int, scratch_pages: int,
                      key) -> None:
        """Shard-local host state: one fresh pool + scratch region per
        shard. Split from the device rebuild so the pool-invariant
        property tests can exercise shard-local free lists without
        allocating device arrays."""
        # phase 1: every shard must be rebuildable before any is
        # touched — a half-rebuilt shard set would desync the global
        # array from the pools
        for sv in self.shards:
            if sv.lost:
                raise PagePoolError(
                    f"cannot rebuild shard {sv.index}: marked lost")
            if sv.pool is not None:
                sv.drop_prefix_cache()
                old_scratch = sv._scratch.size \
                    if sv._scratch is not None else 0
                if sv.pool.pages_in_use > old_scratch:
                    raise PagePoolError(
                        f"cannot rebuild shard {sv.index}'s page pool "
                        "while pages are held")
        for sv in self.shards:
            sv.pool = PagePool(num_pages, self.page_size)
            sv._scratch = sv.pool.alloc(scratch_pages)
            sv._capacity_key = key
            sv.stats.pool_pages = num_pages
            sv._sample_usage()

    def _rebuild_device(self, num_pages: int) -> None:
        import jax.numpy as jnp
        cfg = self.cfg
        shape = (self.n_shards, cfg.num_layers, num_pages,
                 self.page_size, cfg.num_kv_heads,
                 cfg.resolved_head_dim)
        dt = jnp.int8 if self.layout == "quant" \
            else jnp.dtype(cfg.dtype)
        if self.smesh.n_model > 1:
            # 2-D mesh: each model column holds only its kv-head
            # slice of every page — per-device page bytes shrink by
            # the model-axis size, which is exactly where the
            # capacity gain of tensor parallelism comes from
            code_spec = P("data", None, None, None, "model", None)
            scale_spec = P("data", None, None, None, "model")
        else:
            code_spec = scale_spec = P("data")

        def put(a, spec):
            return jax.device_put(
                a, NamedSharding(self.smesh.mesh, spec))

        pages = {"k": put(jnp.zeros(shape, dt), code_spec),
                 "v": put(jnp.zeros(shape, dt), code_spec)}
        if self.layout == "quant":
            pages["k_scale"] = put(jnp.zeros(shape[:-1], jnp.float32),
                                   scale_spec)
            pages["v_scale"] = put(jnp.zeros(shape[:-1], jnp.float32),
                                   scale_spec)
        self.pages = pages

    # -- fault simulation ----------------------------------------------
    def mark_shard_lost(self, index: int) -> None:
        """Simulated shard loss: the shard's host-side pool is
        abandoned in place (pages are forfeited, not released — a dead
        host cannot run a release path) and every allocation or prefix
        lookup against it fails from now on. The device array is left
        as-is; displaced rows re-prefill on surviving shards."""
        self.shards[index].lost = True

    # -- accounting ----------------------------------------------------
    def aggregate_stats(self) -> KVStats:
        """Summed accounting across shards. Pool capacity, high-water
        and reuse counters add (each shard is an independent pool);
        ``page_bytes``/``page_size`` are per-page quantities and stay
        as-is."""
        base = self.shards[0].stats
        out = KVStats(model=base.model, page_size=base.page_size,
                      page_bytes=base.page_bytes)
        for sv in self.shards:
            st = sv.stats
            out.pool_pages += st.pool_pages
            out.pages_in_use += st.pages_in_use
            out.pages_highwater += st.pages_highwater
            out.probe_pages_highwater += st.probe_pages_highwater
            out.prefill_tokens_computed += st.prefill_tokens_computed
            out.prefill_tokens_reused_probe += \
                st.prefill_tokens_reused_probe
            out.prefill_tokens_reused_prefix += \
                st.prefill_tokens_reused_prefix
            out.cow_forks += st.cow_forks
            out.prefill_chunks += st.prefill_chunks
            out.prefix_evictions += st.prefix_evictions
        return out

    def per_shard_pages_in_use(self) -> Dict[int, int]:
        return {sv.index: sv.pool.pages_in_use
                for sv in self.shards if sv.pool is not None}

    def pad_fork_ids(self, k: int) -> np.ndarray:
        """(n_shards, k) self-copy page ids (each shard's first scratch
        page) — the identity fork for shards with nothing to fork."""
        out = np.empty((self.n_shards, k), np.int32)
        for sv in self.shards:
            out[sv.index] = int(sv._scratch[0])
        return out
