"""Paged KV-cache subsystem: ref-counted page pool, block tables, and
prefix sharing between the probe and ensemble stages.

Dense serving caches pay full-ensemble *memory* even when the router
avoids full-ensemble *compute*: every wave allocates contiguous
``prompt+new``-length caches padded to the batch max, and the probe's
shared-prefix expansion physically copies each prefill N times
(``tile_cache``). This module replaces that with page-granular
allocation:

* **PagePool** — a fixed pool of ``page_size``-token pages with
  reference counts. Allocation, retain, and release are host-side and
  deterministic; double frees and use-after-free raise typed errors
  instead of corrupting block tables; exhaustion raises
  ``PoolExhausted`` with the pool left intact.
* **Block tables** — each sequence maps logical token positions to
  pages via an int32 table row. The N probe samples of one prompt
  *share* the read-only full prompt pages (one ref per owner) and only
  hold private pages for the region decode writes — the partial
  prompt-tail page is materialised per sample by a copy-on-write fork.
* **PagedKVServer** — per-model serving state: the device page pytree
  (``self.pages``), the pool, a ref-counted prompt-prefix cache
  (cross-request reuse of identical prompts), and the wave
  orchestration the engine calls: ``probe_wave`` (N samples, one
  prefill, shared prefix pages), ``reuse_decode`` (ensemble member
  seeded from the probe's retained prompt pages — prefill skipped
  entirely), and ``generate`` (paged single-sample waves for members
  that cannot reuse).

The page pytree is heterogeneous — one server serves one *layout*
(``models.transformer.resolve_layout``), and every leaf keeps the
page/lane id on axis 1 so one fork/scatter program covers them all:

* ``"dense"`` — ``{k, v}`` of ``(L, P, page_size, KV, Dh)`` in the
  model dtype (the original layout).
* ``"quant"`` — ``{k, v}`` int8 codes plus ``{k_scale, v_scale}``
  ``(L, P, page_size, KV)`` f32 per-vector scale planes
  (``models.attention.quantize_kv``): Dh + 4 bytes per position
  instead of 2*Dh — roughly 2x the rows per device at the same pool
  bytes.
* ``"ring"`` — dense-dtype pages, but a row only ever holds
  ``ceil(min(prompt+new, window)/page_size)`` pages; positions wrap in
  place (sliding-window members' KV stops growing with the prompt).
* ``"lanes"`` — recurrent-state lanes for SSM members:
  ``{conv: (L, LANES, conv_width-1, d_in), h: (L, LANES, d_in, N)}``;
  a "page" is one sequence's whole state, block tables are one lane id
  wide, and fork is a state copy.

Bit-equivalence contract: the paged execution path produces tokens
bit-identical to the dense path. The gathered page view sliced to the
dense cache length feeds the *same* ``decode_attention`` math with the
same shapes, stale bytes in recycled pages are masked before softmax
(positions > pos go to the same -1e30 the dense path's zeros go to),
and prefill/logit reuse only ever returns values the dense path would
recompute bit-for-bit (same model, same prompt, batch-invariant
configs — ``models.transformer.paged_supported`` gates the families
where this holds). ``tests/harness/simulate.py --paged-kv`` checks the
contract end to end on record hashes and artifact-chain heads.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.compaction import bucket_size


# ----------------------------------------------------------------------
# errors
# ----------------------------------------------------------------------
class PagePoolError(RuntimeError):
    """Base class for page-pool accounting violations."""


class PoolExhausted(PagePoolError):
    """Allocation request exceeds the pool's free pages. The pool state
    is unchanged: no partial allocation escapes."""


class PageAccountingError(PagePoolError):
    """Refcount violation: double free or retain of a free page."""


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` positions."""
    return -(-int(n_tokens) // page_size) if n_tokens > 0 else 0


@dataclass(frozen=True)
class RowGeometry:
    """Per-layout page accounting for one row of ``prompt_len`` tokens
    decoding up to ``max_new`` more. ``n_shared`` prompt pages are
    read-only shareable across a row's lanes; ``nbp`` pages hold the
    prompt (shared + the COW tail for dense/quant, the whole private
    snapshot for ring/lanes); each decode lane holds ``n_tail``
    private pages and a block table ``nb`` entries wide; the decode
    attention span is ``cache_len`` positions."""
    n_shared: int
    tail_tokens: int        # tokens in the COW prompt-tail page
    nbp: int                # prompt pages per row
    nb: int                 # block-table width per decode lane
    n_tail: int             # private pages per decode lane
    cache_len: int          # decode attention span (dense-equivalent)


# ----------------------------------------------------------------------
# page pool
# ----------------------------------------------------------------------
class PagePool:
    """Fixed pool of KV pages with reference counting.

    Pure host-side bookkeeping (the device arrays live in
    ``PagedKVServer``); every operation is deterministic — the free
    list is LIFO, so identical call sequences produce identical page
    ids, which the bit-equivalence harness relies on.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError("num_pages and page_size must be positive")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self._refs = np.zeros(self.num_pages, np.int32)
        # LIFO free list, seeded so the first allocations are 0,1,2,...
        self._free: List[int] = list(range(self.num_pages - 1, -1, -1))
        self.highwater = 0
        self.allocs_total = 0

    # ------------------------------------------------------------------
    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def refcount(self, page: int) -> int:
        return int(self._refs[page])

    def alloc(self, n: int) -> np.ndarray:
        """Allocate ``n`` pages (refcount 1 each). All-or-nothing:
        raises ``PoolExhausted`` leaving the pool untouched."""
        n = int(n)
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            raise PoolExhausted(
                f"requested {n} pages, {len(self._free)} free "
                f"(pool {self.num_pages} x {self.page_size} tokens)")
        ids = [self._free.pop() for _ in range(n)]
        self._refs[ids] = 1
        self.allocs_total += n
        if self.pages_in_use > self.highwater:
            self.highwater = self.pages_in_use
        return np.asarray(ids, np.int32)

    def retain(self, pages: Sequence[int]) -> None:
        """Add one reference to each page (prefix sharing / COW fork)."""
        for p in np.asarray(pages, np.int64).ravel():
            if self._refs[p] <= 0:
                raise PageAccountingError(
                    f"retain of free page {int(p)}")
            self._refs[p] += 1

    def release(self, pages: Sequence[int]) -> None:
        """Drop one reference from each page; pages hitting zero return
        to the free list (LIFO)."""
        for p in np.asarray(pages, np.int64).ravel():
            if self._refs[p] <= 0:
                raise PageAccountingError(
                    f"double free of page {int(p)}")
            self._refs[p] -= 1
            if self._refs[p] == 0:
                self._free.append(int(p))


# ----------------------------------------------------------------------
# stats
# ----------------------------------------------------------------------
@dataclass
class KVStats:
    """Measured paged-KV accounting for one model's server."""
    model: str = ""
    page_size: int = 0
    page_bytes: int = 0                 # bytes per page (all layers, K+V)
    pool_pages: int = 0
    pages_in_use: int = 0               # latest sample
    pages_highwater: int = 0            # pool-lifetime peak
    # peak pages *referenced by one probe wave* (shared prompt pages +
    # canonical tails + sample-private pages) — the apples-to-apples
    # counterpart of the dense tile_cache working set, excluding
    # prefix-cache retention (a separate, evictable memory/compute
    # trade reported through pages_in_use)
    probe_pages_highwater: int = 0
    prefill_tokens_computed: int = 0
    prefill_tokens_reused_probe: int = 0    # probe -> ensemble seeding
    prefill_tokens_reused_prefix: int = 0   # cross-request prompt reuse
    cow_forks: int = 0                  # partial-tail pages materialised
    prefill_chunks: int = 0             # chunked-prefill calls issued
    prefix_evictions: int = 0           # cost-aware cache evictions

    @property
    def prefill_tokens_reused(self) -> int:
        return (self.prefill_tokens_reused_probe
                + self.prefill_tokens_reused_prefix)

    @property
    def probe_highwater_bytes(self) -> int:
        return self.probe_pages_highwater * self.page_bytes


def dense_tile_slots(batch: int, n_samples: int, prompt_len: int,
                     max_new_tokens: int) -> int:
    """Token slots the dense ``tile_cache`` probe path materialises for
    one wave: every sample row holds a full prompt+new cache."""
    return batch * n_samples * (prompt_len + max_new_tokens)


# ----------------------------------------------------------------------
# prefix cache (cross-request reuse of identical prompts)
# ----------------------------------------------------------------------
@dataclass
class _PrefixEntry:
    shared: np.ndarray          # full prompt pages (read-only, cache ref)
    tail: Optional[int]         # pristine partial prompt-tail page
    logits0: np.ndarray         # (V,) last-position prefill logits
    tokens: int = 0             # prompt tokens a hit saves recomputing
    hits: int = 0               # hits since insertion
    seq: int = 0                # insertion order (deterministic ties)

    @property
    def pages_held(self) -> int:
        return int(self.shared.size) + (1 if self.tail is not None
                                        else 0)

    @property
    def score(self) -> float:
        """Cost-aware retention value: prefill tokens saved per page
        held. A hit saves ``tokens`` of prefill; un-hit entries carry
        one optimistic expected hit so fresh prompts are not evicted
        before they can prove themselves. Pure LRU evicts a hot long
        prompt to keep a cold short one — this ranks by what eviction
        actually costs."""
        return self.tokens * (self.hits + 1) / max(self.pages_held, 1)


# ----------------------------------------------------------------------
# probe wave handle
# ----------------------------------------------------------------------
@dataclass
class ProbeHandle:
    """Per-wave retention of the probe's prompt pages, so ensemble
    members sharing the probe's model can seed their prefill from them.
    Rows are released the moment their route resolves (``resolve``);
    ``close`` drops whatever is left."""
    server: "PagedKVServer"
    prompt_len: int
    max_new_tokens: int
    logits0: np.ndarray                    # (B, V) float32, host copy
    shared: List[np.ndarray]               # per row: full prompt pages
    tails: List[Optional[int]]             # per row: canonical tail page
    live: np.ndarray                       # (B,) bool — handle refs held

    @property
    def batch(self) -> int:
        return self.live.shape[0]

    def _release_row(self, r: int) -> None:
        if not self.live[r]:
            return
        self.server.pool.release(self.shared[r])
        if self.tails[r] is not None:
            self.server.pool.release([self.tails[r]])
        self.live[r] = False

    def resolve(self, keep_rows: Sequence[int]) -> None:
        """Free every row's prompt pages except ``keep_rows`` (the rows
        some ensemble member will still seed its prefill from)."""
        keep = set(int(r) for r in keep_rows)
        for r in range(self.batch):
            if r not in keep:
                self._release_row(r)
        self.server._sample_usage()

    def close(self) -> None:
        for r in range(self.batch):
            self._release_row(r)
        self.server._sample_usage()


# ----------------------------------------------------------------------
# per-model paged serving state
# ----------------------------------------------------------------------
class PagedKVServer:
    """Paged KV serving state for one model (one set of params).

    Owns the device page arrays, the pool, and the prefix cache. The
    engine creates one server per distinct ``params`` object, so an
    ensemble member that *is* the probe model shares the probe's
    server — which is what makes probe->ensemble prefill reuse sound
    (KV caches are functions of params, not just configs).
    """

    def __init__(self, cfg: ModelConfig, *, page_size: int = 8,
                 prefix_cache_entries: int = 32):
        from repro.models.transformer import resolve_layout
        layout = resolve_layout(cfg)
        if layout is None:
            raise ValueError(
                f"config {cfg.name!r} is not paged-KV capable "
                "(GQA, linear cache, and dense or gather-dispatch "
                "MoE FFN required; hybrid stacks stay dense)")
        self.cfg = cfg
        self.layout = layout
        self.page_size = int(page_size)
        # ring pages are per-lane snapshots and lane state depends on
        # the decode horizon; neither is a reusable read-only prompt
        # prefix, so the prefix cache only runs for dense/quant
        self.prefix_cache_entries = (int(prefix_cache_entries)
                                     if layout in ("dense", "quant")
                                     else 0)
        # simulated shard loss (serving/faults.py): a lost server's
        # pool is abandoned — allocations and prefix hits must fail so
        # no new row can land on dead pages
        self.lost = False
        self.pool: Optional[PagePool] = None
        self.pages = None
        self._scratch: Optional[np.ndarray] = None
        self._prefix: "OrderedDict[bytes, _PrefixEntry]" = OrderedDict()
        self._prefix_seq = 0
        self._capacity_key: Optional[Tuple[int, int, int, int]] = None
        self.stats = KVStats(
            model=cfg.name, page_size=self.page_size,
            page_bytes=self._page_bytes())

    def _page_bytes(self) -> int:
        """Bytes one page (all layers) holds under this layout — the
        unit the capacity benchmarks compare across layouts."""
        cfg = self.cfg
        itemsize = np.dtype(cfg.dtype).itemsize
        per_vec = {
            "dense": 2 * cfg.resolved_head_dim * itemsize,
            "ring": 2 * cfg.resolved_head_dim * itemsize,
            # int8 codes + one f32 scale, K and V
            "quant": 2 * (cfg.resolved_head_dim + 4),
        }
        if self.layout == "lanes":
            from repro.models import ssm as ssm_mod
            d_in, _, n = ssm_mod.ssm_dims(cfg)
            w = cfg.ssm.conv_width
            return cfg.num_layers * ((w - 1) * d_in * itemsize
                                     + d_in * n * 4)
        return (cfg.num_layers * self.page_size * cfg.num_kv_heads
                * per_vec[self.layout])

    # -- layout geometry -----------------------------------------------
    @property
    def chunked(self) -> bool:
        """Whether this server's rows may prefill in chunks. Only the
        dense layout composes chunk-by-chunk bit-identically (a quant
        chunk would re-read the already-quantised prefix, ring pages
        overwrite in place, lane prefill is one scan)."""
        return self.layout == "dense"

    def row_geometry(self, prompt_len: int,
                     max_new_tokens: int) -> RowGeometry:
        """Page accounting for one row under this server's layout."""
        s, m, ps = int(prompt_len), int(max_new_tokens), self.page_size
        if self.layout in ("dense", "quant"):
            n_shared = s // ps
            nbp = pages_for(s, ps)
            nb = pages_for(s + m, ps)
            return RowGeometry(
                n_shared=n_shared, tail_tokens=s - n_shared * ps,
                nbp=nbp, nb=nb, n_tail=nb - n_shared, cache_len=s + m)
        if self.layout == "ring":
            cl = min(s + m, self.cfg.window)
            nb = pages_for(cl, ps)
            # no read-only sharing: every lane writes into (and wraps
            # over) its whole snapshot, so lanes fork all nbp pages
            return RowGeometry(n_shared=0, tail_tokens=0, nbp=nb,
                               nb=nb, n_tail=nb, cache_len=cl)
        # lanes: one "page" is the row's entire recurrent state
        return RowGeometry(n_shared=0, tail_tokens=0, nbp=1, nb=1,
                           n_tail=1, cache_len=s + m)

    def table_width(self, prompt_len: int, max_new_tokens: int) -> int:
        """Block-table width one decode lane needs."""
        return self.row_geometry(prompt_len, max_new_tokens).nb

    # -- back-compat array views ---------------------------------------
    @property
    def k_pages(self):
        """Dense/quant K page leaf (capacity probes and older callers
        read this; ``self.pages`` is the full layout pytree)."""
        return None if self.pages is None else self.pages.get("k")

    @property
    def v_pages(self):
        return None if self.pages is None else self.pages.get("v")

    # -- capacity ------------------------------------------------------
    def _ensure_capacity(self, batch: int, prompt_len: int,
                         n_samples: int, max_new_tokens: int) -> None:
        """(Re)build the pool + device arrays when a wave's worst case
        outgrows them. Only called at wave boundaries, when no handle
        holds pages; rebuilding drops the prefix cache."""
        key = (batch, prompt_len, n_samples, max_new_tokens)
        if self._capacity_key is not None and self.pool is not None:
            b0, s0, n0, m0 = self._capacity_key
            if (batch <= b0 and prompt_len <= s0 and n_samples <= n0
                    and max_new_tokens <= m0):
                return
            key = (max(batch, b0), max(prompt_len, s0),
                   max(n_samples, n0), max(max_new_tokens, m0))
        b, s, n, m = key
        g = self.row_geometry(s, m)
        need = (b * (g.nbp + n * g.n_tail)  # probe wave peak
                + b * g.nb                  # one member wave (own prefill)
                + self.prefix_cache_entries * g.nbp
                + g.nbp)                    # scratch pages
        self._rebuild(need, g.nbp, key)

    def _zero_pages(self, num_pages: int) -> dict:
        """Freshly zeroed page pytree for this layout (axis 1 = page
        or lane id on every leaf)."""
        import jax.numpy as jnp
        cfg = self.cfg
        if self.layout == "lanes":
            from repro.models import ssm as ssm_mod
            d_in, _, n = ssm_mod.ssm_dims(cfg)
            w = cfg.ssm.conv_width
            # mirrors _ssm_cache's per-layer dtypes exactly: the lane
            # scatter/gather must be a pure copy of the dense state
            return {
                "conv": jnp.zeros((cfg.num_layers, num_pages, w - 1,
                                   d_in), jnp.dtype(cfg.dtype)),
                "h": jnp.zeros((cfg.num_layers, num_pages, d_in, n),
                               jnp.float32),
            }
        shape = (cfg.num_layers, num_pages, self.page_size,
                 cfg.num_kv_heads, cfg.resolved_head_dim)
        dt = jnp.int8 if self.layout == "quant" \
            else jnp.dtype(cfg.dtype)
        pages = {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
        if self.layout == "quant":
            pages["k_scale"] = jnp.zeros(shape[:-1], jnp.float32)
            pages["v_scale"] = jnp.zeros(shape[:-1], jnp.float32)
        return pages

    def _rebuild(self, num_pages: int, scratch_pages: int,
                 key: Tuple[int, int, int, int]) -> None:
        if self.pool is not None:
            self.drop_prefix_cache()
            # only the OLD scratch pages may remain held — they are
            # discarded with the old pool; user pages must be gone
            # (comparing against the NEW scratch size would spuriously
            # reject rebuilds that shrink the scratch region)
            old_scratch = self._scratch.size \
                if self._scratch is not None else 0
            if self.pool.pages_in_use > old_scratch:
                raise PagePoolError(
                    "cannot rebuild the page pool while pages are held")
        self.pool = PagePool(num_pages, self.page_size)
        self.pages = self._zero_pages(num_pages)
        # scratch pages soak up the prefill writes of bucket-padding
        # rows; never referenced by any block table, so their contents
        # are dead by construction
        self._scratch = self.pool.alloc(scratch_pages)
        self._capacity_key = key
        self.stats.pool_pages = num_pages
        self._sample_usage()

    def drop_prefix_cache(self) -> None:
        for entry in self._prefix.values():
            self.pool.release(entry.shared)
            if entry.tail is not None:
                self.pool.release([entry.tail])
        self._prefix.clear()

    def _sample_usage(self) -> None:
        if self.pool is not None:
            self.stats.pages_in_use = self.pool.pages_in_use
            self.stats.pages_highwater = self.pool.highwater

    # -- prefix cache --------------------------------------------------
    def _prefix_lookup(self, key: bytes) -> Optional[_PrefixEntry]:
        if self.lost or self.prefix_cache_entries <= 0:
            return None
        entry = self._prefix.get(key)
        if entry is not None:
            entry.hits += 1
        return entry

    def _release_entry(self, entry: _PrefixEntry) -> None:
        self.pool.release(entry.shared)
        if entry.tail is not None:
            self.pool.release([entry.tail])

    def _evict_one(self) -> bool:
        """Evict the lowest-value cache entry (prefill-tokens-saved
        per page held; insertion order breaks ties deterministically).
        Returns False when the cache is empty."""
        if not self._prefix:
            return False
        worst = min(self._prefix,
                    key=lambda k: (self._prefix[k].score,
                                   self._prefix[k].seq))
        self._release_entry(self._prefix.pop(worst))
        self.stats.prefix_evictions += 1
        return True

    def evict_prefix(self, pages_needed: int) -> int:
        """Cost-aware eviction until at least ``pages_needed`` pages
        are free, the cache is empty, or an eviction round frees
        nothing. Returns the free-page count — the pages *actually on
        the free list*, not a sum of victims' page counts, because a
        victim whose pages are still shared (refcount > 1: a live row
        retained the same prompt pages via a cache hit) releases
        references without returning a single page. Stopping on a
        no-progress round keeps the retry loop from shredding every
        remaining entry — and from spinning — when shared victims
        cannot free what the caller needs. The engine's
        evict-and-retry loop calls this on ``PoolExhausted`` instead
        of failing the wave."""
        while self.pool.free_pages < pages_needed:
            before = self.pool.free_pages
            if not self._evict_one():
                break                  # cache empty
            if self.pool.free_pages == before:
                break                  # victim fully shared: no progress
        self._sample_usage()
        return self.pool.free_pages

    def _alloc_retry(self, n: int) -> np.ndarray:
        """Pool allocation with the evict-and-retry loop: on
        exhaustion, shed prefix-cache entries (cheapest value per page
        first) and retry; ``PoolExhausted`` escapes once the cache is
        empty — or eviction stops making progress (shared victims free
        nothing) — and the pages genuinely do not exist."""
        if self.lost:
            raise PoolExhausted(
                f"server {self.stats.model!r} is marked lost; its "
                "page pool is abandoned")
        try:
            return self.pool.alloc(n)
        except PoolExhausted:
            if self.evict_prefix(n) < n:
                raise
            return self.pool.alloc(n)

    def _prefix_insert(self, key: bytes, shared: np.ndarray,
                       tail: Optional[int],
                       logits0: np.ndarray, tokens: int = 0) -> None:
        if self.prefix_cache_entries <= 0:
            return
        old = self._prefix.pop(key, None)
        if old is not None:
            self._release_entry(old)
        self.pool.retain(shared)
        if tail is not None:
            self.pool.retain([tail])
        self._prefix[key] = _PrefixEntry(
            shared=shared.copy(), tail=tail, logits0=logits0.copy(),
            tokens=tokens, seq=self._prefix_seq)
        self._prefix_seq += 1
        while len(self._prefix) > self.prefix_cache_entries:
            self._evict_one()

    # -- waves ---------------------------------------------------------
    def probe_wave(self, params: dict, ids: np.ndarray, n_samples: int,
                   *, max_new_tokens: int, temperature: float,
                   key, eos_id: int, pad_id: int, row_keys=None):
        """N-sample probe decode with shared prefix pages.

        One prefill per *distinct uncached* prompt; the N samples of a
        prompt share its full prompt pages read-only and fork only the
        partial tail page (COW). ``row_keys`` ((B*N, 2) uint32) opts
        into per-row sampling key streams (batch-composition
        invariant — required for step-loop equivalence). Returns
        ``(GenerateOutput, ProbeHandle)`` — the handle retains each
        row's prompt pages for ensemble prefill seeding until
        ``resolve``/``close``.
        """
        import jax.numpy as jnp
        from repro.sampling import sampler as S

        b, s = ids.shape
        n = int(n_samples)
        self._ensure_capacity(b, s, n, max_new_tokens)
        g = self.row_geometry(s, max_new_tokens)

        # 1. prompt pages per row: prefix-cache hit -> retain the
        # cached pages; miss -> allocate fresh ones (handle-owned).
        # Ring/lanes rows have no read-only shareable prefix — all
        # g.nbp prompt pages ride in ``shared`` and every lane forks
        # the lot. On any failure, release whatever this wave
        # accumulated so an exhausted pool stays consistent instead of
        # leaking refs.
        shared_rows: List[np.ndarray] = []
        tail_rows: List[Optional[int]] = []
        miss: List[int] = []
        hits: List[Optional[_PrefixEntry]] = []
        try:
            for r in range(b):
                entry = self._prefix_lookup(ids[r].tobytes())
                hits.append(entry)
                if entry is not None:
                    self.pool.retain(entry.shared)
                    if entry.tail is not None:
                        self.pool.retain([entry.tail])
                    shared_rows.append(entry.shared.copy())
                    tail_rows.append(entry.tail)
                    self.stats.prefill_tokens_reused_prefix += s
                else:
                    pages = self._alloc_retry(g.nbp)
                    if self.layout in ("dense", "quant"):
                        shared_rows.append(pages[:g.n_shared])
                        tail_rows.append(int(pages[g.n_shared])
                                         if g.tail_tokens else None)
                    else:
                        shared_rows.append(pages)
                        tail_rows.append(None)
                    miss.append(r)

            # 2. one prefill over the uncached rows, gathered into a
            # power-of-two bucket (padding rows replicate row 0 and
            # write into scratch pages)
            logits0 = np.zeros((b, self.cfg.vocab_size), np.float32)
            if miss:
                bucket = bucket_size(len(miss), cap=b)
                rows_idx = miss + [miss[0]] * (bucket - len(miss))
                pf_table = np.empty((bucket, g.nbp), np.int32)
                for i, r in enumerate(rows_idx):
                    if i < len(miss):
                        row_pages = list(shared_rows[r])
                        if g.tail_tokens:
                            row_pages.append(tail_rows[r])
                        pf_table[i] = row_pages
                    else:
                        pf_table[i] = self._scratch[:g.nbp]
                if self.layout == "lanes":
                    lg, self.pages = S.prefill_lanes(
                        self.cfg, params, jnp.asarray(ids[rows_idx]),
                        self.pages, jnp.asarray(pf_table[:, 0]))
                else:
                    lg, self.pages = S.prefill_paged(
                        self.cfg, params, jnp.asarray(ids[rows_idx]),
                        self.pages, jnp.asarray(pf_table),
                        cache_len=(s + max_new_tokens
                                   if self.layout == "ring" else None))
                lg = np.asarray(lg, np.float32)
                for i, r in enumerate(miss):
                    logits0[r] = lg[i]
                # the bucket's padding rows compute real (discarded)
                # prefill work — count what actually ran
                self.stats.prefill_tokens_computed += bucket * s
            for r, entry in enumerate(hits):
                if entry is not None:
                    logits0[r] = entry.logits0

            # 3. publish the fresh rows to the prefix cache
            for r in miss:
                self._prefix_insert(ids[r].tobytes(), shared_rows[r],
                                    tail_rows[r], logits0[r],
                                    tokens=s)
        except BaseException:
            for r in range(len(shared_rows)):
                self.pool.release(shared_rows[r])
                if tail_rows[r] is not None:
                    self.pool.release([tail_rows[r]])
            self._sample_usage()
            raise

        # the handle owns the prompt pages from here on: any failure
        # below must close it (and drop the sample pages) so a raised
        # decode cannot wedge the pool with orphaned refcounts
        handle = ProbeHandle(
            server=self, prompt_len=s, max_new_tokens=max_new_tokens,
            logits0=logits0, shared=shared_rows, tails=tail_rows,
            live=np.ones(b, bool))
        sample_tails = None
        try:
            # 4. sample-private pages + fork of the prompt state each
            # lane mutates: dense/quant COW-fork only the partial tail
            # page; ring/lanes fork the row's whole prompt snapshot
            sample_tails = self._alloc_retry(b * n * g.n_tail).reshape(
                b, n, g.n_tail)
            self.stats.probe_pages_highwater = max(
                self.stats.probe_pages_highwater,
                b * (g.nbp + n * g.n_tail))
            block_table = np.empty((b * n, g.nb), np.int32)
            for r in range(b):
                for j in range(n):
                    block_table[r * n + j, :g.n_shared] = \
                        shared_rows[r][:g.n_shared]
                    block_table[r * n + j, g.n_shared:] = \
                        sample_tails[r, j]
            if g.tail_tokens:
                src = np.repeat(
                    np.asarray([tail_rows[r] for r in range(b)],
                               np.int32), n)
                dst = sample_tails[:, :, 0].reshape(-1)
                self.pages = S.fork_pages(
                    self.pages, jnp.asarray(src), jnp.asarray(dst))
                self.stats.cow_forks += b * n
            elif g.n_shared == 0:
                src = np.repeat(
                    np.stack([shared_rows[r] for r in range(b)]),
                    n, axis=0).reshape(-1)
                dst = sample_tails.reshape(-1)
                self.pages = S.fork_pages(
                    self.pages, jnp.asarray(src), jnp.asarray(dst))
                self.stats.cow_forks += b * n * g.nbp

            # 5. decode the expanded (B*N) wave over the shared pages
            out, self.pages = S.decode_paged(
                self.cfg, params,
                jnp.asarray(np.repeat(logits0, n, axis=0)),
                self.pages, jnp.asarray(block_table),
                key, start_pos=s, max_new_tokens=max_new_tokens,
                temperature=temperature, eos_id=eos_id, pad_id=pad_id,
                row_keys=None if row_keys is None
                else jnp.asarray(row_keys))
            # force tokens to host before the sample pages are recycled
            out = type(out)(tokens=np.asarray(out.tokens),
                            logprobs=np.asarray(out.logprobs),
                            lengths=np.asarray(out.lengths))
        except BaseException:
            if sample_tails is not None:
                self.pool.release(sample_tails.reshape(-1))
            handle.close()
            raise
        self.pool.release(sample_tails.reshape(-1))
        self._sample_usage()
        return out, handle

    def reuse_decode(self, params: dict, handle: ProbeHandle,
                     rows: Sequence[int], *, max_new_tokens: int,
                     temperature: float, key, eos_id: int,
                     pad_id: int, row_keys=None):
        """Ensemble decode seeded from the probe's prompt pages:
        prefill is skipped entirely — the rows' shared pages are read
        in place, the canonical tail page is COW-forked per decode row,
        and the prefill logits come from the probe's host snapshot.
        Only sound when ``params`` is the probe's params (the engine
        keys servers by params identity)."""
        import jax.numpy as jnp
        from repro.sampling import sampler as S

        rows = [int(r) for r in rows]
        s = handle.prompt_len
        g = self.row_geometry(s, max_new_tokens)
        if self.layout == "ring":
            g0 = self.row_geometry(s, handle.max_new_tokens)
            if g.cache_len != g0.cache_len:
                raise ValueError(
                    "ring prompt snapshot was compressed for "
                    f"cache_len {g0.cache_len}; a member decoding to "
                    f"cache_len {g.cache_len} cannot reuse it")
        for r in rows:
            if not handle.live[r]:
                raise PageAccountingError(
                    f"reuse of row {r} after its pages were resolved")

        nr = len(rows)
        tails = self._alloc_retry(nr * g.n_tail).reshape(nr, g.n_tail)
        try:
            block_table = np.empty((nr, g.nb), np.int32)
            for i, r in enumerate(rows):
                block_table[i, :g.n_shared] = \
                    handle.shared[r][:g.n_shared]
                block_table[i, g.n_shared:] = tails[i]
            if g.tail_tokens:
                src = np.asarray([handle.tails[r] for r in rows],
                                 np.int32)
                self.pages = S.fork_pages(
                    self.pages, jnp.asarray(src),
                    jnp.asarray(tails[:, 0]))
                self.stats.cow_forks += nr
            elif g.n_shared == 0:
                src = np.stack([handle.shared[r]
                                for r in rows]).reshape(-1)
                self.pages = S.fork_pages(
                    self.pages, jnp.asarray(src),
                    jnp.asarray(tails.reshape(-1)))
                self.stats.cow_forks += nr * g.nbp
            out, self.pages = S.decode_paged(
                self.cfg, params, jnp.asarray(handle.logits0[rows]),
                self.pages, jnp.asarray(block_table),
                key, start_pos=s, max_new_tokens=max_new_tokens,
                temperature=temperature, eos_id=eos_id, pad_id=pad_id,
                row_keys=None if row_keys is None
                else jnp.asarray(row_keys))
            out = type(out)(tokens=np.asarray(out.tokens),
                            logprobs=np.asarray(out.logprobs),
                            lengths=np.asarray(out.lengths))
        finally:
            self.pool.release(tails.reshape(-1))
            self._sample_usage()
        self.stats.prefill_tokens_reused_probe += s * nr
        return out

    def generate(self, params: dict, ids: np.ndarray, *,
                 max_new_tokens: int, temperature: float, key,
                 eos_id: int, pad_id: int, row_keys=None):
        """Paged single-sample generation (a probe wave with N=1 whose
        prompt pages are released immediately): page-granular
        allocation instead of batch-max padded dense caches, plus
        cross-request prompt reuse through the prefix cache."""
        out, handle = self.probe_wave(
            params, ids, 1, max_new_tokens=max_new_tokens,
            temperature=temperature, key=key, eos_id=eos_id,
            pad_id=pad_id, row_keys=row_keys)
        handle.close()
        return out

    # -- step-level serving support ------------------------------------
    def stream_row_pages(self, prompt_len: int, lanes_per_row: int,
                         max_new_tokens: int) -> int:
        """Worst-case pages one step-loop row holds on this server:
        the prompt pages (shared read-only for dense/quant, the
        forkable snapshot for ring/lanes) plus each lane's private
        pages (probe samples and seeded ensemble decodes alike)."""
        g = self.row_geometry(prompt_len, max_new_tokens)
        return g.nbp + lanes_per_row * g.n_tail

    def ensure_capacity_stream(self, max_rows: int, prompt_len: int,
                               lanes_per_row: int,
                               max_new_tokens: int) -> None:
        """Size the pool for the step-level loop's steady state:
        ``max_rows`` rows concurrently resident, each holding its
        prompt pages and ``lanes_per_row`` private lanes — plus the
        prefix cache and a scratch region wide enough for a *full*
        (prompt+decode) pad-row block table. Must run before any pages
        are held (the step loop calls it at admission of the first
        row)."""
        g = self.row_geometry(prompt_len, max_new_tokens)
        need = (max_rows * self.stream_row_pages(
                    prompt_len, lanes_per_row, max_new_tokens)
                + self.prefix_cache_entries * g.nbp
                + g.nb)                              # scratch pages
        key = (max_rows, prompt_len, lanes_per_row, max_new_tokens)
        if (self._capacity_key is not None and self.pool is not None
                and self.pool.num_pages >= need
                and self._scratch is not None
                and self._scratch.size >= g.nb):
            return
        self._rebuild(need, g.nb, key)
