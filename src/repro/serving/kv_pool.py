"""Paged KV-cache subsystem: ref-counted page pool, block tables, and
prefix sharing between the probe and ensemble stages.

Dense serving caches pay full-ensemble *memory* even when the router
avoids full-ensemble *compute*: every wave allocates contiguous
``prompt+new``-length caches padded to the batch max, and the probe's
shared-prefix expansion physically copies each prefill N times
(``tile_cache``). This module replaces that with page-granular
allocation:

* **PagePool** — a fixed pool of ``page_size``-token pages with
  reference counts. Allocation, retain, and release are host-side and
  deterministic; double frees and use-after-free raise typed errors
  instead of corrupting block tables; exhaustion raises
  ``PoolExhausted`` with the pool left intact.
* **Block tables** — each sequence maps logical token positions to
  pages via an int32 table row. The N probe samples of one prompt
  *share* the read-only full prompt pages (one ref per owner) and only
  hold private pages for the region decode writes — the partial
  prompt-tail page is materialised per sample by a copy-on-write fork.
* **PagedKVServer** — per-model serving state: the device page arrays
  (``(L, P, page_size, KV, Dh)`` for K and V), the pool, a ref-counted
  prompt-prefix cache (cross-request reuse of identical prompts), and
  the wave orchestration the engine calls: ``probe_wave`` (N samples,
  one prefill, shared prefix pages), ``reuse_decode`` (ensemble member
  seeded from the probe's retained prompt pages — prefill skipped
  entirely), and ``generate`` (paged single-sample waves for members
  that cannot reuse).

Bit-equivalence contract: the paged execution path produces tokens
bit-identical to the dense path. The gathered page view sliced to the
dense cache length feeds the *same* ``decode_attention`` math with the
same shapes, stale bytes in recycled pages are masked before softmax
(positions > pos go to the same -1e30 the dense path's zeros go to),
and prefill/logit reuse only ever returns values the dense path would
recompute bit-for-bit (same model, same prompt, batch-invariant
configs — ``models.transformer.paged_supported`` gates the families
where this holds). ``tests/harness/simulate.py --paged-kv`` checks the
contract end to end on record hashes and artifact-chain heads.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.compaction import bucket_size


# ----------------------------------------------------------------------
# errors
# ----------------------------------------------------------------------
class PagePoolError(RuntimeError):
    """Base class for page-pool accounting violations."""


class PoolExhausted(PagePoolError):
    """Allocation request exceeds the pool's free pages. The pool state
    is unchanged: no partial allocation escapes."""


class PageAccountingError(PagePoolError):
    """Refcount violation: double free or retain of a free page."""


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` positions."""
    return -(-int(n_tokens) // page_size) if n_tokens > 0 else 0


# ----------------------------------------------------------------------
# page pool
# ----------------------------------------------------------------------
class PagePool:
    """Fixed pool of KV pages with reference counting.

    Pure host-side bookkeeping (the device arrays live in
    ``PagedKVServer``); every operation is deterministic — the free
    list is LIFO, so identical call sequences produce identical page
    ids, which the bit-equivalence harness relies on.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError("num_pages and page_size must be positive")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self._refs = np.zeros(self.num_pages, np.int32)
        # LIFO free list, seeded so the first allocations are 0,1,2,...
        self._free: List[int] = list(range(self.num_pages - 1, -1, -1))
        self.highwater = 0
        self.allocs_total = 0

    # ------------------------------------------------------------------
    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def refcount(self, page: int) -> int:
        return int(self._refs[page])

    def alloc(self, n: int) -> np.ndarray:
        """Allocate ``n`` pages (refcount 1 each). All-or-nothing:
        raises ``PoolExhausted`` leaving the pool untouched."""
        n = int(n)
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            raise PoolExhausted(
                f"requested {n} pages, {len(self._free)} free "
                f"(pool {self.num_pages} x {self.page_size} tokens)")
        ids = [self._free.pop() for _ in range(n)]
        self._refs[ids] = 1
        self.allocs_total += n
        if self.pages_in_use > self.highwater:
            self.highwater = self.pages_in_use
        return np.asarray(ids, np.int32)

    def retain(self, pages: Sequence[int]) -> None:
        """Add one reference to each page (prefix sharing / COW fork)."""
        for p in np.asarray(pages, np.int64).ravel():
            if self._refs[p] <= 0:
                raise PageAccountingError(
                    f"retain of free page {int(p)}")
            self._refs[p] += 1

    def release(self, pages: Sequence[int]) -> None:
        """Drop one reference from each page; pages hitting zero return
        to the free list (LIFO)."""
        for p in np.asarray(pages, np.int64).ravel():
            if self._refs[p] <= 0:
                raise PageAccountingError(
                    f"double free of page {int(p)}")
            self._refs[p] -= 1
            if self._refs[p] == 0:
                self._free.append(int(p))


# ----------------------------------------------------------------------
# stats
# ----------------------------------------------------------------------
@dataclass
class KVStats:
    """Measured paged-KV accounting for one model's server."""
    model: str = ""
    page_size: int = 0
    page_bytes: int = 0                 # bytes per page (all layers, K+V)
    pool_pages: int = 0
    pages_in_use: int = 0               # latest sample
    pages_highwater: int = 0            # pool-lifetime peak
    # peak pages *referenced by one probe wave* (shared prompt pages +
    # canonical tails + sample-private pages) — the apples-to-apples
    # counterpart of the dense tile_cache working set, excluding
    # prefix-cache retention (a separate, evictable memory/compute
    # trade reported through pages_in_use)
    probe_pages_highwater: int = 0
    prefill_tokens_computed: int = 0
    prefill_tokens_reused_probe: int = 0    # probe -> ensemble seeding
    prefill_tokens_reused_prefix: int = 0   # cross-request prompt reuse
    cow_forks: int = 0                  # partial-tail pages materialised
    prefill_chunks: int = 0             # chunked-prefill calls issued
    prefix_evictions: int = 0           # cost-aware cache evictions

    @property
    def prefill_tokens_reused(self) -> int:
        return (self.prefill_tokens_reused_probe
                + self.prefill_tokens_reused_prefix)

    @property
    def probe_highwater_bytes(self) -> int:
        return self.probe_pages_highwater * self.page_bytes


def dense_tile_slots(batch: int, n_samples: int, prompt_len: int,
                     max_new_tokens: int) -> int:
    """Token slots the dense ``tile_cache`` probe path materialises for
    one wave: every sample row holds a full prompt+new cache."""
    return batch * n_samples * (prompt_len + max_new_tokens)


# ----------------------------------------------------------------------
# prefix cache (cross-request reuse of identical prompts)
# ----------------------------------------------------------------------
@dataclass
class _PrefixEntry:
    shared: np.ndarray          # full prompt pages (read-only, cache ref)
    tail: Optional[int]         # pristine partial prompt-tail page
    logits0: np.ndarray         # (V,) last-position prefill logits
    tokens: int = 0             # prompt tokens a hit saves recomputing
    hits: int = 0               # hits since insertion
    seq: int = 0                # insertion order (deterministic ties)

    @property
    def pages_held(self) -> int:
        return int(self.shared.size) + (1 if self.tail is not None
                                        else 0)

    @property
    def score(self) -> float:
        """Cost-aware retention value: prefill tokens saved per page
        held. A hit saves ``tokens`` of prefill; un-hit entries carry
        one optimistic expected hit so fresh prompts are not evicted
        before they can prove themselves. Pure LRU evicts a hot long
        prompt to keep a cold short one — this ranks by what eviction
        actually costs."""
        return self.tokens * (self.hits + 1) / max(self.pages_held, 1)


# ----------------------------------------------------------------------
# probe wave handle
# ----------------------------------------------------------------------
@dataclass
class ProbeHandle:
    """Per-wave retention of the probe's prompt pages, so ensemble
    members sharing the probe's model can seed their prefill from them.
    Rows are released the moment their route resolves (``resolve``);
    ``close`` drops whatever is left."""
    server: "PagedKVServer"
    prompt_len: int
    max_new_tokens: int
    logits0: np.ndarray                    # (B, V) float32, host copy
    shared: List[np.ndarray]               # per row: full prompt pages
    tails: List[Optional[int]]             # per row: canonical tail page
    live: np.ndarray                       # (B,) bool — handle refs held

    @property
    def batch(self) -> int:
        return self.live.shape[0]

    def _release_row(self, r: int) -> None:
        if not self.live[r]:
            return
        self.server.pool.release(self.shared[r])
        if self.tails[r] is not None:
            self.server.pool.release([self.tails[r]])
        self.live[r] = False

    def resolve(self, keep_rows: Sequence[int]) -> None:
        """Free every row's prompt pages except ``keep_rows`` (the rows
        some ensemble member will still seed its prefill from)."""
        keep = set(int(r) for r in keep_rows)
        for r in range(self.batch):
            if r not in keep:
                self._release_row(r)
        self.server._sample_usage()

    def close(self) -> None:
        for r in range(self.batch):
            self._release_row(r)
        self.server._sample_usage()


# ----------------------------------------------------------------------
# per-model paged serving state
# ----------------------------------------------------------------------
class PagedKVServer:
    """Paged KV serving state for one model (one set of params).

    Owns the device page arrays, the pool, and the prefix cache. The
    engine creates one server per distinct ``params`` object, so an
    ensemble member that *is* the probe model shares the probe's
    server — which is what makes probe->ensemble prefill reuse sound
    (KV caches are functions of params, not just configs).
    """

    def __init__(self, cfg: ModelConfig, *, page_size: int = 8,
                 prefix_cache_entries: int = 32):
        from repro.models.transformer import paged_supported
        if not paged_supported(cfg):
            raise ValueError(
                f"config {cfg.name!r} is not paged-KV capable "
                "(GQA, linear cache, and dense or gather-dispatch "
                "MoE FFN required)")
        self.cfg = cfg
        self.page_size = int(page_size)
        self.prefix_cache_entries = int(prefix_cache_entries)
        # simulated shard loss (serving/faults.py): a lost server's
        # pool is abandoned — allocations and prefix hits must fail so
        # no new row can land on dead pages
        self.lost = False
        self.pool: Optional[PagePool] = None
        self.k_pages = None
        self.v_pages = None
        self._scratch: Optional[np.ndarray] = None
        self._prefix: "OrderedDict[bytes, _PrefixEntry]" = OrderedDict()
        self._prefix_seq = 0
        self._capacity_key: Optional[Tuple[int, int, int, int]] = None
        itemsize = np.dtype(cfg.dtype).itemsize
        self.stats = KVStats(
            model=cfg.name, page_size=self.page_size,
            page_bytes=(2 * cfg.num_layers * self.page_size
                        * cfg.num_kv_heads * cfg.resolved_head_dim
                        * itemsize))

    # -- capacity ------------------------------------------------------
    def _ensure_capacity(self, batch: int, prompt_len: int,
                         n_samples: int, max_new_tokens: int) -> None:
        """(Re)build the pool + device arrays when a wave's worst case
        outgrows them. Only called at wave boundaries, when no handle
        holds pages; rebuilding drops the prefix cache."""
        key = (batch, prompt_len, n_samples, max_new_tokens)
        if self._capacity_key is not None and self.pool is not None:
            b0, s0, n0, m0 = self._capacity_key
            if (batch <= b0 and prompt_len <= s0 and n_samples <= n0
                    and max_new_tokens <= m0):
                return
            key = (max(batch, b0), max(prompt_len, s0),
                   max(n_samples, n0), max(max_new_tokens, m0))
        b, s, n, m = key
        ps = self.page_size
        nbp = pages_for(s, ps)
        nb = pages_for(s + m, ps)
        n_tail = nb - s // ps
        need = (b * (nbp + n * n_tail)      # probe wave peak
                + b * nb                    # one member wave (own prefill)
                + self.prefix_cache_entries * nbp
                + nbp)                      # scratch pages
        self._rebuild(need, nbp, key)

    def _rebuild(self, num_pages: int, scratch_pages: int,
                 key: Tuple[int, int, int, int]) -> None:
        import jax.numpy as jnp
        if self.pool is not None:
            self.drop_prefix_cache()
            # only the OLD scratch pages may remain held — they are
            # discarded with the old pool; user pages must be gone
            # (comparing against the NEW scratch size would spuriously
            # reject rebuilds that shrink the scratch region)
            old_scratch = self._scratch.size \
                if self._scratch is not None else 0
            if self.pool.pages_in_use > old_scratch:
                raise PagePoolError(
                    "cannot rebuild the page pool while pages are held")
        cfg = self.cfg
        self.pool = PagePool(num_pages, self.page_size)
        dt = jnp.dtype(cfg.dtype)
        shape = (cfg.num_layers, num_pages, self.page_size,
                 cfg.num_kv_heads, cfg.resolved_head_dim)
        self.k_pages = jnp.zeros(shape, dt)
        self.v_pages = jnp.zeros(shape, dt)
        # scratch pages soak up the prefill writes of bucket-padding
        # rows; never referenced by any block table, so their contents
        # are dead by construction
        self._scratch = self.pool.alloc(scratch_pages)
        self._capacity_key = key
        self.stats.pool_pages = num_pages
        self._sample_usage()

    def drop_prefix_cache(self) -> None:
        for entry in self._prefix.values():
            self.pool.release(entry.shared)
            if entry.tail is not None:
                self.pool.release([entry.tail])
        self._prefix.clear()

    def _sample_usage(self) -> None:
        if self.pool is not None:
            self.stats.pages_in_use = self.pool.pages_in_use
            self.stats.pages_highwater = self.pool.highwater

    # -- prefix cache --------------------------------------------------
    def _prefix_lookup(self, key: bytes) -> Optional[_PrefixEntry]:
        if self.lost or self.prefix_cache_entries <= 0:
            return None
        entry = self._prefix.get(key)
        if entry is not None:
            entry.hits += 1
        return entry

    def _release_entry(self, entry: _PrefixEntry) -> None:
        self.pool.release(entry.shared)
        if entry.tail is not None:
            self.pool.release([entry.tail])

    def _evict_one(self) -> bool:
        """Evict the lowest-value cache entry (prefill-tokens-saved
        per page held; insertion order breaks ties deterministically).
        Returns False when the cache is empty."""
        if not self._prefix:
            return False
        worst = min(self._prefix,
                    key=lambda k: (self._prefix[k].score,
                                   self._prefix[k].seq))
        self._release_entry(self._prefix.pop(worst))
        self.stats.prefix_evictions += 1
        return True

    def evict_prefix(self, pages_needed: int) -> int:
        """Cost-aware eviction until at least ``pages_needed`` pages
        are free, the cache is empty, or an eviction round frees
        nothing. Returns the free-page count — the pages *actually on
        the free list*, not a sum of victims' page counts, because a
        victim whose pages are still shared (refcount > 1: a live row
        retained the same prompt pages via a cache hit) releases
        references without returning a single page. Stopping on a
        no-progress round keeps the retry loop from shredding every
        remaining entry — and from spinning — when shared victims
        cannot free what the caller needs. The engine's
        evict-and-retry loop calls this on ``PoolExhausted`` instead
        of failing the wave."""
        while self.pool.free_pages < pages_needed:
            before = self.pool.free_pages
            if not self._evict_one():
                break                  # cache empty
            if self.pool.free_pages == before:
                break                  # victim fully shared: no progress
        self._sample_usage()
        return self.pool.free_pages

    def _alloc_retry(self, n: int) -> np.ndarray:
        """Pool allocation with the evict-and-retry loop: on
        exhaustion, shed prefix-cache entries (cheapest value per page
        first) and retry; ``PoolExhausted`` escapes once the cache is
        empty — or eviction stops making progress (shared victims free
        nothing) — and the pages genuinely do not exist."""
        if self.lost:
            raise PoolExhausted(
                f"server {self.stats.model!r} is marked lost; its "
                "page pool is abandoned")
        try:
            return self.pool.alloc(n)
        except PoolExhausted:
            if self.evict_prefix(n) < n:
                raise
            return self.pool.alloc(n)

    def _prefix_insert(self, key: bytes, shared: np.ndarray,
                       tail: Optional[int],
                       logits0: np.ndarray, tokens: int = 0) -> None:
        if self.prefix_cache_entries <= 0:
            return
        old = self._prefix.pop(key, None)
        if old is not None:
            self._release_entry(old)
        self.pool.retain(shared)
        if tail is not None:
            self.pool.retain([tail])
        self._prefix[key] = _PrefixEntry(
            shared=shared.copy(), tail=tail, logits0=logits0.copy(),
            tokens=tokens, seq=self._prefix_seq)
        self._prefix_seq += 1
        while len(self._prefix) > self.prefix_cache_entries:
            self._evict_one()

    # -- waves ---------------------------------------------------------
    def probe_wave(self, params: dict, ids: np.ndarray, n_samples: int,
                   *, max_new_tokens: int, temperature: float,
                   key, eos_id: int, pad_id: int, row_keys=None):
        """N-sample probe decode with shared prefix pages.

        One prefill per *distinct uncached* prompt; the N samples of a
        prompt share its full prompt pages read-only and fork only the
        partial tail page (COW). ``row_keys`` ((B*N, 2) uint32) opts
        into per-row sampling key streams (batch-composition
        invariant — required for step-loop equivalence). Returns
        ``(GenerateOutput, ProbeHandle)`` — the handle retains each
        row's prompt pages for ensemble prefill seeding until
        ``resolve``/``close``.
        """
        import jax.numpy as jnp
        from repro.sampling import sampler as S

        b, s = ids.shape
        n = int(n_samples)
        ps = self.page_size
        self._ensure_capacity(b, s, n, max_new_tokens)
        n_shared = s // ps
        tail_tokens = s - n_shared * ps
        nbp = pages_for(s, ps)
        nb = pages_for(s + max_new_tokens, ps)
        n_tail = nb - n_shared

        # 1. prompt pages per row: prefix-cache hit -> retain the
        # cached pages; miss -> allocate fresh ones (handle-owned).
        # On any failure, release whatever this wave accumulated so an
        # exhausted pool stays consistent instead of leaking refs.
        shared_rows: List[np.ndarray] = []
        tail_rows: List[Optional[int]] = []
        miss: List[int] = []
        hits: List[Optional[_PrefixEntry]] = []
        try:
            for r in range(b):
                entry = self._prefix_lookup(ids[r].tobytes())
                hits.append(entry)
                if entry is not None:
                    self.pool.retain(entry.shared)
                    if entry.tail is not None:
                        self.pool.retain([entry.tail])
                    shared_rows.append(entry.shared.copy())
                    tail_rows.append(entry.tail)
                    self.stats.prefill_tokens_reused_prefix += s
                else:
                    pages = self._alloc_retry(nbp)
                    shared_rows.append(pages[:n_shared])
                    tail_rows.append(int(pages[n_shared])
                                     if tail_tokens else None)
                    miss.append(r)

            # 2. one prefill over the uncached rows, gathered into a
            # power-of-two bucket (padding rows replicate row 0 and
            # write into scratch pages)
            logits0 = np.zeros((b, self.cfg.vocab_size), np.float32)
            if miss:
                bucket = bucket_size(len(miss), cap=b)
                rows_idx = miss + [miss[0]] * (bucket - len(miss))
                pf_table = np.empty((bucket, nbp), np.int32)
                for i, r in enumerate(rows_idx):
                    if i < len(miss):
                        row_pages = list(shared_rows[r])
                        if tail_tokens:
                            row_pages.append(tail_rows[r])
                        pf_table[i] = row_pages
                    else:
                        pf_table[i] = self._scratch[:nbp]
                lg, self.k_pages, self.v_pages = S.prefill_paged(
                    self.cfg, params, jnp.asarray(ids[rows_idx]),
                    self.k_pages, self.v_pages, jnp.asarray(pf_table))
                lg = np.asarray(lg, np.float32)
                for i, r in enumerate(miss):
                    logits0[r] = lg[i]
                # the bucket's padding rows compute real (discarded)
                # prefill work — count what actually ran
                self.stats.prefill_tokens_computed += bucket * s
            for r, entry in enumerate(hits):
                if entry is not None:
                    logits0[r] = entry.logits0

            # 3. publish the fresh rows to the prefix cache
            for r in miss:
                self._prefix_insert(ids[r].tobytes(), shared_rows[r],
                                    tail_rows[r], logits0[r],
                                    tokens=s)
        except BaseException:
            for r in range(len(shared_rows)):
                self.pool.release(shared_rows[r])
                if tail_rows[r] is not None:
                    self.pool.release([tail_rows[r]])
            self._sample_usage()
            raise

        # the handle owns the prompt pages from here on: any failure
        # below must close it (and drop the sample pages) so a raised
        # decode cannot wedge the pool with orphaned refcounts
        handle = ProbeHandle(
            server=self, prompt_len=s, max_new_tokens=max_new_tokens,
            logits0=logits0, shared=shared_rows, tails=tail_rows,
            live=np.ones(b, bool))
        sample_tails = None
        try:
            # 4. sample-private pages + COW fork of the partial tail
            sample_tails = self._alloc_retry(b * n * n_tail).reshape(
                b, n, n_tail)
            self.stats.probe_pages_highwater = max(
                self.stats.probe_pages_highwater,
                b * (nbp + n * n_tail))
            block_table = np.empty((b * n, nb), np.int32)
            for r in range(b):
                for j in range(n):
                    block_table[r * n + j, :n_shared] = shared_rows[r]
                    block_table[r * n + j, n_shared:] = sample_tails[r, j]
            if tail_tokens:
                src = np.repeat(
                    np.asarray([tail_rows[r] for r in range(b)],
                               np.int32), n)
                dst = sample_tails[:, :, 0].reshape(-1)
                self.k_pages, self.v_pages = S.fork_pages(
                    self.k_pages, self.v_pages, jnp.asarray(src),
                    jnp.asarray(dst))
                self.stats.cow_forks += b * n

            # 5. decode the expanded (B*N) wave over the shared pages
            out, self.k_pages, self.v_pages = S.decode_paged(
                self.cfg, params,
                jnp.asarray(np.repeat(logits0, n, axis=0)),
                self.k_pages, self.v_pages, jnp.asarray(block_table),
                key, start_pos=s, max_new_tokens=max_new_tokens,
                temperature=temperature, eos_id=eos_id, pad_id=pad_id,
                row_keys=None if row_keys is None
                else jnp.asarray(row_keys))
            # force tokens to host before the sample pages are recycled
            out = type(out)(tokens=np.asarray(out.tokens),
                            logprobs=np.asarray(out.logprobs),
                            lengths=np.asarray(out.lengths))
        except BaseException:
            if sample_tails is not None:
                self.pool.release(sample_tails.reshape(-1))
            handle.close()
            raise
        self.pool.release(sample_tails.reshape(-1))
        self._sample_usage()
        return out, handle

    def reuse_decode(self, params: dict, handle: ProbeHandle,
                     rows: Sequence[int], *, max_new_tokens: int,
                     temperature: float, key, eos_id: int,
                     pad_id: int, row_keys=None):
        """Ensemble decode seeded from the probe's prompt pages:
        prefill is skipped entirely — the rows' shared pages are read
        in place, the canonical tail page is COW-forked per decode row,
        and the prefill logits come from the probe's host snapshot.
        Only sound when ``params`` is the probe's params (the engine
        keys servers by params identity)."""
        import jax.numpy as jnp
        from repro.sampling import sampler as S

        rows = [int(r) for r in rows]
        s = handle.prompt_len
        ps = self.page_size
        n_shared = s // ps
        tail_tokens = s - n_shared * ps
        nb = pages_for(s + max_new_tokens, ps)
        n_tail = nb - n_shared
        for r in rows:
            if not handle.live[r]:
                raise PageAccountingError(
                    f"reuse of row {r} after its pages were resolved")

        nr = len(rows)
        tails = self._alloc_retry(nr * n_tail).reshape(nr, n_tail)
        try:
            block_table = np.empty((nr, nb), np.int32)
            for i, r in enumerate(rows):
                block_table[i, :n_shared] = handle.shared[r]
                block_table[i, n_shared:] = tails[i]
            if tail_tokens:
                src = np.asarray([handle.tails[r] for r in rows],
                                 np.int32)
                self.k_pages, self.v_pages = S.fork_pages(
                    self.k_pages, self.v_pages, jnp.asarray(src),
                    jnp.asarray(tails[:, 0]))
                self.stats.cow_forks += nr
            out, self.k_pages, self.v_pages = S.decode_paged(
                self.cfg, params, jnp.asarray(handle.logits0[rows]),
                self.k_pages, self.v_pages, jnp.asarray(block_table),
                key, start_pos=s, max_new_tokens=max_new_tokens,
                temperature=temperature, eos_id=eos_id, pad_id=pad_id,
                row_keys=None if row_keys is None
                else jnp.asarray(row_keys))
            out = type(out)(tokens=np.asarray(out.tokens),
                            logprobs=np.asarray(out.logprobs),
                            lengths=np.asarray(out.lengths))
        finally:
            self.pool.release(tails.reshape(-1))
            self._sample_usage()
        self.stats.prefill_tokens_reused_probe += s * nr
        return out

    def generate(self, params: dict, ids: np.ndarray, *,
                 max_new_tokens: int, temperature: float, key,
                 eos_id: int, pad_id: int, row_keys=None):
        """Paged single-sample generation (a probe wave with N=1 whose
        prompt pages are released immediately): page-granular
        allocation instead of batch-max padded dense caches, plus
        cross-request prompt reuse through the prefix cache."""
        out, handle = self.probe_wave(
            params, ids, 1, max_new_tokens=max_new_tokens,
            temperature=temperature, key=key, eos_id=eos_id,
            pad_id=pad_id, row_keys=row_keys)
        handle.close()
        return out

    # -- step-level serving support ------------------------------------
    def stream_row_pages(self, prompt_len: int, lanes_per_row: int,
                         max_new_tokens: int) -> int:
        """Worst-case pages one step-loop row holds on this server:
        shared prompt pages plus one private decode tail per lane
        (probe samples and seeded ensemble decodes alike)."""
        ps = self.page_size
        nbp = pages_for(prompt_len, ps)
        n_tail = pages_for(prompt_len + max_new_tokens, ps) \
            - prompt_len // ps
        return nbp + lanes_per_row * n_tail

    def ensure_capacity_stream(self, max_rows: int, prompt_len: int,
                               lanes_per_row: int,
                               max_new_tokens: int) -> None:
        """Size the pool for the step-level loop's steady state:
        ``max_rows`` rows concurrently resident, each holding its
        shared prompt pages and ``lanes_per_row`` private decode
        tails — plus the prefix cache and a scratch region wide enough
        for a *full* (prompt+decode) pad-row block table. Must run
        before any pages are held (the step loop calls it at admission
        of the first row)."""
        ps = self.page_size
        nbp = pages_for(prompt_len, ps)
        nb = pages_for(prompt_len + max_new_tokens, ps)
        need = (max_rows * self.stream_row_pages(
                    prompt_len, lanes_per_row, max_new_tokens)
                + self.prefix_cache_entries * nbp
                + nb)                                # scratch pages
        key = (max_rows, prompt_len, lanes_per_row, max_new_tokens)
        if (self._capacity_key is not None and self.pool is not None
                and self.pool.num_pages >= need
                and self._scratch is not None
                and self._scratch.size >= nb):
            return
        self._rebuild(need, nb, key)
