"""Pluggable span tracing for the serving stack.

``NullTracer`` is the default: the runners hold ``tracer = None`` when
handed one (or nothing), so every instrumentation site reduces to a
single attribute check — exactly the ``serving/faults.py`` discipline,
and ``benchmarks/obs_bench.py`` gates the armed overhead at ≤3%.

``SpanTracer`` records the full lifecycle — admit → prefill-chunk →
probe-decode → route → ensemble-member launch → judge → retire, plus
every fault-path transition (requeue, retry, quarantine-degraded
route, shard re-placement, crash→restore) — as deterministic hashed
span records (``teamllm.spans``). Structure is a pure function of the
admission-ordered run; wall-clock stamps ride the non-hashed
``wall_time`` side channel. Parenting is implicit per stream: a
trace's row-lifecycle spans chain linearly, while per-lane decode
streams and per-member execution streams fork from the row stream and
chain launch-to-launch across megasteps and retries (``key=`` picks
the stream).

The tracer also carries the KV provenance map: prefix-cache owners are
recorded at insert (first writer in admission order — deterministic),
so a later hit can name its donor trace and PROV can materialize the
reuse as a ``wasDerivedFrom`` edge (``teamllm.prov``).
"""
from __future__ import annotations

import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple, Union

from repro.teamllm.spans import SpanLog, span_record


class NullTracer:
    """Disarmed tracer: every hook is a no-op. The runners normalise
    ``NullTracer`` (or ``None``) to ``tracer = None`` so the serving
    loop pays one attribute check per site and nothing else."""

    armed = False

    def span(self, *a: Any, **k: Any) -> None:
        return None

    def kv_insert(self, *a: Any, **k: Any) -> None:
        return None

    def kv_source(self, *a: Any, **k: Any) -> None:
        return None

    def records(self) -> List[dict]:
        return []

    def flush(self) -> Optional[str]:
        return None


class SpanTracer:
    """JSONL span tracer. ``path=None`` keeps the chain in memory only
    (the harness reads ``records()`` directly); with a path, ``flush``
    writes an ``ArtifactStore``-verifiable hash-chained file.

    ``attribution`` controls whether the step loop schedules
    on-capacity leave-one-out recomputation for full-arena rows
    (span phase ``attribution``); it defaults on — the whole point of
    arming a tracer is the audit story.
    """

    armed = True

    def __init__(self, path: Union[str, Path, None] = None, *,
                 attribution: bool = True):
        self.path = Path(path) if path is not None else None
        self.log = SpanLog()
        self.attribution = attribution
        self._seq: Dict[str, int] = {}
        self._last: Dict[Tuple[str, Any], str] = {}
        # (model, prompt-ids hash) -> (owner trace, owner span): first
        # inserter in admission order owns the cached prefix pages
        self._prefix_owner: Dict[Tuple[str, str], Tuple[str, str]] = {}

    # -- spans ---------------------------------------------------------
    def span(self, phase: str, trace: str, tick: int, *,
             key: Any = None, parent: Optional[str] = None,
             wall: float = 0.0, **fields: Any) -> str:
        """Emit one span on ``trace``. ``key=None`` is the row
        lifecycle stream; any other key names a forked stream (a probe
        lane, a member execution) whose first span parents on the row
        stream and whose later spans chain within the fork."""
        seq = self._seq.get(trace, 0)
        self._seq[trace] = seq + 1
        sid = f"{trace}/{seq}"
        if parent is None:
            parent = self._last.get((trace, key))
            if parent is None and key is not None:
                parent = self._last.get((trace, None))
        self.log.append(
            span_record(phase, trace, sid, tick, parent=parent,
                        **fields),
            wall_time=wall or time.time())
        self._last[(trace, key)] = sid
        return sid

    # -- KV provenance -------------------------------------------------
    def kv_insert(self, model: str, ids_hash: str, trace: str,
                  span: str) -> None:
        """Record the owner of freshly inserted prefix-cache pages.
        ``setdefault`` keeps the first (admission-ordered) writer when
        duplicates race within one run — deterministic."""
        self._prefix_owner.setdefault((model, ids_hash), (trace, span))

    def kv_source(self, model: str, ids_hash: str
                  ) -> Optional[Tuple[str, str]]:
        """The (trace, span) whose prefill populated these cached
        pages, or None for an untracked entry (e.g. inserted before
        the tracer armed)."""
        return self._prefix_owner.get((model, ids_hash))

    # -- output --------------------------------------------------------
    @property
    def head(self) -> str:
        return self.log.head

    def records(self) -> List[dict]:
        return self.log.records()

    def flush(self) -> Optional[str]:
        """Persist the chain (one buffered write; see ``SpanLog``).
        Returns the chain head, or None when memory-only."""
        if self.path is None:
            return self.log.head
        return self.log.flush(self.path)
