"""Batched ACAR serving engine — the JAX-native adaptation of Alg. 1.

The paper routes one task at a time with host-side Python. On TPU the
profitable formulation batches: a request batch of B tasks becomes one
probe decode, sigma and the routing decision are computed on-device
with ``sigma_batch`` / ``route_batch``, and the ensemble members run as
batched decodes. Aggregation (majority vote, arena-lite verification,
full-arena judge) is vectorised over answer ids, so the entire routing
pipeline is a handful of XLA programs instead of 1,510 host
round-trips.

Two compute-follows-routing optimisations make decode cost
proportional to what the router actually escalated:

* **Shared-prefix probe prefill** — the N probe samples of a prompt
  share one prefill; the KV cache is broadcast across samples and only
  the decode scan runs at (B*N) (sampling/sampler.py
  ``generate_samples``), cutting probe prefill FLOPs ~N x.
* **Escalated-subset compaction** — ensemble members decode only the
  ``sigma>0`` rows (and the ``modes>=2`` subset for members past the
  arena-lite pair), gathered into power-of-two shape buckets and
  scattered back (serving/compaction.py). The masked fallback decodes
  the full batch and discards non-escalated answers; both paths feed
  ``judge_batch`` bit-identical inputs. Compaction engages only when
  the decode is batch-composition invariant: greedy ensemble
  temperature (categorical draws depend on batch shape) and, for MoE
  members, the capacity-free gather dispatch (``MoEConfig.impl ==
  "gather"`` — the capacity path's cross-row cumsum couples rows;
  ``models.moe.moe_ffn_gather`` removes it, so gather-MoE members
  take the compacted escalated-subset path like dense ones).

Answer ids: EXTRACT runs host-side on decoded text (string logic), then
canonical answers are interned to int32 ids for the on-device math —
one interning table per batch, shared between probe and ensemble
answers so the judge compares ids from a single namespace.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.acar import ACARConfig
from repro.configs.base import ModelConfig
from repro.core.extract import extract
from repro.core.sigma import (
    MODE_NAMES, majority_vote_batch, route_batch, sigma_batch)
from repro.data import tokenizer as tok
from repro.data.tasks import Task
from repro.models.transformer import resolve_layout
from repro.sampling import (
    batch_invariant, generate, generate_samples, member_row_keys,
    probe_row_keys)
from repro.serving.compaction import (
    CompactionStats, plan_compaction)
from repro.serving.kv_pool import (
    KVStats, PagedKVServer, PoolExhausted, ProbeHandle)
from repro.serving.metrics import PromCounters
from repro.serving.queue import AdmissionQueue, MicroBatchPolicy


@dataclass
class ZooModel:
    name: str
    cfg: ModelConfig
    params: dict


def intern_answers(answers: Sequence[str],
                   table: Optional[Dict[str, int]] = None) -> np.ndarray:
    """Intern canonical answer strings to dense int32 ids.

    Pass ``table`` to thread one namespace through several calls (the
    engine interns probe and ensemble answers into a single table)."""
    if table is None:
        table = {}
    out = np.empty(len(answers), np.int32)
    for i, a in enumerate(answers):
        out[i] = table.setdefault(a, len(table))
    return out


def judge_batch(member_ids: jax.Array, probe_majority: jax.Array,
                modes: jax.Array) -> jax.Array:
    """Vectorised aggregation. member_ids: (B, M) answer ids (M ensemble
    members, invalid entries = -1); probe_majority: (B,); modes: (B,).

    single_agent -> probe majority.
    arena_lite   -> probe majority unless the first two members agree on
                    a common different answer.
    full_arena   -> plurality over members, probe majority breaks ties.
    """
    b, m = member_ids.shape
    # plurality over valid member answers
    valid = member_ids >= 0
    eq = (member_ids[:, :, None] == member_ids[:, None, :]) \
        & valid[:, :, None] & valid[:, None, :]
    votes = eq.sum(-1)                                   # (B, M)
    # prefer answers matching probe majority on vote ties
    bonus = (member_ids == probe_majority[:, None]) & valid
    score = votes * 2 + bonus
    best = jnp.argmax(jnp.where(valid, score, -1), axis=-1)
    plural = jnp.take_along_axis(member_ids, best[:, None], 1)[:, 0]

    two_agree = (member_ids[:, 0] == member_ids[:, 1]) \
        & valid[:, 0] & valid[:, 1]
    lite = jnp.where(two_agree & (member_ids[:, 0] != probe_majority),
                     member_ids[:, 0], probe_majority)

    return jnp.where(modes == 0, probe_majority,
                     jnp.where(modes == 1, lite, plural))


@dataclass
class BatchResult:
    sigma: np.ndarray            # (B,)
    modes: np.ndarray            # (B,) int mode ids
    final_answers: List[str]
    probe_texts: List[List[str]]
    ensemble_calls_saved: int
    wall_ms: float
    # per-row, per-member extracted answers; None where the router did
    # not escalate the row to that member (exactly the judge's -1
    # entries) — identical between compacted and masked execution
    member_answers: Optional[List[List[Optional[str]]]] = None
    compaction: Optional[CompactionStats] = None


class BatchedACAREngine:
    """Batched ACAR engine over real JAX zoo models.

    ``compact`` enables escalated-subset compaction, ``shared_prefix``
    the single-prefill probe expansion; both auto-disable per model
    when the bit-equivalence preconditions fail (see module docstring),
    so disabling them explicitly is only needed for A/B measurement.
    ``route_fn`` overrides sigma->mode routing (tests use it to force
    escalation rates)."""

    def __init__(self, acfg: ACARConfig, probe: ZooModel,
                 ensemble: Sequence[ZooModel], prompt_len: int = 16,
                 max_new_tokens: int = 8, compact: bool = True,
                 shared_prefix: bool = True,
                 paged: Optional[bool] = None,
                 kv_page_size: int = 8,
                 kv_prefix_cache: int = 32,
                 route_fn: Optional[Callable[[jax.Array],
                                             jax.Array]] = None):
        self.acfg = acfg
        self.probe = probe
        self.ensemble = list(ensemble)
        self.prompt_len = prompt_len
        self.max_new_tokens = max_new_tokens
        self.compact = compact
        self.shared_prefix = shared_prefix
        # paged KV: None = auto (on for every model whose config
        # supports the paged path bit-identically); False disables for
        # A/B baselines
        self.paged = paged
        self.kv_page_size = kv_page_size
        self.kv_prefix_cache = kv_prefix_cache
        self._kv_servers: Dict[int, PagedKVServer] = {}
        self._stepped_servers: Dict[int, PagedKVServer] = {}
        self._kv_emitted: Dict[Tuple[str, str], int] = {}
        self.route_fn = route_fn or route_batch
        # a route_fn may take (sigma, admission_indices) so forced-mode
        # benchmarks stay deterministic under out-of-order (step-level)
        # route resolution; plain sigma-only callables keep working
        import inspect
        try:
            n_params = len([
                p for p in inspect.signature(
                    self.route_fn).parameters.values()
                if p.kind in (p.POSITIONAL_ONLY,
                              p.POSITIONAL_OR_KEYWORD)])
        except (TypeError, ValueError):
            n_params = 1
        self._route_takes_indices = n_params >= 2

    # -- paged KV servers ----------------------------------------------
    def _kv_server(self, zm: ZooModel) -> Optional[PagedKVServer]:
        """One server per distinct params object: an ensemble member
        that *is* the probe model shares the probe's server, which is
        what makes probe->ensemble prefill-page reuse sound (KV is a
        function of params, not just configs). Wave-path serving
        speaks the dense and quant page layouts; ring and lanes
        members serve dense in wave mode and take their layouts
        through ``_stepped_server`` in the step loop."""
        if (self.paged is False
                or resolve_layout(zm.cfg) not in ("dense", "quant")):
            return None
        key = id(zm.params)
        srv = self._kv_servers.get(key)
        if srv is None:
            srv = PagedKVServer(zm.cfg, page_size=self.kv_page_size,
                                prefix_cache_entries=self.kv_prefix_cache)
            srv.stats.model = zm.name
            self._kv_servers[key] = srv
        return srv

    def _stepped_server(self, zm: ZooModel) -> Optional[PagedKVServer]:
        """Server for the step-level loop, which additionally speaks
        the ring (sliding-window) and lanes (recurrent-state) layouts.
        Dense/quant members return the *same object* as
        ``_kv_server`` — ``_kv_reuse_member`` compares servers by
        identity, so splitting them would silently disable
        probe->ensemble page reuse."""
        if self.paged is False:
            return None
        layout = resolve_layout(zm.cfg)
        if layout is None:
            return None
        if layout in ("dense", "quant"):
            return self._kv_server(zm)
        key = id(zm.params)
        srv = self._stepped_servers.get(key)
        if srv is None:
            srv = PagedKVServer(zm.cfg, page_size=self.kv_page_size,
                                prefix_cache_entries=self.kv_prefix_cache)
            srv.stats.model = zm.name
            self._stepped_servers[key] = srv
        return srv

    def kv_stats(self) -> Dict[str, KVStats]:
        """Measured paged-KV accounting per model server (wave and
        stepped server caches merged; dense/quant members live in
        both roles as one server)."""
        out = {srv.stats.model: srv.stats
               for srv in self._kv_servers.values()}
        for srv in self._stepped_servers.values():
            out.setdefault(srv.stats.model, srv.stats)
        return out

    def _kv_reuse_member(self, zm: ZooModel,
                         kv_srv: Optional[PagedKVServer]) -> bool:
        return (kv_srv is not None and zm.cfg == self.probe.cfg
                and self._kv_server(zm) is kv_srv)

    # ------------------------------------------------------------------
    def _decode_texts(self, out_tokens) -> List[str]:
        return [tok.decode(row) for row in np.asarray(out_tokens)]

    def route_modes(self, sig, admission_indices) -> jax.Array:
        """Invoke route_fn, passing admission indices when it wants
        them (forced-rate benchmarks key modes off task identity so
        wave and step execution force the same routes)."""
        if self._route_takes_indices:
            return self.route_fn(sig, list(admission_indices))
        return self.route_fn(sig)

    def _probe_decode(self, ids: np.ndarray, key: jax.Array,
                      stats: CompactionStats,
                      row_keys=None) -> List[str]:
        """N-sample probe decode; prefers the shared-prefix path."""
        b, s = ids.shape
        n = self.acfg.n_probe_samples
        stats.probe_prefill_tokens += b * s
        if self.shared_prefix and batch_invariant(self.probe.cfg):
            out = generate_samples(
                self.probe.cfg, self.probe.params, jnp.asarray(ids), n,
                max_new_tokens=self.max_new_tokens,
                temperature=self.acfg.probe_temperature,
                key=key, eos_id=tok.EOS, pad_id=tok.PAD,
                row_keys=row_keys)
            saved = b * (n - 1) * s
            stats.probe_prefill_tokens_saved += saved
            stats.probe_prefill_flops_saved += \
                2.0 * self.probe.cfg.active_param_count() * saved
        else:
            # (B*N) expansion recomputes each prompt's prefill N times
            stats.probe_prefill_tokens += b * (n - 1) * s
            out = generate(
                self.probe.cfg, self.probe.params,
                jnp.asarray(np.repeat(ids, n, axis=0)),
                max_new_tokens=self.max_new_tokens,
                temperature=self.acfg.probe_temperature,
                key=key, eos_id=tok.EOS, pad_id=tok.PAD,
                row_keys=row_keys)
        return self._decode_texts(out.tokens)

    def _member_decode(self, zm: ZooModel,
                       srv_m: Optional[PagedKVServer],
                       sub_ids: np.ndarray, mkey: jax.Array,
                       row_keys=None):
        """One ensemble member decode over ``sub_ids`` rows: paged
        when the member's config supports it, dense otherwise —
        bit-identical either way. A paged decode that exhausts its
        pool even after cost-aware prefix eviction falls back to the
        dense path (same bits) instead of failing the wave."""
        if srv_m is not None:
            try:
                return srv_m.generate(
                    zm.params, sub_ids,
                    max_new_tokens=self.max_new_tokens,
                    temperature=self.acfg.ensemble_temperature,
                    key=mkey, eos_id=tok.EOS, pad_id=tok.PAD,
                    row_keys=row_keys)
            except PoolExhausted:
                pass
        return generate(zm.cfg, zm.params, jnp.asarray(sub_ids),
                        max_new_tokens=self.max_new_tokens,
                        temperature=self.acfg.ensemble_temperature,
                        key=mkey, eos_id=tok.EOS, pad_id=tok.PAD,
                        row_keys=row_keys)

    def _member_compactable(self, zm: ZooModel) -> bool:
        """Compaction must not perturb the decoded rows: greedy decode
        (temperature-0 sampling is batch-shape independent, categorical
        draws are not) of a batch-invariant config."""
        return (self.compact
                and self.acfg.ensemble_temperature <= 0.0
                and batch_invariant(zm.cfg))

    def _probe_decode_paged(self, ids: np.ndarray, key: jax.Array,
                            stats: CompactionStats,
                            kv_srv: PagedKVServer,
                            row_keys=None
                            ) -> Tuple[List[str], ProbeHandle]:
        """Paged N-sample probe: one prefill per uncached prompt, the
        samples share read-only prefix pages (kv_pool COW fork), and
        the prompt pages stay retained for ensemble seeding. Prefill
        accounting records what actually ran (prefix-cache hits and
        bucket padding included), so the dense-equivalent baseline
        stays b*n*s and the reduction reflects real reuse."""
        b, s = ids.shape
        n = self.acfg.n_probe_samples
        computed0 = kv_srv.stats.prefill_tokens_computed
        out, handle = kv_srv.probe_wave(
            self.probe.params, ids, n,
            max_new_tokens=self.max_new_tokens,
            temperature=self.acfg.probe_temperature, key=key,
            eos_id=tok.EOS, pad_id=tok.PAD, row_keys=row_keys)
        computed = kv_srv.stats.prefill_tokens_computed - computed0
        saved = b * n * s - computed
        stats.probe_prefill_tokens += computed
        stats.probe_prefill_tokens_saved += saved
        stats.probe_prefill_flops_saved += \
            2.0 * self.probe.cfg.active_param_count() * saved
        return self._decode_texts(out.tokens), handle

    def run_batch(self, tasks: Sequence[Task],
                  start_index: int = 0, tracer=None,
                  request_ids: Optional[Sequence[str]] = None
                  ) -> BatchResult:
        """One wave over ``tasks``. ``start_index`` is the admission
        index of the first row — the stable per-task identity that
        seeds every row's sampling key stream, so a task emits the
        same tokens whether it is served in this wave, a different
        wave, or the step-level loop.

        ``tracer`` (serving/tracing.py) emits the wave path's
        lifecycle spans post-hoc after the wave resolves — the wave is
        lockstep, so per-phase spans at ``tick = admission index``
        carry the same decision fields the step loop records live.
        ``request_ids`` names the traces (one per task); absent, a
        task-derived id is used."""
        t0 = time.perf_counter()
        tracer = tracer if (tracer is not None
                            and getattr(tracer, "armed", False)) \
            else None
        b = len(tasks)
        n = self.acfg.n_probe_samples
        ids = tok.encode_aligned([t.text for t in tasks])
        key = jax.random.PRNGKey(self.acfg.seed)
        admission = list(range(start_index, start_index + b))
        probe_keys = probe_row_keys(key, admission, n)
        stats = CompactionStats(batch=b)
        kv_srv = self._kv_server(self.probe) if self.shared_prefix \
            else None
        handle: Optional[ProbeHandle] = None
        if kv_srv is not None:
            try:
                texts, handle = self._probe_decode_paged(
                    ids, key, stats, kv_srv, row_keys=probe_keys)
            except PoolExhausted:
                # cost-aware eviction could not free enough pages:
                # serve the wave on the dense path (same bits) rather
                # than failing it
                kv_srv = None
                texts = self._probe_decode(ids, key, stats,
                                           row_keys=probe_keys)
        else:
            texts = self._probe_decode(ids, key, stats,
                                       row_keys=probe_keys)
        try:
            answers = [extract(texts[i * n + j], tasks[i].kind)
                       for i in range(b) for j in range(n)]
            # one interning table for the whole batch: probe ids first,
            # ensemble answers join the same namespace below
            id_table: Dict[str, int] = {}
            answer_ids = intern_answers(answers, id_table).reshape(b, n)

            sig = sigma_batch(jnp.asarray(answer_ids))
            modes = self.route_modes(sig, admission)
            probe_major = majority_vote_batch(jnp.asarray(answer_ids))

            # ensemble decodes over the escalated subset: gather sigma>0
            # rows (modes>=2 for members past the arena-lite pair) into
            # power-of-two buckets, decode, scatter answers back; masked
            # full-batch decode when compaction preconditions fail
            modes_np = np.asarray(modes)
            plan = plan_compaction(modes_np, len(self.ensemble),
                                   self.acfg.arena_lite_size)
            stats.escalated_rows = plan.escalated_rows
            stats.full_arena_rows = plan.full_arena_rows
            if handle is not None:
                # a task's probe pages are freed the moment its route
                # resolves; only rows some probe-model ensemble member will
                # seed its prefill from stay retained
                keep: set = set()
                for mi, zm in enumerate(self.ensemble):
                    mp = plan.members[mi]
                    if (self._kv_reuse_member(zm, kv_srv)
                            and self._member_compactable(zm)
                            and mp.bucket < b):
                        keep.update(int(r) for r in mp.rows)
                handle.resolve(sorted(keep))
            member_cols = []
            member_answers: List[List[Optional[str]]] = \
                [[None] * len(self.ensemble) for _ in range(b)]
            reused_rows: set = set()     # (mi, row): probe-page seed
            for mi, zm in enumerate(self.ensemble):
                mp = plan.members[mi]
                col = np.full(b, -1, np.int32)
                if mp.n_rows == 0:
                    member_cols.append(col)
                    continue
                mkey = jax.random.fold_in(key, 1000 + mi)
                srv_m = self._kv_server(zm)
                if self._member_compactable(zm) and mp.bucket < b:
                    rows = mp.padded_rows()
                    mrk = member_row_keys(
                        key, [start_index + int(r) for r in rows], mi)
                    if (handle is not None
                            and self._kv_reuse_member(zm, kv_srv)):
                        # seed from the probe's retained prompt pages:
                        # prefill skipped, logits0 reused, tail COW-forked
                        mout = kv_srv.reuse_decode(
                            self.probe.params, handle, rows.tolist(),
                            max_new_tokens=self.max_new_tokens,
                            temperature=self.acfg.ensemble_temperature,
                            key=mkey, eos_id=tok.EOS, pad_id=tok.PAD,
                            row_keys=mrk)
                        reused_rows.update(
                            (mi, int(r)) for r in mp.rows)
                    else:
                        mout = self._member_decode(zm, srv_m,
                                                   ids[rows], mkey,
                                                   row_keys=mrk)
                    sub_texts = self._decode_texts(mout.tokens)
                    for j, r in enumerate(mp.rows):
                        a = extract(sub_texts[j], tasks[r].kind)
                        col[r] = id_table.setdefault(a, len(id_table))
                        member_answers[r][mi] = a
                    decoded_rows = mp.bucket
                else:
                    mout = self._member_decode(
                        zm, srv_m, ids, mkey,
                        row_keys=member_row_keys(key, admission, mi))
                    mtexts = self._decode_texts(mout.tokens)
                    for r in mp.rows:
                        a = extract(mtexts[r], tasks[r].kind)
                        col[r] = id_table.setdefault(a, len(id_table))
                        member_answers[r][mi] = a
                    decoded_rows = b
                member_cols.append(col)
                stats.bucket_sizes.append(decoded_rows)
                stats.bucket_rows.append(mp.n_rows)
                stats.ensemble_decode_tokens += \
                    decoded_rows * self.max_new_tokens
                stats.ensemble_decode_tokens_saved += \
                    (b - decoded_rows) * self.max_new_tokens
            member_ids = jnp.asarray(np.stack(member_cols, axis=1))

            final_ids = judge_batch(member_ids, probe_major, modes)
            rev = {v: k for k, v in id_table.items()}
            final_answers = [rev[int(i)] for i in np.asarray(final_ids)]
            saved = int(np.sum(len(self.ensemble) - np.where(
                modes_np == 0, 0,
                np.where(modes_np == 1, self.acfg.arena_lite_size,
                         len(self.ensemble)))))
            probe_texts = [texts[i * n:(i + 1) * n] for i in range(b)]
            if tracer is not None:
                self._trace_wave(
                    tracer, tasks, start_index, request_ids,
                    int(ids.shape[1]), n, np.asarray(sig), modes_np,
                    member_answers, final_answers, reused_rows)
            return BatchResult(
                sigma=np.asarray(sig), modes=modes_np,
                final_answers=final_answers, probe_texts=probe_texts,
                ensemble_calls_saved=saved,
                wall_ms=(time.perf_counter() - t0) * 1e3,
                member_answers=member_answers, compaction=stats)
        finally:
            # probe prompt pages must never outlive the batch,
            # even when a member decode raises (close is
            # idempotent over already-resolved rows)
            if handle is not None:
                handle.close()

    def _trace_wave(self, tracer, tasks, start_index, request_ids,
                    prompt_tokens, n, sig, modes_np, member_answers,
                    final_answers, reused_rows) -> None:
        """Post-hoc span emission for one resolved wave: the lockstep
        wave has no per-tick interleaving, so each task's lifecycle
        spans are stamped at ``tick = admission index`` with the same
        decision fields the step loop records live (structure stays a
        pure function of the admission-ordered run)."""
        from repro.teamllm.spans import make_trace_id
        for i, task in enumerate(tasks):
            adm = start_index + i
            rid = request_ids[i] if request_ids is not None \
                else f"task-{task.task_id}"
            trace = make_trace_id(rid, adm)
            sigma = float(sig[i])
            mode = int(modes_np[i])
            tracer.span("admit", trace, adm,
                        prompt_tokens=prompt_tokens, arrival=adm)
            tracer.span("probe_decode", trace, adm,
                        model=self.probe.name, n_samples=n)
            tracer.span("route", trace, adm, sigma=sigma, mode=mode,
                        n_samples=n)
            members = []
            for mi, zm in enumerate(self.ensemble):
                if member_answers[i][mi] is None:
                    continue
                members.append(mi)
                reuse = (mi, i) in reused_rows
                tracer.span("member_launch", trace, adm,
                            key=("m", mi), member=mi, model=zm.name,
                            reuse=int(reuse))
                if reuse:
                    tracer.span("kv_reuse", trace, adm, key=("m", mi),
                                kind="probe", model=zm.name,
                                source=trace)
                tracer.span("member_decode", trace, adm,
                            key=("m", mi), member=mi, model=zm.name,
                            done=1)
            tracer.span("judge", trace, adm, mode=mode,
                        members=members)
            tracer.span("retire", trace, adm, task_id=task.task_id,
                        final_answer=final_answers[i], sigma=sigma,
                        mode=mode, aborted=None)
            if (getattr(tracer, "attribution", False) and mode >= 2
                    and members):
                from repro.core.attribution import leave_one_out
                from repro.teamllm.trace import ModelResponse
                responses = [
                    ModelResponse(model=self.ensemble[mi].name,
                                  response="",
                                  answer=member_answers[i][mi],
                                  cost=0.0)
                    for mi in members]
                loo = leave_one_out(responses, task.task_id,
                                    task.gold)
                tracer.span("attribution", trace, adm,
                            task_id=task.task_id, mode=mode,
                            values={m: float(v)
                                    for m, v in loo.items()})

    # ------------------------------------------------------------------
    # continuous-batching entry point: admission queue -> micro-batches
    # ------------------------------------------------------------------
    def run_queued(self, tasks: Sequence[Task],
                   policy: MicroBatchPolicy = MicroBatchPolicy(),
                   tracer=None) -> "QueuedServeResult":
        """Serve a request stream through the admission queue: tasks are
        submitted with logical arrival ticks, grouped into micro-batches
        under the policy budget, and each micro-batch runs the batched
        probe -> route -> ensemble pipeline. Per-batch results are
        concatenated in admission order."""
        t0 = time.perf_counter()
        tracer = tracer if (tracer is not None
                            and getattr(tracer, "armed", False)) \
            else None
        queue = AdmissionQueue(policy)
        for t in tasks:
            queue.submit(t)
        metrics = PromCounters()
        compaction = CompactionStats()
        batch_results: List[BatchResult] = []
        batch_sizes: List[int] = []
        for batch in queue.drain_batches():
            res = self.run_batch(
                [r.task for r in batch.requests],
                start_index=batch.requests[0].admission_index,
                tracer=tracer,
                request_ids=[r.request_id for r in batch.requests])
            batch_results.append(res)
            batch_sizes.append(len(batch))
            metrics.inc("acar_engine_batches_total",
                        help="micro-batches decoded")
            metrics.inc("acar_engine_tasks_total", len(batch),
                        help="tasks served")
            metrics.inc("acar_engine_ensemble_calls_saved_total",
                        res.ensemble_calls_saved,
                        help="ensemble decodes avoided by routing")
            for m in res.modes:
                metrics.inc("acar_engine_mode_total",
                            mode=MODE_NAMES[int(m)],
                            help="tasks routed per execution mode")
            cs = res.compaction
            if cs is not None:
                compaction.merge(cs)
                metrics.inc("acar_engine_escalated_rows_total",
                            cs.escalated_rows,
                            help="rows with sigma>0 per wave, summed")
                metrics.inc("acar_engine_full_arena_rows_total",
                            cs.full_arena_rows,
                            help="rows escalated to the full arena")
                metrics.inc(
                    "acar_engine_ensemble_decode_tokens_total",
                    cs.ensemble_decode_tokens,
                    help="ensemble decode tokens actually generated")
                metrics.inc(
                    "acar_engine_ensemble_decode_tokens_saved_total",
                    cs.ensemble_decode_tokens_saved,
                    help="decode tokens the masked full-batch path "
                         "would have generated but compaction skipped")
                metrics.inc(
                    "acar_engine_probe_prefill_tokens_saved_total",
                    cs.probe_prefill_tokens_saved,
                    help="probe prefill tokens elided by shared-prefix "
                         "expansion")
                metrics.inc(
                    "acar_engine_probe_prefill_flops_saved_total",
                    cs.probe_prefill_flops_saved,
                    help="approx. prefill FLOPs saved "
                         "(2 * active params * tokens)")
                for bkt, rows in zip(cs.bucket_sizes, cs.bucket_rows):
                    metrics.inc("acar_engine_bucket_waves_total",
                                bucket=str(bkt),
                                help="member decode waves per shape "
                                     "bucket")
                    metrics.set_gauge(
                        "acar_engine_bucket_occupancy",
                        rows / bkt if bkt else 0.0, bucket=str(bkt),
                        help="escalated-row fill of the last decode "
                             "wave in each shape bucket")
            self._emit_kv_metrics(metrics)
        return QueuedServeResult(
            sigma=np.concatenate([r.sigma for r in batch_results])
            if batch_results else np.zeros(0, np.float32),
            modes=np.concatenate([r.modes for r in batch_results])
            if batch_results else np.zeros(0, np.int32),
            final_answers=[a for r in batch_results
                           for a in r.final_answers],
            batch_sizes=batch_sizes,
            ensemble_calls_saved=sum(r.ensemble_calls_saved
                                     for r in batch_results),
            wall_ms=(time.perf_counter() - t0) * 1e3,
            metrics=metrics, compaction=compaction,
            probe_texts=[p for r in batch_results
                         for p in r.probe_texts],
            member_answers=[m for r in batch_results
                            for m in (r.member_answers or [])],
            kv=self.kv_stats() or None,
            spans=tracer.records() if tracer is not None else None,
            span_head=tracer.flush() if tracer is not None else None)

    # ------------------------------------------------------------------
    # step-level continuous batching entry point
    # ------------------------------------------------------------------
    def run_stepped(self, tasks: Sequence[Task],
                    policy: MicroBatchPolicy = MicroBatchPolicy(), *,
                    chunk_tokens: int = 8,
                    max_active_rows: Optional[int] = None,
                    data_shards: Optional[int] = None,
                    model_shards: int = 1,
                    megastep=1,
                    faults=None,
                    journal_path=None,
                    recovered: Optional[Dict[int, dict]] = None,
                    tracer=None) -> "QueuedServeResult":
        """Serve a request stream through the step-level loop: rows
        admitted from ``AdmissionQueue.ready()`` the moment the page
        budget opens, prompts prefilled in ``chunk_tokens`` chunks,
        probe/ensemble decodes advanced one token per logical tick
        over mixed bucketed batches, finished rows retired (pages
        freed) mid-stream. Emits exactly the per-task outputs
        ``run_queued`` emits — bit-identical sigma, modes, probe
        texts, member answers and final answers — in admission order
        (``tests/harness/simulate.py --step-loop`` enforces this).

        ``data_shards`` switches to the mesh-sharded loop
        (serving/mesh.py): rows placed on the least-loaded shard of a
        ("data",) device mesh, per-shard page pools, one shard_map'd
        program per tick — still bit-identical per task
        (``simulate.py --sharded``), with ``max_active_rows``
        interpreted per shard. ``model_shards`` > 1 widens the mesh
        to 2-D ("data", "model"): each data shard's program runs
        tensor-parallel across its model columns (column-parallel
        params, kv-head-sharded pages — sharding/tp.py), still
        bit-identical (``simulate.py --mesh2d``). Needs
        ``data_shards * model_shards`` visible devices (on CPU:
        ``--xla_force_host_platform_device_count``).

        ``megastep`` fuses up to K decode ticks into one device
        launch with lane state kept device-resident
        (``sampler.decode_megastep_rows``); only emitted token ids +
        done bits cross back per megastep. Any K emits bit-identical
        outputs (``simulate.py --megastep``) — it trades nothing but
        launch overhead. ``megastep="auto"`` fuses up to 16 ticks but
        caps each group's span at its shortest remaining lane budget,
        eliminating masked budget-exhaustion steps
        (``StepPlanner.megastep_auto``).

        Fault tolerance: ``faults`` (a ``FaultPlan``) attaches a
        deterministic fault injector; ``journal_path`` attaches a
        hash-chained write-ahead ``StepJournal``; ``recovered`` (an
        admission-index -> retire-payload map from
        ``StepJournal.load``) restores already-retired rows verbatim
        while everything else re-executes from scratch — see
        ``recover``. All three hooks are zero-cost when unset.

        ``tracer`` (serving/tracing.py) attaches deterministic span
        tracing: one hashed span per lifecycle transition (admit,
        prefill chunk, decode megastep, route, member launch, judge,
        retire, every fault-path event), structure bit-identical run
        to run while wall-times ride the non-hashed side channel —
        arming it cannot perturb record hashes or chain heads
        (``simulate.py --obs`` proves it). Zero-cost when unset."""
        from repro.serving.scheduler import StepPlanner
        from repro.serving.step_loop import (
            ShardedStepLoopRunner, StepLoopRunner)
        t0 = time.perf_counter()
        injector = None
        if faults is not None:
            from repro.serving.faults import FaultInjector
            injector = FaultInjector(faults)
        journal = None
        if journal_path is not None:
            from repro.serving.journal import StepJournal
            journal = StepJournal(journal_path, injector=injector)
        queue = AdmissionQueue(policy)
        for t in tasks:
            queue.submit(t)
        if megastep == "auto":
            planner = StepPlanner(
                chunk_tokens=chunk_tokens,
                max_active_rows=max_active_rows
                or policy.max_batch_size,
                megastep=16, megastep_auto=True)
        else:
            planner = StepPlanner(
                chunk_tokens=chunk_tokens,
                max_active_rows=max_active_rows
                or policy.max_batch_size,
                megastep=megastep)
        metrics = PromCounters()
        if data_shards is None:
            if model_shards != 1:
                raise ValueError(
                    "model_shards > 1 requires the sharded loop: "
                    "pass data_shards as well")
            runner = StepLoopRunner(self, queue, planner, metrics,
                                    faults=injector, journal=journal,
                                    recovered=recovered,
                                    tracer=tracer)
        else:
            from repro.serving.mesh import ServingMesh
            runner = ShardedStepLoopRunner(
                self, queue, planner,
                ServingMesh(data=data_shards, model=model_shards),
                metrics, faults=injector, journal=journal,
                recovered=recovered, tracer=tracer)
        step_stats = runner.run()
        # the sharded runner's servers live outside self._kv_servers:
        # emit the pool gauges / reuse counters from whichever set
        # actually served the run (plain runner: the engine's own)
        self._emit_kv_metrics(metrics, kv=runner.kv_stats())

        rows = [runner.done_rows[i] for i in range(len(tasks))]
        saved = sum(
            len(self.ensemble) - sum(
                1 for mi in range(len(self.ensemble))
                if r.mode >= (1 if mi < self.acfg.arena_lite_size
                              else 2))
            for r in rows)
        admit_ticks: Dict[int, int] = {}
        for a, (_, adm, _) in sorted(step_stats.timeline.items()):
            admit_ticks[adm] = admit_ticks.get(adm, 0) + 1
        return QueuedServeResult(
            sigma=np.asarray([r.sigma for r in rows], np.float32),
            modes=np.asarray([r.mode for r in rows], np.int32),
            final_answers=[r.final_answer for r in rows],
            batch_sizes=[v for _, v in sorted(admit_ticks.items())],
            ensemble_calls_saved=saved,
            wall_ms=(time.perf_counter() - t0) * 1e3,
            metrics=metrics,
            probe_texts=[r.probe_texts or [] for r in rows],
            member_answers=[r.member_answers or
                            [None] * len(self.ensemble)
                            for r in rows],
            kv=runner.kv_stats() or None,
            step=step_stats,
            faults=runner.fault_events or None,
            restored_rows=step_stats.restored,
            spans=runner.tracer.records()
            if runner.tracer is not None else None,
            span_head=runner.tracer.flush()
            if runner.tracer is not None else None)

    def recover(self, tasks: Sequence[Task],
                policy: MicroBatchPolicy = MicroBatchPolicy(), *,
                journal_path, chunk_tokens: int = 8,
                max_active_rows: Optional[int] = None,
                data_shards: Optional[int] = None,
                model_shards: int = 1,
                megastep=1, tracer=None) -> "QueuedServeResult":
        """Resume a killed ``run_stepped`` run from its write-ahead
        journal: rows with a durable ``retire`` event are restored
        verbatim; in-flight and unadmitted rows re-execute from
        scratch with their original admission indices, so the
        recovered run's record hashes and artifact-chain heads are
        byte-identical to an uninterrupted run's
        (``tests/harness/simulate.py --crash-at`` proves it). Must be
        called with the same task stream, policy and engine config as
        the killed run."""
        from repro.serving.journal import StepJournal
        state = StepJournal.load(journal_path)
        return self.run_stepped(
            tasks, policy, chunk_tokens=chunk_tokens,
            max_active_rows=max_active_rows, data_shards=data_shards,
            model_shards=model_shards, megastep=megastep,
            recovered=state.retired, tracer=tracer)

    def _emit_kv_metrics(self, metrics: PromCounters,
                         kv: Optional[Dict[str, KVStats]] = None
                         ) -> None:
        """Per-batch paged-KV exposition: pool gauges plus monotonic
        prefill-reuse counters (deltas since the last emission, so
        repeated run_queued calls on one engine stay cumulative).
        ``kv`` overrides the stats source — the sharded step loop's
        servers are runner-owned (aggregated per model), not in
        ``self._kv_servers``."""
        stats = kv.values() if kv is not None else \
            [srv.stats for srv in (list(self._kv_servers.values())
                                   + list(self._stepped_servers
                                          .values()))]
        for st in stats:
            metrics.set_gauge(
                "acar_kv_pages_in_use", st.pages_in_use,
                model=st.model,
                help="KV pool pages currently referenced")
            metrics.set_gauge(
                "acar_kv_pages_highwater", st.pages_highwater,
                model=st.model,
                help="KV pool pages-in-use peak since server creation")
            for source, value in (
                    ("probe", st.prefill_tokens_reused_probe),
                    ("prefix_cache", st.prefill_tokens_reused_prefix)):
                k = (st.model, source)
                delta = value - self._kv_emitted.get(k, 0)
                if delta:
                    metrics.inc(
                        "acar_kv_prefill_tokens_reused_total", delta,
                        model=st.model, source=source,
                        help="prefill tokens served from retained "
                             "pages instead of recomputation")
                    self._kv_emitted[k] = value


@dataclass
class QueuedServeResult:
    """Concatenated (admission-order) result of a queued serve run."""
    sigma: np.ndarray
    modes: np.ndarray
    final_answers: List[str]
    batch_sizes: List[int]
    ensemble_calls_saved: int
    wall_ms: float
    metrics: Optional[object] = field(default=None, repr=False)
    compaction: Optional[CompactionStats] = None
    probe_texts: Optional[List[List[str]]] = None
    member_answers: Optional[List[List[Optional[str]]]] = None
    # paged-KV accounting per model server (None when paged KV is off)
    kv: Optional[Dict[str, KVStats]] = None
    # step-loop accounting (None for wave-lockstep execution)
    step: Optional[object] = None
    # fault-path events (retries, quarantines, degraded routes,
    # displacements, aborts) in firing order; None on fault-free runs
    faults: Optional[List[dict]] = None
    # rows restored verbatim from a step journal by ``recover``
    restored_rows: int = 0
    # deterministic span records + chain head when a tracer was armed
    # (serving/tracing.py); None otherwise
    spans: Optional[List[dict]] = None
    span_head: Optional[str] = None
