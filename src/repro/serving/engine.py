"""Batched ACAR serving engine — the JAX-native adaptation of Alg. 1.

The paper routes one task at a time with host-side Python. On TPU the
profitable formulation batches: a request batch of B tasks becomes one
(B x N) probe decode, sigma and the routing decision are computed
on-device with ``sigma_batch`` / ``route_batch``, and the ensemble
members run as batched decodes with per-row mode masks. Aggregation
(majority vote, arena-lite verification, full-arena judge) is
vectorised over answer ids, so the entire routing pipeline is a handful
of XLA programs instead of 1,510 host round-trips.

Answer ids: EXTRACT runs host-side on decoded text (string logic), then
canonical answers are interned to int32 ids for the on-device math.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.acar import ACARConfig
from repro.configs.base import ModelConfig
from repro.core.extract import extract
from repro.core.sigma import (
    MODE_NAMES, majority_vote_batch, route_batch, sigma_batch)
from repro.data import tokenizer as tok
from repro.data.tasks import Task
from repro.sampling import generate
from repro.serving.metrics import PromCounters
from repro.serving.queue import AdmissionQueue, MicroBatchPolicy


@dataclass
class ZooModel:
    name: str
    cfg: ModelConfig
    params: dict


def intern_answers(answers: Sequence[str]) -> np.ndarray:
    """Intern canonical answer strings to dense int32 ids."""
    table: Dict[str, int] = {}
    out = np.empty(len(answers), np.int32)
    for i, a in enumerate(answers):
        out[i] = table.setdefault(a, len(table))
    return out


def judge_batch(member_ids: jax.Array, probe_majority: jax.Array,
                modes: jax.Array) -> jax.Array:
    """Vectorised aggregation. member_ids: (B, M) answer ids (M ensemble
    members, invalid entries = -1); probe_majority: (B,); modes: (B,).

    single_agent -> probe majority.
    arena_lite   -> probe majority unless the first two members agree on
                    a common different answer.
    full_arena   -> plurality over members, probe majority breaks ties.
    """
    b, m = member_ids.shape
    # plurality over valid member answers
    valid = member_ids >= 0
    eq = (member_ids[:, :, None] == member_ids[:, None, :]) \
        & valid[:, :, None] & valid[:, None, :]
    votes = eq.sum(-1)                                   # (B, M)
    # prefer answers matching probe majority on vote ties
    bonus = (member_ids == probe_majority[:, None]) & valid
    score = votes * 2 + bonus
    best = jnp.argmax(jnp.where(valid, score, -1), axis=-1)
    plural = jnp.take_along_axis(member_ids, best[:, None], 1)[:, 0]

    two_agree = (member_ids[:, 0] == member_ids[:, 1]) \
        & valid[:, 0] & valid[:, 1]
    lite = jnp.where(two_agree & (member_ids[:, 0] != probe_majority),
                     member_ids[:, 0], probe_majority)

    return jnp.where(modes == 0, probe_majority,
                     jnp.where(modes == 1, lite, plural))


@dataclass
class BatchResult:
    sigma: np.ndarray            # (B,)
    modes: np.ndarray            # (B,) int mode ids
    final_answers: List[str]
    probe_texts: List[List[str]]
    ensemble_calls_saved: int
    wall_ms: float


class BatchedACAREngine:
    def __init__(self, acfg: ACARConfig, probe: ZooModel,
                 ensemble: Sequence[ZooModel], prompt_len: int = 16,
                 max_new_tokens: int = 8):
        self.acfg = acfg
        self.probe = probe
        self.ensemble = list(ensemble)
        self.prompt_len = prompt_len
        self.max_new_tokens = max_new_tokens

    # ------------------------------------------------------------------
    def _decode_texts(self, out_tokens) -> List[str]:
        return [tok.decode(row) for row in np.asarray(out_tokens)]

    def run_batch(self, tasks: Sequence[Task]) -> BatchResult:
        t0 = time.perf_counter()
        b = len(tasks)
        n = self.acfg.n_probe_samples
        ids = tok.encode_aligned([t.text for t in tasks])
        # (B*N) probe expansion — one decode program for all samples
        tiled = np.repeat(ids, n, axis=0)
        key = jax.random.PRNGKey(self.acfg.seed)
        out = generate(self.probe.cfg, self.probe.params,
                       jnp.asarray(tiled),
                       max_new_tokens=self.max_new_tokens,
                       temperature=self.acfg.probe_temperature,
                       key=key, eos_id=tok.EOS, pad_id=tok.PAD)
        texts = self._decode_texts(out.tokens)
        answers = [extract(texts[i * n + j], tasks[i].kind)
                   for i in range(b) for j in range(n)]
        answer_ids = intern_answers(answers).reshape(b, n)

        sig = sigma_batch(jnp.asarray(answer_ids))
        modes = route_batch(sig)
        probe_major = majority_vote_batch(jnp.asarray(answer_ids))

        # ensemble decodes (batched over all rows; per-row mode masks
        # select which answers count — a compacting scheduler would slice
        # the escalated subset instead, same math)
        id_table: Dict[str, int] = {}
        for i, a in enumerate(answers):
            id_table.setdefault(a, len(id_table))
        member_cols = []
        member_texts: List[List[str]] = []
        modes_np = np.asarray(modes)
        for mi, zm in enumerate(self.ensemble):
            needed = modes_np >= (1 if mi < self.acfg.arena_lite_size
                                  else 2)
            if not needed.any():
                member_cols.append(np.full(b, -1, np.int32))
                member_texts.append([""] * b)
                continue
            mout = generate(zm.cfg, zm.params, jnp.asarray(ids),
                            max_new_tokens=self.max_new_tokens,
                            temperature=self.acfg.ensemble_temperature,
                            key=jax.random.fold_in(key, 1000 + mi),
                            eos_id=tok.EOS, pad_id=tok.PAD)
            mtexts = self._decode_texts(mout.tokens)
            member_texts.append(mtexts)
            col = np.full(b, -1, np.int32)
            for i in range(b):
                if needed[i]:
                    a = extract(mtexts[i], tasks[i].kind)
                    col[i] = id_table.setdefault(a, len(id_table))
            member_cols.append(col)
        member_ids = jnp.asarray(np.stack(member_cols, axis=1))

        final_ids = judge_batch(member_ids, probe_major, modes)
        rev = {v: k for k, v in id_table.items()}
        final_answers = [rev[int(i)] for i in np.asarray(final_ids)]
        saved = int(np.sum(3 - np.where(
            modes_np == 0, 0,
            np.where(modes_np == 1, self.acfg.arena_lite_size,
                     len(self.ensemble)))))
        probe_texts = [texts[i * n:(i + 1) * n] for i in range(b)]
        return BatchResult(
            sigma=np.asarray(sig), modes=modes_np,
            final_answers=final_answers, probe_texts=probe_texts,
            ensemble_calls_saved=saved,
            wall_ms=(time.perf_counter() - t0) * 1e3)

    # ------------------------------------------------------------------
    # continuous-batching entry point: admission queue -> micro-batches
    # ------------------------------------------------------------------
    def run_queued(self, tasks: Sequence[Task],
                   policy: MicroBatchPolicy = MicroBatchPolicy()
                   ) -> "QueuedServeResult":
        """Serve a request stream through the admission queue: tasks are
        submitted with logical arrival ticks, grouped into micro-batches
        under the policy budget, and each micro-batch runs the batched
        probe -> route -> ensemble pipeline. Per-batch results are
        concatenated in admission order."""
        t0 = time.perf_counter()
        queue = AdmissionQueue(policy)
        for t in tasks:
            queue.submit(t)
        metrics = PromCounters()
        batch_results: List[BatchResult] = []
        batch_sizes: List[int] = []
        for batch in queue.drain_batches():
            res = self.run_batch([r.task for r in batch.requests])
            batch_results.append(res)
            batch_sizes.append(len(batch))
            metrics.inc("acar_engine_batches_total",
                        help="micro-batches decoded")
            metrics.inc("acar_engine_tasks_total", len(batch),
                        help="tasks served")
            metrics.inc("acar_engine_ensemble_calls_saved_total",
                        res.ensemble_calls_saved,
                        help="ensemble decodes avoided by routing")
            for m in res.modes:
                metrics.inc("acar_engine_mode_total",
                            mode=MODE_NAMES[int(m)],
                            help="tasks routed per execution mode")
        return QueuedServeResult(
            sigma=np.concatenate([r.sigma for r in batch_results])
            if batch_results else np.zeros(0, np.float32),
            modes=np.concatenate([r.modes for r in batch_results])
            if batch_results else np.zeros(0, np.int32),
            final_answers=[a for r in batch_results
                           for a in r.final_answers],
            batch_sizes=batch_sizes,
            ensemble_calls_saved=sum(r.ensemble_calls_saved
                                     for r in batch_results),
            wall_ms=(time.perf_counter() - t0) * 1e3,
            metrics=metrics)


@dataclass
class QueuedServeResult:
    """Concatenated (admission-order) result of a queued serve run."""
    sigma: np.ndarray
    modes: np.ndarray
    final_answers: List[str]
    batch_sizes: List[int]
    ensemble_calls_saved: int
    wall_ms: float
    metrics: Optional[object] = field(default=None, repr=False)
