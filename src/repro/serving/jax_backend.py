"""JaxModelBackend — a real JAX model from the zoo behind the
``ModelBackend`` protocol.

The examples use this to run the *entire* ACAR serving path (probe
decode -> EXTRACT -> sigma -> routed ensemble -> judge) over genuinely
executing models: reduced zoo configs trained on the arithmetic corpus.
Cost is modelled as active-params x generated-tokens; latency is the
measured wall time of the jitted generate call.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.backends import GenResult
from repro.core.extract import extract_math
from repro.data import tokenizer as tok
from repro.data.tasks import Task
from repro.sampling import generate
from repro.teamllm.fingerprint import stable_fingerprint

# $ per active-parameter per generated token (synthetic pricing used to
# make the cost axis comparable across zoo members)
COST_PER_APARAM_TOKEN = 1e-12


@dataclass
class JaxModelBackend:
    name: str
    cfg: ModelConfig
    params: dict
    prompt_len: int = 16
    max_new_tokens: int = 8

    def __post_init__(self):
        self._active_params = self.cfg.active_param_count()

    def generate(self, task: Task, prompt: str, *, temperature: float,
                 sample_idx: int = 0, seed: int = 0,
                 **_ignored) -> GenResult:
        ids = tok.encode_aligned([task.text])
        # stable_fingerprint, not hash(): builtin str hashing is salted
        # per process, which would draw different keys for identical
        # runs (breaking the deterministic-execution invariant)
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(seed), sample_idx),
            stable_fingerprint(task.task_id))
        t0 = time.perf_counter()
        out = generate(
            self.cfg, self.params, jnp.asarray(ids),
            max_new_tokens=self.max_new_tokens,
            temperature=float(temperature), key=key,
            eos_id=tok.EOS, pad_id=tok.PAD)
        text = tok.decode(np.asarray(out.tokens[0]))
        latency_ms = (time.perf_counter() - t0) * 1e3
        n_tok = int(out.lengths[0]) or 1
        cost = self._active_params * n_tok * COST_PER_APARAM_TOKEN
        semantic = extract_math(text) if text.strip() else text
        return GenResult(response=text, semantic_answer=semantic,
                         cost=cost, latency_ms=latency_ms)
