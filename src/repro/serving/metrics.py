"""Prometheus-style metric registry (dependency-free).

Shared by the continuous-batching scheduler and the real-model engine's
queued serving path; rendering follows the Prometheus text exposition
format with deterministic ordering. Counters accumulate via ``inc``;
gauges (``set_gauge``) hold the last observed value — used for
per-wave occupancy readings like compaction bucket fill; histograms
(``observe``) bucket wall-clock samples — used for per-phase span
latencies (``acar_span_duration{phase}``) and decode-launch times.

A metric name owns one kind for the registry's lifetime: re-using a
counter name as a gauge (or any other cross-kind collision) raises
``ValueError`` instead of silently flipping the rendered TYPE and
corrupting both series.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

# Fault-tolerance metric names (one constant per exported series so
# the step loop, harness, and tests agree on spelling).
FAULTS_INJECTED = "acar_faults_injected_total"
MEMBER_RETRIES = "acar_member_retries_total"
MEMBER_QUARANTINED = "acar_member_quarantined"
ROUTES_DEGRADED = "acar_routes_degraded_total"
RECOVERY_ROWS_RESTORED = "acar_recovery_rows_restored_total"
ROW_DEADLINE_ABORTS = "acar_row_deadline_aborts_total"
STEP_REQUEUES = "acar_step_requeues_total"
# Work stealing (sharded step loop): member executions re-placed onto
# a roomier shard when the home shard's pool is page-tight, labelled
# {src, dst}.
SHARD_STEALS = "acar_shard_steals_total"

# Default histogram buckets: sub-millisecond host hooks up to
# multi-second device launches (seconds, Prometheus convention).
DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                   0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


class PromCounters:
    """Minimal Prometheus text-format counter/gauge/histogram
    registry."""

    def __init__(self):
        self._values: Dict[Tuple[str, Tuple[Tuple[str, str], ...]],
                           float] = {}
        self._help: Dict[str, str] = {}
        self._types: Dict[str, str] = {}
        # histogram state, keyed like _values: per-series cumulative
        # bucket counts plus running sum/count
        self._buckets: Dict[str, Tuple[float, ...]] = {}
        self._hist: Dict[Tuple[str, Tuple[Tuple[str, str], ...]],
                         List[float]] = {}

    @staticmethod
    def _key(name: str, labels: Dict[str, str]):
        return (name, tuple(sorted((k, str(v))
                                   for k, v in labels.items())))

    def _register(self, name: str, kind: str, help: str) -> None:
        """Claim ``name`` for ``kind``; a cross-kind re-use raises
        instead of silently flipping the rendered TYPE (the original
        ``set_gauge`` clobber bug). Later ``help=`` text lands when
        the first call passed none."""
        prev = self._types.setdefault(name, kind)
        if prev != kind:
            raise ValueError(
                f"metric {name!r} already registered as {prev}, "
                f"cannot re-use it as a {kind}")
        if help and name not in self._help:
            self._help[name] = help

    def inc(self, name: str, value: float = 1.0,
            help: str = "", **labels: str) -> None:
        self._register(name, "counter", help)
        key = self._key(name, labels)
        self._values[key] = self._values.get(key, 0.0) + value

    def set_gauge(self, name: str, value: float,
                  help: str = "", **labels: str) -> None:
        """Set a gauge to its latest observation (no accumulation)."""
        self._register(name, "gauge", help)
        self._values[self._key(name, labels)] = value

    def observe(self, name: str, value: float,
                buckets: Sequence[float] = DEFAULT_BUCKETS,
                help: str = "", **labels: str) -> None:
        """Record one histogram sample. The first ``observe`` for a
        name fixes its bucket bounds; a later call with different
        bounds raises (mixed-bound series render nonsense)."""
        self._register(name, "histogram", help)
        bounds = tuple(float(b) for b in buckets)
        prev = self._buckets.setdefault(name, bounds)
        if prev != bounds:
            raise ValueError(
                f"histogram {name!r} already registered with buckets "
                f"{prev}, cannot re-use it with {bounds}")
        key = self._key(name, labels)
        state = self._hist.get(key)
        if state is None:
            # one slot per finite bucket + [sum, count]
            state = self._hist[key] = [0.0] * (len(bounds) + 2)
        for i, b in enumerate(bounds):
            if value <= b:
                state[i] += 1
        state[-2] += value
        state[-1] += 1

    def get(self, name: str, **labels: str) -> float:
        return self._values.get(self._key(name, labels), 0.0)

    def get_histogram(self, name: str, **labels: str
                      ) -> Tuple[float, float]:
        """(sum, count) for one histogram series (0, 0 if unseen)."""
        state = self._hist.get(self._key(name, labels))
        if state is None:
            return (0.0, 0.0)
        return (state[-2], state[-1])

    @staticmethod
    def _escape_label(value: str) -> str:
        """Escape a label value per the Prometheus text exposition
        format: backslash, double-quote and line-feed must appear as
        ``\\\\``, ``\\"`` and ``\\n`` inside the quoted value — a model
        name containing any of them otherwise renders invalid
        exposition text."""
        return (value.replace("\\", "\\\\").replace('"', '\\"')
                .replace("\n", "\\n"))

    @staticmethod
    def _escape_help(text: str) -> str:
        """HELP text escaping (backslash and line feed only, per the
        exposition format)."""
        return text.replace("\\", "\\\\").replace("\n", "\\n")

    @staticmethod
    def _fmt_le(bound: float) -> str:
        return f"{bound:g}"

    def _render_histogram(self, name: str, lines: List[str]) -> None:
        bounds = self._buckets[name]
        for (n, labels), state in sorted(self._hist.items()):
            if n != name:
                continue
            base = [f'{k}="{self._escape_label(v)}"'
                    for k, v in labels]
            for i, b in enumerate(bounds):
                lab = ",".join(base + [f'le="{self._fmt_le(b)}"'])
                lines.append(
                    f"{name}_bucket{{{lab}}} {state[i]:g}")
            lab = ",".join(base + ['le="+Inf"'])
            lines.append(f"{name}_bucket{{{lab}}} {state[-1]:g}")
            suffix = "{" + ",".join(base) + "}" if base else ""
            lines.append(f"{name}_sum{suffix} {state[-2]:g}")
            lines.append(f"{name}_count{suffix} {state[-1]:g}")

    def render(self) -> str:
        """Prometheus exposition text format, deterministically sorted."""
        lines: List[str] = []
        names = ({n for n, _ in self._values}
                 | {n for n, _ in self._hist})
        for name in sorted(names):
            if name in self._help:
                lines.append(f"# HELP {name} "
                             f"{self._escape_help(self._help[name])}")
            lines.append(
                f"# TYPE {name} {self._types.get(name, 'counter')}")
            if self._types.get(name) == "histogram":
                self._render_histogram(name, lines)
                continue
            for (n, labels), v in sorted(self._values.items()):
                if n != name:
                    continue
                if labels:
                    lab = ",".join(
                        f'{k}="{self._escape_label(v_)}"'
                        for k, v_ in labels)
                    lines.append(f"{name}{{{lab}}} {v:g}")
                else:
                    lines.append(f"{name} {v:g}")
        return "\n".join(lines) + ("\n" if lines else "")
