"""Prometheus-style counter registry (dependency-free).

Shared by the continuous-batching scheduler and the real-model engine's
queued serving path; rendering follows the Prometheus text exposition
format with deterministic ordering.
"""
from __future__ import annotations

from typing import Dict, List, Tuple


class PromCounters:
    """Minimal Prometheus text-format counter registry."""

    def __init__(self):
        self._values: Dict[Tuple[str, Tuple[Tuple[str, str], ...]],
                           float] = {}
        self._help: Dict[str, str] = {}

    def inc(self, name: str, value: float = 1.0,
            help: str = "", **labels: str) -> None:
        key = (name, tuple(sorted((k, str(v))
                                  for k, v in labels.items())))
        self._values[key] = self._values.get(key, 0.0) + value
        if help and name not in self._help:
            self._help[name] = help

    def get(self, name: str, **labels: str) -> float:
        key = (name, tuple(sorted((k, str(v))
                                  for k, v in labels.items())))
        return self._values.get(key, 0.0)

    def render(self) -> str:
        """Prometheus exposition text format, deterministically sorted."""
        lines: List[str] = []
        for name in sorted({n for n, _ in self._values}):
            if name in self._help:
                lines.append(f"# HELP {name} {self._help[name]}")
            lines.append(f"# TYPE {name} counter")
            for (n, labels), v in sorted(self._values.items()):
                if n != name:
                    continue
                if labels:
                    lab = ",".join(f'{k}="{v_}"' for k, v_ in labels)
                    lines.append(f"{name}{{{lab}}} {v:g}")
                else:
                    lines.append(f"{name} {v:g}")
        return "\n".join(lines) + ("\n" if lines else "")
