"""Prometheus-style metric registry (dependency-free).

Shared by the continuous-batching scheduler and the real-model engine's
queued serving path; rendering follows the Prometheus text exposition
format with deterministic ordering. Counters accumulate via ``inc``;
gauges (``set_gauge``) hold the last observed value — used for
per-wave occupancy readings like compaction bucket fill.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

# Fault-tolerance metric names (one constant per exported series so
# the step loop, harness, and tests agree on spelling).
FAULTS_INJECTED = "acar_faults_injected_total"
MEMBER_RETRIES = "acar_member_retries_total"
MEMBER_QUARANTINED = "acar_member_quarantined"
ROUTES_DEGRADED = "acar_routes_degraded_total"
RECOVERY_ROWS_RESTORED = "acar_recovery_rows_restored_total"
ROW_DEADLINE_ABORTS = "acar_row_deadline_aborts_total"
STEP_REQUEUES = "acar_step_requeues_total"
# Work stealing (sharded step loop): member executions re-placed onto
# a roomier shard when the home shard's pool is page-tight, labelled
# {src, dst}.
SHARD_STEALS = "acar_shard_steals_total"


class PromCounters:
    """Minimal Prometheus text-format counter/gauge registry."""

    def __init__(self):
        self._values: Dict[Tuple[str, Tuple[Tuple[str, str], ...]],
                           float] = {}
        self._help: Dict[str, str] = {}
        self._types: Dict[str, str] = {}

    @staticmethod
    def _key(name: str, labels: Dict[str, str]):
        return (name, tuple(sorted((k, str(v))
                                   for k, v in labels.items())))

    def inc(self, name: str, value: float = 1.0,
            help: str = "", **labels: str) -> None:
        key = self._key(name, labels)
        self._values[key] = self._values.get(key, 0.0) + value
        if help and name not in self._help:
            self._help[name] = help
        self._types.setdefault(name, "counter")

    def set_gauge(self, name: str, value: float,
                  help: str = "", **labels: str) -> None:
        """Set a gauge to its latest observation (no accumulation)."""
        self._values[self._key(name, labels)] = value
        if help and name not in self._help:
            self._help[name] = help
        self._types[name] = "gauge"

    def get(self, name: str, **labels: str) -> float:
        return self._values.get(self._key(name, labels), 0.0)

    @staticmethod
    def _escape_label(value: str) -> str:
        """Escape a label value per the Prometheus text exposition
        format: backslash, double-quote and line-feed must appear as
        ``\\\\``, ``\\"`` and ``\\n`` inside the quoted value — a model
        name containing any of them otherwise renders invalid
        exposition text."""
        return (value.replace("\\", "\\\\").replace('"', '\\"')
                .replace("\n", "\\n"))

    @staticmethod
    def _escape_help(text: str) -> str:
        """HELP text escaping (backslash and line feed only, per the
        exposition format)."""
        return text.replace("\\", "\\\\").replace("\n", "\\n")

    def render(self) -> str:
        """Prometheus exposition text format, deterministically sorted."""
        lines: List[str] = []
        for name in sorted({n for n, _ in self._values}):
            if name in self._help:
                lines.append(f"# HELP {name} "
                             f"{self._escape_help(self._help[name])}")
            lines.append(
                f"# TYPE {name} {self._types.get(name, 'counter')}")
            for (n, labels), v in sorted(self._values.items()):
                if n != name:
                    continue
                if labels:
                    lab = ",".join(
                        f'{k}="{self._escape_label(v_)}"'
                        for k, v_ in labels)
                    lines.append(f"{name}{{{lab}}} {v:g}")
                else:
                    lines.append(f"{name} {v:g}")
        return "\n".join(lines) + ("\n" if lines else "")
