from repro.optim.adamw import (
    AdamWState, clip_by_global_norm, cosine_schedule, global_norm, init,
    update)
from repro.optim.loss import softmax_cross_entropy

__all__ = [
    "AdamWState", "clip_by_global_norm", "cosine_schedule", "global_norm",
    "init", "softmax_cross_entropy", "update",
]
