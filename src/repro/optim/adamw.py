"""AdamW + warmup-cosine schedule + global-norm clipping, in-house.

Pure-pytree implementation (no optax dependency): ``init`` builds the
moment state, ``update`` is a jit-safe pure function. The state carries
the step as a scalar int32 array so the whole optimizer threads through
``jax.jit`` / ``pjit`` unchanged, and moments inherit the parameter
shardings automatically under pjit.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array          # scalar int32
    mu: PyTree               # first moment
    nu: PyTree               # second moment


def init(params: PyTree) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def cosine_schedule(step: jax.Array, tc: TrainConfig) -> jax.Array:
    """Linear warmup to ``learning_rate`` then cosine decay to 10%."""
    warm = tc.learning_rate * (step + 1) / max(tc.warmup_steps, 1)
    frac = jnp.clip((step - tc.warmup_steps)
                    / max(tc.total_steps - tc.warmup_steps, 1), 0.0, 1.0)
    cos = tc.learning_rate * (0.1 + 0.9 * 0.5
                              * (1.0 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < tc.warmup_steps, warm, cos)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float
                        ) -> Tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def update(params: PyTree, grads: PyTree, state: AdamWState,
           tc: TrainConfig) -> Tuple[PyTree, AdamWState, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if tc.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
    else:
        gnorm = global_norm(grads)

    step = state.step
    lr = cosine_schedule(step, tc)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - tc.b1 ** t
    bc2 = 1.0 - tc.b2 ** t

    mu = jax.tree.map(lambda m, g: tc.b1 * m + (1 - tc.b1) * g,
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: tc.b2 * v + (1 - tc.b2) * g * g,
                      state.nu, grads)

    def step_fn(p, m, v):
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + tc.eps)
        upd = upd + tc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

    new_params = jax.tree.map(step_fn, params, mu, nu)
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, AdamWState(step + 1, mu, nu), metrics
