"""Next-token cross-entropy with masking, numerically stable in fp32."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None
                          ) -> Tuple[jax.Array, dict]:
    """logits: (B, S, V); labels: (B, S) int32; mask: (B, S) {0,1}.

    Returns (mean loss over unmasked positions, metrics dict).
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    total = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / total
    acc = ((jnp.argmax(logits, -1) == labels) * mask).sum() / total
    return loss, {"loss": loss, "token_accuracy": acc,
                  "tokens": total}
