"""Shard-aware npz pytree checkpointing.

Flattens an arbitrary pytree to ``path/key/parts`` npz entries; restore
takes a template tree (for structure + dtypes + shardings). On a mesh,
arrays are gathered from their addressable shards before saving and
re-placed with ``jax.device_put`` against the template sharding on
restore, so a checkpoint written under one mesh layout restores under
another.
"""
from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Any, Dict, Union

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

_SEP = "/"


def _flatten(tree: PyTree) -> Dict[str, jax.Array]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_elem(p) for p in path)
        out[key] = leaf
    return out


def _path_elem(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(path: Union[str, Path], tree: PyTree,
                    step: int = 0, metadata: Dict = None) -> Path:
    """Atomically write ``tree`` (+ metadata json) to ``path``."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    # bf16 has no numpy dtype — store as uint16 view + dtype tag
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        a = np.asarray(jax.device_get(v))
        if a.dtype == jnp.bfloat16:
            dtypes[k] = "bfloat16"
            a = a.view(np.uint16)
        else:
            dtypes[k] = str(a.dtype)
        arrays[k] = a
    meta = {"step": step, "dtypes": dtypes,
            "user": metadata or {}}
    fd, tmp = tempfile.mkstemp(dir=str(path.parent), suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, __meta__=json.dumps(meta), **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def load_metadata(path: Union[str, Path]) -> Dict:
    with np.load(Path(path), allow_pickle=False) as z:
        return json.loads(str(z["__meta__"]))


def restore_checkpoint(path: Union[str, Path], template: PyTree
                       ) -> PyTree:
    """Restore into the structure/dtypes/shardings of ``template``."""
    path = Path(path)
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__meta__"]))
        dtypes = meta["dtypes"]
        flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, leaf in flat_t:
            key = _SEP.join(_path_elem(e) for e in p)
            if key not in z:
                raise KeyError(f"checkpoint {path} missing {key!r}")
            a = z[key]
            if dtypes.get(key) == "bfloat16":
                a = a.view(jnp.bfloat16)
            if a.shape != leaf.shape:
                raise ValueError(
                    f"{key}: checkpoint shape {a.shape} != template "
                    f"{leaf.shape}")
            arr = jnp.asarray(a, dtype=leaf.dtype)
            sharding = getattr(leaf, "sharding", None)
            if sharding is not None and hasattr(sharding, "mesh"):
                arr = jax.device_put(arr, sharding)
            leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
