from repro.checkpoint.io import (
    load_metadata, restore_checkpoint, save_checkpoint)

__all__ = ["load_metadata", "restore_checkpoint", "save_checkpoint"]
