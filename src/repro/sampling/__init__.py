from repro.sampling.sampler import (
    GenerateOutput, batch_invariant, decode_paged, decode_text,
    fork_pages, generate, generate_samples, prefill_paged,
    sample_token, tile_cache)

__all__ = ["GenerateOutput", "batch_invariant", "decode_paged",
           "decode_text", "fork_pages", "generate", "generate_samples",
           "prefill_paged", "sample_token", "tile_cache"]
