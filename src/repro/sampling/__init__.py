from repro.sampling.sampler import (
    GenerateOutput, batch_invariant, decode_text, generate,
    generate_samples, sample_token, tile_cache)

__all__ = ["GenerateOutput", "batch_invariant", "decode_text",
           "generate", "generate_samples", "sample_token", "tile_cache"]
