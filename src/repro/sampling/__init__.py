from repro.sampling.sampler import (
    GenerateOutput, batch_invariant, decode_megastep_rows,
    decode_megastep_rows_sharded, decode_paged, decode_step_rows,
    decode_step_rows_sharded, decode_text, fork_pages,
    fork_pages_sharded, generate, generate_samples, member_row_keys,
    prefill_chunk_paged, prefill_chunk_paged_sharded, prefill_lanes,
    prefill_paged, prefill_paged_sharded, probe_row_keys,
    sample_token, sample_token_rows, tile_cache)

__all__ = ["GenerateOutput", "batch_invariant",
           "decode_megastep_rows", "decode_megastep_rows_sharded",
           "decode_paged", "decode_step_rows",
           "decode_step_rows_sharded", "decode_text", "fork_pages",
           "fork_pages_sharded", "generate", "generate_samples",
           "member_row_keys", "prefill_chunk_paged",
           "prefill_chunk_paged_sharded", "prefill_lanes",
           "prefill_paged", "prefill_paged_sharded",
           "probe_row_keys", "sample_token", "sample_token_rows",
           "tile_cache"]
