from repro.sampling.sampler import (
    GenerateOutput, decode_text, generate, sample_token)

__all__ = ["GenerateOutput", "decode_text", "generate", "sample_token"]
