"""Prefill + KV-cache decode sampling loop.

``generate`` is a single jitted XLA program per (config, shape): prefill
builds the cache sized for prompt+new tokens, then a ``lax.scan`` drives
``decode_step`` for ``max_new_tokens`` steps. Temperature 0 is greedy;
otherwise tokens come from a temperature-scaled categorical. Finished
rows (EOS emitted) keep emitting ``pad_id`` without disturbing the
cache, so the whole batch runs a fixed-length program.

``generate_samples`` is the shared-prefix N-sample variant the ACAR
probe uses: each prompt is prefilled **once**, the KV cache is
broadcast across the N samples, and only the decode scan runs at the
expanded (B*N) batch — cutting prefill FLOPs by ~N x while emitting
tokens bit-identical to ``generate`` over an ``np.repeat``-expanded
prompt batch (per-row computation is batch-composition invariant for
every non-MoE family; MoE prefill routes with a capacity that couples
rows, see ``batch_invariant``).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T


class GenerateOutput(NamedTuple):
    tokens: jax.Array        # (B, max_new) int32, pad_id after EOS
    logprobs: jax.Array      # (B, max_new) float32 logprob of chosen tok
    lengths: jax.Array       # (B,) int32 — emitted tokens incl. EOS


def sample_token(logits: jax.Array, temperature: float,
                 key: jax.Array) -> jax.Array:
    """logits: (B, V) -> (B,) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


def batch_invariant(cfg: ModelConfig) -> bool:
    """True when one row's forward pass cannot depend on which other
    rows share the batch. Dense / SSM / hybrid stacks compute strictly
    per row; MoE prefill routes with a capacity proportional to the
    *total* token count, so expert overflow (token dropping) couples
    rows — compaction and shared-prefix prefill are only bit-equivalent
    to the padded/tiled paths for batch-invariant configs."""
    return cfg.moe is None


def _decode_scan(cfg: ModelConfig, params: dict, cache, logits0,
                 start_pos: int, batch: int, max_new_tokens: int,
                 temperature: float, key: jax.Array, eos_id: int,
                 pad_id: int, decode_fn=None
                 ) -> Tuple[GenerateOutput, object]:
    """Shared fixed-length decode loop over an existing prefill cache.

    ``decode_fn(cache, token, pos) -> (logits, cache)`` overrides the
    per-step transition — the paged path threads (k_pages, v_pages)
    through it; the default is the dense ``T.decode_step``. Returns the
    final cache alongside the output (dense callers drop it; the paged
    path must keep its updated pages)."""
    if decode_fn is None:
        def decode_fn(cache, token, pos):
            return T.decode_step(cfg, params, cache, token, pos)

    def body(carry, step_key):
        cache, logits, pos, done = carry
        tok = sample_token(logits, temperature, step_key)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        tok_logp = jnp.take_along_axis(logp, tok[:, None], -1)[:, 0]
        emit = jnp.where(done, pad_id, tok)
        new_done = done | (tok == eos_id)
        next_logits, cache = decode_fn(cache, emit, pos)
        return ((cache, next_logits, pos + 1, new_done),
                (emit, jnp.where(done, 0.0, tok_logp), ~done))

    keys = jax.random.split(key, max_new_tokens)
    init = (cache, logits0, jnp.int32(start_pos),
            jnp.zeros((batch,), bool))
    (cache, _, _, _), (toks, logps, live) = jax.lax.scan(body, init,
                                                         keys)
    toks = toks.T                      # (B, max_new)
    logps = logps.T
    # a row emits a real token (possibly EOS, possibly one that merely
    # *equals* pad_id) at every step it was not yet done — counting
    # pad_id occurrences would undercount legitimately sampled pads
    lengths = live.T.sum(axis=1).astype(jnp.int32)
    return GenerateOutput(tokens=toks, logprobs=logps,
                          lengths=lengths), cache


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "max_new_tokens", "temperature", "eos_id",
                     "pad_id"))
def generate(cfg: ModelConfig, params: dict, prompt_tokens: jax.Array,
             *, max_new_tokens: int, temperature: float = 0.0,
             key: Optional[jax.Array] = None, eos_id: int = -1,
             pad_id: int = 0,
             frontend_embeds: Optional[jax.Array] = None
             ) -> GenerateOutput:
    """prompt_tokens: (B, S) int32 — fixed-length prompts."""
    b, s = prompt_tokens.shape
    if key is None:
        key = jax.random.PRNGKey(0)
    total = s + max_new_tokens
    logits0, cache = T.prefill(cfg, params, prompt_tokens,
                               frontend_embeds, cache_len=total)
    out, _ = _decode_scan(cfg, params, cache, logits0, s, b,
                          max_new_tokens, temperature, key, eos_id,
                          pad_id)
    return out


def tile_cache(cache, n: int, batch: Optional[int] = None):
    """Broadcast a prefill cache of batch B to B*n rows (row i's
    replicas occupy rows i*n .. i*n+n-1, matching ``np.repeat`` on the
    prompt batch). Stacked layer pytrees (``layers`` / ``dec_layers`` /
    ``cross``) carry (L, B, ...); unrolled per-layer entries
    (``layer_XX``) carry (B, ...). Pass ``batch`` to assert the chosen
    axis really is the batch axis — the key->axis rule mirrors
    ``transformer.init_cache``'s layout and must fail loudly if a new
    cache entry breaks it."""
    out = {}
    for k, v in cache.items():
        axis = 1 if k in ("layers", "dec_layers", "cross") else 0
        if batch is not None:
            for leaf in jax.tree.leaves(v):
                if leaf.shape[axis] != batch:
                    raise ValueError(
                        f"cache entry {k!r}: expected batch {batch} on "
                        f"axis {axis}, got shape {leaf.shape} — "
                        "tile_cache's key->axis rule no longer matches "
                        "the cache layout")
        out[k] = jax.tree.map(
            lambda a, ax=axis: jnp.repeat(a, n, axis=ax), v)
    return out


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "n", "max_new_tokens", "temperature",
                     "eos_id", "pad_id"))
def generate_samples(cfg: ModelConfig, params: dict,
                     prompt_tokens: jax.Array, n: int, *,
                     max_new_tokens: int, temperature: float = 0.0,
                     key: Optional[jax.Array] = None, eos_id: int = -1,
                     pad_id: int = 0,
                     frontend_embeds: Optional[jax.Array] = None
                     ) -> GenerateOutput:
    """N samples per prompt with a single shared-prefix prefill.

    prompt_tokens: (B, S) -> GenerateOutput over B*n rows, row-major in
    sample index (row i*n+j is sample j of prompt i). Bit-identical to
    ``generate(cfg, params, np.repeat(prompt_tokens, n, axis=0), ...)``
    with the same key for ``batch_invariant`` configs, because the
    decode scan sees the same (B*n, V) logits and the same per-step
    keys — only the redundant n-1 prefills per prompt are elided.
    """
    b, s = prompt_tokens.shape
    if key is None:
        key = jax.random.PRNGKey(0)
    total = s + max_new_tokens
    logits0, cache = T.prefill(cfg, params, prompt_tokens,
                               frontend_embeds, cache_len=total)
    cache = tile_cache(cache, n, batch=b)
    logits0 = jnp.repeat(logits0, n, axis=0)
    out, _ = _decode_scan(cfg, params, cache, logits0, s, b * n,
                          max_new_tokens, temperature, key, eos_id,
                          pad_id)
    return out


# ----------------------------------------------------------------------
# paged KV-cache path (serving/kv_pool.py owns allocation; these are
# the jitted device programs it drives)
# ----------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("cfg",))
def prefill_paged(cfg: ModelConfig, params: dict,
                  prompt_tokens: jax.Array, k_pages: jax.Array,
                  v_pages: jax.Array, prefill_table: jax.Array):
    """Prompt prefill scattering K/V into pool pages.

    prompt_tokens: (B, S); k_pages/v_pages: (L, P, page_size, KV, Dh);
    prefill_table: (B, NBp) int32. Returns (logits0 (B, V), k_pages,
    v_pages). Logits are bit-identical to the dense ``T.prefill`` —
    only the cache packing differs."""
    return T.prefill_paged(cfg, params, prompt_tokens, k_pages,
                           v_pages, prefill_table)


@jax.jit
def fork_pages(k_pages: jax.Array, v_pages: jax.Array,
               src: jax.Array, dst: jax.Array):
    """Copy-on-write materialisation: page ``dst[i]`` becomes a private
    copy of ``src[i]`` across every layer. ``src`` may repeat (one
    canonical prompt-tail page forked to N samples); ``dst`` must not.
    """
    k_pages = k_pages.at[:, dst].set(k_pages[:, src])
    v_pages = v_pages.at[:, dst].set(v_pages[:, src])
    return k_pages, v_pages


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "start_pos", "max_new_tokens",
                     "temperature", "eos_id", "pad_id"))
def decode_paged(cfg: ModelConfig, params: dict, logits0: jax.Array,
                 k_pages: jax.Array, v_pages: jax.Array,
                 block_table: jax.Array, key: jax.Array, *,
                 start_pos: int, max_new_tokens: int,
                 temperature: float = 0.0, eos_id: int = -1,
                 pad_id: int = 0):
    """Fixed-length decode over a paged cache, from prefill logits.

    logits0: (B, V) last-prompt-position logits (freshly computed or
    reused from a retained probe prefill — bit-identical either way);
    block_table: (B, NB) page ids per row. The N-sample probe wave
    passes block tables whose prompt-prefix entries point at *shared*
    read-only pages — that sharing, not a tiled cache copy, is what
    replaced ``tile_cache`` for the probe. Returns (GenerateOutput,
    k_pages, v_pages); emitted tokens are bit-identical to the dense
    ``generate``/``generate_samples`` over the same prompts and key.
    """
    b = logits0.shape[0]
    cache_len = start_pos + max_new_tokens

    def decode_fn(pages, token, pos):
        kp, vp = pages
        logits, kp, vp = T.decode_step_paged(
            cfg, params, kp, vp, block_table, token, pos,
            cache_len=cache_len)
        return logits, (kp, vp)

    out, (k_pages, v_pages) = _decode_scan(
        cfg, params, (k_pages, v_pages), logits0, start_pos, b,
        max_new_tokens, temperature, key, eos_id, pad_id,
        decode_fn=decode_fn)
    return out, k_pages, v_pages


def decode_text(tokens, detok) -> list:
    """Apply a detokenizer callable row-wise (host-side helper)."""
    import numpy as np
    toks = np.asarray(tokens)
    return [detok(row) for row in toks]
