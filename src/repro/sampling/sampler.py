"""Prefill + KV-cache decode sampling loop.

``generate`` is a single jitted XLA program per (config, shape): prefill
builds the cache sized for prompt+new tokens, then a ``lax.scan`` drives
``decode_step`` for ``max_new_tokens`` steps. Temperature 0 is greedy;
otherwise tokens come from a temperature-scaled categorical. Finished
rows (EOS emitted) keep emitting ``pad_id`` without disturbing the
cache, so the whole batch runs a fixed-length program.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T


class GenerateOutput(NamedTuple):
    tokens: jax.Array        # (B, max_new) int32, pad_id after EOS
    logprobs: jax.Array      # (B, max_new) float32 logprob of chosen tok
    lengths: jax.Array       # (B,) int32 — emitted tokens incl. EOS


def sample_token(logits: jax.Array, temperature: float,
                 key: jax.Array) -> jax.Array:
    """logits: (B, V) -> (B,) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "max_new_tokens", "temperature", "eos_id",
                     "pad_id"))
def generate(cfg: ModelConfig, params: dict, prompt_tokens: jax.Array,
             *, max_new_tokens: int, temperature: float = 0.0,
             key: Optional[jax.Array] = None, eos_id: int = -1,
             pad_id: int = 0,
             frontend_embeds: Optional[jax.Array] = None
             ) -> GenerateOutput:
    """prompt_tokens: (B, S) int32 — fixed-length prompts."""
    b, s = prompt_tokens.shape
    if key is None:
        key = jax.random.PRNGKey(0)
    total = s + max_new_tokens
    logits0, cache = T.prefill(cfg, params, prompt_tokens,
                               frontend_embeds, cache_len=total)

    def body(carry, step_key):
        cache, logits, pos, done = carry
        tok = sample_token(logits, temperature, step_key)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        tok_logp = jnp.take_along_axis(logp, tok[:, None], -1)[:, 0]
        emit = jnp.where(done, pad_id, tok)
        new_done = done | (tok == eos_id)
        next_logits, cache = T.decode_step(cfg, params, cache, emit, pos)
        return ((cache, next_logits, pos + 1, new_done),
                (emit, jnp.where(done, 0.0, tok_logp)))

    keys = jax.random.split(key, max_new_tokens)
    init = (cache, logits0, jnp.int32(s),
            jnp.zeros((b,), bool))
    (_, _, _, done), (toks, logps) = jax.lax.scan(body, init, keys)
    toks = toks.T                      # (B, max_new)
    logps = logps.T
    lengths = (toks != pad_id).sum(axis=1).astype(jnp.int32)
    return GenerateOutput(tokens=toks, logprobs=logps, lengths=lengths)


def decode_text(tokens, detok) -> list:
    """Apply a detokenizer callable row-wise (host-side helper)."""
    import numpy as np
    toks = np.asarray(tokens)
    return [detok(row) for row in toks]
