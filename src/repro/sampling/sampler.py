"""Prefill + KV-cache decode sampling loop.

``generate`` is a single jitted XLA program per (config, shape): prefill
builds the cache sized for prompt+new tokens, then a ``lax.scan`` drives
``decode_step`` for ``max_new_tokens`` steps. Temperature 0 is greedy;
otherwise tokens come from a temperature-scaled categorical. Finished
rows (EOS emitted) keep emitting ``pad_id`` without disturbing the
cache, so the whole batch runs a fixed-length program.

``generate_samples`` is the shared-prefix N-sample variant the ACAR
probe uses: each prompt is prefilled **once**, the KV cache is
broadcast across the N samples, and only the decode scan runs at the
expanded (B*N) batch — cutting prefill FLOPs by ~N x while emitting
tokens bit-identical to ``generate`` over an ``np.repeat``-expanded
prompt batch (per-row computation is batch-composition invariant for
every non-MoE family; MoE prefill routes with a capacity that couples
rows, see ``batch_invariant``).
"""
from __future__ import annotations

import contextlib
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.sharding import tp_context, tp_local_cfg, tp_param_specs


class GenerateOutput(NamedTuple):
    tokens: jax.Array        # (B, max_new) int32, pad_id after EOS
    logprobs: jax.Array      # (B, max_new) float32 logprob of chosen tok
    lengths: jax.Array       # (B,) int32 — emitted tokens incl. EOS


def sample_token(logits: jax.Array, temperature: float,
                 key: jax.Array) -> jax.Array:
    """logits: (B, V) -> (B,) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


def sample_token_rows(logits: jax.Array, temperature: float,
                      row_keys: jax.Array,
                      steps: jax.Array) -> jax.Array:
    """Batch-composition-invariant sampling: one private key stream
    per row. logits: (B, V); row_keys: (B, 2) uint32 raw PRNG keys;
    steps: scalar or (B,) int32 decode-step index per row. Row i draws
    from categorical(fold_in(row_keys[i], steps[i]), logits[i]) — a
    pure function of that row alone, so a row emits identical tokens
    whatever batch it shares. ``sample_token`` draws the whole batch's
    Gumbel noise from one key, which couples every row to the batch
    shape — fine for lockstep waves, fatal for step-level batching.
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    steps = jnp.broadcast_to(steps, (logits.shape[0],))

    def draw(key, row, t):
        return jax.random.categorical(jax.random.fold_in(key, t), row)

    return jax.vmap(draw)(row_keys, scaled, steps).astype(jnp.int32)


# row-key streams: disjoint tags keep probe and ensemble draws
# independent even for the same admission index
_PROBE_KEY_TAG = 0x5052_4f42      # "PROB"
_MEMBER_KEY_TAG = 0x454d_4245     # "EMBE"


def probe_row_keys(base_key: jax.Array, admission_indices,
                   n_samples: int) -> jax.Array:
    """Per-(task, sample) probe decode keys, (len(indices)*n, 2).

    Row ``i*n + j`` is sample j of the task with admission index
    ``admission_indices[i]`` — a stable identity shared by the wave
    and step-level execution paths, which is what makes their sampled
    tokens bit-identical under different batch compositions."""
    idx = jnp.asarray(list(admission_indices), jnp.uint32)
    tagged = jax.random.fold_in(base_key, _PROBE_KEY_TAG)
    per_task = jax.vmap(jax.random.fold_in, (None, 0))(tagged, idx)
    per_sample = jax.vmap(
        lambda k: jax.vmap(jax.random.fold_in, (None, 0))(
            k, jnp.arange(n_samples, dtype=jnp.uint32)))(per_task)
    return per_sample.reshape(idx.shape[0] * n_samples, -1)


def member_row_keys(base_key: jax.Array, admission_indices,
                    member_idx: int) -> jax.Array:
    """Per-task ensemble decode keys for one member, (len(indices), 2)."""
    idx = jnp.asarray(list(admission_indices), jnp.uint32)
    tagged = jax.random.fold_in(
        jax.random.fold_in(base_key, _MEMBER_KEY_TAG), member_idx)
    return jax.vmap(jax.random.fold_in, (None, 0))(tagged, idx)


def batch_invariant(cfg: ModelConfig) -> bool:
    """True when one row's forward pass cannot depend on which other
    rows share the batch. Dense / SSM / hybrid stacks compute strictly
    per row. MoE capacity dispatch (``impl`` "tp"/"ep") routes with a
    capacity proportional to the *total* token count, so expert
    overflow (token dropping) couples rows; the capacity-free
    ``impl == "gather"`` dispatch (``models.moe.moe_ffn_gather`` /
    ``moe_ffn_token``) computes each token's top-k combine from that
    token alone, so those configs are invariant too — compaction and
    shared-prefix prefill are only bit-equivalent to the padded/tiled
    paths for batch-invariant configs."""
    return cfg.moe is None or cfg.moe.impl == "gather"


def _decode_scan(cfg: ModelConfig, params: dict, cache, logits0,
                 start_pos: int, batch: int, max_new_tokens: int,
                 temperature: float, key: jax.Array, eos_id: int,
                 pad_id: int, decode_fn=None, row_keys=None
                 ) -> Tuple[GenerateOutput, object]:
    """Shared fixed-length decode loop over an existing prefill cache.

    ``decode_fn(cache, token, pos) -> (logits, cache)`` overrides the
    per-step transition — the paged path threads (k_pages, v_pages)
    through it; the default is the dense ``T.decode_step``. With
    ``row_keys`` ((B, 2) uint32), sampling switches to the per-row key
    streams of ``sample_token_rows`` (step i of row r draws from
    fold_in(row_keys[r], i)) — the batch-composition-invariant scheme
    the step-level serving loop replays one step at a time. Returns the
    final cache alongside the output (dense callers drop it; the paged
    path must keep its updated pages)."""
    if decode_fn is None:
        def decode_fn(cache, token, pos):
            return T.decode_step(cfg, params, cache, token, pos)

    def body(carry, step_in):
        cache, logits, pos, done = carry
        if row_keys is None:
            tok = sample_token(logits, temperature, step_in)
        else:
            tok = sample_token_rows(logits, temperature, row_keys,
                                    step_in)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        tok_logp = jnp.take_along_axis(logp, tok[:, None], -1)[:, 0]
        emit = jnp.where(done, pad_id, tok)
        new_done = done | (tok == eos_id)
        next_logits, cache = decode_fn(cache, emit, pos)
        return ((cache, next_logits, pos + 1, new_done),
                (emit, jnp.where(done, 0.0, tok_logp), ~done))

    steps = jnp.arange(max_new_tokens) if row_keys is not None \
        else jax.random.split(key, max_new_tokens)
    init = (cache, logits0, jnp.int32(start_pos),
            jnp.zeros((batch,), bool))
    (cache, _, _, _), (toks, logps, live) = jax.lax.scan(body, init,
                                                         steps)
    toks = toks.T                      # (B, max_new)
    logps = logps.T
    # a row emits a real token (possibly EOS, possibly one that merely
    # *equals* pad_id) at every step it was not yet done — counting
    # pad_id occurrences would undercount legitimately sampled pads
    lengths = live.T.sum(axis=1).astype(jnp.int32)
    return GenerateOutput(tokens=toks, logprobs=logps,
                          lengths=lengths), cache


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "max_new_tokens", "temperature", "eos_id",
                     "pad_id"))
def generate(cfg: ModelConfig, params: dict, prompt_tokens: jax.Array,
             *, max_new_tokens: int, temperature: float = 0.0,
             key: Optional[jax.Array] = None, eos_id: int = -1,
             pad_id: int = 0,
             frontend_embeds: Optional[jax.Array] = None,
             row_keys: Optional[jax.Array] = None
             ) -> GenerateOutput:
    """prompt_tokens: (B, S) int32 — fixed-length prompts.
    ``row_keys`` ((B, 2) uint32) switches sampling to per-row key
    streams (batch-composition invariant; see ``sample_token_rows``)."""
    b, s = prompt_tokens.shape
    if key is None:
        key = jax.random.PRNGKey(0)
    total = s + max_new_tokens
    logits0, cache = T.prefill(cfg, params, prompt_tokens,
                               frontend_embeds, cache_len=total)
    out, _ = _decode_scan(cfg, params, cache, logits0, s, b,
                          max_new_tokens, temperature, key, eos_id,
                          pad_id, row_keys=row_keys)
    return out


def tile_cache(cache, n: int, batch: Optional[int] = None):
    """Broadcast a prefill cache of batch B to B*n rows (row i's
    replicas occupy rows i*n .. i*n+n-1, matching ``np.repeat`` on the
    prompt batch). Stacked layer pytrees (``layers`` / ``dec_layers`` /
    ``cross``) carry (L, B, ...); unrolled per-layer entries
    (``layer_XX``) carry (B, ...). Pass ``batch`` to assert the chosen
    axis really is the batch axis — the key->axis rule mirrors
    ``transformer.init_cache``'s layout and must fail loudly if a new
    cache entry breaks it."""
    out = {}
    for k, v in cache.items():
        axis = 1 if k in ("layers", "dec_layers", "cross") else 0
        if batch is not None:
            for leaf in jax.tree.leaves(v):
                if leaf.shape[axis] != batch:
                    raise ValueError(
                        f"cache entry {k!r}: expected batch {batch} on "
                        f"axis {axis}, got shape {leaf.shape} — "
                        "tile_cache's key->axis rule no longer matches "
                        "the cache layout")
        out[k] = jax.tree.map(
            lambda a, ax=axis: jnp.repeat(a, n, axis=ax), v)
    return out


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "n", "max_new_tokens", "temperature",
                     "eos_id", "pad_id"))
def generate_samples(cfg: ModelConfig, params: dict,
                     prompt_tokens: jax.Array, n: int, *,
                     max_new_tokens: int, temperature: float = 0.0,
                     key: Optional[jax.Array] = None, eos_id: int = -1,
                     pad_id: int = 0,
                     frontend_embeds: Optional[jax.Array] = None,
                     row_keys: Optional[jax.Array] = None
                     ) -> GenerateOutput:
    """N samples per prompt with a single shared-prefix prefill.

    prompt_tokens: (B, S) -> GenerateOutput over B*n rows, row-major in
    sample index (row i*n+j is sample j of prompt i). Bit-identical to
    ``generate(cfg, params, np.repeat(prompt_tokens, n, axis=0), ...)``
    with the same key for ``batch_invariant`` configs, because the
    decode scan sees the same (B*n, V) logits and the same per-step
    keys — only the redundant n-1 prefills per prompt are elided.
    """
    b, s = prompt_tokens.shape
    if key is None:
        key = jax.random.PRNGKey(0)
    total = s + max_new_tokens
    logits0, cache = T.prefill(cfg, params, prompt_tokens,
                               frontend_embeds, cache_len=total)
    cache = tile_cache(cache, n, batch=b)
    logits0 = jnp.repeat(logits0, n, axis=0)
    out, _ = _decode_scan(cfg, params, cache, logits0, s, b * n,
                          max_new_tokens, temperature, key, eos_id,
                          pad_id, row_keys=row_keys)
    return out


# ----------------------------------------------------------------------
# paged KV-cache path (serving/kv_pool.py owns allocation; these are
# the jitted device programs it drives)
# ----------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("cfg", "cache_len"))
def prefill_paged(cfg: ModelConfig, params: dict,
                  prompt_tokens: jax.Array, pages,
                  prefill_table: jax.Array,
                  cache_len: Optional[int] = None):
    """Prompt prefill scattering K/V into pool pages.

    prompt_tokens: (B, S); pages: the pool's page pytree (leaves
    (L, P, page_size, ...) — dense {k, v}, quant adds the f32
    {k_scale, v_scale} planes); prefill_table: (B, NBp) int32;
    cache_len: dense-equivalent total length, required for ring
    layouts. Returns (logits0 (B, V), pages). Logits are bit-identical
    to the dense ``T.prefill`` — only the cache packing differs."""
    return T.prefill_paged(cfg, params, prompt_tokens, pages,
                           prefill_table, cache_len=cache_len)


@functools.partial(jax.jit, static_argnames=("cfg",))
def prefill_lanes(cfg: ModelConfig, params: dict,
                  prompt_tokens: jax.Array, pages,
                  lane_ids: jax.Array):
    """Prompt prefill for a recurrent-state (SSM) member, scattering
    each row's final state into its pool lane.

    prompt_tokens: (B, S); pages: the lane arena pytree (leaves
    (L, LANES, ...) — the per-layer {conv, h} state with a lane axis
    where the kv layouts have a page axis); lane_ids: (B,) int32 lane
    per row. The prefill itself is the dense ``T.prefill`` scan
    bit-for-bit; only the state parking differs. Returns
    (logits0 (B, V), pages)."""
    logits0, cache = T.prefill(cfg, params, prompt_tokens)
    states = cache["layers"]                  # leaves (L, B, ...)
    for arena, st in zip(jax.tree.leaves(pages),
                         jax.tree.leaves(states)):
        # the scatter must be a pure copy: a dtype cast here would
        # drift the parked state off the dense reference path
        assert arena.dtype == st.dtype, (arena.dtype, st.dtype)
    pages = jax.tree.map(
        lambda a, st: a.at[:, lane_ids].set(st), pages, states)
    return logits0, pages


@jax.jit
def fork_pages(pages, src: jax.Array, dst: jax.Array):
    """Page/lane fork: index ``dst[i]`` becomes a private copy of
    ``src[i]`` across every layer and every leaf of the pytree (axis 1
    is the page axis for dense/quant/ring kv leaves and the lane axis
    for recurrent-state leaves — one program serves COW tail
    materialisation, whole-ring forks and lane state copies alike).
    ``src`` may repeat (one canonical prompt page forked to N
    samples); ``dst`` must not."""
    return jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), pages)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "start_pos", "max_new_tokens",
                     "temperature", "eos_id", "pad_id"))
def decode_paged(cfg: ModelConfig, params: dict, logits0: jax.Array,
                 pages, block_table: jax.Array, key: jax.Array, *,
                 start_pos: int, max_new_tokens: int,
                 temperature: float = 0.0, eos_id: int = -1,
                 pad_id: int = 0,
                 row_keys: Optional[jax.Array] = None):
    """Fixed-length decode over paged state, from prefill logits.

    logits0: (B, V) last-prompt-position logits (freshly computed or
    reused from a retained probe prefill — bit-identical either way);
    pages: the pool's page pytree; block_table: (B, NB) page ids per
    row. The N-sample probe wave passes block tables whose
    prompt-prefix entries point at *shared* read-only pages — that
    sharing, not a tiled cache copy, is what replaced ``tile_cache``
    for the probe. Returns (GenerateOutput, pages); emitted tokens are
    bit-identical to the dense ``generate``/``generate_samples`` over
    the same prompts and key.
    """
    b = logits0.shape[0]
    cache_len = start_pos + max_new_tokens

    def decode_fn(pages, token, pos):
        return T.decode_step_paged(cfg, params, pages, block_table,
                                   token, pos, cache_len=cache_len)

    out, pages = _decode_scan(
        cfg, params, pages, logits0, start_pos, b, max_new_tokens,
        temperature, key, eos_id, pad_id, decode_fn=decode_fn,
        row_keys=row_keys)
    return out, pages


# ----------------------------------------------------------------------
# step-level programs (serving/step_loop.py drives these one logical
# tick at a time: mixed batches, per-row positions and key streams)
# ----------------------------------------------------------------------
@functools.partial(
    jax.jit, static_argnames=("cfg", "prompt_len"))
def prefill_chunk_paged(cfg: ModelConfig, params: dict,
                        tokens: jax.Array, pages,
                        block_table: jax.Array,
                        start_pos: jax.Array, *, prompt_len: int):
    """One prompt chunk appended to the paged cache (dense layout).
    tokens: (B, C) covering absolute positions
    [start_pos[b], start_pos[b] + C) per row — start offsets are
    traced, so mixed-depth rows share one compiled program;
    block_table: (B, NB). Returns (chunk-final logits (B, V), pages);
    bit-identical composition with ``prefill_paged`` — see
    ``models.transformer.prefill_chunk_paged``.
    """
    return T.prefill_chunk_paged(cfg, params, tokens, pages,
                                 block_table, start_pos,
                                 prompt_len=prompt_len)


def _decode_step_rows_impl(cfg: ModelConfig, params: dict,
                           logits: jax.Array, pages,
                           block_table: jax.Array,
                           pos: jax.Array, row_keys: jax.Array,
                           steps: jax.Array, done: jax.Array, *,
                           cache_len: int, temperature: float,
                           eos_id: int, pad_id: int):
    """Unjitted body of ``decode_step_rows`` — shared with the
    shard_map'd variant so both paths run identical math."""
    tok = sample_token_rows(logits, temperature, row_keys, steps)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    tok_logp = jnp.take_along_axis(logp, tok[:, None], -1)[:, 0]
    emit = jnp.where(done, pad_id, tok)
    new_done = done | (tok == eos_id)
    next_logits, pages = T.decode_step_paged(
        cfg, params, pages, block_table, emit, pos,
        cache_len=cache_len)
    return (emit, jnp.where(done, 0.0, tok_logp), ~done, new_done,
            next_logits, pages)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "cache_len", "temperature", "eos_id",
                     "pad_id"))
def decode_step_rows(cfg: ModelConfig, params: dict,
                     logits: jax.Array, pages,
                     block_table: jax.Array,
                     pos: jax.Array, row_keys: jax.Array,
                     steps: jax.Array, done: jax.Array, *,
                     cache_len: int, temperature: float,
                     eos_id: int, pad_id: int):
    """One decode step for a mixed batch of rows.

    logits: (B, V) each row's pending next-token logits; pages: the
    pool's page pytree (any layout — ``T.decode_step_paged``
    dispatches); pos: (B,) per-row write position; steps: (B,)
    per-row decode-step index; done: (B,) rows already past EOS.
    Mirrors one iteration of ``_decode_scan``'s body exactly (same
    sampling, logprob, emit and done arithmetic), so replaying it
    step-by-step over any batch composition emits the same per-row
    tokens the fixed-length scan does. Returns (emit, logprob, live,
    new_done, next_logits, pages)."""
    return _decode_step_rows_impl(
        cfg, params, logits, pages, block_table, pos,
        row_keys, steps, done, cache_len=cache_len,
        temperature=temperature, eos_id=eos_id, pad_id=pad_id)


def _decode_megastep_rows_impl(cfg: ModelConfig, params: dict,
                               logits: jax.Array, pages,
                               block_table: jax.Array, pos: jax.Array,
                               row_keys: jax.Array, steps: jax.Array,
                               done: jax.Array, *, n_ticks: int,
                               cache_len: int, temperature: float,
                               eos_id: int, pad_id: int):
    """Unjitted body of ``decode_megastep_rows`` — ``n_ticks``
    iterations of the ``_decode_step_rows_impl`` tick arithmetic fused
    into one ``lax.scan``, so lane state (logits, positions, step
    indices, done bits) never leaves the device between ticks.

    Each scan iteration draws from the identical per-row key stream
    (``fold_in(row_keys[i], steps[i])``), emits pad for done rows, and
    appends the emitted token's KV at the row's current position.
    Rows that finish (or exhaust their budget) mid-megastep keep
    ticking with masked emissions; their write position is clamped to
    ``cache_len - 1`` so the dead appends land inside the row's own
    tail page — never read again, because the attention mask keys off
    the true position, and the host replay drops masked emissions.
    """
    def body(carry, _):
        lg, pg, pos_, steps_, done_ = carry
        tok = sample_token_rows(lg, temperature, row_keys, steps_)
        emit = jnp.where(done_, pad_id, tok)
        new_done = done_ | (tok == eos_id)
        write_pos = jnp.minimum(pos_, cache_len - 1)
        next_lg, pg = T.decode_step_paged(
            cfg, params, pg, block_table, emit, write_pos,
            cache_len=cache_len)
        return ((next_lg, pg, pos_ + 1, steps_ + 1, new_done),
                (emit, new_done))

    init = (logits, pages, pos, steps, done)
    (lg, pages, _, _, _), (emits, dones) = jax.lax.scan(
        body, init, None, length=n_ticks)
    return emits, dones, lg, pages


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "n_ticks", "cache_len", "temperature",
                     "eos_id", "pad_id"))
def decode_megastep_rows(cfg: ModelConfig, params: dict,
                         logits: jax.Array, pages,
                         block_table: jax.Array,
                         pos: jax.Array, row_keys: jax.Array,
                         steps: jax.Array, done: jax.Array, *,
                         n_ticks: int, cache_len: int,
                         temperature: float, eos_id: int,
                         pad_id: int):
    """``n_ticks`` fused decode ticks for a mixed batch of rows — the
    device-resident megastep. One launch advances every row K ticks;
    the only arrays that cross back to the host are the (K, B) stacks
    of emitted token ids and done bits (the step loop pulls those once
    per megastep and replays them lane by lane). Per-tick sampling,
    emit and done arithmetic is ``_decode_step_rows_impl``'s exactly,
    and the key stream is indexed by the per-row step counter — so
    ``n_ticks`` is a pure performance knob: K=1 *is* the per-tick
    baseline, and any K produces bit-identical token streams.

    Returns (emits (K, B), dones (K, B), next_logits (B, V), pages);
    ``next_logits`` keeps each lane's pending logits on device for the
    next megastep."""
    return _decode_megastep_rows_impl(
        cfg, params, logits, pages, block_table, pos,
        row_keys, steps, done, n_ticks=n_ticks, cache_len=cache_len,
        temperature=temperature, eos_id=eos_id, pad_id=pad_id)


# ----------------------------------------------------------------------
# mesh-sharded step programs (serving/mesh.py drives these: one
# shard_map'd launch advances every shard's bucket simultaneously;
# on a 2-D ("data", "model") mesh each data shard's program runs
# tensor-parallel across its model columns — see sharding/tp.py)
# ----------------------------------------------------------------------
def _mesh_model_size(mesh) -> int:
    return int(mesh.shape["model"]) if "model" in mesh.axis_names else 1


def _row_spec():
    from jax.sharding import PartitionSpec as P
    return P("data")


def _page_specs(pages, m: int):
    """Per-leaf specs for a sharded page pytree. Code/value leaves
    (n_shards, L, P, page, KV, Dh) and scale planes (n_shards, L, P,
    page, KV) both put rows over "data"; under tensor parallelism each
    model column stores only its kv-head slice, so the KV axis shards
    over "model" (per-shard pool bytes divide by m — capacity at a
    fixed byte budget scales x m). Only the "dense" and "quant"
    layouts reach the sharded runners, so every leaf has KV at axis 4."""
    from jax.sharding import PartitionSpec as P

    def leaf(a):
        if m <= 1:
            return P("data")
        if a.ndim == 6:
            return P("data", None, None, None, "model", None)
        return P("data", None, None, None, "model")

    return jax.tree.map(leaf, pages)


def _param_spec(params, m: int):
    """Params replicate over "data"; under tensor parallelism the
    column-parallel leaves shard over "model" (sharding.tp)."""
    from jax.sharding import PartitionSpec as P
    return tp_param_specs(params) if m > 1 else P()


def _tp_trace_ctx(m: int):
    """Trace-time tensor-parallel context for shard_map bodies: makes
    every ``tp_all_gather`` gather point live on the "model" axis.
    No-op (and byte-identical traces) at m == 1."""
    return tp_context("model", m) if m > 1 else contextlib.nullcontext()


def _shard_map(body, mesh, in_specs, out_specs):
    from jax.experimental.shard_map import shard_map
    return shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                     out_specs=tuple(out_specs), check_rep=False)


@functools.partial(
    jax.jit, static_argnames=("cfg", "prompt_len", "mesh"))
def prefill_chunk_paged_sharded(cfg: ModelConfig, params: dict,
                                tokens: jax.Array, pages,
                                block_table: jax.Array,
                                start_pos: jax.Array, *,
                                prompt_len: int, mesh):
    """``prefill_chunk_paged`` across every shard of a serving mesh in
    one launch. All array operands carry a leading ``n_shards`` axis
    (tokens: (n_sh, B, C); page leaves: (n_sh, L, P, page, KV, ...);
    tables: (n_sh, B, NBp); start_pos: (n_sh, B)); params replicate
    over "data". Each shard's slice runs the exact single-device chunk
    program, so per-row results are bit-identical to unsharded
    execution — sharding is placement, not math. On a 2-D ("data",
    "model") mesh the program additionally runs tensor-parallel inside
    each data shard: params/pages carry model-column slices and every
    sharded-axis contraction all-gathers first (sharding/tp.py), which
    keeps the reduction order — and therefore the bits — identical."""
    m = _mesh_model_size(mesh)
    lcfg = tp_local_cfg(cfg, m)
    row, pg = _row_spec(), _page_specs(pages, m)

    def body(p, tk, pgs, table, starts):
        with _tp_trace_ctx(m):
            lg, pgs1 = T.prefill_chunk_paged(
                lcfg, p, tk[0],
                jax.tree.map(lambda a: a[0], pgs),
                table[0], starts[0], prompt_len=prompt_len)
        return lg[None], jax.tree.map(lambda a: a[None], pgs1)

    return _shard_map(
        body, mesh,
        (_param_spec(params, m), row, pg, row, row),
        (row, pg))(
        params, tokens, pages, block_table, start_pos)


@functools.partial(jax.jit, static_argnames=("cfg", "mesh"))
def prefill_paged_sharded(cfg: ModelConfig, params: dict,
                          prompt_tokens: jax.Array, pages,
                          prefill_table: jax.Array, *, mesh):
    """``prefill_paged`` across every shard of a serving mesh in one
    launch — the whole-prompt program the step loop uses for layouts
    that cannot compose chunk-by-chunk (quant: a chunk would re-read
    the already-quantised prefix). prompt_tokens: (n_sh, B, S);
    prefill_table: (n_sh, B, NBp); page leaves carry the leading
    ``n_shards`` axis. Only dense/quant layouts reach the sharded
    runners, so no ``cache_len`` (ring-only) is needed. Returns
    (logits0 (n_sh, B, V), pages)."""
    m = _mesh_model_size(mesh)
    lcfg = tp_local_cfg(cfg, m)
    row, pg = _row_spec(), _page_specs(pages, m)

    def body(p, tk, pgs, table):
        with _tp_trace_ctx(m):
            lg, pgs1 = T.prefill_paged(
                lcfg, p, tk[0],
                jax.tree.map(lambda a: a[0], pgs), table[0])
        return lg[None], jax.tree.map(lambda a: a[None], pgs1)

    return _shard_map(
        body, mesh,
        (_param_spec(params, m), row, pg, row),
        (row, pg))(
        params, prompt_tokens, pages, prefill_table)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "cache_len", "temperature", "eos_id",
                     "pad_id", "mesh"))
def decode_step_rows_sharded(cfg: ModelConfig, params: dict,
                             logits: jax.Array, pages,
                             block_table: jax.Array, pos: jax.Array,
                             row_keys: jax.Array, steps: jax.Array,
                             done: jax.Array, *, cache_len: int,
                             temperature: float, eos_id: int,
                             pad_id: int, mesh):
    """``decode_step_rows`` across every shard of a serving mesh in
    one launch (leading ``n_shards`` axis on every array operand;
    params replicate over "data" and, on a 2-D mesh, tensor-shard over
    "model"). Runs ``_decode_step_rows_impl`` — the identical per-row
    math — on each shard's slice, so a row emits the same token
    whatever shard hosts it and whatever the model-axis size."""
    m = _mesh_model_size(mesh)
    lcfg = tp_local_cfg(cfg, m)
    row, pg = _row_spec(), _page_specs(pages, m)

    def body(p, lg, pgs, table, pos_, keys, steps_, done_):
        with _tp_trace_ctx(m):
            *out, pgs1 = _decode_step_rows_impl(
                lcfg, p, lg[0],
                jax.tree.map(lambda a: a[0], pgs),
                table[0], pos_[0], keys[0], steps_[0], done_[0],
                cache_len=cache_len, temperature=temperature,
                eos_id=eos_id, pad_id=pad_id)
        return (tuple(o[None] for o in out)
                + (jax.tree.map(lambda a: a[None], pgs1),))

    return _shard_map(
        body, mesh,
        (_param_spec(params, m), row, pg, row, row, row, row, row),
        (row, row, row, row, row, pg))(
        params, logits, pages, block_table, pos, row_keys,
        steps, done)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "n_ticks", "cache_len", "temperature",
                     "eos_id", "pad_id", "mesh"))
def decode_megastep_rows_sharded(cfg: ModelConfig, params: dict,
                                 logits: jax.Array, pages,
                                 block_table: jax.Array,
                                 pos: jax.Array, row_keys: jax.Array,
                                 steps: jax.Array, done: jax.Array, *,
                                 n_ticks: int, cache_len: int,
                                 temperature: float, eos_id: int,
                                 pad_id: int, mesh):
    """``decode_megastep_rows`` across every shard of a serving mesh
    in one launch (leading ``n_shards`` axis on every array operand;
    params replicate over "data" and, on a 2-D mesh, tensor-shard over
    "model"; emits/dones come back as (n_sh, K, B)). Each shard's
    slice runs the identical fused scan, so a row emits the same
    tokens whatever shard hosts it, whatever K the planner picked and
    whatever the model-axis size — the decode tick path stays free of
    host-side collectives; the model-axis all-gathers live inside the
    device program."""
    m = _mesh_model_size(mesh)
    lcfg = tp_local_cfg(cfg, m)
    row, pg = _row_spec(), _page_specs(pages, m)

    def body(p, lg, pgs, table, pos_, keys, steps_, done_):
        with _tp_trace_ctx(m):
            *out, pgs1 = _decode_megastep_rows_impl(
                lcfg, p, lg[0],
                jax.tree.map(lambda a: a[0], pgs),
                table[0], pos_[0], keys[0], steps_[0], done_[0],
                n_ticks=n_ticks, cache_len=cache_len,
                temperature=temperature, eos_id=eos_id, pad_id=pad_id)
        return (tuple(o[None] for o in out)
                + (jax.tree.map(lambda a: a[None], pgs1),))

    return _shard_map(
        body, mesh,
        (_param_spec(params, m), row, pg, row, row, row, row, row),
        (row, row, row, pg))(
        params, logits, pages, block_table, pos, row_keys,
        steps, done)


@functools.partial(jax.jit, static_argnames=("mesh",))
def fork_pages_sharded(pages, src: jax.Array, dst: jax.Array, *, mesh):
    """Per-shard ``fork_pages`` in one launch. src/dst: (n_sh, K)
    shard-local page ids; shards with nothing to fork pass
    ``src == dst`` self-copies (the identity write), so one shard's
    COW fork never stalls on the others. On a 2-D mesh each model
    column copies its own kv-head slice of the pages — page ids are
    column-invariant, so the fork stays a pure local copy."""
    m = _mesh_model_size(mesh)
    row, pg = _row_spec(), _page_specs(pages, m)

    def body(pgs, s, d):
        pgs1 = fork_pages(jax.tree.map(lambda a: a[0], pgs),
                          s[0], d[0])
        return (jax.tree.map(lambda a: a[None], pgs1),)

    return _shard_map(body, mesh, (pg, row, row), (pg,))(
        pages, src, dst)[0]


def decode_text(tokens, detok) -> list:
    """Apply a detokenizer callable row-wise (host-side helper)."""
    import numpy as np
    toks = np.asarray(tokens)
    return [detok(row) for row in toks]
