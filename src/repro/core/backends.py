"""Model backends for the ACAR orchestrator.

``ModelBackend`` is the provider abstraction (paper: Claude / GPT-4o /
Gemini). Two implementations:

* ``SyntheticBackend`` — deterministic, seeded simulator whose per-task
  correctness statistics are calibrated to the paper's published
  numbers. It replaces the unreachable frontier APIs (repro gate, see
  DESIGN.md) while exercising the *identical* routing/trace machinery.
* ``JaxModelBackend`` (in repro/serving/jax_backend.py) — real JAX
  models from the zoo; used by the runnable examples.

The simulator's generative model: each task has latent difficulty z;
model m answers correctly with probability sigmoid(skill_m - z). Wrong
answers are drawn from the task's finite confusion pool (shared across
models -> correlated errors -> the paper's "agreement-but-wrong" mode).
Code responses get a non-canonical nonce with high probability,
reproducing the paper's inflated LiveCodeBench escalation (§8).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, Optional, Protocol, Sequence, Tuple

import numpy as np

from repro.data.tasks import Task


class ModelBackend(Protocol):
    name: str

    def generate(self, task: Task, prompt: str, *, temperature: float,
                 sample_idx: int, seed: int) -> "GenResult":
        ...


@dataclass(frozen=True)
class GenResult:
    response: str              # raw response text
    semantic_answer: str       # ground-truth-comparable answer
    cost: float
    latency_ms: float
    # judge-visible quality signal; correlates with correctness in the
    # calibrated simulator (a competent black-box judge's view).
    score: float = 0.0


@dataclass(frozen=True)
class ModelProfile:
    name: str
    skill: float               # logit offset vs task difficulty
    cost_per_call: float
    latency_mean_ms: float
    latency_sigma: float       # lognormal sigma
    # per-benchmark skill adjustments (e.g. code-specialised models)
    bench_skill: Dict[str, float] = field(default_factory=dict)
    # confident-misconception rate: on a trapped (model, task) pair the
    # model consistently produces the same wrong answer regardless of
    # temperature -- the paper's "agreement-but-wrong" mechanism (S6.2).
    # Scaled per benchmark: misconceptions live in knowledge/reasoning
    # tasks; competition math / verified code rarely reward confident
    # wrong answers consistently.
    trap_p: float = 0.10


# calibrated to the paper's Table 1 / Fig. 3 (see EXPERIMENTS.md):
#   claude-sonnet-4 single-model overall = 45.4%
#   arena ensembles and probe behaviour per §5
PAPER_MODELS = {
    "claude-sonnet-4": ModelProfile(
        "claude-sonnet-4", skill=0.0, cost_per_call=0.01129,
        latency_mean_ms=6200.0, latency_sigma=0.45,
        bench_skill={"supergpqa": 0.76, "matharena": 0.88,
                     "reasoning_gym": 0.19, "livecodebench": 0.05}),
    "gpt-4o": ModelProfile(
        "gpt-4o", skill=0.0, cost_per_call=0.00155,
        latency_mean_ms=4800.0, latency_sigma=0.5,
        bench_skill={"supergpqa": 0.36, "matharena": 0.73,
                     "reasoning_gym": 0.13, "livecodebench": 0.00}),
    "gemini-2.0-flash": ModelProfile(
        "gemini-2.0-flash", skill=0.0, cost_per_call=0.00004,
        latency_mean_ms=1400.0, latency_sigma=0.4,
        bench_skill={"supergpqa": 1.15, "matharena": 0.42,
                     "reasoning_gym": 0.50, "livecodebench": 0.00},
        trap_p=0.17),  # flash probe: more confident misconceptions
}

# probability that a code response is non-canonical (unique formatting)
CODE_NONCE_P = 0.85
TRAP_BENCH_FACTOR = {
    "supergpqa": 0.6,       # misconception-prone knowledge MCQ
    "reasoning_gym": 0.6,
    "matharena": 0.10,      # competition math: wrong != consistent
    "livecodebench": 0.20,
}
# correlated-error strength: probability a wrong answer is drawn from
# the shared confusion pool head rather than uniformly
DEFAULT_RETRIEVAL_BETA = 0.50   # quality shift per unit similarity
JUDGE_SCORE_NOISE = 0.45         # sd of the judge-visible quality signal
RETRIEVAL_SIM0 = 0.45           # similarity at which retrieval is neutral


def _task_rng(name: str, task_id: str, sample_idx: int,
              seed: int) -> np.random.Generator:
    h = hashlib.blake2b(
        f"{name}|{task_id}|{sample_idx}|{seed}".encode(),
        digest_size=8).digest()
    return np.random.default_rng(int.from_bytes(h, "little"))


@dataclass
class SyntheticBackend:
    """Deterministic calibrated model simulator."""

    profile: ModelProfile
    temperature_skill_penalty: float = 0.45   # sampling hurts a bit
    retrieval_beta: float = DEFAULT_RETRIEVAL_BETA

    @property
    def name(self) -> str:
        return self.profile.name

    def p_correct(self, task: Task, temperature: float,
                  retrieval_sim: Optional[float] = None) -> float:
        s = self.profile.skill + self.profile.bench_skill.get(
            task.benchmark, 0.0)
        if temperature > 0:
            s -= self.temperature_skill_penalty * temperature
        if retrieval_sim is not None:
            # §6.1: low-similarity exemplars inject noise
            s += self.retrieval_beta * (retrieval_sim - RETRIEVAL_SIM0)
        z = task.difficulty
        return float(1.0 / (1.0 + np.exp(-(s - z) * 1.6)))

    def _model_rng(self, task: Task, seed: int) -> np.random.Generator:
        """Sample-independent randomness: systematic per-(model, task)
        behaviour that temperature cannot shake (misconceptions)."""
        return _task_rng(self.name, task.task_id, -1, seed)

    def generate(self, task: Task, prompt: str, *, temperature: float,
                 sample_idx: int = 0, seed: int = 0,
                 retrieval_sim: Optional[float] = None) -> GenResult:
        rng = _task_rng(self.name, task.task_id, sample_idx, seed)
        mrng = self._model_rng(task, seed)
        trap_p = self.profile.trap_p * TRAP_BENCH_FACTOR.get(
            task.benchmark, 1.0)
        trapped = bool(mrng.random() < trap_p)
        p = self.p_correct(task, temperature, retrieval_sim)
        correct = (not trapped) and bool(rng.random() < p)
        if correct:
            semantic = task.gold
        else:
            if task.wrong_pool:
                # trapped: the model's own deterministic wrong answer;
                # otherwise a fresh temperature-jittered draw.
                draw = mrng if trapped else rng
                idx = draw.choice(len(task.wrong_pool),
                                  p=np.asarray(task.wrong_weights))
                semantic = task.wrong_pool[int(idx)]
            else:
                semantic = f"wrong_{self.name}_{task.task_id}" \
                    if trapped else f"wrong_{rng.integers(1 << 30)}"
        response = self._render(task, semantic, rng)
        latency = float(np.exp(
            np.log(self.profile.latency_mean_ms)
            + self.profile.latency_sigma * rng.standard_normal()))
        # quality signal a black-box judge extracts from the response:
        # correlated with correctness, noisy (JUDGE_SCORE_NOISE).
        score = float((1.0 if correct else 0.0)
                      + JUDGE_SCORE_NOISE * rng.standard_normal())
        return GenResult(response=response, semantic_answer=semantic,
                         cost=self.profile.cost_per_call,
                         latency_ms=latency, score=score)

    def _render(self, task: Task, semantic: str,
                rng: np.random.Generator) -> str:
        """Render the semantic answer as response text. Code responses
        are usually non-canonical (unique formatting nonce)."""
        if task.kind == "code" and rng.random() < CODE_NONCE_P:
            return f"def solution():  # v{rng.integers(1 << 20)}\n" \
                   f"    return {semantic}"
        if task.kind == "mcq":
            return f"Answer: {semantic}"
        if task.kind == "math":
            return f"After working through the steps, answer: {semantic}"
        return f"answer: {semantic}"


def paper_backends(
        retrieval_beta: float = DEFAULT_RETRIEVAL_BETA
) -> Dict[str, SyntheticBackend]:
    return {name: SyntheticBackend(profile, retrieval_beta=retrieval_beta)
            for name, profile in PAPER_MODELS.items()}
