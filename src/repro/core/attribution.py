"""Model attribution (paper §6.3): ground-truth counterfactuals vs
proxy signals.

Ground truth: leave-one-out (LOO) values and exact Shapley values over
the 2^3 coalitions, computed by *re-running the judge* on each subset —
explicit counterfactual computation, exactly what the paper concludes
is required.

Proxies: response-similarity-to-final-answer, output entropy, and
agreement patterns — the signals the paper shows do NOT correlate with
ground truth. ``proxy_vs_truth_correlation`` quantifies it.
"""
from __future__ import annotations

import itertools
import math
from collections import Counter
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from repro.core.judge import judge_select
from repro.core.retrieval import embed_text
from repro.teamllm.trace import ModelResponse

CoalitionValue = Callable[[Sequence[ModelResponse]], float]


def coalition_accuracy(responses: Sequence[ModelResponse], task_id: str,
                       gold: str) -> float:
    """v(S): did the judge over subset S produce the gold answer?"""
    if not responses:
        return 0.0
    return float(judge_select(responses, task_id) == gold)


def leave_one_out(responses: Sequence[ModelResponse], task_id: str,
                  gold: str) -> Dict[str, float]:
    """LOO_i = v(N) - v(N \\ {i})."""
    full = coalition_accuracy(responses, task_id, gold)
    out = {}
    for i, r in enumerate(responses):
        rest = [x for j, x in enumerate(responses) if j != i]
        out[r.model] = full - coalition_accuracy(rest, task_id, gold)
    return out


def shapley(responses: Sequence[ModelResponse], task_id: str,
            gold: str) -> Dict[str, float]:
    """Exact Shapley values over all 2^n coalitions (n = 3 here)."""
    n = len(responses)
    idx = list(range(n))
    values: Dict[frozenset, float] = {}
    for r in range(n + 1):
        for subset in itertools.combinations(idx, r):
            values[frozenset(subset)] = coalition_accuracy(
                [responses[i] for i in subset], task_id, gold)
    out = {r.model: 0.0 for r in responses}
    for i in idx:
        phi = 0.0
        others = [j for j in idx if j != i]
        for r in range(n):
            for subset in itertools.combinations(others, r):
                s = frozenset(subset)
                w = (math.factorial(len(s))
                     * math.factorial(n - len(s) - 1) / math.factorial(n))
                phi += w * (values[s | {i}] - values[s])
        out[responses[i].model] = phi
    return out


# ----------------------------------------------------------------------
# proxy signals (the ones that fail)
# ----------------------------------------------------------------------
def proxy_similarity(responses: Sequence[ModelResponse],
                     final_answer: str) -> Dict[str, float]:
    """Cosine similarity of each response to the final answer text."""
    fvec = embed_text(final_answer)
    return {r.model: float(embed_text(r.response) @ fvec)
            for r in responses}


def proxy_entropy(responses: Sequence[ModelResponse]) -> Dict[str, float]:
    """Negative token-distribution entropy (lower entropy -> claimed
    higher contribution)."""
    out = {}
    for r in responses:
        toks = r.response.lower().split() or [""]
        counts = Counter(toks)
        total = sum(counts.values())
        ent = -sum((c / total) * math.log(c / total + 1e-12)
                   for c in counts.values())
        out[r.model] = -ent
    return out


def proxy_agreement(responses: Sequence[ModelResponse]) -> Dict[str, float]:
    """Fraction of other models agreeing with each response."""
    out = {}
    for r in responses:
        others = [x for x in responses if x.model != r.model]
        if not others:
            out[r.model] = 0.0
            continue
        out[r.model] = sum(x.answer == r.answer for x in others) \
            / len(others)
    return out


def proxy_vs_truth_correlation(
        truth_rows: List[Dict[str, float]],
        proxy_rows: List[Dict[str, float]]) -> float:
    """Pearson correlation between flattened per-(task, model) values."""
    t, p = [], []
    for tr, pr in zip(truth_rows, proxy_rows):
        for m in tr:
            if m in pr:
                t.append(tr[m])
                p.append(pr[m])
    if len(t) < 2:
        return 0.0
    t_arr, p_arr = np.asarray(t), np.asarray(p)
    if t_arr.std() == 0 or p_arr.std() == 0:
        return 0.0
    return float(np.corrcoef(t_arr, p_arr)[0, 1])
