"""EXTRACT: map a model response to a canonical answer representation
(paper §3.2.1). Domain-specific comparison logic per benchmark kind:

* math      — last number in the response, normalised (strip trailing
              zeros, unify integer/float forms);
* mcq       — first standalone choice letter A-J (SuperGPQA is 10-option);
* reasoning — final token sequence after "answer:" (or whole string),
              lowercased/stripped;
* code      — whitespace/comment-normalised body. The paper notes code
              outputs are rarely canonical (inflating escalation); the
              ``canonicalize_code`` flag reproduces that knob.
"""
from __future__ import annotations

import re
from typing import Optional

_NUM_RE = re.compile(r"-?\d+(?:\.\d+)?(?:[eE][-+]?\d+)?")
_CHOICE_RE = re.compile(r"\b([A-J])\b")
# 10-option MCQ: "A" and "I" are English words; only treat them as
# choices in explicit contexts ("(A)", "option I", "answer: A").
_CHOICE_STRICT_RE = re.compile(
    r"\(([A-J])\)|(?:option|choice)\s+([A-J])\b", re.IGNORECASE)
_CHOICE_SAFE_RE = re.compile(r"\b([B-HJ])\b")
_ANSWER_RE = re.compile(r"answer\s*[:=]\s*(.+)", re.IGNORECASE)


def _norm_number(tok: str) -> str:
    try:
        v = float(tok)
    except ValueError:
        return tok
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


def extract_math(response: str) -> str:
    nums = _NUM_RE.findall(response)
    if not nums:
        return response.strip().lower()[:64]
    return _norm_number(nums[-1])


def extract_mcq(response: str) -> str:
    m = _ANSWER_RE.search(response)
    if m:
        c = _CHOICE_RE.search(m.group(1))
        if c:
            return c.group(1).upper()
    m = _CHOICE_STRICT_RE.search(response)
    if m:
        return (m.group(1) or m.group(2)).upper()
    c = _CHOICE_SAFE_RE.search(response)
    if c:
        return c.group(1)
    c = _CHOICE_RE.search(response)
    return c.group(1) if c else response.strip().upper()[:8]


def extract_reasoning(response: str) -> str:
    m = _ANSWER_RE.search(response)
    text = m.group(1) if m else response
    return " ".join(text.lower().split())[:64]


_COMMENT_RE = re.compile(r"#[^\n]*|//[^\n]*")


def extract_code(response: str, canonicalize: bool = True) -> str:
    """Code answers: strip comments + normalise whitespace when
    ``canonicalize``; otherwise compare raw text (the paper's setting,
    which inflates full_arena escalation on LiveCodeBench to 96%)."""
    if not canonicalize:
        return response.strip()
    body = _COMMENT_RE.sub("", response)
    lines = [" ".join(l.split()) for l in body.splitlines()]
    return "\n".join(l for l in lines if l)


_EXTRACTORS = {
    "math": extract_math,
    "mcq": extract_mcq,
    "reasoning": extract_reasoning,
}


def extract(response: str, kind: str,
            canonicalize_code: bool = False) -> str:
    if kind == "code":
        return extract_code(response, canonicalize=canonicalize_code)
    fn = _EXTRACTORS.get(kind)
    if fn is None:
        return extract_reasoning(response)
    return fn(response)


def extract_batch(responses, kinds,
                  canonicalize_code: bool = False) -> list:
    """Extract a whole tick's worth of responses in one call.

    Element-wise identical to ``[extract(r, k) for r, k in zip(...)]``
    — extraction is a pure per-response function, so batching is purely
    an execution strategy (the step-level serving loop collects every
    row routing in the same tick here instead of calling ``extract``
    once per row). Duplicate (response, kind) pairs — N probe samples
    that decoded the same text, duplicate-bearing request streams —
    are extracted once and shared.
    """
    if len(responses) != len(kinds):
        raise ValueError(
            f"{len(responses)} responses vs {len(kinds)} kinds")
    memo: dict = {}
    out = []
    for r, k in zip(responses, kinds):
        key = (r, k)
        if key not in memo:
            memo[key] = extract(r, k,
                                canonicalize_code=canonicalize_code)
        out.append(memo[key])
    return out
