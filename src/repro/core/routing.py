"""ACAR routing (paper Alg. 1, Def. 2): sigma -> execution mode."""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass
from typing import List, Sequence

SINGLE_AGENT = "single_agent"
ARENA_LITE = "arena_lite"
FULL_ARENA = "full_arena"

MODES = (SINGLE_AGENT, ARENA_LITE, FULL_ARENA)


def execution_mode(sigma: float) -> str:
    """Def. 2: M(sigma)."""
    if sigma <= 0.0:
        return SINGLE_AGENT
    if sigma < 1.0:
        return ARENA_LITE
    return FULL_ARENA


def models_for_mode(mode: str, ensemble: Sequence[str],
                    arena_lite_size: int = 2) -> List[str]:
    """Which ensemble members execute in each mode (Alg. 1 lines 8-19)."""
    if mode == SINGLE_AGENT:
        return []                       # probe consensus answer is final
    if mode == ARENA_LITE:
        return list(ensemble[:arena_lite_size])
    return list(ensemble)


def degrade_mode(mode: int, healthy: Sequence[bool],
                 arena_lite_size: int = 2) -> int:
    """Graceful degradation ladder over unhealthy ensemble members:
    the highest integer mode (0=single_agent, 1=arena_lite,
    2=full_arena) at-or-below ``mode`` that the healthy members can
    still execute. full_arena survives while *any* member is healthy
    (it runs over the healthy subset); arena_lite needs a healthy
    member among the first ``arena_lite_size`` (those are the only
    members it consults); with no healthy member the probe consensus
    is final (single_agent). Pure and deterministic, so degraded
    routing replays bit-identically under the same fault plan."""
    if mode <= 0:
        return 0
    if mode >= 2 and any(healthy):
        return 2
    if any(healthy[:arena_lite_size]):
        return 1
    return 0


def majority_vote(answers: Sequence[str]) -> str:
    """MajorityVote over extracted answers; ties break to first seen."""
    counts = Counter(answers)
    top = max(counts.values())
    for a in answers:
        if counts[a] == top:
            return a
    raise AssertionError("unreachable")


@dataclass(frozen=True)
class RoutingDecision:
    sigma: float
    mode: str
    executed_models: tuple
    probe_answer: str          # consensus / majority probe answer

    @property
    def ensemble_calls_saved(self) -> int:
        """Calls avoided vs always-full-arena (3 models)."""
        return 3 - len(self.executed_models)


def decide(sigma_value: float, probe_answers: Sequence[str],
           ensemble: Sequence[str],
           arena_lite_size: int = 2) -> RoutingDecision:
    mode = execution_mode(sigma_value)
    return RoutingDecision(
        sigma=sigma_value,
        mode=mode,
        executed_models=tuple(models_for_mode(mode, ensemble,
                                              arena_lite_size)),
        probe_answer=majority_vote(probe_answers),
    )
