"""JudgeSelect (paper Alg. 1 line 17) and answer aggregation.

The paper treats the judge as a black box that selects among ensemble
responses. We implement a deterministic score-weighted plurality judge:
each response carries a judge-visible quality score (confidence /
formatting heuristics — in the calibrated simulator this correlates
with correctness, as a competent black-box judge does); an answer's
weight is its vote count plus ``JUDGE_SCORE_WEIGHT`` times its total
score. Plurality therefore dominates — two models agreeing on a wrong
answer still outvote one correct model (the paper's agreement-but-wrong
ceiling, §6.2) — while ties and all-distinct cases resolve toward the
more convincing response. Residual exact ties break by (a) agreement
with the probe majority, then (b) a seeded, model-order-stable coin
derived from the task id — fully reproducible given the trace.
"""
from __future__ import annotations

import hashlib
from collections import defaultdict
from typing import Dict, Optional, Sequence

from repro.teamllm.trace import ModelResponse

JUDGE_SCORE_WEIGHT = 0.45


def _stable_coin(task_id: str, options: Sequence[str]) -> str:
    h = hashlib.sha256(task_id.encode()).digest()
    return sorted(options)[h[0] % len(options)]


def judge_select(responses: Sequence[ModelResponse], task_id: str,
                 probe_answer: Optional[str] = None,
                 score_weight: float = JUDGE_SCORE_WEIGHT) -> str:
    """Select the final answer among model responses."""
    weight: Dict[str, float] = defaultdict(float)
    for r in responses:
        weight[r.answer] += 1.0 + score_weight * r.score
    top = max(weight.values())
    winners = sorted(a for a, w in weight.items()
                     if abs(w - top) < 1e-9)
    if len(winners) == 1:
        return winners[0]
    if probe_answer is not None and probe_answer in winners:
        return probe_answer
    return _stable_coin(task_id, winners)


def arena_verify(probe_majority: str,
                 responses: Sequence[ModelResponse],
                 task_id: str) -> str:
    """arena_lite (Alg. 1 lines 11-14): the probe majority stands unless
    the verification models unanimously contradict it with a common
    alternative."""
    answers = [r.answer for r in responses]
    if answers and all(a == answers[0] for a in answers) \
            and answers[0] != probe_majority:
        return answers[0]
    return probe_majority
