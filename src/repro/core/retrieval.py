"""Jungler experience store (ACAR-UJ, paper §3.2.4 and §6.1).

Asynchronous retrieval of "similar past experiences" injected into
prompts before dispatch. Embeddings are deterministic hashed
bag-of-token vectors (no learned encoder — keeps the substrate
deterministic); similarity is cosine. The paper's configuration uses
threshold 0.0 (any match), which §6.1 shows is harmful: median
similarity 0.167 injects noise. ``threshold`` reproduces that study.
"""
from __future__ import annotations

import hashlib
import re
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

_TOKEN_RE = re.compile(r"[a-z0-9]+")
EMBED_DIM = 512


def embed_text(text: str, dim: int = EMBED_DIM) -> np.ndarray:
    """Deterministic hashed bag-of-tokens embedding, L2-normalised."""
    v = np.zeros(dim, np.float32)
    for tok in _TOKEN_RE.findall(text.lower()):
        h = hashlib.blake2b(tok.encode(), digest_size=8).digest()
        idx = int.from_bytes(h[:4], "little") % dim
        sign = 1.0 if h[4] % 2 == 0 else -1.0
        v[idx] += sign
    n = np.linalg.norm(v)
    return v / n if n > 0 else v


@dataclass(frozen=True)
class Experience:
    task_text: str
    answer: str
    correct: bool
    benchmark: str


@dataclass
class ExperienceStore:
    """Append-only store of past (task, answer) experiences."""

    dim: int = EMBED_DIM
    _items: List[Experience] = field(default_factory=list)
    _vecs: List[np.ndarray] = field(default_factory=list)

    def add(self, exp: Experience) -> None:
        self._items.append(exp)
        self._vecs.append(embed_text(exp.task_text, self.dim))

    def __len__(self) -> int:
        return len(self._items)

    def query(self, task_text: str, top_k: int = 1,
              threshold: float = 0.0
              ) -> List[Tuple[Experience, float]]:
        """Top-k experiences with similarity >= threshold."""
        if not self._items:
            return []
        q = embed_text(task_text, self.dim)
        sims = np.asarray(self._vecs) @ q
        order = np.argsort(-sims)[:max(top_k, 1)]
        return [(self._items[i], float(sims[i]))
                for i in order if sims[i] >= threshold]

    def similarity_stats(self, queries: Sequence[str]) -> dict:
        """Hit rate + similarity distribution for a query workload
        (reproduces Fig. 8/9)."""
        sims = []
        hits = 0
        for qtext in queries:
            res = self.query(qtext, top_k=1, threshold=0.0)
            if res:
                hits += 1
                sims.append(res[0][1])
        sims_arr = np.asarray(sims) if sims else np.zeros(1)
        return {
            "hit_rate": hits / max(len(queries), 1),
            "median_similarity": float(np.median(sims_arr)),
            "mean_similarity": float(np.mean(sims_arr)),
            "similarities": [float(s) for s in sims],
        }
