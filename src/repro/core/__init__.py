# ACAR: the paper's primary contribution — sigma-based adaptive
# complexity routing with auditable traces, plus the negative-result
# machinery (retrieval, attribution).
from repro.core.backends import (
    GenResult, ModelBackend, ModelProfile, PAPER_MODELS,
    SyntheticBackend, paper_backends)
from repro.core.extract import extract
from repro.core.judge import arena_verify, judge_select
from repro.core.orchestrator import (
    ACAROrchestrator, TaskOutcome, run_fixed_mode)
from repro.core.retrieval import Experience, ExperienceStore, embed_text
from repro.core.routing import (
    ARENA_LITE, FULL_ARENA, MODES, SINGLE_AGENT, RoutingDecision,
    decide, execution_mode, majority_vote, models_for_mode)
from repro.core.sigma import (
    MODE_NAMES, majority_vote_batch, route_batch, sigma, sigma_batch)

__all__ = [
    "ACAROrchestrator", "ARENA_LITE", "Experience", "ExperienceStore",
    "FULL_ARENA", "GenResult", "MODES", "MODE_NAMES", "ModelBackend",
    "ModelProfile", "PAPER_MODELS", "RoutingDecision", "SINGLE_AGENT",
    "SyntheticBackend", "TaskOutcome", "arena_verify", "decide",
    "embed_text", "execution_mode", "extract", "judge_select",
    "majority_vote", "majority_vote_batch", "models_for_mode",
    "paper_backends", "route_batch", "run_fixed_mode", "sigma",
    "sigma_batch",
]
