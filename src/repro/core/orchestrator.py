"""ACAR orchestrator — paper Algorithm 1 atop the TEAMLLM substrate.

Phase 1 (difficulty estimation): N probe samples -> EXTRACT -> sigma.
Phase 2 (adaptive routing): sigma -> {single_agent, arena_lite,
full_arena}; execute ensemble members accordingly; aggregate.
Phase 3 (logging): append the immutable TraceRecord.

Every run flows through the forward-only state machine and the
hash-chained artifact store. ``run_fixed_mode`` provides the paper's
baselines (Single-Model / Arena-2 / Arena-3) over the same substrate.

The per-task phases are module-level functions (``retrieve_exemplar``,
``probe_task``, ``execute_ensemble``, ``aggregate``, ``build_trace``)
so the continuous-batching scheduler (serving/scheduler.py) executes
the *same* code per task — the batched path differs only in how work
is grouped, which is what makes sequential<->batched equivalence
provable rather than aspirational.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.configs.acar import ACARConfig
from repro.core.backends import GenResult, ModelBackend, SyntheticBackend
from repro.core.extract import extract
from repro.core.judge import arena_verify, judge_select
from repro.core.retrieval import ExperienceStore
from repro.core.routing import (
    ARENA_LITE, FULL_ARENA, SINGLE_AGENT, decide, execution_mode,
    majority_vote, models_for_mode)
from repro.core.sigma import sigma as sigma_fn
from repro.data.tasks import Task
from repro.teamllm.artifacts import ArtifactStore
from repro.teamllm.fingerprint import render_prompt
from repro.teamllm.state_machine import RunState, RunStateMachine
from repro.teamllm.trace import ModelResponse, ProbeSample, TraceRecord

COORDINATION_COST = 0.0008      # per multi-model task (paper §4: the
#                                 overhead that makes Arena-2 == Arena-3)
COORDINATION_LATENCY_MS = 900.0


@dataclass
class TaskOutcome:
    trace: TraceRecord
    latency_ms: float
    semantic_answer: str
    correct: bool


# ----------------------------------------------------------------------
# per-task phases, shared between the sequential orchestrator and the
# continuous-batching scheduler
# ----------------------------------------------------------------------
def retrieve_exemplar(acfg: ACARConfig,
                      experience: Optional[ExperienceStore],
                      task: Task):
    """ACAR-UJ: query the experience store; returns
    (exemplar_text, similarity, meta) or (None, None, None)."""
    if not (acfg.retrieval_enabled and experience and len(experience)):
        return None, None, None
    res = experience.query(
        task.text, top_k=acfg.retrieval_top_k,
        threshold=acfg.retrieval_threshold)
    if not res:
        return None, None, {"hit": False}
    exp, sim = res[0]
    meta = {"hit": True, "similarity": sim,
            "exemplar_benchmark": exp.benchmark}
    return f"{exp.task_text} -> {exp.answer}", sim, meta


def backend_generate(backend: ModelBackend, task: Task, prompt: str,
                     temperature: float, sample_idx: int, seed: int,
                     retrieval_sim: Optional[float]) -> GenResult:
    kwargs = dict(temperature=temperature, sample_idx=sample_idx,
                  seed=seed)
    if isinstance(backend, SyntheticBackend):
        kwargs["retrieval_sim"] = retrieval_sim
    return backend.generate(task, prompt, **kwargs)


def probe_task(acfg: ACARConfig, probe: ModelBackend, task: Task,
               prompt: str, retrieval_sim: Optional[float]
               ) -> Tuple[List[ProbeSample], List[GenResult], float]:
    """Phase 1: N probe samples -> EXTRACT. Returns
    (probe_samples, raw results, probe latency = max over samples)."""
    probe_samples: List[ProbeSample] = []
    probe_results: List[GenResult] = []
    probe_latency = 0.0
    for i in range(acfg.n_probe_samples):
        r = backend_generate(probe, task, prompt,
                             acfg.probe_temperature, i, acfg.seed,
                             retrieval_sim)
        probe_results.append(r)
        probe_samples.append(ProbeSample(
            response=r.response,
            answer=extract(r.response, task.kind),
            cost=r.cost))
        probe_latency = max(probe_latency, r.latency_ms)
    return probe_samples, probe_results, probe_latency


def execute_ensemble(acfg: ACARConfig,
                     ensemble: Dict[str, ModelBackend],
                     executed_models: Sequence[str], task: Task,
                     prompt: str, retrieval_sim: Optional[float]
                     ) -> Tuple[List[ModelResponse],
                                Dict[str, GenResult], float]:
    """Phase 2 execution: run the routed ensemble members."""
    responses: List[ModelResponse] = []
    results: Dict[str, GenResult] = {}
    exec_latency = 0.0
    for name in executed_models:
        r = backend_generate(ensemble[name], task, prompt,
                             acfg.ensemble_temperature, 0, acfg.seed,
                             retrieval_sim)
        results[name] = r
        responses.append(ModelResponse(
            model=name, response=r.response,
            answer=extract(r.response, task.kind), cost=r.cost,
            score=r.score))
        exec_latency = max(exec_latency, r.latency_ms)
    return responses, results, exec_latency


def aggregate(task: Task, mode: str, probe_majority: str,
              probe_samples: Sequence[ProbeSample],
              probe_results: Sequence[GenResult],
              responses: Sequence[ModelResponse],
              results: Dict[str, GenResult]) -> Tuple[str, str]:
    """Returns (final extracted answer, semantic answer)."""
    def probe_semantic(ans: str) -> str:
        for p, r in zip(probe_samples, probe_results):
            if p.answer == ans:
                return r.semantic_answer
        return probe_results[0].semantic_answer

    def response_semantic(ans: str) -> str:
        for m in responses:
            if m.answer == ans:
                return results[m.model].semantic_answer
        return probe_semantic(ans)

    if mode == SINGLE_AGENT:
        return probe_majority, probe_semantic(probe_majority)
    if mode == ARENA_LITE:
        final = arena_verify(probe_majority, responses, task.task_id)
        if final == probe_majority:
            return final, probe_semantic(final)
        return final, response_semantic(final)
    final = judge_select(responses, task.task_id,
                         probe_answer=probe_majority)
    return final, response_semantic(final)


def task_cost_latency(probe_samples: Sequence[ProbeSample],
                      responses: Sequence[ModelResponse],
                      probe_latency: float,
                      exec_latency: float) -> Tuple[float, float]:
    cost = sum(p.cost for p in probe_samples) \
        + sum(r.cost for r in responses)
    latency = probe_latency + exec_latency
    if len(responses) > 1:
        cost += COORDINATION_COST
        latency += COORDINATION_LATENCY_MS
    return cost, latency


def build_trace(run_id: str, task: Task, prompt: str, seed: int,
                sig: float, mode: str,
                probe_samples: Sequence[ProbeSample],
                responses: Sequence[ModelResponse],
                final_answer: str, correct: bool, cost: float,
                ret_meta: Optional[Dict[str, Any]], logical_time: int,
                schedule: Optional[Dict[str, Any]] = None
                ) -> TraceRecord:
    return TraceRecord(
        run_id=run_id,
        task_id=task.task_id,
        benchmark=task.benchmark,
        prompt_hash=hashlib.sha256(prompt.encode()).hexdigest()[:16],
        seed=seed,
        sigma=sig,
        mode=mode,
        probe_samples=tuple(probe_samples),
        responses=tuple(responses),
        final_answer=final_answer,
        correct=correct,
        cost=cost,
        retrieval=ret_meta,
        logical_time=logical_time,
        schedule=schedule,
    )


class ACAROrchestrator:
    def __init__(self, acfg: ACARConfig, probe: ModelBackend,
                 ensemble: Dict[str, ModelBackend],
                 store: Optional[ArtifactStore] = None,
                 experience: Optional[ExperienceStore] = None,
                 run_id: str = "acar"):
        self.acfg = acfg
        self.probe = probe
        self.ensemble = ensemble
        self.ensemble_order = list(ensemble)
        self.store = store
        self.experience = experience
        self.run_id = run_id
        self._clock = 0

    # ------------------------------------------------------------------
    def run_task(self, task: Task) -> TaskOutcome:
        sm = RunStateMachine(f"{self.run_id}/{task.task_id}")
        sm.advance(RunState.EXECUTING)

        exemplar, sim, ret_meta = retrieve_exemplar(
            self.acfg, self.experience, task)
        prompt = render_prompt(task.text, exemplar or "")

        # Phase 1: probe sampling
        probe_samples, probe_results, probe_latency = probe_task(
            self.acfg, self.probe, task, prompt, sim)

        probe_answers = [p.answer for p in probe_samples]
        sig = sigma_fn(probe_answers)
        decision = decide(sig, probe_answers, self.ensemble_order,
                          self.acfg.arena_lite_size)
        mode = decision.mode

        # Phase 2: adaptive execution
        responses, results, exec_latency = execute_ensemble(
            self.acfg, self.ensemble, decision.executed_models, task,
            prompt, sim)

        final_answer, semantic = aggregate(
            task, mode, decision.probe_answer, probe_samples,
            probe_results, responses, results)

        sm.advance(RunState.VERIFYING)
        correct = semantic == task.gold
        cost, latency = task_cost_latency(
            probe_samples, responses, probe_latency, exec_latency)

        trace = build_trace(
            self.run_id, task, prompt, self.acfg.seed, sig, mode,
            probe_samples, responses, final_answer, correct, cost,
            ret_meta, self._clock)
        self._clock += 1
        if self.store is not None:
            self.store.append(trace)
        sm.advance(RunState.COMPLETED)
        return TaskOutcome(trace=trace, latency_ms=latency,
                           semantic_answer=semantic, correct=correct)

    # ------------------------------------------------------------------
    def run_suite(self, tasks: Sequence[Task]) -> List[TaskOutcome]:
        return [self.run_task(t) for t in tasks]


# ----------------------------------------------------------------------
# fixed-mode baselines (paper §4.3)
# ----------------------------------------------------------------------
def run_fixed_mode(tasks: Sequence[Task],
                   backends: Dict[str, ModelBackend],
                   members: Sequence[str],
                   store: Optional[ArtifactStore] = None,
                   seed: int = 0,
                   run_id: str = "baseline") -> List[TaskOutcome]:
    """Always execute exactly ``members`` (Single / Arena-2 / Arena-3)."""
    outcomes = []
    clock = 0
    for task in tasks:
        prompt = render_prompt(task.text)
        responses, results = [], {}
        latency = 0.0
        for name in members:
            r = backends[name].generate(
                task, prompt, temperature=0.0, sample_idx=0, seed=seed)
            results[name] = r
            responses.append(ModelResponse(
                model=name, response=r.response,
                answer=extract(r.response, task.kind), cost=r.cost,
                score=r.score))
            latency = max(latency, r.latency_ms)
        if len(responses) == 1:
            final = responses[0].answer
            semantic = results[members[0]].semantic_answer
        else:
            final = judge_select(responses, task.task_id)
            semantic = next(
                (results[m.model].semantic_answer for m in responses
                 if m.answer == final),
                results[members[0]].semantic_answer)
        cost = sum(m.cost for m in responses)
        if len(responses) > 1:
            cost += COORDINATION_COST
            latency += COORDINATION_LATENCY_MS
        correct = semantic == task.gold
        mode = {1: SINGLE_AGENT, 2: ARENA_LITE}.get(
            len(responses), FULL_ARENA)
        trace = TraceRecord(
            run_id=run_id, task_id=task.task_id, benchmark=task.benchmark,
            prompt_hash=hashlib.sha256(prompt.encode()).hexdigest()[:16],
            seed=seed, sigma=-1.0, mode=mode,
            probe_samples=(), responses=tuple(responses),
            final_answer=final, correct=correct, cost=cost,
            logical_time=clock)
        clock += 1
        if store is not None:
            store.append(trace)
        outcomes.append(TaskOutcome(trace=trace, latency_ms=latency,
                                    semantic_answer=semantic,
                                    correct=correct))
    return outcomes
