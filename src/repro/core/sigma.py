"""Self-consistency variance (paper Def. 1).

    sigma = (|{a_1, ..., a_N}| - 1) / (N - 1)   in {0, 0.5, 1} for N=3.

Two implementations: a host-side one over canonical answer strings, and
a vectorised jnp one over batches of answer ids — the serving runtime
routes whole request batches on-device with the latter (DESIGN.md §1.1).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def sigma(answers: Sequence[str]) -> float:
    """Host-side sigma over extracted canonical answers."""
    n = len(answers)
    if n < 2:
        return 0.0
    distinct = len(set(answers))
    return (distinct - 1) / (n - 1)


def sigma_batch(answer_ids: jax.Array) -> jax.Array:
    """Vectorised sigma over answer ids.

    answer_ids: (B, N) int32 — canonical answer ids per probe sample.
    Returns (B,) float32 sigma values. Distinct-count is computed by
    pairwise comparison (N is small — the paper uses N=3).
    """
    b, n = answer_ids.shape
    # distinct count: sum over i of [a_i not equal to any earlier a_j]
    eq = answer_ids[:, :, None] == answer_ids[:, None, :]   # (B,N,N)
    earlier = jnp.tril(jnp.ones((n, n), bool), k=-1)        # j < i
    dup = jnp.any(eq & earlier[None], axis=-1)              # (B,N)
    distinct = n - jnp.sum(dup, axis=-1)                    # (B,)
    return (distinct - 1).astype(jnp.float32) / (n - 1)


def route_batch(sig: jax.Array) -> jax.Array:
    """Map sigma values to mode ids: 0=single_agent, 1=arena_lite,
    2=full_arena. sig: (B,) float32."""
    return jnp.where(sig <= 0.0, 0, jnp.where(sig < 1.0, 1, 2)).astype(
        jnp.int32)


MODE_NAMES = ("single_agent", "arena_lite", "full_arena")


def majority_vote_batch(answer_ids: jax.Array) -> jax.Array:
    """Majority answer id per row (ties -> first sample), (B, N) int32."""
    b, n = answer_ids.shape
    eq = (answer_ids[:, :, None] == answer_ids[:, None, :]).sum(-1)
    best = jnp.argmax(eq, axis=-1)                          # (B,)
    return jnp.take_along_axis(answer_ids, best[:, None], axis=1)[:, 0]
