from repro.sharding.partitioning import (
    FULL_DP_RULES,
    MULTI_POD_RULES,
    NO_KV_SHARD_RULES,
    RULE_SETS,
    SINGLE_POD_RULES,
    axis_rules,
    mesh_axis_size,
    named_sharding,
    resolve,
    rule_set,
    shard,
)
from repro.sharding.tp import (
    tp_active,
    tp_all_gather,
    tp_check_cfg,
    tp_context,
    tp_local_cfg,
    tp_param_specs,
    tp_size,
)

__all__ = [
    "FULL_DP_RULES", "MULTI_POD_RULES", "NO_KV_SHARD_RULES",
    "RULE_SETS", "SINGLE_POD_RULES", "axis_rules", "mesh_axis_size",
    "named_sharding", "resolve", "rule_set", "shard",
    "tp_active", "tp_all_gather", "tp_check_cfg", "tp_context",
    "tp_local_cfg", "tp_param_specs", "tp_size",
]
