from repro.sharding.partitioning import (
    FULL_DP_RULES,
    MULTI_POD_RULES,
    NO_KV_SHARD_RULES,
    RULE_SETS,
    SINGLE_POD_RULES,
    axis_rules,
    mesh_axis_size,
    named_sharding,
    resolve,
    rule_set,
    shard,
)

__all__ = [
    "FULL_DP_RULES", "MULTI_POD_RULES", "NO_KV_SHARD_RULES",
    "RULE_SETS", "SINGLE_POD_RULES", "axis_rules", "mesh_axis_size",
    "named_sharding", "resolve", "rule_set", "shard",
]
