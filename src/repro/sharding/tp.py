"""Bit-exact tensor parallelism for the serving mesh's "model" axis.

The sharded serving programs must stay *bit-identical* to single-device
execution (the harness proves record hashes + artifact-chain heads
equal), which rules out the textbook row-parallel scheme: ``psum`` over
a sharded contraction reorders the float reduction. Instead every
matmul whose *output* axis is sharded (wq -> heads, wk/wv -> kv_heads,
w_gate/w_up -> ff / expert_ff, lm_head -> vocab) runs column-parallel,
and before any contraction *over* a sharded axis the activation is
``all_gather``'d (tiled) back to full length — an all-gather is pure
concatenation in mesh-axis order, matching the contiguous column slices
of the weight, so every contraction sees the exact full-length operands
the single-device program does. Contracted-input weights (wo, w_down,
router, shared experts, norms, embeddings) stay replicated.

Model code calls ``tp_all_gather`` at each gather point; outside a
``tp_context`` it is a no-op, so the single-device and 1-D ("data",)
paths trace byte-identical programs. The context is entered at
*trace time* inside the ``shard_map`` bodies of the sharded sampler
programs (``sampling/sampler.py``), which also swap in ``tp_local_cfg``
so cfg-derived reshape dims (num_heads, num_kv_heads) match the local
parameter slices.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

# Weights sharded over "model" on their LAST (output) axis; everything
# else is replicated. Keyed on the leaf name in the param pytree —
# logical axis names can't express the column/row distinction (wq and
# wo both carry "heads").
_COL_PARALLEL = frozenset({"wq", "wk", "wv", "w_gate", "w_up",
                           "lm_head"})


class _Tp(threading.local):
    def __init__(self):
        self.axis: Optional[str] = None
        self.size: int = 1


_CTX = _Tp()


@contextlib.contextmanager
def tp_context(axis: str, size: int):
    """Activate tensor parallelism for model code traced inside."""
    prev = (_CTX.axis, _CTX.size)
    _CTX.axis, _CTX.size = axis, int(size)
    try:
        yield
    finally:
        _CTX.axis, _CTX.size = prev


def tp_active() -> bool:
    return _CTX.axis is not None


def tp_size() -> int:
    return _CTX.size if _CTX.axis is not None else 1


def tp_all_gather(x: jax.Array) -> jax.Array:
    """Gather a model-sharded last axis back to full length (no-op
    outside a tp context). ``tiled=True`` concatenates the per-device
    slices in mesh-axis order — exactly the column order of the
    sharded weight that produced them — so the result is bit-identical
    to the unsharded activation."""
    if _CTX.axis is None:
        return x
    return jax.lax.all_gather(x, _CTX.axis, axis=x.ndim - 1, tiled=True)


def tp_local_cfg(cfg, m: int):
    """Config whose head counts describe one model shard's param
    slice, for the reshapes inside attention. Head dim is pinned so
    halving num_heads cannot silently change ``resolved_head_dim``."""
    if m <= 1:
        return cfg
    if cfg.num_heads % m or cfg.num_kv_heads % m:
        raise ValueError(
            f"config {cfg.name!r}: num_heads={cfg.num_heads} / "
            f"num_kv_heads={cfg.num_kv_heads} not divisible by "
            f"model={m}")
    return cfg.replace(num_heads=cfg.num_heads // m,
                       num_kv_heads=cfg.num_kv_heads // m,
                       head_dim=cfg.resolved_head_dim)


def tp_param_specs(params, axis: str = "model"):
    """Per-leaf PartitionSpec tree for the bit-exact column-parallel
    layout: ``_COL_PARALLEL`` leaves shard their last axis over
    ``axis`` (leading axes — including a stacked "layers" axis — stay
    unsharded); every other leaf is fully replicated."""

    def spec(path, leaf):
        key = path[-1]
        name = getattr(key, "key", None) or str(key)
        if name in _COL_PARALLEL:
            return P(*((None,) * (leaf.ndim - 1) + (axis,)))
        return P()

    return jax.tree_util.tree_map_with_path(spec, params)


def tp_check_cfg(cfg, m: int) -> None:
    """Raise early (at placement, not trace) when a config cannot run
    bit-exact column-parallel at model=m."""
    if m <= 1:
        return
    tp_local_cfg(cfg, m)  # head divisibility
    if cfg.d_ff % m:
        raise ValueError(
            f"config {cfg.name!r}: d_ff={cfg.d_ff} not divisible by "
            f"model={m}")
    if cfg.moe is not None and cfg.moe.d_ff_expert % m:
        raise ValueError(
            f"config {cfg.name!r}: d_ff_expert={cfg.moe.d_ff_expert} "
            f"not divisible by model={m}")
    if not cfg.tie_embeddings and cfg.vocab_size % m:
        raise ValueError(
            f"config {cfg.name!r}: untied vocab_size={cfg.vocab_size} "
            f"not divisible by model={m}")
