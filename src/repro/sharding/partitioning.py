"""Logical-axis partitioning rules.

Model code annotates activations with *logical* axis names via
``shard(x, "batch", "seq", "heads", None)``. At launch time a rule set maps
logical names to mesh axes; outside a rules context the helpers are no-ops,
so smoke tests on one CPU device never touch device state.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, None, Tuple[str, ...]]

# Default rules for the ("data", "model") production mesh. "pod" (multi-pod)
# extends the data axis: batch shards over ("pod", "data").
SINGLE_POD_RULES = {
    "batch": "data",
    "seq": None,
    "seq_kv": "model",     # MQA decode: shard KV cache along sequence
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "vocab": "model",
    "experts": None,       # "tp" MoE: experts replicated, d_ff sharded
    "expert_ff": "model",
    "state": None,
    "d_inner": "model",    # mamba/rglru channel dim
    "layers": None,
    "frames": None,
    "kv_lora": None,
}

MULTI_POD_RULES = dict(SINGLE_POD_RULES, batch=("pod", "data"))

# ----------------------------------------------------------------------
# alternative rule sets (perf iterations, EXPERIMENTS.md §Perf)
# ----------------------------------------------------------------------
# Pure data parallelism: weights replicated, batch shards over BOTH mesh
# axes. For small models (smollm) the model axis only buys redundant
# compute + weight all-gathers; folding it into batch divides per-chip
# FLOPs by the model-axis size.
FULL_DP_RULES = dict(
    SINGLE_POD_RULES,
    batch=("data", "model"),
    heads=None, kv_heads=None, ff=None, vocab=None, expert_ff=None,
    d_inner=None, seq_kv=None,
)

# KV replicated across the model axis (for kv_heads < model-axis archs
# where head sharding pads and seq sharding all-reduces every step).
NO_KV_SHARD_RULES = dict(SINGLE_POD_RULES, kv_heads=None, seq_kv=None)

# Expert parallelism: routed-expert weights and dispatch buffers shard
# over the model axis along the EXPERT dim (E % 16 == 0 for both MoE
# archs); per-expert d_ff stays whole, so the expert FFN contracts
# locally — token dispatch/combine becomes the only cross-shard traffic
# (vs the "tp" default, which psums the full (E, cap, d) buffer).
EXPERT_PARALLEL_RULES = dict(
    SINGLE_POD_RULES, experts="model", expert_ff=None)

RULE_SETS = {
    "default": SINGLE_POD_RULES,
    "dp": FULL_DP_RULES,
    "no-kv-shard": NO_KV_SHARD_RULES,
    "ep": EXPERT_PARALLEL_RULES,
}


def rule_set(name: str, multi_pod: bool = False) -> dict:
    rules = dict(RULE_SETS[name])
    if multi_pod:
        ba = rules["batch"]
        ba = ba if isinstance(ba, tuple) else (ba,)
        rules["batch"] = ("pod",) + ba
    return rules


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[dict] = None


_CTX = _Ctx()


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Optional[dict] = None):
    """Activate logical->mesh axis mapping for model-code annotations."""
    if rules is None:
        rules = MULTI_POD_RULES if "pod" in mesh.axis_names else SINGLE_POD_RULES
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def active() -> bool:
    return _CTX.mesh is not None


def resolve(*logical: Optional[str]) -> P:
    """Map logical axis names to a PartitionSpec under the active rules."""
    assert _CTX.rules is not None
    spec = []
    used = set()
    for name in logical:
        if name is None:
            spec.append(None)
            continue
        mesh_axis = _CTX.rules.get(name)
        # an axis may appear only once in a spec; drop duplicates
        if mesh_axis is None or mesh_axis in used:
            spec.append(None)
        else:
            spec.append(mesh_axis)
            used.add(mesh_axis)
            if isinstance(mesh_axis, tuple):
                used.update(mesh_axis)
    return P(*spec)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names (no-op w/o rules)."""
    if not active():
        return x
    assert x.ndim == len(logical), (x.shape, logical)
    sh = NamedSharding(_CTX.mesh, resolve(*logical))
    return jax.lax.with_sharding_constraint(x, sh)


def named_sharding(*logical: Optional[str]) -> Optional[NamedSharding]:
    if not active():
        return None
    return NamedSharding(_CTX.mesh, resolve(*logical))


def mesh_axis_size(logical: str) -> int:
    """Size of the mesh axis a logical name maps to (1 outside a context)."""
    if not active():
        return 1
    mesh_axis = _CTX.rules.get(logical)
    if mesh_axis is None:
        return 1
    if isinstance(mesh_axis, tuple):
        n = 1
        for a in mesh_axis:
            n *= _CTX.mesh.shape[a]
        return n
    return _CTX.mesh.shape[mesh_axis]
