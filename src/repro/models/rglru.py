"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Recurrence: h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t) with
a_t = exp(-c * softplus(Lambda) * r_t), r_t/i_t sigmoid gates of the
conv output. State is (B, width) per layer, so the whole sequence scan
fits as a single log-depth ``lax.associative_scan`` (state dim 1 per
channel) — no chunking needed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import causal_conv1d, causal_conv1d_step
from repro.sharding import shard

_C = 8.0  # Griffin's fixed recurrence sharpness constant


def _gates(p: dict, xc: jax.Array):
    """xc: (..., w) conv output -> (log_a, gated input) in f32."""
    xf = xc.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", xf,
                                  p["w_a"].astype(jnp.float32))
                       + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", xf,
                                  p["w_i"].astype(jnp.float32))
                       + p["b_i"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) input normalisation, clipped for stability
    beta = jnp.sqrt(jnp.clip(1.0 - jnp.square(a), 1e-12, 1.0))
    return a, beta * i * xf


def rglru_scan(p: dict, xc: jax.Array, h0=None):
    """xc: (B, S, w). Returns y (B, S, w) f32, h_final (B, w) f32."""
    bsz, s, w = xc.shape
    a, u = _gates(p, xc)                                   # (B,S,w)
    if h0 is not None:
        # fold the carried state in as a virtual step before t=0
        u = u.at[:, 0].add(a[:, 0] * h0)
    def comb(left, right):
        al, ul = left
        ar, ur = right
        return al * ar, ul * ar + ur
    _, hs = jax.lax.associative_scan(comb, (a, u), axis=1)
    return hs, hs[:, -1]


def rglru_step(p: dict, x_t: jax.Array, h: jax.Array):
    """x_t: (B, w) conv output; h: (B, w) f32 state."""
    a, u = _gates(p, x_t)
    h_new = a * h + u
    return h_new, h_new


def rglru_block(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    """Full-sequence Griffin recurrent block. x: (B, S, d_model)."""
    xb = jnp.einsum("bsd,dw->bsw", x, p["w_x"])
    gb = jnp.einsum("bsd,dw->bsw", x, p["w_g"])
    xb = shard(xb, "batch", "seq", "d_inner")
    gb = shard(gb, "batch", "seq", "d_inner")
    xc = causal_conv1d(xb, p["conv_w"], p["conv_b"])
    if cfg.use_pallas:
        # TPU deployment: RG-LRU chunk-walk Pallas kernel.
        from repro.kernels import ops
        a, u = _gates(p, xc)
        y, _ = ops.rglru_scan(a, u, chunk=cfg.rglru.chunk)
    else:
        y, _ = rglru_scan(p, xc)
    y = y * jax.nn.gelu(gb.astype(jnp.float32))
    return jnp.einsum("bsw,wd->bsd", y.astype(x.dtype), p["w_out"])


def rglru_prefill(cfg: ModelConfig, p: dict, x: jax.Array):
    width = cfg.rglru.conv_width
    xb = jnp.einsum("bsd,dw->bsw", x, p["w_x"])
    gb = jnp.einsum("bsd,dw->bsw", x, p["w_g"])
    xc = causal_conv1d(xb, p["conv_w"], p["conv_b"])
    y, h = rglru_scan(p, xc)
    y = y * jax.nn.gelu(gb.astype(jnp.float32))
    out = jnp.einsum("bsw,wd->bsd", y.astype(x.dtype), p["w_out"])
    return out, {"conv": xb[:, -(width - 1):, :], "h": h}


def rglru_block_step(cfg: ModelConfig, p: dict, x_t: jax.Array,
                     state: dict):
    """One decode step. x_t: (B, d_model); state {conv, h}."""
    xb = jnp.einsum("bd,dw->bw", x_t, p["w_x"])
    gb = jnp.einsum("bd,dw->bw", x_t, p["w_g"])
    xc, conv_state = causal_conv1d_step(xb, state["conv"], p["conv_w"],
                                        p["conv_b"])
    y, h = rglru_step(p, xc, state["h"])
    y = y * jax.nn.gelu(gb.astype(jnp.float32))
    out = jnp.einsum("bw,wd->bd", y.astype(x_t.dtype), p["w_out"])
    return out, {"conv": conv_state, "h": h}
