from repro.models.params import (
    abstract_params, count_params, init_params, model_defs, param_specs)
from repro.models.transformer import (
    decode_step, forward, init_cache, prefill)

__all__ = [
    "abstract_params", "count_params", "decode_step", "forward",
    "init_cache", "init_params", "model_defs", "param_specs", "prefill",
]
