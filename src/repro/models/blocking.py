"""Fixed-shape token blocking: bitwise batch invariance for projections.

XLA picks a dot's tiling (and the CPU backend its GEMM blocking) *per
shape*. A token's projection can therefore round differently depending
on how many other tokens happen to share the GEMM — batch composition
— and, under column-parallel tensor sharding, on how many output
columns the local shard computes. Both break the serving stack's
bit-equivalence contracts: compaction decodes a row inside a gathered
escalated subset, the sharded step loop splits a batch over data
shards, and the 2-D mesh splits projection columns over model shards,
yet every record hash must match the single-device full-batch run.

``blocked_rows`` removes the shape dependence instead of hoping the
compiler's thresholds cooperate: the row-parallel function runs under
``lax.map`` over fixed (``TOKEN_BLOCK``, d) row blocks (tail
zero-padded, output sliced back). Every elementary dot then has one
static shape, so it compiles to one kernel with one reduction order —
a token's bits depend only on its own values, never on its
neighbours. Column-parallel splits of the serving configs' projection
widths are exact at the fixed block shape (verified by
tests/test_batch_invariant_ops.py), which is what makes the 2-D
("data", "model") mesh bit-identical to a single device.

The loop always runs through ``lax.map`` — even for a single block —
so the block body sits in the same program structure (and fuses the
same way) at every token count.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

# 8 rows: every serving-path GEMM becomes (8, d) x (d, f). Small enough
# that decode batches (<= max_active_rows) stay one or two blocks, big
# enough that chunked prefill is not dominated by loop overhead.
TOKEN_BLOCK = 8


def blocked_rows(fn: Callable[[jax.Array], jax.Array],
                 xt: jax.Array) -> jax.Array:
    """Apply a row-parallel ``fn`` over fixed-size row blocks of ``xt``.

    xt: (T, d). ``fn`` maps (TOKEN_BLOCK, d) -> (TOKEN_BLOCK, ...) and
    must be row-parallel (each output row a function of the matching
    input row only — projections, gated MLPs, routers). Returns the
    concatenation of the per-block outputs, sliced back to T rows.
    """
    t, d = xt.shape
    nb = -(-t // TOKEN_BLOCK)
    pad = nb * TOKEN_BLOCK - t
    xp = jnp.pad(xt, ((0, pad), (0, 0))) if pad else xt
    yb = jax.lax.map(fn, xp.reshape(nb, TOKEN_BLOCK, d))
    y = yb.reshape(nb * TOKEN_BLOCK, *yb.shape[2:])
    return y[:t] if pad else y
