"""Shared neural-net layers: norms, RoPE, MLPs, embeddings.

All layers are pure functions over plain dict params so the same code path
serves init, training, prefill and decode, and params remain a transparent
pytree for sharding/checkpointing.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.blocking import blocked_rows
from repro.sharding import shard, tp_all_gather


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dtype)


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,), float32."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    half = x.shape[-1] // 2
    inv = rope_freqs(x.shape[-1], theta)                   # (half,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (..., seq, half)
    sin = jnp.sin(ang)[..., None, :]                       # (..., seq, 1, half)
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1 = x1.astype(jnp.float32)
    xf2 = x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# MLPs
# ----------------------------------------------------------------------
def swiglu_mlp(params: dict, x: jax.Array) -> jax.Array:
    """Gated SwiGLU MLP: params {w_gate, w_up, w_down}; x (..., d).

    Runs over fixed-shape token blocks (``models.blocking``) so each
    token's bits are independent of batch composition and of the
    column-parallel shard width — the property the serving engine's
    compaction and the 2-D mesh's bit-equivalence contracts rest on.
    """
    wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]

    def blk(xb: jax.Array) -> jax.Array:
        g = jnp.einsum("td,df->tf", xb, wg)
        u = jnp.einsum("td,df->tf", xb, wu)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xb.dtype) * u
        # under tensor parallelism w_gate/w_up are column-sharded and
        # w_down is replicated: gather the hidden back to full d_ff so
        # the down-projection contracts full-length (bit-exact, no psum)
        h = tp_all_gather(h)
        return jnp.einsum("tf,fd->td", h, wd)

    xt = x.reshape(-1, x.shape[-1])
    return blocked_rows(blk, xt).reshape(x.shape)


def gelu_mlp(params: dict, x: jax.Array) -> jax.Array:
    """2-matmul GELU MLP (whisper): params {w_in, b_in, w_out, b_out}."""
    h = jnp.einsum("...d,df->...f", x, params["w_in"]) + params["b_in"]
    if x.ndim == 3:
        h = shard(h, "batch", "seq", "ff")
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, params["w_out"]) + params["b_out"]


def embed_tokens(embedding: jax.Array, tokens: jax.Array) -> jax.Array:
    """embedding (V, d) [vocab-sharded]; tokens (B, S) int32."""
    out = jnp.take(embedding, tokens, axis=0)
    return shard(out, "batch", "seq", "embed")


def lm_head(params: dict, x: jax.Array, tie_embeddings: bool) -> jax.Array:
    w = params["embedding"] if tie_embeddings else params["lm_head"]
    logits = jnp.einsum("...d,vd->...v", x, w) if tie_embeddings else \
        jnp.einsum("...d,dv->...v", x, w)
    if not tie_embeddings:
        # untied lm_head is vocab-column-sharded under tensor
        # parallelism; tied logits contract the replicated embedding
        # and are already full-vocab
        logits = tp_all_gather(logits)
    if logits.ndim == 3:
        logits = shard(logits, "batch", "seq", "vocab")
    return logits


def causal_conv1d(x: jax.Array, w: jax.Array,
                  bias: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv via shifted adds.

    x: (B, S, C); w: (width, C). Cheap for the small widths (4) used by
    mamba / RG-LRU, and trivially shardable over C.
    """
    width = w.shape[0]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for k in range(width):
        shift = width - 1 - k   # tap k sees x[t - shift]
        xs = x if shift == 0 else jnp.pad(
            x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xs.astype(jnp.float32) * w[k].astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def causal_conv1d_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array,
                       bias: Optional[jax.Array] = None):
    """Single decode step. x_t: (B, C); conv_state: (B, width-1, C)."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)
    out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                     w.astype(jnp.float32))
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    new_state = window[:, 1:, :]
    return out.astype(x_t.dtype), new_state
