"""Parameter definitions, initialisation, and partition specs.

Each weight is declared once as a ``WeightDef`` (shape + logical axis
names + init kind); ``init_params`` and ``param_specs`` both traverse the
same def tree, so sharding specs can never drift from the param pytree.
Scanned layer stacks get a leading "layers" axis.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.ssm import ssm_dims


@dataclasses.dataclass(frozen=True)
class WeightDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | a_log | lam
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _norm_def(d: int, with_bias: bool) -> Dict[str, WeightDef]:
    out = {"scale": WeightDef((d,), ("embed",), "ones")}
    if with_bias:
        out["bias"] = WeightDef((d,), ("embed",), "zeros")
    return out


def _attn_defs(cfg: ModelConfig) -> Dict[str, WeightDef]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    return {
        "wq": WeightDef((d, h * hd), ("embed", "heads")),
        "wk": WeightDef((d, kv * hd), ("embed", "kv_heads")),
        "wv": WeightDef((d, kv * hd), ("embed", "kv_heads")),
        "wo": WeightDef((h * hd, d), ("heads", "embed")),
    }


def _mla_defs(cfg: ModelConfig) -> Dict[str, WeightDef]:
    m = cfg.mla
    assert m is not None
    d, h = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": WeightDef((d, m.q_lora_rank), ("embed", None)),
        "q_norm": WeightDef((m.q_lora_rank,), (None,), "ones"),
        "wq_b": WeightDef((m.q_lora_rank, h * qk), (None, "heads")),
        "wkv_a": WeightDef((d, m.kv_lora_rank + m.qk_rope_head_dim),
                           ("embed", None)),
        "kv_norm": WeightDef((m.kv_lora_rank,), (None,), "ones"),
        "wk_b": WeightDef((m.kv_lora_rank, h * m.qk_nope_head_dim),
                          (None, "heads")),
        "wv_b": WeightDef((m.kv_lora_rank, h * m.v_head_dim),
                          (None, "heads")),
        "wo": WeightDef((h * m.v_head_dim, d), ("heads", "embed")),
    }


def _mlp_defs(cfg: ModelConfig, d_ff: int) -> Dict[str, WeightDef]:
    d = cfg.d_model
    if cfg.family == "audio":
        return {
            "w_in": WeightDef((d, d_ff), ("embed", "ff")),
            "b_in": WeightDef((d_ff,), ("ff",), "zeros"),
            "w_out": WeightDef((d_ff, d), ("ff", "embed")),
            "b_out": WeightDef((d,), ("embed",), "zeros"),
        }
    return {
        "w_gate": WeightDef((d, d_ff), ("embed", "ff")),
        "w_up": WeightDef((d, d_ff), ("embed", "ff")),
        "w_down": WeightDef((d_ff, d), ("ff", "embed")),
    }


def _moe_defs(cfg: ModelConfig) -> Dict[str, WeightDef]:
    m = cfg.moe
    assert m is not None
    d, e, f = cfg.d_model, m.num_experts, m.d_ff_expert
    out = {
        "router": WeightDef((d, e), ("embed", None)),
        "w_gate": WeightDef((e, d, f), ("experts", "embed", "expert_ff")),
        "w_up": WeightDef((e, d, f), ("experts", "embed", "expert_ff")),
        "w_down": WeightDef((e, f, d), ("experts", "expert_ff", "embed")),
    }
    if m.num_shared_experts:
        sf = m.num_shared_experts * m.d_ff_shared
        out.update({
            "shared_w_gate": WeightDef((d, sf), ("embed", "ff")),
            "shared_w_up": WeightDef((d, sf), ("embed", "ff")),
            "shared_w_down": WeightDef((sf, d), ("ff", "embed")),
        })
    return out


def _ssm_defs(cfg: ModelConfig) -> Dict[str, WeightDef]:
    s = cfg.ssm
    assert s is not None
    d = cfg.d_model
    d_in, dt_rank, n = ssm_dims(cfg)
    return {
        "w_in_x": WeightDef((d, d_in), ("embed", "d_inner")),
        "w_in_z": WeightDef((d, d_in), ("embed", "d_inner")),
        "conv_w": WeightDef((s.conv_width, d_in), (None, "d_inner")),
        "conv_b": WeightDef((d_in,), ("d_inner",), "zeros"),
        "w_xproj": WeightDef((d_in, dt_rank + 2 * n), ("d_inner", None)),
        "w_dt": WeightDef((dt_rank, d_in), (None, "d_inner")),
        "b_dt": WeightDef((d_in,), ("d_inner",), "zeros"),
        "a_log": WeightDef((d_in, n), ("d_inner", None), "a_log"),
        "d_skip": WeightDef((d_in,), ("d_inner",), "ones"),
        "w_out": WeightDef((d_in, d), ("d_inner", "embed")),
    }


def _rglru_defs(cfg: ModelConfig) -> Dict[str, WeightDef]:
    r = cfg.rglru
    assert r is not None
    d = cfg.d_model
    w = r.lru_width or d
    return {
        "w_x": WeightDef((d, w), ("embed", "d_inner")),
        "w_g": WeightDef((d, w), ("embed", "d_inner")),
        "conv_w": WeightDef((r.conv_width, w), (None, "d_inner")),
        "conv_b": WeightDef((w,), ("d_inner",), "zeros"),
        "w_a": WeightDef((w, w), ("d_inner", None)),
        "b_a": WeightDef((w,), (None,), "zeros"),
        "w_i": WeightDef((w, w), ("d_inner", None)),
        "b_i": WeightDef((w,), (None,), "zeros"),
        "lam": WeightDef((w,), (None,), "lam"),
        "w_out": WeightDef((w, d), ("d_inner", "embed")),
    }


def layer_defs(cfg: ModelConfig, kind: str, layer_idx: int,
               cross_attn: bool = False) -> dict:
    """Def tree for one decoder layer of the given kind."""
    d = cfg.d_model
    bias = cfg.family == "audio"
    if kind == "ssm":
        return {"norm": _norm_def(d, bias), "ssm": _ssm_defs(cfg)}
    out: dict = {}
    if kind == "attn":
        out["attn_norm"] = _norm_def(d, bias)
        out["attn"] = _mla_defs(cfg) if cfg.attn_kind == "mla" \
            else _attn_defs(cfg)
        if cross_attn:
            out["cross_norm"] = _norm_def(d, bias)
            out["cross"] = _attn_defs(cfg)
    elif kind == "rglru":
        out["mix_norm"] = _norm_def(d, bias)
        out["rglru"] = _rglru_defs(cfg)
    out["mlp_norm"] = _norm_def(d, bias)
    use_moe = (cfg.moe is not None and kind == "attn"
               and layer_idx >= cfg.moe.first_moe_layer)
    out["mlp"] = _moe_defs(cfg) if use_moe else _mlp_defs(cfg, cfg.d_ff)
    return out


def _stack_defs(defs: dict, n: int) -> dict:
    """Prepend a (scanned) layers axis to every WeightDef in a tree."""
    return jax.tree.map(
        lambda wd: WeightDef((n,) + wd.shape, ("layers",) + wd.axes,
                             wd.init, wd.scale),
        defs, is_leaf=lambda x: isinstance(x, WeightDef))


def model_defs(cfg: ModelConfig) -> dict:
    """Full parameter def tree for an architecture."""
    d, v = cfg.d_model, cfg.vocab_size
    defs: dict = {
        "embedding": WeightDef((v, d), ("vocab", "embed")),
        "final_norm": _norm_def(d, cfg.family == "audio"),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = WeightDef((d, v), ("embed", "vocab"))

    kinds = cfg.layer_kinds
    if cfg.family == "hybrid":
        # non-uniform layer stack: per-layer subtrees (unrolled)
        for i, kind in enumerate(kinds):
            defs[f"layer_{i:02d}"] = layer_defs(cfg, kind, i)
    elif cfg.family == "audio":
        e = cfg.encoder
        assert e is not None
        enc_layer = {
            "attn_norm": _norm_def(d, True),
            "attn": _attn_defs(cfg),
            "mlp_norm": _norm_def(d, True),
            "mlp": _mlp_defs(cfg, cfg.d_ff),
        }
        defs["enc_layers"] = _stack_defs(enc_layer, e.num_layers)
        defs["enc_final_norm"] = _norm_def(d, True)
        defs["dec_layers"] = _stack_defs(
            layer_defs(cfg, "attn", 0, cross_attn=True), cfg.num_layers)
        defs["dec_pos"] = WeightDef((cfg.max_position, d),
                                    (None, "embed"))
    elif cfg.moe is not None and cfg.moe.first_moe_layer > 0:
        # deepseek-v2: dense layer(s) first, uniform MoE stack after
        k = cfg.moe.first_moe_layer
        for i in range(k):
            dense = {
                "attn_norm": _norm_def(d, False),
                "attn": _mla_defs(cfg) if cfg.attn_kind == "mla"
                else _attn_defs(cfg),
                "mlp_norm": _norm_def(d, False),
                "mlp": _mlp_defs(cfg, cfg.d_ff),
            }
            defs[f"layer_{i:02d}"] = dense
        defs["layers"] = _stack_defs(
            layer_defs(cfg, "attn", k), cfg.num_layers - k)
    else:
        defs["layers"] = _stack_defs(
            layer_defs(cfg, kinds[0], 0), cfg.num_layers)
    return defs


# ----------------------------------------------------------------------
def _is_def(x) -> bool:
    return isinstance(x, WeightDef)


def init_params(cfg: ModelConfig, rng: jax.Array) -> dict:
    """Materialise parameters (deterministic per tree path)."""
    defs = model_defs(cfg)
    dtype = jnp.dtype(cfg.dtype)
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(rng, len(leaves))

    def make(wd: WeightDef, key):
        if wd.init == "zeros":
            return jnp.zeros(wd.shape, dtype)
        if wd.init == "ones":
            return jnp.ones(wd.shape, dtype)
        if wd.init == "a_log":
            # mamba S4D-real init: A = -(1..N) per channel
            n = wd.shape[-1]
            a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32),
                                 wd.shape)
            return jnp.log(a)
        if wd.init == "lam":
            # RG-LRU: a in (0.9, 0.999) at init
            u = jax.random.uniform(key, wd.shape, jnp.float32,
                                   0.9 ** 2, 0.999 ** 2)
            return jnp.log(jnp.exp(-jnp.log(u) / (2 * _RG_C)) - 1.0)
        fan_in = wd.shape[-2] if len(wd.shape) >= 2 else wd.shape[-1]
        scale = min(wd.scale, 1.0 / np.sqrt(fan_in))
        return (jax.random.normal(key, wd.shape, jnp.float32)
                * scale).astype(dtype)

    params = [make(wd, k) for wd, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, params)


_RG_C = 8.0


def param_specs(cfg: ModelConfig, rules: dict) -> dict:
    """PartitionSpec tree mirroring init_params exactly."""
    defs = model_defs(cfg)

    def to_spec(wd: WeightDef) -> P:
        spec, used = [], set()
        for ax in wd.axes:
            mesh_axis = rules.get(ax) if ax is not None else None
            if mesh_axis is None or mesh_axis in used:
                spec.append(None)
            else:
                spec.append(mesh_axis)
                used.add(mesh_axis)
        return P(*spec)

    return jax.tree.map(to_spec, defs, is_leaf=_is_def)


def abstract_params(cfg: ModelConfig) -> dict:
    """ShapeDtypeStruct tree (no allocation) for dry-run lowering."""
    defs = model_defs(cfg)
    dtype = jnp.dtype(cfg.dtype)

    def to_sds(wd: WeightDef):
        dt = jnp.float32 if wd.init in ("a_log", "lam") else dtype
        return jax.ShapeDtypeStruct(wd.shape, dt)

    return jax.tree.map(to_sds, defs, is_leaf=_is_def)


def count_params(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
