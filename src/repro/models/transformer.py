"""Composable transformer: full-sequence forward (train), prefill, and
single-token decode for every architecture family in the zoo.

Layer stacks are scanned (``lax.scan`` over stacked params) whenever the
stack is uniform — dense, MoE, SSM, and whisper's two stacks — keeping
HLO size and compile time bounded for 88-layer models on 512 devices.
The non-uniform hybrid (recurrentgemma) stack is unrolled (26 small
layers). Decode carries a cache pytree whose per-layer entries are
stacked along the scan axis so the same scan drives decoding.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    embed_tokens, gelu_mlp, layer_norm, rms_norm, swiglu_mlp)
from repro.sharding import shard, tp_all_gather

Cache = Dict[str, Any]


# ----------------------------------------------------------------------
# small helpers
# ----------------------------------------------------------------------
def norm_apply(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if "bias" in p:
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def mlp_apply(cfg: ModelConfig, p: dict, x: jax.Array,
              moe_shards: int) -> Tuple[jax.Array, jax.Array]:
    """Returns (y, aux). aux is 0 for dense MLPs."""
    if "router" in p:
        if cfg.moe is not None and cfg.moe.impl == "gather":
            # capacity-free per-token expert math: batch-composition
            # invariant, so MoE members qualify for compacted /
            # shared-prefix execution (sampling.batch_invariant)
            return moe_mod.moe_ffn_gather(cfg, p, x)
        return moe_mod.moe_ffn(cfg, p, x, moe_shards)
    if "w_in" in p:
        return gelu_mlp(p, x), jnp.zeros((), jnp.float32)
    if cfg.use_pallas:
        # TPU deployment: fused-SwiGLU Pallas kernel (kernels/ops.py
        # dispatches to the jnp oracle off-TPU, so CPU tests/examples
        # stay exact).
        from repro.kernels import ops
        t = x.reshape(-1, x.shape[-1])
        y = ops.fused_swiglu(t, p["w_gate"], p["w_up"], p["w_down"])
        return y.reshape(x.shape), jnp.zeros((), jnp.float32)
    return swiglu_mlp(p, x), jnp.zeros((), jnp.float32)


def mlp_apply_token(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if "router" in p:
        if cfg.moe is not None and cfg.moe.impl == "gather":
            # decode runs the same capacity-free gather math as
            # prefill: one code path, one bit-contract (fixed-shape
            # token blocks make it batch-composition invariant and
            # column-split exact under the 2-D mesh)
            y, _ = moe_mod.moe_ffn_gather(cfg, p, x[:, None])
            return y[:, 0]
        return moe_mod.moe_ffn_token(cfg, p, x)
    if "w_in" in p:
        return gelu_mlp(p, x)
    return swiglu_mlp(p, x)


def _attn_cache_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.window is not None:
        return min(seq_len, cfg.window)
    if cfg.use_pallas and seq_len > 0:
        # the flash-decode kernel tiles the cache in BLOCK_S chunks;
        # allocating on the block grid here means its off-grid fallback
        # (pad-and-copy per call) never triggers on the deployment
        # path — positions past the true length are masked like any
        # other invalid slot. Caches shorter than one block stay exact
        # (the kernel runs them as a single s-sized block).
        from repro.kernels.decode_attention import DEFAULT_BLOCK_S
        if seq_len > DEFAULT_BLOCK_S:
            return -(-seq_len // DEFAULT_BLOCK_S) * DEFAULT_BLOCK_S
    return seq_len


def ring_compress(k: jax.Array, cache_len: int) -> jax.Array:
    """Compress prefill keys (B,S,KV,D) to a ring cache
    (B,cache_len,...). Slot = absolute position mod cache_len; when the
    prompt is shorter than the ring, the tail slots are zero-padded
    (decode's slot arithmetic needs the full ring length, else the ring
    wraps early and evicts live positions)."""
    s = k.shape[1]
    if s < cache_len:
        pad = [(0, 0)] * k.ndim
        pad[1] = (0, cache_len - s)
        return jnp.pad(k, pad)
    if s == cache_len:
        return k
    pos = jnp.arange(s - cache_len, s)
    slots = jnp.mod(pos, cache_len)
    out = jnp.zeros((k.shape[0], cache_len) + k.shape[2:], k.dtype)
    return out.at[:, slots].set(k[:, -cache_len:])


# ----------------------------------------------------------------------
# layer forward (training / full sequence)
# ----------------------------------------------------------------------
def layer_fwd(cfg: ModelConfig, lp: dict, x: jax.Array,
              positions: jax.Array, kind: str, moe_shards: int,
              enc_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
              causal: bool = True) -> Tuple[jax.Array, jax.Array]:
    """One decoder/encoder layer over a full sequence. Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "ssm":
        h = norm_apply(cfg, lp["norm"], x)
        x = x + ssm_mod.mamba_block(cfg, lp["ssm"], h)
        return x, aux
    if kind == "rglru":
        h = norm_apply(cfg, lp["mix_norm"], x)
        x = x + rglru_mod.rglru_block(cfg, lp["rglru"], h)
    else:  # attn
        h = norm_apply(cfg, lp["attn_norm"], x)
        if cfg.attn_kind == "mla":
            a = attn.mla_attention(cfg, lp["attn"], h, positions)
        else:
            a = attn.gqa_attention(cfg, lp["attn"], h, positions,
                                   causal=causal, window=cfg.window)
        x = x + a
        if enc_kv is not None:
            h = norm_apply(cfg, lp["cross_norm"], x)
            x = x + attn.cross_attention(cfg, lp["cross"], h, *enc_kv)
    h = norm_apply(cfg, lp["mlp_norm"], x)
    y, aux = mlp_apply(cfg, lp["mlp"], h, moe_shards)
    x = x + y
    x = shard(x, "batch", "seq", "embed")
    return x, aux


# ----------------------------------------------------------------------
# embedding / head
# ----------------------------------------------------------------------
def _embed_inputs(cfg: ModelConfig, params: dict, tokens: jax.Array,
                  frontend_embeds: Optional[jax.Array]) -> jax.Array:
    x = embed_tokens(params["embedding"], tokens)
    if cfg.frontend == "vision" and frontend_embeds is not None:
        # image patches occupy the first num_patches positions
        p = frontend_embeds.shape[1]
        x = jnp.concatenate(
            [frontend_embeds.astype(x.dtype), x[:, p:]], axis=1)
        x = shard(x, "batch", "seq", "embed")
    return x


def _logits(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    x = norm_apply(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, params["embedding"])
    else:
        logits = jnp.einsum("...d,dv->...v", x, params["lm_head"])
        # tensor parallelism: untied lm_head is vocab-column-sharded;
        # gather logits to the full vocab (tied logits contract the
        # replicated embedding and are already full)
        logits = tp_all_gather(logits)
    if logits.ndim == 3:
        logits = shard(logits, "batch", "seq", "vocab")
    return logits


def _dec_pos(cfg: ModelConfig, params: dict,
             positions: jax.Array) -> jax.Array:
    """Learned decoder positions, indexed cyclically: the real whisper
    table has 448 slots; decode shapes past that are a sharding/shape
    exercise (DESIGN.md §4) and wrap modulo the table length."""
    table = params["dec_pos"]
    return jnp.take(table, jnp.mod(positions, table.shape[0]), axis=0)


def _sinusoidal_pos(length: int, d: int) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------------
# whisper encoder
# ----------------------------------------------------------------------
def _encode(cfg: ModelConfig, params: dict,
            frames: jax.Array) -> jax.Array:
    """frames: (B, F, d) stub frontend output -> encoder states."""
    e = cfg.encoder
    assert e is not None
    x = frames + _sinusoidal_pos(frames.shape[1],
                                 cfg.d_model).astype(frames.dtype)[None]
    positions = jnp.arange(frames.shape[1])

    def body(x, lp):
        x, _ = layer_fwd(cfg, lp, x, positions, "attn", 1, causal=False)
        return x, None

    x, _ = stack_scan(cfg, body, x, params["enc_layers"],
                      e.num_layers)
    return norm_apply(cfg, params["enc_final_norm"], x)


# ----------------------------------------------------------------------
# full-sequence forward (training / scoring)
# ----------------------------------------------------------------------
def forward(cfg: ModelConfig, params: dict, tokens: jax.Array,
            frontend_embeds: Optional[jax.Array] = None,
            remat: bool = False, moe_shards: int = 1
            ) -> Tuple[jax.Array, jax.Array]:
    """tokens: (B, S) -> (logits (B, S, V), moe_aux scalar)."""
    b, s = tokens.shape
    positions = jnp.arange(s)
    aux0 = jnp.zeros((), jnp.float32)

    if cfg.family == "audio":
        enc_out = _encode(cfg, params, frontend_embeds)
        x = embed_tokens(params["embedding"], tokens)
        x = x + _dec_pos(cfg, params, positions).astype(x.dtype)[None]

        def dec_body(carry, lp):
            x, aux = carry
            kv = attn.cross_kv(cfg, lp["cross"], enc_out)
            x, a = layer_fwd(cfg, lp, x, positions, "attn", moe_shards,
                             enc_kv=kv)
            return (x, aux + a), None

        if remat:
            dec_body = jax.checkpoint(dec_body)
        (x, aux), _ = stack_scan(cfg, dec_body, (x, aux0),
                                 params["dec_layers"], cfg.num_layers)
        return _logits(cfg, params, x), aux

    x = _embed_inputs(cfg, params, tokens, frontend_embeds)

    if cfg.family == "hybrid":
        aux = aux0
        for i, kind in enumerate(cfg.layer_kinds):
            lp = params[f"layer_{i:02d}"]
            fn = functools.partial(layer_fwd, cfg, lp,
                                   positions=positions, kind=kind,
                                   moe_shards=moe_shards)
            if remat:
                fn = jax.checkpoint(fn)
            x, a = fn(x)
            aux = aux + a
        return _logits(cfg, params, x), aux

    aux = aux0
    kinds = cfg.layer_kinds
    # leading dense layers (deepseek-v2 keeps layer 0 dense)
    n_unrolled = cfg.moe.first_moe_layer if (
        cfg.moe is not None and cfg.moe.first_moe_layer > 0) else 0
    for i in range(n_unrolled):
        x, a = layer_fwd(cfg, params[f"layer_{i:02d}"], x, positions,
                         "attn", moe_shards)
        aux = aux + a

    def body(carry, lp):
        x, aux = carry
        x, a = layer_fwd(cfg, lp, x, positions, kinds[-1], moe_shards)
        return (x, aux + a), None

    if remat:
        body = jax.checkpoint(body)
    (x, aux), _ = stack_scan(cfg, body, (x, aux), params["layers"],
                             cfg.num_layers - n_unrolled)
    return _logits(cfg, params, x), aux


# ----------------------------------------------------------------------
# cache construction
# ----------------------------------------------------------------------
def _attn_cache_sds(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    if cfg.attn_kind == "mla":
        m = cfg.mla
        return {
            "c_kv": jnp.zeros((batch, cache_len, m.kv_lora_rank), dt),
            "k_rope": jnp.zeros((batch, cache_len, m.qk_rope_head_dim),
                                dt),
        }
    kv = cfg.num_kv_heads
    if cfg.kv_quant:
        return {
            "k": jnp.zeros((batch, cache_len, kv, hd), jnp.int8),
            "v": jnp.zeros((batch, cache_len, kv, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, cache_len, kv), jnp.float32),
            "v_scale": jnp.zeros((batch, cache_len, kv), jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, cache_len, kv, hd), dt),
        "v": jnp.zeros((batch, cache_len, kv, hd), dt),
    }


def _ssm_cache(cfg: ModelConfig, batch: int) -> dict:
    d_in, _, n = ssm_mod.ssm_dims(cfg)
    w = cfg.ssm.conv_width
    return {
        "conv": jnp.zeros((batch, w - 1, d_in), jnp.dtype(cfg.dtype)),
        "h": jnp.zeros((batch, d_in, n), jnp.float32),
    }


def _rglru_cache(cfg: ModelConfig, batch: int) -> dict:
    w = cfg.rglru.lru_width or cfg.d_model
    cw = cfg.rglru.conv_width
    return {
        "conv": jnp.zeros((batch, cw - 1, w), jnp.dtype(cfg.dtype)),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def _stack(tree_list):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *tree_list)


def stack_scan(cfg: ModelConfig, body, init, xs, length: int):
    """``lax.scan`` over stacked layer pytrees, or an unrolled python
    loop when ``cfg.scan_layers`` is False (dry-run cost-exact compiles
    — XLA cost analysis counts a while body once)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, init, xs)
    carry = init
    ys = []
    for i in range(length):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    return carry, jax.tree.map(lambda *zs: jnp.stack(zs), *ys)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> Cache:
    """Zero-initialised decode cache for ``seq_len`` total positions."""
    cache_len = _attn_cache_len(cfg, seq_len)
    if cfg.family == "audio":
        e = cfg.encoder
        hd = cfg.resolved_head_dim
        dt = jnp.dtype(cfg.dtype)
        self_c = _stack([_attn_cache_sds(cfg, batch, cache_len)
                         for _ in range(cfg.num_layers)])
        cross = {
            "k": jnp.zeros((cfg.num_layers, batch, e.num_frames,
                            cfg.num_kv_heads, hd), dt),
            "v": jnp.zeros((cfg.num_layers, batch, e.num_frames,
                            cfg.num_kv_heads, hd), dt),
        }
        return {"dec_layers": self_c, "cross": cross}
    if cfg.family == "ssm":
        return {"layers": _stack([_ssm_cache(cfg, batch)
                                  for _ in range(cfg.num_layers)])}
    if cfg.family == "hybrid":
        out: Cache = {}
        for i, kind in enumerate(cfg.layer_kinds):
            if kind == "attn":
                out[f"layer_{i:02d}"] = _attn_cache_sds(
                    cfg, batch, cache_len)
            else:
                out[f"layer_{i:02d}"] = _rglru_cache(cfg, batch)
        return out
    out = {}
    n_unrolled = cfg.moe.first_moe_layer if (
        cfg.moe is not None and cfg.moe.first_moe_layer > 0) else 0
    for i in range(n_unrolled):
        out[f"layer_{i:02d}"] = _attn_cache_sds(cfg, batch, cache_len)
    out["layers"] = _stack(
        [_attn_cache_sds(cfg, batch, cache_len)
         for _ in range(cfg.num_layers - n_unrolled)])
    return out


# ----------------------------------------------------------------------
# prefill
# ----------------------------------------------------------------------
def _attn_prefill_layer(cfg: ModelConfig, lp: dict, x, positions,
                        cache_len: int, moe_shards: int,
                        enc_kv=None):
    """Full-seq layer that also emits its decode cache entry."""
    aux = jnp.zeros((), jnp.float32)
    h = norm_apply(cfg, lp["attn_norm"], x)
    if cfg.attn_kind == "mla":
        c_kv, k_rope = attn.mla_project_kv_latent(cfg, lp["attn"], h)
        k_rope_r = attn.apply_rope(
            k_rope[:, :, None], positions[None], cfg.rope_theta)[:, :, 0]
        a = attn.mla_attention(cfg, lp["attn"], h, positions)
        entry = {"c_kv": _pad_cache(c_kv, cache_len),
                 "k_rope": _pad_cache(k_rope_r, cache_len)}
    else:
        q, k, v = attn.gqa_project_qkv(cfg, lp["attn"], h)
        if cfg.use_rope:
            q = attn.apply_rope(q, positions[None], cfg.rope_theta)
            k = attn.apply_rope(k, positions[None], cfg.rope_theta)
        o = attn.flash_attention(q, k, v, positions, positions,
                                 causal=True, window=cfg.window)
        b, s = x.shape[:2]
        o = o.reshape(b, s, cfg.num_heads * cfg.resolved_head_dim)
        a = jnp.einsum("bsh,hd->bsd", o, lp["attn"]["wo"])
        if cfg.kv_quant:
            kq, ks = attn.quantize_kv(k)
            vq, vs = attn.quantize_kv(v)
            pack = ring_compress if cfg.window is not None \
                else _pad_cache
            entry = {"k": pack(kq, cache_len),
                     "v": pack(vq, cache_len),
                     "k_scale": pack(ks, cache_len),
                     "v_scale": pack(vs, cache_len)}
        elif cfg.window is not None:
            entry = {"k": ring_compress(k, cache_len),
                     "v": ring_compress(v, cache_len)}
        else:
            entry = {"k": _pad_cache(k, cache_len),
                     "v": _pad_cache(v, cache_len)}
    x = x + a
    if enc_kv is not None:
        h = norm_apply(cfg, lp["cross_norm"], x)
        x = x + attn.cross_attention(cfg, lp["cross"], h, *enc_kv)
    h = norm_apply(cfg, lp["mlp_norm"], x)
    y, aux = mlp_apply(cfg, lp["mlp"], h, moe_shards)
    x = x + y
    return x, entry, aux


def _pad_cache(k: jax.Array, cache_len: int) -> jax.Array:
    s = k.shape[1]
    if s == cache_len:
        return k
    assert s < cache_len
    pad = [(0, 0)] * k.ndim
    pad[1] = (0, cache_len - s)
    return jnp.pad(k, pad)


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
            frontend_embeds: Optional[jax.Array] = None,
            cache_len: Optional[int] = None, moe_shards: int = 1
            ) -> Tuple[jax.Array, Cache]:
    """Process a prompt, returning (last-position logits, decode cache)."""
    b, s = tokens.shape
    if cache_len is None:
        cache_len = s
    a_len = _attn_cache_len(cfg, cache_len)
    positions = jnp.arange(s)

    if cfg.family == "audio":
        enc_out = _encode(cfg, params, frontend_embeds)
        x = embed_tokens(params["embedding"], tokens)
        x = x + _dec_pos(cfg, params, positions).astype(x.dtype)[None]

        def body(x, lp):
            kv = attn.cross_kv(cfg, lp["cross"], enc_out)
            x, entry, _ = _attn_prefill_layer(cfg, lp, x, positions,
                                              a_len, moe_shards,
                                              enc_kv=kv)
            return x, (entry, {"k": kv[0], "v": kv[1]})

        x, (self_c, cross_c) = stack_scan(cfg, body, x,
                                          params["dec_layers"],
                                          cfg.num_layers)
        logits = _logits(cfg, params, x[:, -1])
        return logits, {"dec_layers": self_c, "cross": cross_c}

    x = _embed_inputs(cfg, params, tokens, frontend_embeds)

    if cfg.family == "ssm":
        def body(x, lp):
            h = norm_apply(cfg, lp["norm"], x)
            y, st = ssm_mod.mamba_prefill(cfg, lp["ssm"], h)
            return x + y, st

        x, states = stack_scan(cfg, body, x, params["layers"],
                               cfg.num_layers)
        return _logits(cfg, params, x[:, -1]), {"layers": states}

    if cfg.family == "hybrid":
        cache: Cache = {}
        for i, kind in enumerate(cfg.layer_kinds):
            lp = params[f"layer_{i:02d}"]
            if kind == "attn":
                x, entry, _ = _attn_prefill_layer(cfg, lp, x, positions,
                                                  a_len, moe_shards)
                cache[f"layer_{i:02d}"] = entry
            else:
                h = norm_apply(cfg, lp["mix_norm"], x)
                y, st = rglru_mod.rglru_prefill(cfg, lp["rglru"], h)
                x = x + y
                h = norm_apply(cfg, lp["mlp_norm"], x)
                y, _ = mlp_apply(cfg, lp["mlp"], h, moe_shards)
                x = x + y
                cache[f"layer_{i:02d}"] = st
        return _logits(cfg, params, x[:, -1]), cache

    cache = {}
    n_unrolled = cfg.moe.first_moe_layer if (
        cfg.moe is not None and cfg.moe.first_moe_layer > 0) else 0
    for i in range(n_unrolled):
        x, entry, _ = _attn_prefill_layer(
            cfg, params[f"layer_{i:02d}"], x, positions, a_len,
            moe_shards)
        cache[f"layer_{i:02d}"] = entry

    def body(x, lp):
        x, entry, _ = _attn_prefill_layer(cfg, lp, x, positions, a_len,
                                          moe_shards)
        return x, entry

    x, entries = stack_scan(cfg, body, x, params["layers"],
                            cfg.num_layers - n_unrolled)
    cache["layers"] = entries
    return _logits(cfg, params, x[:, -1]), cache


# ----------------------------------------------------------------------
# paged KV-cache path (serving/kv_pool.py page pool + block tables)
# ----------------------------------------------------------------------
def resolve_layout(cfg: ModelConfig) -> Optional[str]:
    """Page-pool layout descriptor for ``cfg``, or None when only the
    dense (contiguous-cache) path can serve it.

    - ``"dense"``: bf16 K/V pages, linear cache, COW tail pages.
    - ``"quant"``: int8 code pages + per-vector f32 scale planes
      (``attn.quantize_kv``) — same page/COW geometry as dense at
      roughly half the bytes per position; bit-identical to the quant
      *dense* cache, not to bf16.
    - ``"ring"``: sliding-window layers (``cfg.window``) wrap their
      pages in place, capping pages-per-row at ceil(window/page).
    - ``"lanes"``: fixed-size recurrent-state lanes for SSM members —
      one lane holds a row's conv taps + SSM state, no growth with
      sequence length.

    A uniform GQA stack is required for the kv layouts; MoE configs
    qualify only with the capacity-free ``MoEConfig.impl == "gather"``
    dispatch (per-token expert math — batch-composition invariant,
    which the bucketed prefill relies on; the capacity path cumsums
    across rows) and a uniform stack (``first_moe_layer == 0`` — the
    paged bodies scan ``params["layers"]`` alone). Hybrid stacks
    (recurrentgemma: rglru + SWA layers interleaved) stay on the dense
    fallback — a per-block ring+lane mix is a ROADMAP follow-up.
    """
    if cfg.family == "ssm":
        return "lanes"
    moe_ok = cfg.moe is None or (cfg.moe.impl == "gather"
                                 and cfg.moe.first_moe_layer == 0)
    if not (cfg.family in ("dense", "moe") and cfg.attn_kind == "gqa"
            and moe_ok and cfg.frontend is None):
        return None
    if cfg.kv_quant:
        # quantised sliding-window caches would need ring scale planes
        # too; nothing in the zoo combines them — keep it dense
        return "quant" if cfg.window is None else None
    if cfg.window is not None:
        return "ring"
    return "dense"


def paged_supported(cfg: ModelConfig) -> bool:
    """True when some page layout serves the config bit-identically to
    its dense reference path (see ``resolve_layout``)."""
    return resolve_layout(cfg) is not None


def prefill_paged(cfg: ModelConfig, params: dict, tokens: jax.Array,
                  pages: Dict[str, jax.Array],
                  prefill_table: jax.Array, moe_shards: int = 1, *,
                  cache_len: Optional[int] = None
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Prompt prefill that scatters each layer's K/V into pool pages.

    tokens: (B, S); pages: the pool's page pytree — every leaf has
    leading axes (L, P, ...): dense holds {k, v} bf16
    (L, P, page_size, KV, Dh); quant adds int8 codes plus
    {k_scale, v_scale} f32 (L, P, page_size, KV) planes; ring is the
    dense leaf set over ceil(window/page) pages per row.
    prefill_table: (B, NBp) int32 page ids covering the row's prompt
    pages (rows must not alias writable pages); cache_len: the
    dense-equivalent total cache length (prompt + max_new) — required
    for ring layouts, where the pages hold the min(cache_len, window)
    ring snapshot. Returns (last-position logits, updated pages). The
    hidden-state math is the dense ``prefill`` bit-for-bit — only the
    cache packing differs; quant packing runs the same
    ``attn.quantize_kv`` the dense quant cache does, so codes and
    scales match that path bit-for-bit.
    """
    layout = resolve_layout(cfg)
    assert layout in ("dense", "quant", "ring"), cfg.name
    b, s = tokens.shape
    ps = pages["k"].shape[2]
    nbp = prefill_table.shape[1]
    positions = jnp.arange(s)
    x = _embed_inputs(cfg, params, tokens, None)

    def body(x, lp):
        h = norm_apply(cfg, lp["attn_norm"], x)
        q, k, v = attn.gqa_project_qkv(cfg, lp["attn"], h)
        if cfg.use_rope:
            q = attn.apply_rope(q, positions[None], cfg.rope_theta)
            k = attn.apply_rope(k, positions[None], cfg.rope_theta)
        o = attn.flash_attention(q, k, v, positions, positions,
                                 causal=True, window=cfg.window)
        o = o.reshape(b, s, cfg.num_heads * cfg.resolved_head_dim)
        # tensor parallelism: gather head-local attention outputs to
        # the full head axis before the replicated output projection
        o = tp_all_gather(o)
        x = x + jnp.einsum("bsh,hd->bsd", o, lp["attn"]["wo"])
        h = norm_apply(cfg, lp["mlp_norm"], x)
        y, _ = mlp_apply(cfg, lp["mlp"], h, moe_shards)
        return x + y, (k, v)

    x, (ks, vs) = stack_scan(cfg, body, x, params["layers"],
                             cfg.num_layers)
    logits = _logits(cfg, params, x[:, -1])

    if layout == "ring":
        # compress to the ring snapshot the dense path stores: slot =
        # absolute position mod cache_len over the surviving window
        cl = _attn_cache_len(cfg, s if cache_len is None else cache_len)
        ks = jax.vmap(lambda a: ring_compress(a, cl))(ks)
        vs = jax.vmap(lambda a: ring_compress(a, cl))(vs)

    entry = {"k": ks, "v": vs}
    if layout == "quant":
        kq, ksc = attn.quantize_kv(ks)
        vq, vsc = attn.quantize_kv(vs)
        entry = {"k": kq, "v": vq, "k_scale": ksc, "v_scale": vsc}

    # pack (L, B, S', ...) into pages: pad S' to the page boundary and
    # scatter page-shaped chunks at the block-table ids (pad chunks land
    # in the partial tail page's dead slots, matching the dense cache's
    # zero padding)
    s_pad = nbp * ps

    def pack(a):
        if s_pad != a.shape[2]:
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, s_pad - a.shape[2])
            a = jnp.pad(a, pad)
        return a.reshape((cfg.num_layers, b, nbp, ps) + a.shape[3:])

    pages = {name: pages[name].at[:, prefill_table].set(
                 pack(entry[name]).astype(pages[name].dtype))
             for name in pages}
    return logits, pages


def prefill_chunk_paged(cfg: ModelConfig, params: dict,
                        tokens: jax.Array,
                        pages: Dict[str, jax.Array],
                        block_table: jax.Array,
                        start_pos: jax.Array, *, prompt_len: int,
                        moe_shards: int = 1
                        ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One chunk of a paged prompt prefill (dense layout only).

    tokens: (B, C) — each row's prompt slice covering absolute
    positions [start_pos[b], start_pos[b] + C); start_pos: (B,) int32
    per-row offsets (traced — rows at different prefill depths share
    one compiled program); block_table: (B, NB) page ids covering at
    least ``prompt_len`` positions, with every chunk before a row's
    ``start_pos`` already written by earlier calls. Returns
    (last-chunk-position logits (B, V), k_pages, v_pages).

    Bit-equivalence contract: running chunks [0,C), [C,2C), ... [.., S)
    through this function yields K/V pages and final-position logits
    bit-identical to one ``prefill_paged`` call over the whole prompt.
    Per-token math (embedding, norms, MLP, output head) is position
    independent; attention reads the prefix from the same pages the
    one-shot path writes and always reduces over the full static
    ``prompt_len`` key axis (see ``attn.gqa_prefill_chunk_paged``), so
    no floating-point reduction regroups across chunk boundaries.

    Only the dense layout chunks: a quant chunk would attend the
    already-quantised int8 prefix where the dense quant reference
    attends full precision (quantisation only happens *into* the
    cache); a ring chunk overwrites positions the later chunks still
    attend; a lane prefill is one sequential scan. Those layouts
    prefill one-shot (``prefill_paged`` / the sampler's lane prefill).
    """
    assert resolve_layout(cfg) == "dense", cfg.name
    # the one-shot path switches to blockwise online softmax exactly
    # when prompt_len is a multiple of the flash block (attention.py
    # flash_attention); chunked prefill keeps the plain masked softmax
    # and would silently drift by ulps there — fail loudly instead
    assert (prompt_len <= attn._FLASH_BLOCK
            or prompt_len % attn._FLASH_BLOCK != 0), (
        f"chunked prefill is bit-exact only off the flash-block grid "
        f"(prompt_len={prompt_len} is a multiple of "
        f"{attn._FLASH_BLOCK}); use one-shot prefill_paged")
    b, c = tokens.shape
    x = _embed_inputs(cfg, params, tokens, None)

    def body(x, xs):
        lp, pg = xs
        h = norm_apply(cfg, lp["attn_norm"], x)
        a, kp, vp = attn.gqa_prefill_chunk_paged(
            cfg, lp["attn"], h, pg["k"], pg["v"], block_table,
            start_pos, prompt_len=prompt_len)
        x = x + a
        h = norm_apply(cfg, lp["mlp_norm"], x)
        y, _ = mlp_apply(cfg, lp["mlp"], h, moe_shards)
        return x + y, {"k": kp, "v": vp}

    x, pages = stack_scan(
        cfg, body, x, (params["layers"], pages), cfg.num_layers)
    return _logits(cfg, params, x[:, -1]), pages


def decode_step_paged(cfg: ModelConfig, params: dict,
                      pages: Dict[str, jax.Array],
                      block_table: jax.Array, token: jax.Array,
                      pos: jax.Array, *, cache_len: int
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step over the paged state, dispatching on the
    config's layout. token: (B,) int32; pos: scalar int32, or (B,)
    int32 per-row positions (the step-level loop advances mixed
    batches whose rows sit at different depths); cache_len: static
    dense-equivalent cache length (prompt + max_new — ring layouts cap
    it at the window internally; lanes ignore it and ``pos``: the
    recurrent state is position free).

    Dense/quant write each layer's K/V (codes + scales) at ``pos``
    into the row's block-table page; ring writes at
    ``pos mod min(cache_len, window)``; lanes gather each row's
    recurrent state at its block-table lane id, run the SSM step, and
    scatter the new state back. Returns (logits, updated pages)."""
    layout = resolve_layout(cfg)
    assert layout is not None, cfg.name
    x = jnp.take(params["embedding"], token, axis=0)
    x = shard(x, "batch", "embed")

    if layout == "lanes":
        lanes = block_table[:, 0]

        def lane_body(x, xs):
            lp, pg = xs
            h = norm_apply(cfg, lp["norm"], x)
            st = jax.tree.map(lambda a: a[lanes], pg)
            y, new_st = ssm_mod.mamba_step(cfg, lp["ssm"], h, st)
            # lane arena dtypes equal the state dtypes mamba emits
            # (conv: cfg.dtype taps, h: f32), so the scatter is a pure
            # copy — the gathered state round-trips bit-exactly
            pg = jax.tree.map(lambda a, ns: a.at[lanes].set(ns),
                              pg, new_st)
            return x + y, pg

        x, pages = stack_scan(cfg, lane_body, x,
                              (params["layers"], pages),
                              cfg.num_layers)
        return _logits(cfg, params, x), pages

    def body(x, xs):
        lp, pg = xs
        h = norm_apply(cfg, lp["attn_norm"], x)
        if layout == "quant":
            a, pg = attn.gqa_decode_quant_paged(
                cfg, lp["attn"], h, pg, block_table, pos,
                cache_len=cache_len)
        elif layout == "ring":
            a, pg = attn.gqa_decode_ring_paged(
                cfg, lp["attn"], h, pg, block_table, pos,
                cache_len=min(cache_len, cfg.window))
        else:
            a, kp, vp = attn.gqa_decode_paged(
                cfg, lp["attn"], h, pg["k"], pg["v"], block_table,
                pos, cache_len=cache_len)
            pg = {"k": kp, "v": vp}
        x = x + a
        h = norm_apply(cfg, lp["mlp_norm"], x)
        x = x + mlp_apply_token(cfg, lp["mlp"], h)
        return x, pg

    x, pages = stack_scan(cfg, body, x, (params["layers"], pages),
                          cfg.num_layers)
    return _logits(cfg, params, x), pages


# ----------------------------------------------------------------------
# decode
# ----------------------------------------------------------------------
def _attn_decode_layer(cfg: ModelConfig, lp: dict, x_t, cache_l, pos,
                       cross=None):
    h = norm_apply(cfg, lp["attn_norm"], x_t)
    if cfg.attn_kind == "mla":
        a, new_c = attn.mla_decode(cfg, lp["attn"], h, cache_l, pos)
    else:
        a, new_c = attn.gqa_decode(cfg, lp["attn"], h, cache_l, pos,
                                   ring=cfg.window is not None)
    x_t = x_t + a
    if cross is not None:
        h = norm_apply(cfg, lp["cross_norm"], x_t)
        x_t = x_t + attn.cross_attention(cfg, lp["cross"], h,
                                         cross["k"], cross["v"])
    h = norm_apply(cfg, lp["mlp_norm"], x_t)
    x_t = x_t + mlp_apply_token(cfg, lp["mlp"], h)
    return x_t, new_c


def decode_step(cfg: ModelConfig, params: dict, cache: Cache,
                token: jax.Array, pos: jax.Array
                ) -> Tuple[jax.Array, Cache]:
    """One decode step. token: (B,) int32; pos: scalar int32.

    Writes KV/state at ``pos`` and returns logits for position pos+1.
    """
    x = jnp.take(params["embedding"], token, axis=0)   # (B, d)
    x = shard(x, "batch", "embed")

    if cfg.family == "audio":
        x = x + _dec_pos(cfg, params,
                         jnp.atleast_1d(pos))[0].astype(
            x.dtype)[None]

        def body(x, xs):
            lp, cache_l, cross_l = xs
            x, new_c = _attn_decode_layer(cfg, lp, x, cache_l, pos,
                                          cross=cross_l)
            return x, new_c

        x, new_self = stack_scan(
            cfg, body, x, (params["dec_layers"], cache["dec_layers"],
                           cache["cross"]), cfg.num_layers)
        logits = _logits(cfg, params, x)
        return logits, {"dec_layers": new_self, "cross": cache["cross"]}

    if cfg.family == "ssm":
        def body(x, xs):
            lp, st = xs
            h = norm_apply(cfg, lp["norm"], x)
            y, new_st = ssm_mod.mamba_step(cfg, lp["ssm"], h, st)
            return x + y, new_st

        x, new_states = stack_scan(
            cfg, body, x, (params["layers"], cache["layers"]),
            cfg.num_layers)
        return _logits(cfg, params, x), {"layers": new_states}

    if cfg.family == "hybrid":
        new_cache: Cache = {}
        for i, kind in enumerate(cfg.layer_kinds):
            lp = params[f"layer_{i:02d}"]
            cl = cache[f"layer_{i:02d}"]
            if kind == "attn":
                x, new_cache[f"layer_{i:02d}"] = _attn_decode_layer(
                    cfg, lp, x, cl, pos)
            else:
                h = norm_apply(cfg, lp["mix_norm"], x)
                y, st = rglru_mod.rglru_block_step(cfg, lp["rglru"], h,
                                                   cl)
                x = x + y
                h = norm_apply(cfg, lp["mlp_norm"], x)
                x = x + mlp_apply_token(cfg, lp["mlp"], h)
                new_cache[f"layer_{i:02d}"] = st
        return _logits(cfg, params, x), new_cache

    new_cache = {}
    n_unrolled = cfg.moe.first_moe_layer if (
        cfg.moe is not None and cfg.moe.first_moe_layer > 0) else 0
    for i in range(n_unrolled):
        x, new_cache[f"layer_{i:02d}"] = _attn_decode_layer(
            cfg, params[f"layer_{i:02d}"], x, cache[f"layer_{i:02d}"],
            pos)

    def body(x, xs):
        lp, cache_l = xs
        x, new_c = _attn_decode_layer(cfg, lp, x, cache_l, pos)
        return x, new_c

    x, entries = stack_scan(cfg, body, x, (params["layers"],
                                           cache["layers"]),
                            cfg.num_layers - n_unrolled)
    new_cache["layers"] = entries
    return _logits(cfg, params, x), new_cache
