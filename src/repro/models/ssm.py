"""Mamba-1 selective state-space block.

TPU adaptation (DESIGN.md §3): the CUDA selective-scan kernel is
re-derived as a *two-level* scan —
  outer: sequential ``lax.scan`` over chunks (bounded memory),
  inner: ``lax.associative_scan`` within a chunk (log-depth parallel
         prefix, maps onto the VPU instead of warp shuffles).
Only one chunk's (B, c, d_inner, N) decay/update tensors are live at a
time; d_inner is sharded over the model axis.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import causal_conv1d, causal_conv1d_step
from repro.sharding import shard


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    s = cfg.ssm
    assert s is not None
    d_in = s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return d_in, dt_rank, s.state_dim


def selective_scan(x, dt, a_log, b_in, c_in, h0=None, chunk: int = 256):
    """Chunked selective scan.

    x, dt: (B, S, D); a_log: (D, N); b_in, c_in: (B, S, N).
    h0: optional (B, D, N) initial state.
    Returns y (B, S, D), h_final (B, D, N), all f32 math.
    """
    bsz, s, d = x.shape
    n = a_log.shape[1]
    if s % chunk != 0:
        chunk = s
    nc = s // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))               # (D,N), < 0

    def to_chunks(t):
        return t.reshape(bsz, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xs = (to_chunks(x.astype(jnp.float32)),
          to_chunks(dt.astype(jnp.float32)),
          to_chunks(b_in.astype(jnp.float32)),
          to_chunks(c_in.astype(jnp.float32)))

    if h0 is None:
        h0 = jnp.zeros((bsz, d, n), jnp.float32)

    def comb(left, right):
        al, ul = left
        ar, ur = right
        return al * ar, ul * ar + ur

    def body(h, xc):
        xb, dtb, bb, cb = xc                              # (B,c,D),(B,c,D),(B,c,N)
        dta = jnp.exp(dtb[..., None] * a)                 # (B,c,D,N) decay
        u = (dtb * xb)[..., None] * bb[:, :, None, :]     # (B,c,D,N)
        a_s, u_s = jax.lax.associative_scan(comb, (dta, u), axis=1)
        hs = a_s * h[:, None] + u_s                       # (B,c,D,N)
        y = jnp.einsum("bcdn,bcn->bcd", hs, cb)
        return hs[:, -1], y

    from repro.models.scan_flags import scan_unroll_arg
    h_final, ys = jax.lax.scan(body, h0, xs, unroll=scan_unroll_arg())
    y = ys.swapaxes(0, 1).reshape(bsz, s, d)
    return y, h_final


def selective_scan_step(x_t, dt_t, a_log, b_t, c_t, h):
    """One decode step. x_t, dt_t: (B, D); b_t, c_t: (B, N); h: (B, D, N)."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    dta = jnp.exp(dt_t.astype(jnp.float32)[..., None] * a)       # (B,D,N)
    u = (dt_t * x_t).astype(jnp.float32)[..., None] * \
        b_t.astype(jnp.float32)[:, None, :]
    h_new = dta * h + u
    y = jnp.einsum("bdn,bn->bd", h_new, c_t.astype(jnp.float32))
    return y, h_new


def _proj_inputs(cfg: ModelConfig, p: dict, xc):
    """Shared dt/B/C projection from the conv output."""
    d_in, dt_rank, n = ssm_dims(cfg)
    xdb = jnp.einsum("...d,dr->...r", xc, p["w_xproj"])
    dt_low = xdb[..., :dt_rank]
    b_in = xdb[..., dt_rank:dt_rank + n]
    c_in = xdb[..., dt_rank + n:]
    dt = jax.nn.softplus(
        jnp.einsum("...r,rd->...d", dt_low, p["w_dt"]).astype(jnp.float32)
        + p["b_dt"].astype(jnp.float32))
    return dt, b_in, c_in


def mamba_block(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    """Full-sequence mamba block. x: (B, S, d_model)."""
    d_in, _, _ = ssm_dims(cfg)
    xb = jnp.einsum("bsd,dk->bsk", x, p["w_in_x"])
    z = jnp.einsum("bsd,dk->bsk", x, p["w_in_z"])
    xb = shard(xb, "batch", "seq", "d_inner")
    z = shard(z, "batch", "seq", "d_inner")
    xc = causal_conv1d(xb, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    dt, b_in, c_in = _proj_inputs(cfg, p, xc)
    if cfg.use_pallas:
        # TPU deployment: chunked selective-scan Pallas kernel
        # (jnp-oracle fallback off-TPU keeps CPU paths exact).
        from repro.kernels import ops
        y, _ = ops.selective_scan(xc, dt, p["a_log"], b_in, c_in,
                                  chunk=cfg.ssm.chunk)
        y = y.astype(jnp.float32)
    else:
        y, _ = selective_scan(xc, dt, p["a_log"], b_in, c_in,
                              chunk=cfg.ssm.chunk)
    y = y + xc.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y.astype(x.dtype)
    return jnp.einsum("bsk,kd->bsd", y, p["w_out"])


def mamba_prefill(cfg: ModelConfig, p: dict, x: jax.Array):
    """Like mamba_block but also returns decode state {conv, h}."""
    d_in, _, _ = ssm_dims(cfg)
    width = cfg.ssm.conv_width
    xb = jnp.einsum("bsd,dk->bsk", x, p["w_in_x"])
    z = jnp.einsum("bsd,dk->bsk", x, p["w_in_z"])
    xc = causal_conv1d(xb, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    dt, b_in, c_in = _proj_inputs(cfg, p, xc)
    y, h = selective_scan(xc, dt, p["a_log"], b_in, c_in,
                          chunk=cfg.ssm.chunk)
    y = y + xc.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bsk,kd->bsd", y.astype(x.dtype), p["w_out"])
    conv_state = xb[:, -(width - 1):, :]                  # pre-activation taps
    return out, {"conv": conv_state, "h": h}


def mamba_step(cfg: ModelConfig, p: dict, x_t: jax.Array, state: dict):
    """One decode step. x_t: (B, d_model); state {conv, h}."""
    xb = jnp.einsum("bd,dk->bk", x_t, p["w_in_x"])
    z = jnp.einsum("bd,dk->bk", x_t, p["w_in_z"])
    xc, conv_state = causal_conv1d_step(xb, state["conv"], p["conv_w"],
                                        p["conv_b"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x_t.dtype)
    dt, b_t, c_t = _proj_inputs(cfg, p, xc)
    y, h = selective_scan_step(xc, dt, p["a_log"], b_t, c_t, state["h"])
    y = y + xc.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bk,kd->bd", y.astype(x_t.dtype), p["w_out"])
    return out, {"conv": conv_state, "h": h}
