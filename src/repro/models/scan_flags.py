"""Scan lowering flags for cost-exact dry-run compiles.

XLA's HloCostAnalysis counts a ``while`` body ONCE regardless of trip
count, so any ``lax.scan`` (layer stacks, flash KV-block loop, the SSM
chunk loop) under-reports FLOPs/bytes in ``compiled.cost_analysis()``.
The deployed program keeps the scans (bounded HLO, fast compiles); the
dry-run additionally compiles small *unrolled* variants under
``unrolled_costs()`` and extrapolates exact per-layer costs
(launch/dryrun.py).
"""
from __future__ import annotations

import contextlib
import threading


class _Flags(threading.local):
    def __init__(self):
        self.unroll = False


_FLAGS = _Flags()


def cost_unroll() -> bool:
    """True while lowering for cost analysis — scans fully unroll."""
    return _FLAGS.unroll


@contextlib.contextmanager
def unrolled_costs():
    prev = _FLAGS.unroll
    _FLAGS.unroll = True
    try:
        yield
    finally:
        _FLAGS.unroll = prev


def scan_unroll_arg():
    """Value for lax.scan(..., unroll=...) honoring the flag."""
    return True if _FLAGS.unroll else 1
