"""Mixture-of-experts FFN with capacity-based local dispatch.

TPU adaptation (DESIGN.md §3): tokens are reshaped to
(moe_shards, tokens_per_shard, d) with the leading dim mapped to the
"data" mesh axis, so the cumsum/scatter dispatch is *per-data-shard
local* under pjit (no cross-shard prefix sums). Expert FFN weights are
tensor-sharded over the model axis ("tp" impl: zero all-to-all; the
partial sums over d_ff reduce with the usual psum XLA inserts).

An expert-parallel ("ep") variant — experts sharded over the model axis
with shard_map + all-to-all — is provided for the perf study.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.blocking import blocked_rows
from repro.sharding import shard, tp_active, tp_all_gather


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def moe_capacity(mcfg: MoEConfig, tokens_per_shard: int) -> int:
    cap = int(mcfg.top_k * tokens_per_shard / mcfg.num_experts
              * mcfg.capacity_factor)
    return max(_round_up(max(cap, 1), 8), 8)


def router_topk(probs: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """probs: (..., E) -> (gates (..., k), idx (..., k)); gates renormed."""
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(axis=-1, keepdims=True), 1e-9)
    return gates, idx


def load_balance_aux(probs: jax.Array, idx: jax.Array,
                     num_experts: int) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * P_e (f over top-1 choice)."""
    p_mean = probs.reshape(-1, num_experts).mean(axis=0)
    top1 = idx[..., 0].reshape(-1)
    f = jnp.bincount(top1, length=num_experts) / top1.shape[0]
    return num_experts * jnp.sum(f * p_mean)


def moe_ffn(cfg: ModelConfig, p: dict, x: jax.Array,
            moe_shards: int = 1) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y (B, S, d), aux_loss scalar)."""
    mcfg = cfg.moe
    assert mcfg is not None
    b, s, d = x.shape
    t = b * s
    g = moe_shards if t % moe_shards == 0 else 1
    tl = t // g
    e, k = mcfg.num_experts, mcfg.top_k
    cap = moe_capacity(mcfg, tl)

    xs = x.reshape(g, tl, d)
    xs = shard(xs, "batch", None, "embed")

    logits = jnp.einsum("gtd,de->gte", xs, p["router"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = router_topk(probs, k)                   # (g,tl,k)
    aux = load_balance_aux(probs, eidx, e)

    # position of each (token, choice) within its expert, per shard
    eflat = eidx.reshape(g, tl * k)
    onehot = jax.nn.one_hot(eflat, e, dtype=jnp.int32)    # (g,tl*k,E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot
    pos = jnp.take_along_axis(
        pos_in_e, eflat[..., None], axis=-1)[..., 0]      # (g,tl*k)
    keep = pos < cap
    slot = jnp.where(keep, eflat * cap + pos, e * cap)    # overflow -> sink

    xrep = jnp.broadcast_to(xs[:, :, None, :], (g, tl, k, d)).reshape(
        g, tl * k, d)

    def dispatch(slot_g, xrep_g):
        buf = jnp.zeros((e * cap + 1, d), xs.dtype)
        return buf.at[slot_g].add(xrep_g)

    buf = jax.vmap(dispatch)(slot, xrep)[:, :e * cap]     # (g,E*cap,d)
    ebuf = buf.reshape(g, e, cap, d)
    ebuf = shard(ebuf, "batch", "experts", None, "embed")

    # expert SwiGLU, d_ff sharded over model axis under the "tp" impl
    gate_h = jnp.einsum("gecd,edf->gecf", ebuf, p["w_gate"])
    up_h = jnp.einsum("gecd,edf->gecf", ebuf, p["w_up"])
    gate_h = shard(gate_h, "batch", "experts", None, "expert_ff")
    up_h = shard(up_h, "batch", "experts", None, "expert_ff")
    h = jax.nn.silu(gate_h.astype(jnp.float32)).astype(x.dtype) * up_h
    out = jnp.einsum("gecf,efd->gecd", h, p["w_down"])    # psum over ff

    oflat = out.reshape(g, e * cap, d)
    oflat = jnp.concatenate(
        [oflat, jnp.zeros((g, 1, d), out.dtype)], axis=1)  # sink row

    def combine(slot_g, oflat_g):
        return oflat_g[slot_g]

    yrep = jax.vmap(combine)(slot, oflat)                 # (g,tl*k,d)
    w = (gates.reshape(g, tl * k) * keep).astype(x.dtype)
    y = (yrep * w[..., None]).reshape(g, tl, k, d).sum(axis=2)
    y = y.reshape(b, s, d)

    if mcfg.num_shared_experts:
        sg = jnp.einsum("bsd,df->bsf", x, p["shared_w_gate"])
        su = jnp.einsum("bsd,df->bsf", x, p["shared_w_up"])
        sh = jax.nn.silu(sg.astype(jnp.float32)).astype(x.dtype) * su
        y = y + jnp.einsum("bsf,fd->bsd", sh, p["shared_w_down"])
    return y, aux


def _expert_swiglu(xt: jax.Array, wg: jax.Array, wu: jax.Array,
                   wd: jax.Array) -> jax.Array:
    """One expert's SwiGLU over flattened tokens xt: (T, d).

    Runs over fixed-shape token blocks (``models.blocking``) so each
    token's bits are independent of batch composition — the property
    ``moe_ffn_gather`` promises. Outside a tp context each block
    routes through ``ops.fused_swiglu`` — the Pallas fused kernel on
    TPU, its jnp oracle (bit-identical einsum math) everywhere else.
    Under tensor parallelism w_gate/w_up are column-sharded and w_down
    replicated, so the hidden must be all-gathered to full d_ff before
    the down-projection — the fused kernel's single-device layout
    can't express that, so the unfused (oracle-identical) einsum form
    runs instead."""
    if not tp_active():
        from repro.kernels import ops
        return blocked_rows(
            lambda xb: ops.fused_swiglu(xb, wg, wu, wd), xt)

    def blk(xb: jax.Array) -> jax.Array:
        g = jnp.einsum("td,df->tf", xb, wg)
        u = jnp.einsum("td,df->tf", xb, wu)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xb.dtype) * u
        h = tp_all_gather(h)
        return jnp.einsum("tf,fd->td", h, wd)
    return blocked_rows(blk, xt)


def moe_ffn_gather(cfg: ModelConfig, p: dict, x: jax.Array
                   ) -> Tuple[jax.Array, jax.Array]:
    """Capacity-free top-k MoE for (B, S, d) token batches.

    The capacity path (``moe_ffn``) cumsums dispatch positions across
    every token in the batch, so one row's expert overflow depends on
    which rows share it — the exact coupling that disqualifies MoE
    members from compacted/shared-prefix execution. Here every routed
    expert's SwiGLU runs dense over the flattened tokens
    (``_expert_swiglu`` -> ``ops.fused_swiglu``) and each token
    combines its own top-k experts by gather: no capacity buckets, no
    cross-row cumsum, no token dropping. Per-token outputs are a pure
    function of that token's hidden state, so they are bit-identical
    under any batch composition or row permutation
    (``sampling.batch_invariant`` keys off ``MoEConfig.impl ==
    "gather"``). Compute is E/k-fold denser than dispatch — the price
    of invariance, paid only by configs that opt in.
    """
    mcfg = cfg.moe
    assert mcfg is not None
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    logits = blocked_rows(
        lambda xb: jnp.einsum("td,de->te", xb, p["router"],
                              preferred_element_type=jnp.float32), xt)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = router_topk(probs, mcfg.top_k)          # (T, k)
    aux = load_balance_aux(probs, eidx, mcfg.num_experts)

    ye = jax.lax.map(
        lambda w: _expert_swiglu(xt, w[0], w[1], w[2]),
        (p["w_gate"], p["w_up"], p["w_down"]))            # (E, T, d)
    t = xt.shape[0]
    ysel = ye[eidx, jnp.arange(t)[:, None]]               # (T, k, d)
    y = (ysel * gates[..., None].astype(x.dtype)).sum(axis=1)

    if mcfg.num_shared_experts:
        y = y + _expert_swiglu(xt, p["shared_w_gate"],
                               p["shared_w_up"], p["shared_w_down"])
    return y.reshape(b, s, d), aux


def moe_ffn_token(cfg: ModelConfig, p: dict, x: jax.Array
                  ) -> jax.Array:
    """Decode path: dense-gather MoE for a (B, d) single-token batch.

    At decode the batch is tiny; gathering the top-k expert weights per
    token is cheaper than capacity dispatch.
    """
    mcfg = cfg.moe
    assert mcfg is not None
    logits = jnp.einsum("bd,de->be", x, p["router"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = router_topk(probs, mcfg.top_k)          # (B,k)
    wg = p["w_gate"][eidx]                                # (B,k,d,f)
    wu = p["w_up"][eidx]
    wd = p["w_down"][eidx]                                # (B,k,f,d)
    gh = jnp.einsum("bd,bkdf->bkf", x, wg)
    uh = jnp.einsum("bd,bkdf->bkf", x, wu)
    h = jax.nn.silu(gh.astype(jnp.float32)).astype(x.dtype) * uh
    # tensor parallelism: gathered expert w_gate/w_up slices are
    # column-sharded; gather the hidden to full d_ff_expert before the
    # (replicated, gathered) down-projection contracts it
    h = tp_all_gather(h)
    out = jnp.einsum("bkf,bkfd->bkd", h, wd)
    y = (out * gates[..., None].astype(x.dtype)).sum(axis=1)
    if mcfg.num_shared_experts:
        sg = jnp.einsum("bd,df->bf", x, p["shared_w_gate"])
        su = jnp.einsum("bd,df->bf", x, p["shared_w_up"])
        sh = jax.nn.silu(sg.astype(jnp.float32)).astype(x.dtype) * su
        y = y + jnp.einsum("bf,fd->bd", sh, p["shared_w_down"])
    return y
