"""Modality frontends — STUBS per the assignment.

The audio (mel-spectrogram + conv) and vision (ViT/SigLIP + projector)
encoders are not implemented; ``input_specs()`` provides precomputed
frame/patch embeddings of the right shape. These helpers generate
deterministic synthetic embeddings for smoke tests and examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def audio_frame_shape(cfg: ModelConfig, batch: int):
    e = cfg.encoder
    assert e is not None, "audio frontend requires an encoder config"
    return (batch, e.num_frames, e.d_frontend)


def vision_patch_shape(cfg: ModelConfig, batch: int):
    assert cfg.num_patches > 0, "vision frontend requires num_patches"
    return (batch, cfg.num_patches, cfg.d_model)


def synthetic_frames(cfg: ModelConfig, batch: int, seed: int = 0):
    """Deterministic stand-in for mel+conv output (B, F, d)."""
    shape = audio_frame_shape(cfg, batch)
    return jax.random.normal(jax.random.PRNGKey(seed), shape,
                             jnp.dtype(cfg.dtype)) * 0.02


def synthetic_patches(cfg: ModelConfig, batch: int, seed: int = 0):
    """Deterministic stand-in for ViT+projector output (B, P, d)."""
    shape = vision_patch_shape(cfg, batch)
    return jax.random.normal(jax.random.PRNGKey(seed), shape,
                             jnp.dtype(cfg.dtype)) * 0.02
