"""Attention: GQA/MQA/SWA flash-style prefill + cache decode, and MLA.

TPU adaptation notes (see DESIGN.md §3):
  * training/prefill uses a blockwise online-softmax formulation written
    as ``lax.scan`` over KV blocks, so 32k prefill never materialises the
    (S, S) score matrix;
  * decode attends against a cache whose sharding is decided by the
    partitioning rules (KV-head sharded for kv>=model axis, sequence
    sharded Pope-et-al-style for MQA) — softmax over a sharded axis
    lowers to partial reductions + all-reduce under pjit;
  * sliding-window decode uses a ring buffer of size ``window`` so
    long_500k holds O(window) state, not O(S);
  * MLA decode uses the absorbed formulation: scores and context are
    computed directly against the compressed (kv_lora) cache, never
    expanding per-head K/V for the full history.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope
from repro.sharding import shard, tp_all_gather

_NEG_INF = -1e30
_FLASH_BLOCK = 512


# ======================================================================
# core attention math
# ======================================================================
def _gqa_scores_full(q, k):
    """q: (B,Sq,KV,G,Dk), k: (B,Sk,KV,Dk) -> (B,KV,G,Sq,Sk) f32."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                      preferred_element_type=jnp.float32)


def full_attention(q, k, v, mask) -> jax.Array:
    """Reference path for short sequences.

    q: (B,Sq,H,Dk); k: (B,Sk,KV,Dk); v: (B,Sk,KV,Dv);
    mask: (Sq,Sk) or (B,Sq,Sk) bool (True = attend).
    Returns (B,Sq,H,Dv).
    """
    b, sq, h, dk = q.shape
    kv = k.shape[2]
    g = h // kv
    scale = 1.0 / jnp.sqrt(dk).astype(jnp.float32)
    qr = q.reshape(b, sq, kv, g, dk)
    scores = _gqa_scores_full(qr, k) * scale            # (B,KV,G,Sq,Sk)
    if mask.ndim == 2:
        m = mask[None, None, None]
    else:
        m = mask[:, None, None]
    scores = jnp.where(m, scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, h, v.shape[-1])


def flash_attention(q, k, v, q_positions, k_positions, *,
                    causal: bool = True,
                    window: Optional[int] = None,
                    block: int = _FLASH_BLOCK) -> jax.Array:
    """Blockwise online-softmax attention (pure JAX, lowers everywhere).

    q: (B,Sq,H,Dk); k: (B,Sk,KV,Dk); v: (B,Sk,KV,Dv).
    q_positions: (Sq,) int32; k_positions: (Sk,) int32.
    """
    b, sq, h, dk = q.shape
    sk, kv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // kv

    if sk % block != 0 or sk <= block:
        mask = _make_mask(q_positions, k_positions, causal, window)
        return full_attention(q, k, v, mask)

    nblk = sk // block
    scale = 1.0 / jnp.sqrt(dk).astype(jnp.float32)
    qr = (q.reshape(b, sq, kv, g, dk).astype(jnp.float32) * scale)

    k_blocks = k.reshape(b, nblk, block, kv, dk).swapaxes(0, 1)
    v_blocks = v.reshape(b, nblk, block, kv, dv).swapaxes(0, 1)
    kp_blocks = k_positions.reshape(nblk, block)

    m0 = jnp.full((b, kv, g, sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, kv, g, sq, dv), jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, kp = xs
        s = jnp.einsum("bqkgd,bskd->bkgqs", qr, kb.astype(jnp.float32))
        valid = jnp.ones((sq, block), bool)
        if causal:
            valid &= q_positions[:, None] >= kp[None, :]
        if window is not None:
            valid &= (q_positions[:, None] - kp[None, :]) < window
        s = jnp.where(valid[None, None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    from repro.models.scan_flags import scan_unroll_arg
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0),
                                  (k_blocks, v_blocks, kp_blocks),
                                  unroll=scan_unroll_arg())
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dv)
    return out.astype(q.dtype)


def _make_mask(q_positions, k_positions, causal, window):
    m = jnp.ones((q_positions.shape[0], k_positions.shape[0]), bool)
    if causal:
        m &= q_positions[:, None] >= k_positions[None, :]
    if window is not None:
        m &= (q_positions[:, None] - k_positions[None, :]) < window
    return m


def decode_attention(q, k_cache, v_cache, k_positions, pos) -> jax.Array:
    """Single-token attention against a cache.

    q: (B,H,Dk); k_cache: (B,S,KV,Dk); v_cache: (B,S,KV,Dv);
    k_positions: (S,) or (B,S) int32 — absolute position held in each
    slot (negative = empty; ring pages hold per-row slot contents, so
    the step loop passes the 2-D form); pos: scalar int32 current
    position, or (B,) int32 per-row positions (the step-level serving
    loop decodes mixed batches whose rows sit at different depths;
    per-row masking is the only difference, so each row's output is
    bit-identical to the scalar-pos call at that row's position).
    Returns (B,H,Dv).
    """
    b, h, dk = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    scale = 1.0 / jnp.sqrt(dk).astype(jnp.float32)
    qr = (q.reshape(b, kv, g, dk).astype(jnp.float32)
          * scale).astype(k_cache.dtype)
    # keep the cache in its storage dtype (bf16): the contraction
    # accumulates in f32 via preferred_element_type, so no f32 COPY of
    # the whole cache is ever materialised (2x HBM traffic at 32k+
    # cache lengths — see EXPERIMENTS.md SPerf C2).
    scores = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache,
                        preferred_element_type=jnp.float32)  # (B,KV,G,S)
    kp = k_positions if k_positions.ndim == 2 else k_positions[None]
    p_col = pos[:, None] if jnp.ndim(pos) else pos
    valid = (kp >= 0) & (kp <= p_col)                    # (1|B, S)
    scores = jnp.where(valid[:, None, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs.astype(v_cache.dtype),
                     v_cache, preferred_element_type=jnp.float32)
    return out.reshape(b, h, v_cache.shape[-1]).astype(q.dtype)


# ======================================================================
# int8 KV cache (symmetric per-vector quantization over head_dim)
# ======================================================================
def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (..., D) -> (int8 codes (..., D), f32 scale (...,)).

    Symmetric per-vector quantisation: scale = max|x| / 127 over the
    head dim. Halves cache storage + decode read traffic; the scales
    fold into the attention math (no dequantised cache copy):
        q.k_vec = (q.k_int8) * k_scale_s
        sum_s p_s v_vec_s = sum_s (p_s v_scale_s) v_int8_s
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    # constant-reciprocal multiply, not divide: XLA rewrites /127 to
    # *(1/127) only in some fusion contexts, and the prefill paths
    # quantise in different ones (inside vs outside the layer scan) —
    # the explicit multiply keeps the stored scales bitwise identical
    scale = jnp.maximum(amax, 1e-8) * (1.0 / 127.0)
    codes = jnp.clip(jnp.round(xf / scale[..., None]),
                     -127, 127).astype(jnp.int8)
    return codes, scale


def decode_attention_quant(q, k_codes, k_scale, v_codes, v_scale,
                           k_positions, pos) -> jax.Array:
    """decode_attention against an int8 cache.

    q: (B,H,Dk); k_codes/v_codes: (B,S,KV,D) int8;
    k_scale/v_scale: (B,S,KV) f32; k_positions: (S,) int32;
    pos: scalar int32, or (B,) int32 per-row positions (step-level
    decode batches mix rows at different depths — per-row masking
    keeps each row bit-identical to the scalar-pos call).
    """
    b, h, dk = q.shape
    kv = k_codes.shape[2]
    g = h // kv
    scale = 1.0 / jnp.sqrt(dk).astype(jnp.float32)
    qr = q.reshape(b, kv, g, dk).astype(jnp.float32) * scale
    scores = jnp.einsum("bkgd,bskd->bkgs", qr, k_codes,
                        preferred_element_type=jnp.float32)
    scores = scores * k_scale.transpose(0, 2, 1)[:, :, None, :]
    p_col = pos[:, None] if jnp.ndim(pos) else pos
    valid = (k_positions[None] >= 0) & (k_positions[None] <= p_col)
    scores = jnp.where(valid[:, None, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    # fold the v scales into the probabilities (linearity)
    pv = probs * v_scale.transpose(0, 2, 1)[:, :, None, :]
    out = jnp.einsum("bkgs,bskd->bkgd", pv, v_codes,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, v_codes.shape[-1]).astype(q.dtype)


# ======================================================================
# GQA layer (projections + rope + attend)
# ======================================================================
def gqa_project_qkv(cfg: ModelConfig, p: dict, x: jax.Array):
    """x: (B,S,d) -> q (B,S,H,Dh), k/v (B,S,KV,Dh)."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(
        b, s, cfg.num_heads, hd)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(
        b, s, cfg.num_kv_heads, hd)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(
        b, s, cfg.num_kv_heads, hd)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def gqa_attention(cfg: ModelConfig, p: dict, x: jax.Array,
                  positions: jax.Array, *, causal: bool = True,
                  window: Optional[int] = None) -> jax.Array:
    """Full-sequence GQA attention (train / prefill). x: (B,S,d)."""
    q, k, v = gqa_project_qkv(cfg, p, x)
    if cfg.use_rope:
        q = apply_rope(q, positions[None], cfg.rope_theta)
        k = apply_rope(k, positions[None], cfg.rope_theta)
    out = flash_attention(q, k, v, positions, positions,
                          causal=causal, window=window)
    b, s = x.shape[:2]
    out = out.reshape(b, s, cfg.num_heads * cfg.resolved_head_dim)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"])


def gqa_decode(cfg: ModelConfig, p: dict, x_t: jax.Array, cache: dict,
               pos: jax.Array, *, ring: bool = False
               ) -> Tuple[jax.Array, dict]:
    """Single-token GQA decode. x_t: (B,d); cache: {k,v}: (B,S,KV,Dh).

    With ``ring=True`` the cache is a ring buffer over its own length
    (slot = pos % cache_len — used for sliding-window layers, where
    cache_len = min(seq_len, window)); otherwise a linear cache.
    """
    b, _ = x_t.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bd,dh->bh", x_t, p["wq"]).reshape(
        b, cfg.num_heads, hd)
    k = jnp.einsum("bd,dh->bh", x_t, p["wk"]).reshape(
        b, cfg.num_kv_heads, hd)
    v = jnp.einsum("bd,dh->bh", x_t, p["wv"]).reshape(
        b, cfg.num_kv_heads, hd)
    if cfg.use_rope:
        pos_b = jnp.broadcast_to(pos, (1, 1))
        q = apply_rope(q[:, None], pos_b, cfg.rope_theta)[:, 0]
        k = apply_rope(k[:, None], pos_b, cfg.rope_theta)[:, 0]

    s_cache = cache["k"].shape[1]
    if ring:
        slot = jnp.mod(pos, s_cache)
        slots = jnp.arange(s_cache)
        # absolute position currently held in each ring slot
        k_positions = pos - jnp.mod(pos - slots, s_cache)
    else:
        slot = pos
        k_positions = jnp.arange(s_cache)

    if cfg.use_pallas and not ring and "k_scale" not in cache:
        # TPU deployment: flash-decode Pallas kernel over the linear
        # cache (valid prefix = pos+1). Ring/quant caches use the jnp
        # paths. ops.decode_attention falls back to the oracle off-TPU.
        from repro.kernels import ops
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k[:, None].astype(cache["k"].dtype),
            (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v[:, None].astype(cache["v"].dtype),
            (0, pos, 0, 0))
        out = ops.decode_attention(q, k_cache, v_cache, pos + 1)
        out = out.reshape(b, cfg.num_heads * hd)
        y = jnp.einsum("bh,hd->bd", out, p["wo"])
        return y, {"k": k_cache, "v": v_cache}

    if "k_scale" in cache:                 # int8-quantised cache
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], kq[:, None], (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], vq[:, None], (0, slot, 0, 0))
        k_scale = jax.lax.dynamic_update_slice(
            cache["k_scale"], ks[:, None].astype(
                cache["k_scale"].dtype), (0, slot, 0))
        v_scale = jax.lax.dynamic_update_slice(
            cache["v_scale"], vs[:, None].astype(
                cache["v_scale"].dtype), (0, slot, 0))
        if cfg.use_pallas and not ring:
            # TPU deployment: int8 flash-decode kernel — scales fold
            # in-kernel, HBM reads stay int8. The op's off-TPU
            # dispatch is the jnp quant path with the same linear
            # k_positions/pos masking, so CPU bits are unchanged.
            from repro.kernels import ops
            out = ops.decode_attention_quant(
                q, k_cache, k_scale, v_cache, v_scale, pos + 1)
        else:
            out = decode_attention_quant(q, k_cache, k_scale, v_cache,
                                         v_scale, k_positions, pos)
        out = out.reshape(b, cfg.num_heads * hd)
        y = jnp.einsum("bh,hd->bd", out, p["wo"])
        return y, {"k": k_cache, "v": v_cache,
                   "k_scale": k_scale, "v_scale": v_scale}

    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k[:, None].astype(cache["k"].dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v[:, None].astype(cache["v"].dtype), (0, slot, 0, 0))
    out = decode_attention(q, k_cache, v_cache, k_positions, pos)
    out = out.reshape(b, cfg.num_heads * hd)
    y = jnp.einsum("bh,hd->bd", out, p["wo"])
    return y, {"k": k_cache, "v": v_cache}


def gqa_decode_paged(cfg: ModelConfig, p: dict, x_t: jax.Array,
                     k_pages: jax.Array, v_pages: jax.Array,
                     block_table: jax.Array, pos: jax.Array, *,
                     cache_len: int
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token GQA decode against a paged KV cache.

    x_t: (B, d); k_pages/v_pages: (P, page_size, KV, Dh) — one layer's
    slice of the page pool; block_table: (B, NB) int32 page ids;
    pos: scalar int32, or (B,) int32 per-row positions (step-level
    serving mixes rows at different depths in one decode batch);
    cache_len: static dense-equivalent cache length (prompt + max_new).

    Bit-equivalence contract: the gathered page view sliced to
    ``cache_len`` feeds the *same* ``decode_attention`` with the same
    shapes and values as the dense path's contiguous cache, so the
    output is bit-identical. Stale bytes in recycled pages sit at
    positions > pos and are masked to the same -1e30 the dense path's
    zero-initialised slots are, before any softmax math runs.
    """
    b, _ = x_t.shape
    hd = cfg.resolved_head_dim
    kv = cfg.num_kv_heads
    q = jnp.einsum("bd,dh->bh", x_t, p["wq"]).reshape(
        b, cfg.num_heads, hd)
    k = jnp.einsum("bd,dh->bh", x_t, p["wk"]).reshape(b, kv, hd)
    v = jnp.einsum("bd,dh->bh", x_t, p["wv"]).reshape(b, kv, hd)
    per_row = jnp.ndim(pos) == 1
    if cfg.use_rope:
        pos_b = pos[:, None] if per_row else jnp.broadcast_to(
            pos, (1, 1))
        q = apply_rope(q[:, None], pos_b, cfg.rope_theta)[:, 0]
        k = apply_rope(k[:, None], pos_b, cfg.rope_theta)[:, 0]

    ps = k_pages.shape[1]
    if per_row:
        page_ids = jnp.take_along_axis(
            block_table, (pos // ps)[:, None], axis=1)[:, 0]  # (B,)
        slot = pos % ps                                       # (B,)
    else:
        page_ids = jnp.take(block_table, pos // ps, axis=1)   # (B,)
        slot = pos % ps
    k_pages = k_pages.at[page_ids, slot].set(
        k.astype(k_pages.dtype))
    v_pages = v_pages.at[page_ids, slot].set(
        v.astype(v_pages.dtype))

    if cfg.use_pallas:
        # TPU deployment: block-table flash-decode kernel reads the
        # pages in place (no gathered copy). Off-TPU the op dispatches
        # to the gather-based oracle.
        from repro.kernels import ops
        lengths = jnp.broadcast_to(pos + 1, (b,)).astype(jnp.int32)
        out = ops.paged_decode_attention(q, k_pages, v_pages,
                                         block_table, lengths)
    else:
        k_cache = k_pages[block_table].reshape(
            b, -1, kv, hd)[:, :cache_len]
        v_cache = v_pages[block_table].reshape(
            b, -1, kv, hd)[:, :cache_len]
        out = decode_attention(q, k_cache, v_cache,
                               jnp.arange(cache_len), pos)
    out = out.reshape(b, cfg.num_heads * hd)
    # under tensor parallelism wq/wk/wv are head-column-sharded (cfg
    # carries the local head counts) and wo is replicated: gather the
    # per-head outputs back to the full head axis before the output
    # projection — attention itself is head-local, so each shard's
    # slice is bit-identical to the same heads on one device
    out = tp_all_gather(out)
    y = jnp.einsum("bh,hd->bd", out, p["wo"])
    return y, k_pages, v_pages


def gqa_decode_quant_paged(cfg: ModelConfig, p: dict, x_t: jax.Array,
                           pages: dict, block_table: jax.Array,
                           pos: jax.Array, *, cache_len: int
                           ) -> Tuple[jax.Array, dict]:
    """Single-token GQA decode against int8-quantised KV pages.

    x_t: (B, d); pages: one layer's slice of the quant pool —
    {"k","v"}: (P, page_size, KV, Dh) int8 codes, {"k_scale",
    "v_scale"}: (P, page_size, KV) f32 per-vector scales;
    block_table: (B, NB) page ids; pos: scalar or (B,) int32;
    cache_len: static dense-equivalent cache length.

    Bit-equivalence contract: identical to the dense *quant* cache
    path (``gqa_decode`` with ``k_scale`` in the cache) — the token's
    K/V quantise through the same ``quantize_kv``, and the gathered
    page view sliced to ``cache_len`` feeds the same
    ``decode_attention_quant``. Stale bytes in recycled pages are
    finite int8 codes x finite f32 scales, masked to the same -1e30
    the dense path's zero-initialised slots are (probabilities exactly
    zero either way).
    """
    b, _ = x_t.shape
    hd = cfg.resolved_head_dim
    kv = cfg.num_kv_heads
    q = jnp.einsum("bd,dh->bh", x_t, p["wq"]).reshape(
        b, cfg.num_heads, hd)
    k = jnp.einsum("bd,dh->bh", x_t, p["wk"]).reshape(b, kv, hd)
    v = jnp.einsum("bd,dh->bh", x_t, p["wv"]).reshape(b, kv, hd)
    per_row = jnp.ndim(pos) == 1
    if cfg.use_rope:
        pos_b = pos[:, None] if per_row else jnp.broadcast_to(
            pos, (1, 1))
        q = apply_rope(q[:, None], pos_b, cfg.rope_theta)[:, 0]
        k = apply_rope(k[:, None], pos_b, cfg.rope_theta)[:, 0]
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)

    ps = pages["k"].shape[1]
    if per_row:
        page_ids = jnp.take_along_axis(
            block_table, (pos // ps)[:, None], axis=1)[:, 0]  # (B,)
        slot = pos % ps
    else:
        page_ids = jnp.take(block_table, pos // ps, axis=1)
        slot = pos % ps
    pages = {
        "k": pages["k"].at[page_ids, slot].set(kq),
        "v": pages["v"].at[page_ids, slot].set(vq),
        "k_scale": pages["k_scale"].at[page_ids, slot].set(
            ks.astype(pages["k_scale"].dtype)),
        "v_scale": pages["v_scale"].at[page_ids, slot].set(
            vs.astype(pages["v_scale"].dtype)),
    }

    if cfg.use_pallas:
        # TPU deployment: block-table int8 flash-decode kernel reads
        # codes + scale planes in place. Off-TPU the op dispatches to
        # the gather-based oracle.
        from repro.kernels import ops
        lengths = jnp.broadcast_to(pos + 1, (b,)).astype(jnp.int32)
        out = ops.paged_decode_attention_quant(
            q, pages["k"], pages["k_scale"], pages["v"],
            pages["v_scale"], block_table, lengths)
    else:
        k_cache = pages["k"][block_table].reshape(
            b, -1, kv, hd)[:, :cache_len]
        v_cache = pages["v"][block_table].reshape(
            b, -1, kv, hd)[:, :cache_len]
        k_scale = pages["k_scale"][block_table].reshape(
            b, -1, kv)[:, :cache_len]
        v_scale = pages["v_scale"][block_table].reshape(
            b, -1, kv)[:, :cache_len]
        out = decode_attention_quant(q, k_cache, k_scale, v_cache,
                                     v_scale, jnp.arange(cache_len),
                                     pos)
    out = out.reshape(b, cfg.num_heads * hd)
    # tensor parallelism: gather head-local outputs before the
    # replicated output projection (see ``gqa_decode_paged``)
    out = tp_all_gather(out)
    y = jnp.einsum("bh,hd->bd", out, p["wo"])
    return y, pages


def gqa_decode_ring_paged(cfg: ModelConfig, p: dict, x_t: jax.Array,
                          pages: dict, block_table: jax.Array,
                          pos: jax.Array, *, cache_len: int
                          ) -> Tuple[jax.Array, dict]:
    """Single-token sliding-window GQA decode against ring pages.

    x_t: (B, d); pages: one layer's {"k","v"} (P, page_size, KV, Dh);
    block_table: (B, NB) page ids covering exactly
    ceil(cache_len/page_size) pages (NB never grows past the window);
    pos: scalar or (B,) int32; cache_len: the ring length —
    min(prompt + max_new, window), already window-capped by the
    caller.

    The pages hold the same ring the dense path keeps (slot = absolute
    position mod cache_len): the token's K/V scatter to each row's
    current slot, and masking uses the absolute position each slot
    currently holds — bit-identical per row to ``gqa_decode`` with
    ``ring=True`` at that row's position. Ring pages are lane-private
    (forked whole at spawn, never COW-shared), so the in-place slot
    write is safe.
    """
    b, _ = x_t.shape
    hd = cfg.resolved_head_dim
    kv = cfg.num_kv_heads
    q = jnp.einsum("bd,dh->bh", x_t, p["wq"]).reshape(
        b, cfg.num_heads, hd)
    k = jnp.einsum("bd,dh->bh", x_t, p["wk"]).reshape(b, kv, hd)
    v = jnp.einsum("bd,dh->bh", x_t, p["wv"]).reshape(b, kv, hd)
    per_row = jnp.ndim(pos) == 1
    if cfg.use_rope:
        pos_b = pos[:, None] if per_row else jnp.broadcast_to(
            pos, (1, 1))
        q = apply_rope(q[:, None], pos_b, cfg.rope_theta)[:, 0]
        k = apply_rope(k[:, None], pos_b, cfg.rope_theta)[:, 0]

    pos_rows = pos if per_row else jnp.broadcast_to(pos, (b,))
    ps = pages["k"].shape[1]
    slot = jnp.mod(pos_rows, cache_len)                   # (B,)
    page_ids = jnp.take_along_axis(
        block_table, (slot // ps)[:, None], axis=1)[:, 0]
    offset = slot % ps
    pages = {
        "k": pages["k"].at[page_ids, offset].set(
            k.astype(pages["k"].dtype)),
        "v": pages["v"].at[page_ids, offset].set(
            v.astype(pages["v"].dtype)),
    }

    k_cache = pages["k"][block_table].reshape(
        b, -1, kv, hd)[:, :cache_len]
    v_cache = pages["v"][block_table].reshape(
        b, -1, kv, hd)[:, :cache_len]
    # absolute position currently held in each ring slot, per row
    slots = jnp.arange(cache_len)[None]                   # (1, CL)
    k_positions = pos_rows[:, None] - jnp.mod(
        pos_rows[:, None] - slots, cache_len)             # (B, CL)
    out = decode_attention(q, k_cache, v_cache, k_positions, pos_rows)
    out = out.reshape(b, cfg.num_heads * hd)
    # tensor parallelism: gather head-local outputs before the
    # replicated output projection (see ``gqa_decode_paged``)
    out = tp_all_gather(out)
    y = jnp.einsum("bh,hd->bd", out, p["wo"])
    return y, pages


def gqa_prefill_chunk_paged(cfg: ModelConfig, p: dict, x: jax.Array,
                            k_pages: jax.Array, v_pages: jax.Array,
                            block_table: jax.Array,
                            start_pos: jax.Array, *, prompt_len: int
                            ) -> Tuple[jax.Array, jax.Array,
                                       jax.Array]:
    """One layer's chunked-prefill GQA attention against paged KV.

    x: (B, C, d) hidden states of each row's chunk covering absolute
    positions [start_pos[b], start_pos[b] + C); start_pos: (B,) int32
    *per-row* chunk offsets — traced, not static, so rows at different
    prefill depths share one compiled program (the step loop batches
    every row needing a chunk this tick into one launch);
    k_pages/v_pages: (P, page_size, KV, Dh) one layer's page-pool
    slice; block_table: (B, NB) page ids covering at least
    ``prompt_len`` positions. Writes the chunk's rope'd K/V into the
    pages, then attends the chunk queries over the gathered page view.
    Returns (y (B, C, d), k_pages, v_pages).

    Bit-equivalence contract: the key axis is always gathered to the
    *full static* ``prompt_len`` — the same reduction length the
    one-shot prefill's attention uses — never to ``start + C``.
    Key-axis reductions (softmax normaliser, the PV contraction) are
    only reproducible when their length matches: padding with masked
    lanes is exact (masked scores are -1e30, their probabilities exact
    zeros), but a *shorter* axis regroups the partial sums and drifts
    by ulps. Slots past a row's ``start + C`` hold finite stale page
    bytes and are causally masked, exactly like the one-shot path
    masks the not-yet-attended suffix. (For ``prompt_len`` an exact
    multiple of the flash block the one-shot path switches to the
    blockwise-softmax kernel; chunked prefill keeps the plain masked
    softmax, so its bit-contract holds for the non-blockwise regime —
    every prompt below ``_FLASH_BLOCK`` tokens.)
    """
    b, c, _ = x.shape
    hd = cfg.resolved_head_dim
    kv = cfg.num_kv_heads
    positions = start_pos[:, None] + jnp.arange(c)[None]   # (B, C)
    q, k, v = gqa_project_qkv(cfg, p, x)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    # scatter the chunk's K/V into the pages first, so attention reads
    # every key (prefix and self) from the same storage the decode
    # steps will — and so the Pallas kernel path needs no concat
    ps = k_pages.shape[1]
    page_ids = jnp.take_along_axis(block_table, positions // ps,
                                   axis=1)                 # (B, C)
    slots = positions % ps
    k_pages = k_pages.at[page_ids, slots].set(
        k.astype(k_pages.dtype))
    v_pages = v_pages.at[page_ids, slots].set(
        v.astype(v_pages.dtype))

    if cfg.use_pallas:
        # TPU deployment: paged chunk-prefill kernel reads the pages in
        # place. Off-TPU the op dispatches to the gather-based oracle.
        from repro.kernels import ops
        out = ops.chunked_prefill_attention(
            q, k_pages, v_pages, block_table, positions,
            prompt_len=prompt_len)
    else:
        nb = block_table.shape[1]
        k_all = k_pages[block_table].reshape(
            b, nb * ps, kv, hd)[:, :prompt_len]
        v_all = v_pages[block_table].reshape(
            b, nb * ps, kv, hd)[:, :prompt_len]
        mask = positions[:, :, None] >= \
            jnp.arange(prompt_len)[None, None]             # (B, C, S)
        out = full_attention(q, k_all, v_all, mask)
    out = out.reshape(b, c, cfg.num_heads * hd)
    # tensor parallelism: gather head-local outputs to the full head
    # axis before the replicated output projection (see
    # ``gqa_decode_paged``)
    out = tp_all_gather(out)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return y, k_pages, v_pages


# ======================================================================
# MLA (DeepSeek-V2 multi-head latent attention)
# ======================================================================
def _mla_dims(cfg: ModelConfig):
    m = cfg.mla
    assert m is not None
    return m.q_lora_rank, m.kv_lora_rank, m.qk_nope_head_dim, \
        m.qk_rope_head_dim, m.v_head_dim


def mla_project_q(cfg: ModelConfig, p: dict, x: jax.Array):
    """x: (..., d) -> q_nope (..., H, nope), q_rope (..., H, rope)."""
    from repro.models.layers import rms_norm
    _, _, nope, rope, _ = _mla_dims(cfg)
    h = cfg.num_heads
    q_a = jnp.einsum("...d,dr->...r", x, p["wq_a"])
    q_a = rms_norm(q_a, p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("...r,rh->...h", q_a, p["wq_b"])
    q = q.reshape(*x.shape[:-1], h, nope + rope)
    return q[..., :nope], q[..., nope:]


def mla_project_kv_latent(cfg: ModelConfig, p: dict, x: jax.Array):
    """x: (..., d) -> c_kv (..., kv_lora) [normed], k_rope (..., rope)."""
    from repro.models.layers import rms_norm
    _, kvl, _, rope, _ = _mla_dims(cfg)
    kv_a = jnp.einsum("...d,dr->...r", x, p["wkv_a"])
    c_kv, k_rope = kv_a[..., :kvl], kv_a[..., kvl:]
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    return c_kv, k_rope


def mla_attention(cfg: ModelConfig, p: dict, x: jax.Array,
                  positions: jax.Array) -> jax.Array:
    """Full-sequence MLA (train / prefill): expand per-head K/V."""
    _, kvl, nope, rope, vdim = _mla_dims(cfg)
    b, s, _ = x.shape
    h = cfg.num_heads
    q_nope, q_rope = mla_project_q(cfg, p, x)
    q_rope = apply_rope(q_rope, positions[None], cfg.rope_theta)
    c_kv, k_rope = mla_project_kv_latent(cfg, p, x)
    k_rope = apply_rope(k_rope[:, :, None], positions[None],
                        cfg.rope_theta)                    # (B,S,1,rope)
    k_nope = jnp.einsum("bsr,rh->bsh", c_kv, p["wk_b"]).reshape(
        b, s, h, nope)
    v = jnp.einsum("bsr,rh->bsh", c_kv, p["wv_b"]).reshape(b, s, h, vdim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, rope))], axis=-1)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "heads", None)
    v = shard(v, "batch", "seq", "heads", None)
    out = flash_attention(q, k, v, positions, positions, causal=True)
    out = out.reshape(b, s, h * vdim)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"])


def mla_decode(cfg: ModelConfig, p: dict, x_t: jax.Array, cache: dict,
               pos: jax.Array) -> Tuple[jax.Array, dict]:
    """Absorbed-form MLA decode against the compressed cache.

    cache: {c_kv: (B,S,kv_lora), k_rope: (B,S,rope)}.
    Scores/context are O(S * kv_lora) per head — per-head K/V for the
    history are never materialised.
    """
    _, kvl, nope, rope, vdim = _mla_dims(cfg)
    b, _ = x_t.shape
    h = cfg.num_heads
    q_nope, q_rope = mla_project_q(cfg, p, x_t)            # (B,H,·)
    pos_b = jnp.broadcast_to(pos, (1, 1))
    q_rope = apply_rope(q_rope[:, None], pos_b, cfg.rope_theta)[:, 0]
    c_kv_t, k_rope_t = mla_project_kv_latent(cfg, p, x_t)  # (B,·)
    k_rope_t = apply_rope(k_rope_t[:, None, None], pos_b,
                          cfg.rope_theta)[:, 0, 0]

    c_cache = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv_t[:, None].astype(cache["c_kv"].dtype),
        (0, pos, 0))
    r_cache = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_t[:, None].astype(cache["k_rope"].dtype),
        (0, pos, 0))

    wk_b = p["wk_b"].reshape(kvl, h, nope)
    wv_b = p["wv_b"].reshape(kvl, h, vdim)
    # absorb W_uk into q: (B,H,kv_lora)
    q_c = jnp.einsum("bhn,khn->bhk", q_nope.astype(jnp.float32),
                     wk_b.astype(jnp.float32))
    scale = 1.0 / jnp.sqrt(jnp.float32(nope + rope))
    s_cache = c_cache.shape[1]
    scores = (jnp.einsum("bhk,bsk->bhs", q_c,
                         c_cache.astype(jnp.float32))
              + jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32),
                           r_cache.astype(jnp.float32))) * scale
    valid = jnp.arange(s_cache) <= pos
    scores = jnp.where(valid[None, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx_c = jnp.einsum("bhs,bsk->bhk", probs,
                       c_cache.astype(jnp.float32))        # (B,H,kv_lora)
    out = jnp.einsum("bhk,khv->bhv", ctx_c,
                     wv_b.astype(jnp.float32))             # (B,H,vdim)
    out = out.reshape(b, h * vdim).astype(x_t.dtype)
    y = jnp.einsum("bh,hd->bd", out, p["wo"])
    return y, {"c_kv": c_cache, "k_rope": r_cache}


# ======================================================================
# Cross attention (whisper decoder)
# ======================================================================
def cross_attention(cfg: ModelConfig, p: dict, x: jax.Array,
                    enc_k: jax.Array, enc_v: jax.Array) -> jax.Array:
    """x: (B,S,d) or (B,d); enc_k/enc_v: (B,F,KV,Dh) precomputed."""
    squeeze = x.ndim == 2
    if squeeze:
        x = x[:, None]
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(
        b, s, cfg.num_heads, hd)
    f = enc_k.shape[1]
    mask = jnp.ones((s, f), bool)
    out = full_attention(q, enc_k, enc_v, mask)
    out = out.reshape(b, s, cfg.num_heads * hd)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return y[:, 0] if squeeze else y


def cross_kv(cfg: ModelConfig, p: dict, enc_out: jax.Array):
    """Precompute cross-attention K/V from encoder output (B,F,d)."""
    b, f, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = jnp.einsum("bfd,dh->bfh", enc_out, p["wk"]).reshape(
        b, f, cfg.num_kv_heads, hd)
    v = jnp.einsum("bfd,dh->bfh", enc_out, p["wv"]).reshape(
        b, f, cfg.num_kv_heads, hd)
    return k, v
