"""Deterministic-execution capture (paper §3.1 invariant 1).

Every run records: random seed, prompt template hash, rubric version,
model identifiers, and an environment fingerprint. Re-execution with
identical inputs produces identical trace hashes.
"""
from __future__ import annotations

import hashlib
import platform
import sys
from dataclasses import asdict, dataclass
from typing import Dict, Tuple

RUBRIC_VERSION = "acar-rubric-1.0"
PROMPT_TEMPLATE = (
    "Task: {task}\n"
    "Answer with the final result only.\n")
PROMPT_TEMPLATE_RETRIEVAL = (
    "Similar past example:\n{exemplar}\n\n"
    "Task: {task}\n"
    "Answer with the final result only.\n")


def prompt_hash(template: str) -> str:
    return hashlib.sha256(template.encode()).hexdigest()[:16]


def stable_fingerprint(text: str, bits: int = 31) -> int:
    """Process-stable non-negative integer fingerprint of a string.

    Python's builtin ``hash`` is salted per process (PYTHONHASHSEED),
    so PRNG keys derived from it differ between otherwise identical
    runs — exactly the nondeterminism the §3.1 invariant forbids. This
    sha256-derived value is identical everywhere."""
    h = hashlib.sha256(text.encode()).digest()
    return int.from_bytes(h[:8], "little") % (1 << bits)


@dataclass(frozen=True)
class EnvironmentFingerprint:
    python: str
    platform: str
    jax_version: str
    rubric_version: str
    prompt_template_hash: str

    def digest(self) -> str:
        payload = "|".join(
            f"{k}={v}" for k, v in sorted(asdict(self).items()))
        return hashlib.sha256(payload.encode()).hexdigest()[:16]


def capture_environment() -> EnvironmentFingerprint:
    import jax
    return EnvironmentFingerprint(
        python=sys.version.split()[0],
        platform=platform.platform(),
        jax_version=jax.__version__,
        rubric_version=RUBRIC_VERSION,
        prompt_template_hash=prompt_hash(PROMPT_TEMPLATE),
    )


def render_prompt(task_text: str, exemplar: str = "") -> str:
    if exemplar:
        return PROMPT_TEMPLATE_RETRIEVAL.format(
            exemplar=exemplar, task=task_text)
    return PROMPT_TEMPLATE.format(task=task_text)
