"""Forward-only run state machine (paper §3.1 invariant 3).

PENDING -> EXECUTING -> VERIFYING -> COMPLETED, plus a terminal FAILED
reachable from any non-terminal state. No rollback transitions exist;
attempting one raises.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum
from typing import List, Tuple


class RunState(str, Enum):
    PENDING = "PENDING"
    EXECUTING = "EXECUTING"
    VERIFYING = "VERIFYING"
    COMPLETED = "COMPLETED"
    FAILED = "FAILED"


_ORDER = [RunState.PENDING, RunState.EXECUTING, RunState.VERIFYING,
          RunState.COMPLETED]

_ALLOWED = {
    RunState.PENDING: {RunState.EXECUTING, RunState.FAILED},
    RunState.EXECUTING: {RunState.VERIFYING, RunState.FAILED},
    RunState.VERIFYING: {RunState.COMPLETED, RunState.FAILED},
    RunState.COMPLETED: set(),
    RunState.FAILED: set(),
}


class IllegalTransition(RuntimeError):
    pass


@dataclass
class RunStateMachine:
    run_id: str
    state: RunState = RunState.PENDING
    history: List[Tuple[str, str]] = field(default_factory=list)

    def advance(self, to: RunState) -> None:
        if to not in _ALLOWED[self.state]:
            raise IllegalTransition(
                f"run {self.run_id}: {self.state.value} -> {to.value} "
                "is not a forward transition")
        self.history.append((self.state.value, to.value))
        self.state = to

    @property
    def terminal(self) -> bool:
        return self.state in (RunState.COMPLETED, RunState.FAILED)
