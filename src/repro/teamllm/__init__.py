from repro.teamllm.artifacts import ArtifactStore, ChainCorruption
from repro.teamllm.fingerprint import (
    EnvironmentFingerprint, capture_environment, render_prompt)
from repro.teamllm.state_machine import (
    IllegalTransition, RunState, RunStateMachine)
from repro.teamllm.trace import (
    ModelResponse, ProbeSample, TraceRecord, content_hash, stable_json)

__all__ = [
    "ArtifactStore", "ChainCorruption", "EnvironmentFingerprint",
    "IllegalTransition", "ModelResponse", "ProbeSample", "RunState",
    "RunStateMachine", "TraceRecord", "capture_environment",
    "content_hash", "render_prompt", "stable_json",
]
