"""Decision-trace schema (paper §3.1, Alg. 1 Phase 3).

A TraceRecord is the per-task auditable artifact: task identity, probe
samples, sigma, chosen mode, final answer, per-model responses, cost.
Wall-clock time lives in a separate non-hashed side channel so that the
hash chain is deterministic under re-execution (DESIGN.md §7.2).

Scheduling metadata (``schedule``: arrival tick, admission index,
batch id, probe-cache hit) rides the same non-hashed side channel: a
task routed through the continuous-batching scheduler must hash
identically to the same task routed through the sequential
orchestrator — batching is an execution strategy, not a semantic
input — while the queue/batch provenance stays fully auditable in the
persisted artifact row.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple


def stable_json(obj: Any) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def content_hash(obj: Any) -> str:
    return hashlib.sha256(stable_json(obj).encode()).hexdigest()


def fault_record(kind: str, tick: int, **fields: Any) -> Dict[str, Any]:
    """A fault-path event as a plain hashable dict: injected faults,
    member retries/quarantines, degraded routes, shard losses, row
    aborts. Appended to the artifact chain (fully hashed — unlike
    ``TraceRecord``'s wall-time side channel, every field here is a
    deterministic function of the fault plan and admission order, so
    hashing it keeps degraded runs replay-verifiable)."""
    rec = {"event": "fault", "kind": str(kind), "tick": int(tick)}
    for k in sorted(fields):
        if fields[k] is not None:
            rec[k] = fields[k]
    return rec


@dataclass(frozen=True)
class ProbeSample:
    response: str
    answer: str               # EXTRACT(response)
    cost: float


@dataclass(frozen=True)
class ModelResponse:
    model: str
    response: str
    answer: str
    cost: float
    # judge-visible quality signal (self-rated confidence / verbosity /
    # formatting heuristics -- what a black-box judge actually sees).
    score: float = 0.0


@dataclass(frozen=True)
class TraceRecord:
    run_id: str
    task_id: str
    benchmark: str
    prompt_hash: str
    seed: int
    sigma: float              # in {0.0, 0.5, 1.0}
    mode: str                 # single_agent | arena_lite | full_arena
    probe_samples: Tuple[ProbeSample, ...]
    responses: Tuple[ModelResponse, ...]
    final_answer: str
    correct: Optional[bool]
    cost: float
    retrieval: Optional[Dict[str, Any]] = None
    logical_time: int = 0     # hashed (deterministic counter)
    wall_time: float = 0.0    # NOT hashed
    # scheduler provenance {arrival, admitted, batch_id, ...}; NOT
    # hashed — batched and sequential execution of the same task must
    # produce the same record hash
    schedule: Optional[Dict[str, Any]] = None

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["probe_samples"] = [dataclasses.asdict(p)
                              for p in self.probe_samples]
        d["responses"] = [dataclasses.asdict(r) for r in self.responses]
        return d

    def hashed_view(self) -> Dict[str, Any]:
        d = self.to_dict()
        d.pop("wall_time", None)
        d.pop("schedule", None)
        return d

    def record_hash(self) -> str:
        return content_hash(self.hashed_view())
