"""Deterministic span records for provenance-grade observability.

A span is a plain hashable dict in the ``trace.fault_record`` mold:
its *structure* — phase, trace id, span id, parent link, virtual-clock
tick, and any decision fields — is a deterministic function of the
admission-ordered run, while wall-clock timestamps ride the artifact
store's non-hashed ``wall_time`` side channel. Two runs of the same
stream therefore produce byte-identical span record hashes and chain
heads, and arming a tracer cannot perturb the main decision trace
(``tests/harness/simulate.py --obs`` proves both properties).

Trace ids derive from ``(request_id, admission_index)`` — the same
stable per-task identity that seeds the sampling key streams — so a
task keeps one trace across requeues, retries, shard re-placement and
crash→recover. Span ids are per-trace ordinals: the k-th span a trace
emits is ``{trace}/{k}``, which makes parent/child links plain strings
inside hashed records.

``SpanLog`` keeps the hash chain in memory (same ``GENESIS`` /
``H(prev|record_hash)`` link as ``ArtifactStore``) and flushes to
byte-compatible JSONL in one buffered write, so an armed tracer pays
no per-span fsync; ``ArtifactStore(path)`` re-opens, verifies and
audits the flushed file unchanged.
"""
from __future__ import annotations

import os
from pathlib import Path
from typing import Any, Dict, List, Optional, Union

from repro.teamllm.artifacts import GENESIS, ArtifactStore
from repro.teamllm.trace import content_hash, stable_json


def make_trace_id(request_id: str, admission: int) -> str:
    """Stable trace identity: the request plus its global admission
    index (the pair that keys every sampling stream)."""
    return f"{request_id}#{int(admission)}"


def span_record(phase: str, trace: str, span: str, tick: int,
                parent: Optional[str] = None, **fields: Any
                ) -> Dict[str, Any]:
    """A hashable span event. ``tick`` is the deterministic virtual
    clock; non-None ``fields`` append in sorted order so the record —
    and its content hash — is reproducible."""
    rec: Dict[str, Any] = {
        "event": "span",
        "phase": str(phase),
        "trace": str(trace),
        "span": str(span),
        "tick": int(tick),
    }
    if parent is not None:
        rec["parent"] = str(parent)
    for k in sorted(fields):
        if fields[k] is not None:
            rec[k] = fields[k]
    return rec


class SpanLog:
    """In-memory hash-chained span buffer, ``ArtifactStore``-format on
    flush. The chain advances per append exactly like the store's, but
    the bytes hit disk once — an armed tracer must not put an fsync in
    the serving loop (``benchmarks/obs_bench.py`` gates the overhead).
    """

    def __init__(self):
        self.rows: List[Dict[str, Any]] = []
        self.head = GENESIS

    def __len__(self) -> int:
        return len(self.rows)

    def append(self, record: Dict[str, Any],
               wall_time: float = 0.0) -> str:
        """Chain and buffer one span record; returns the new head.
        ``wall_time`` is stored outside the hashed record, mirroring
        ``ArtifactStore._encode``'s side channel."""
        rh = content_hash(record)
        self.head = ArtifactStore._link(self.head, rh)
        self.rows.append({
            "record": record,
            "record_hash": rh,
            "chain_hash": self.head,
            "wall_time": float(wall_time),
        })
        return self.head

    def records(self) -> List[Dict[str, Any]]:
        return [row["record"] for row in self.rows]

    def flush(self, path: Union[str, Path]) -> str:
        """Write the buffered chain as ArtifactStore-compatible JSONL
        (one buffered write + fsync); returns the chain head.
        ``ArtifactStore(path)`` verifies the result byte-for-byte."""
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        lines = "".join(stable_json(row) + "\n" for row in self.rows)
        with p.open("w") as f:
            f.write(lines)
            f.flush()
            os.fsync(f.fileno())
        return self.head
