"""Immutable append-only artifact store (paper §3.1 invariant 2).

Artifacts are stored as hash-chained JSONL (``runs.jsonl``): each line
carries the record, its content hash, and the chain hash
``H(prev_chain | record_hash)``. Existing records cannot be altered —
the store verifies the chain on open and refuses to append to a
corrupted file. "Modification" means appending a new versioned record.

Crash safety: ``stable_json`` output contains no newlines and each
append writes ``line + "\n"`` in one call followed by flush + fsync,
so a complete record always ends in a newline and a file whose final
byte is *not* a newline can only be a torn final append (the process
died mid-write). Opening the store truncates such a torn tail back to
the last complete line before verifying — the chain is intact up to
the last durable record. A *complete* final line whose hashes do not
verify is tampering, not tearing, and still raises
``ChainCorruption``.
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from pathlib import Path
from typing import Any, Dict, Iterator, List, Optional, Tuple, Union

from repro.teamllm.trace import TraceRecord, content_hash, stable_json

GENESIS = "0" * 64


class ChainCorruption(RuntimeError):
    pass


class ArtifactStore:
    """Append-only, hash-chained JSONL store."""

    def __init__(self, path: Union[str, Path]):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._chain = GENESIS
        self._count = 0
        self.torn_recovered = False
        if self.path.exists():
            self._recover_torn()
            self._chain, self._count = self._verify()

    def _recover_torn(self) -> None:
        """Truncate a torn final line (kill mid-append). Appends write
        whole newline-terminated lines atomically from the reader's
        perspective, so a file not ending in ``\\n`` holds exactly one
        partial record at its tail and nothing else is suspect."""
        data = self.path.read_bytes()
        if not data or data.endswith(b"\n"):
            return
        keep = data.rfind(b"\n") + 1
        with self.path.open("r+b") as f:
            f.truncate(keep)
            f.flush()
            os.fsync(f.fileno())
        self.torn_recovered = True

    # -- chain ---------------------------------------------------------
    @staticmethod
    def _link(prev: str, record_hash: str) -> str:
        return hashlib.sha256(f"{prev}|{record_hash}".encode()).hexdigest()

    def _verify(self) -> tuple:
        chain = GENESIS
        n = 0
        with self.path.open() as f:
            for i, line in enumerate(f):
                row = json.loads(line)
                rh = content_hash(row["record"])
                if rh != row["record_hash"]:
                    raise ChainCorruption(
                        f"{self.path}:{i + 1}: record hash mismatch")
                chain = self._link(chain, rh)
                if chain != row["chain_hash"]:
                    raise ChainCorruption(
                        f"{self.path}:{i + 1}: chain hash mismatch")
                n += 1
        return chain, n

    # -- API -----------------------------------------------------------
    def _encode(self, record: Union[TraceRecord, Dict[str, Any]],
                wall_time: Optional[float] = None
                ) -> Tuple[str, str]:
        """Serialise a record against the current chain state without
        mutating it: returns (newline-terminated line, new chain
        head). Split from ``append`` so the in-memory state only moves
        once the bytes are durable."""
        schedule = None
        if isinstance(record, TraceRecord):
            hashed = record.hashed_view()
            wall = record.wall_time
            schedule = record.schedule
        else:
            hashed = dict(record)
            wall = hashed.pop("wall_time", 0.0)
            schedule = hashed.pop("schedule", None)
        if wall_time is not None:
            wall = wall_time
        rh = content_hash(hashed)
        chain = self._link(self._chain, rh)
        row = {
            "record": hashed,
            "record_hash": rh,
            "chain_hash": chain,
            "wall_time": wall or time.time(),
        }
        if schedule is not None:
            # non-hashed side channel, like wall_time: queue/batch
            # provenance is auditable but does not perturb the chain
            row["schedule"] = schedule
        return stable_json(row) + "\n", chain

    def append(self, record: Union[TraceRecord, Dict[str, Any]],
               wall_time: Optional[float] = None) -> str:
        """Append a record; returns its chain hash. The line is
        written, flushed and fsync'd in one go before the chain state
        advances — a kill anywhere leaves at worst a torn tail that
        ``_recover_torn`` truncates on the next open."""
        line, chain = self._encode(record, wall_time)
        with self.path.open("a") as f:
            f.write(line)
            f.flush()
            os.fsync(f.fileno())
        self._chain = chain
        self._count += 1
        return self._chain

    def __len__(self) -> int:
        return self._count

    @property
    def head(self) -> str:
        return self._chain

    def records(self) -> Iterator[Dict[str, Any]]:
        if not self.path.exists():
            return
        with self.path.open() as f:
            for line in f:
                yield json.loads(line)["record"]

    def read_all(self) -> List[Dict[str, Any]]:
        return list(self.records())

    def audit(self) -> Dict[str, Any]:
        """Full-chain audit report (paper appendix: zero parse errors)."""
        chain, n = self._verify() if self.path.exists() else (GENESIS, 0)
        return {
            "path": str(self.path),
            "records": n,
            "head": chain,
            "parse_errors": 0,  # _verify raises on any corruption
            "ok": chain == self._chain and n == self._count,
        }
