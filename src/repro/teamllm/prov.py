"""W3C PROV-style lineage over deterministic span traces.

``prov_records`` materializes entity / activity / agent records plus
the four relations (wasGeneratedBy, used, wasDerivedFrom,
wasAttributedTo) from a span stream (``teamllm.spans`` /
``serving.tracing``): the final answer chains back through the judge
to the route decision, the route decision to the probe sample set,
each ensemble member's answer to its launch, and KV page reuse —
prefix-cache hits and probe→ensemble seeding — becomes an explicit
``wasDerivedFrom`` edge between traces. Every record is a plain
hashable dict, so the lineage inherits the trace substrate's
determinism: same run, same record hashes, same chain head.

``lineage`` answers the operator question — "which member produced
this answer, via which route decision, from which probe samples?" —
by walking the relation graph backwards from a task's answer entity
(``launch/serve.py --lineage <task>`` is the CLI front end) and
re-verifying the content hash of every span the walk touched.

Identifiers (deterministic, derived from span ids):
  ``answer:{trace}``      the task's final answer entity
  ``route:{trace}``       the route decision entity
  ``probe:{trace}``       the probe sample set entity
  ``member:{trace}/{mi}`` ensemble member ``mi``'s answer entity
  ``attrib:{trace}``      the leave-one-out counterfactual entity
  ``act:{span}``          the activity for span ``{span}``
  ``model:{name}``        a model agent
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Sequence

from repro.teamllm.trace import content_hash


def _rec(kind: str, **fields: Any) -> Dict[str, Any]:
    rec: Dict[str, Any] = {"event": "prov", "kind": kind}
    for k in sorted(fields):
        if fields[k] is not None:
            rec[k] = fields[k]
    return rec


def _entity(eid: str, **fields: Any) -> Dict[str, Any]:
    return _rec("entity", id=eid, **fields)


def _activity(span: Dict[str, Any]) -> Dict[str, Any]:
    return _rec("activity", id=f"act:{span['span']}",
                phase=span["phase"], trace=span["trace"],
                tick=span["tick"], span=span["span"],
                span_hash=content_hash(span))


def prov_records(spans: Sequence[Dict[str, Any]]
                 ) -> List[Dict[str, Any]]:
    """Derive the PROV graph from one run's span stream. Output order
    is deterministic: span order for activities, then per-trace
    entity/relation blocks in first-retire order."""
    out: List[Dict[str, Any]] = []
    agents: Dict[str, bool] = {}
    by_trace: Dict[str, List[Dict[str, Any]]] = {}
    for s in spans:
        out.append(_activity(s))
        by_trace.setdefault(s["trace"], []).append(s)
        m = s.get("model")
        if m and m not in agents:
            agents[m] = True
            out.append(_rec("agent", id=f"model:{m}", model=m))

    for trace, tspans in by_trace.items():
        probe_act = route_act = judge_act = None
        retire = attrib = None
        member_act: Dict[int, Dict[str, Any]] = {}
        member_launch: Dict[int, Dict[str, Any]] = {}
        kv_spans: List[Dict[str, Any]] = []
        for s in tspans:
            p = s["phase"]
            if p == "probe_decode":
                probe_act = s            # last probe megastep
            elif p == "route":
                route_act = s
            elif p == "member_launch":
                member_launch[int(s["member"])] = s
            elif p == "member_decode" and s.get("done"):
                mi = s.get("member")
                if mi is not None:
                    member_act[int(mi)] = s
            elif p == "judge":
                judge_act = s
            elif p == "retire":
                retire = s
            elif p == "attribution":
                attrib = s
            elif p == "kv_reuse":
                kv_spans.append(s)
        if retire is None:
            continue                     # still in flight / displaced

        probe_eid = f"probe:{trace}"
        route_eid = f"route:{trace}"
        answer_eid = f"answer:{trace}"
        if probe_act is not None:
            out.append(_entity(probe_eid, trace=trace))
            out.append(_rec("wasGeneratedBy", entity=probe_eid,
                            activity=f"act:{probe_act['span']}"))
        if route_act is not None:
            out.append(_entity(route_eid, trace=trace,
                               sigma=route_act.get("sigma"),
                               mode=route_act.get("mode")))
            out.append(_rec("wasGeneratedBy", entity=route_eid,
                            activity=f"act:{route_act['span']}"))
            if probe_act is not None:
                out.append(_rec("used",
                                activity=f"act:{route_act['span']}",
                                entity=probe_eid))
                out.append(_rec("wasDerivedFrom", entity=route_eid,
                                source=probe_eid))

        member_eids: List[str] = []
        judged = set(judge_act.get("members", [])) \
            if judge_act is not None else set(member_launch)
        for mi in sorted(member_launch):
            if mi not in judged:
                continue
            ls = member_launch[mi]
            eid = f"member:{trace}/{mi}"
            member_eids.append(eid)
            out.append(_entity(eid, trace=trace, member=mi,
                               model=ls.get("model")))
            gen = member_act.get(mi, ls)
            out.append(_rec("wasGeneratedBy", entity=eid,
                            activity=f"act:{gen['span']}"))
            out.append(_rec("used",
                            activity=f"act:{ls['span']}",
                            entity=route_eid))
            out.append(_rec("wasDerivedFrom", entity=eid,
                            source=route_eid))
            if ls.get("model"):
                out.append(_rec("wasAttributedTo", entity=eid,
                                agent=f"model:{ls['model']}"))

        out.append(_entity(answer_eid, trace=trace,
                           task_id=retire.get("task_id"),
                           answer=retire.get("final_answer")))
        gen = judge_act if judge_act is not None else retire
        out.append(_rec("wasGeneratedBy", entity=answer_eid,
                        activity=f"act:{gen['span']}"))
        sources = member_eids or ([probe_eid]
                                  if probe_act is not None else [])
        for src in sources:
            out.append(_rec("used", activity=f"act:{gen['span']}",
                            entity=src))
            out.append(_rec("wasDerivedFrom", entity=answer_eid,
                            source=src))

        # KV page reuse: pages another trace's prefill populated (or
        # this trace's probe pages) flowed into this execution
        for s in kv_spans:
            src_trace = s.get("source")
            if src_trace is None:
                continue
            src_eid = (probe_eid if src_trace == trace
                       else f"answer:{src_trace}")
            out.append(_rec("wasDerivedFrom",
                            entity=answer_eid, source=src_eid,
                            via=f"act:{s['span']}",
                            kv=s.get("kind")))

        if attrib is not None:
            aid = f"attrib:{trace}"
            out.append(_entity(aid, trace=trace,
                               values=attrib.get("values")))
            out.append(_rec("wasGeneratedBy", entity=aid,
                            activity=f"act:{attrib['span']}"))
            out.append(_rec("used",
                            activity=f"act:{attrib['span']}",
                            entity=answer_eid))
    return out


def lineage(spans: Sequence[Dict[str, Any]], task_id: str,
            records: Optional[Sequence[Dict[str, Any]]] = None
            ) -> Dict[str, Any]:
    """Walk the PROV graph backwards from ``task_id``'s final answer:
    returns the ordered relation path (answer → judge → members →
    route → probe, plus KV-reuse derivations), every entity/activity
    on it, and a hash check re-verifying each touched span record
    against the hash its activity captured at build time.

    ``records`` accepts a previously materialized PROV graph (e.g.
    persisted at serve time); the walk then verifies the current span
    stream against the hashes *that* graph captured, catching spans
    tampered after the fact. Default (None) rebuilds the graph from
    ``spans`` — tamper detection for the default path is the span
    file's own hash chain (``verify_span_file``).

    Result keys: ``trace``, ``records`` (the walked PROV records),
    ``verified`` (spans re-hashed OK), ``hash_failures``, ``ok``.
    """
    trace = None
    span_by_id: Dict[str, Dict[str, Any]] = {}
    for s in spans:
        span_by_id[s["span"]] = s
        if s["phase"] == "retire" and s.get("task_id") == task_id:
            trace = s["trace"]           # latest admission wins
    if trace is None:
        return {"trace": None, "records": [], "verified": 0,
                "hash_failures": [f"no retired trace for {task_id}"],
                "ok": False}

    if records is None:
        records = prov_records(spans)
    by_entity: Dict[str, List[Dict[str, Any]]] = {}
    entities: Dict[str, Dict[str, Any]] = {}
    activities: Dict[str, Dict[str, Any]] = {}
    for r in records:
        if r["kind"] == "entity":
            entities[r["id"]] = r
        elif r["kind"] == "activity":
            activities[r["id"]] = r
        elif r["kind"] in ("wasGeneratedBy", "wasDerivedFrom",
                           "wasAttributedTo"):
            by_entity.setdefault(r["entity"], []).append(r)

    walked: List[Dict[str, Any]] = []
    seen: set = set()
    acts: List[str] = []
    frontier = [f"answer:{trace}"]
    while frontier:
        eid = frontier.pop(0)
        if eid in seen:
            continue
        seen.add(eid)
        if eid in entities:
            walked.append(entities[eid])
        for r in by_entity.get(eid, ()):
            walked.append(r)
            if r["kind"] == "wasGeneratedBy":
                acts.append(r["activity"])
            elif r["kind"] == "wasDerivedFrom":
                frontier.append(r["source"])

    verified = 0
    failures: List[str] = []
    for aid in acts:
        act = activities.get(aid)
        if act is None:
            failures.append(f"missing activity {aid}")
            continue
        walked.append(act)
        s = span_by_id.get(act["span"])
        if s is None:
            failures.append(f"missing span {act['span']}")
        elif content_hash(s) != act["span_hash"]:
            failures.append(f"hash mismatch at {act['span']}")
        else:
            verified += 1
    return {"trace": trace, "records": walked, "verified": verified,
            "hash_failures": failures, "ok": not failures}


def verify_span_file(path) -> Dict[str, Any]:
    """Audit a flushed span chain (``SpanLog.flush`` output) with the
    artifact-store verifier: re-hash every record, re-link the chain.
    Returns the ``ArtifactStore.audit`` dict."""
    from repro.teamllm.artifacts import ArtifactStore
    return ArtifactStore(path).audit()
