"""whisper-medium — encoder-decoder ASR transformer [arXiv:2212.04356].

24L encoder + 24L decoder, d_model=1024, 16 heads (MHA), d_ff=4096,
vocab=51865. The mel-spectrogram + conv frontend is a STUB: input_specs()
provides precomputed frame embeddings (B, 1500, d_model).
Whisper uses learned positional embeddings (no RoPE).
"""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    use_rope=False,
    max_position=448,   # real whisper decoder context; the dry-run
                        # resizes this to the shape's seq_len
    encoder=EncoderConfig(num_layers=24, num_frames=1500, d_frontend=1024),
    frontend="audio",
    source="arXiv:2212.04356",
)

REDUCED = CONFIG.replace(
    name="whisper-medium-reduced",
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=4,
    d_ff=512,
    vocab_size=512,
    max_position=128,
    encoder=EncoderConfig(num_layers=2, num_frames=64, d_frontend=256),
    remat="none",
)
