from repro.configs.base import (
    EncoderConfig,
    INPUT_SHAPES,
    InputShape,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    RGLRUConfig,
    SSMConfig,
    TrainConfig,
)
from repro.configs.registry import ARCH_IDS, all_configs, get_config
from repro.configs.acar import ACARConfig, ACAR_U, ACAR_UJ, ACAR_UJ_ALIGNED

__all__ = [
    "ACARConfig", "ACAR_U", "ACAR_UJ", "ACAR_UJ_ALIGNED", "ARCH_IDS",
    "EncoderConfig", "INPUT_SHAPES", "InputShape", "MLAConfig", "MoEConfig",
    "ModelConfig", "RGLRUConfig", "SSMConfig", "TrainConfig",
    "all_configs", "get_config",
]
