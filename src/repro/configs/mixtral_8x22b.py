"""mixtral-8x22b — Mixtral 8x22B sparse MoE [arXiv:2401.04088].

56L, d_model=6144, 48 heads, GQA kv=8, expert d_ff=16384, vocab=32768,
8 experts top-2, sliding-window attention.
"""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    window=4096,           # SWA per the assignment
    moe=MoEConfig(
        num_experts=8,
        top_k=2,
        d_ff_expert=16384,
        capacity_factor=1.25,
    ),
    rope_theta=1_000_000.0,
    source="arXiv:2401.04088",
)

REDUCED = CONFIG.replace(
    name="mixtral-8x22b-reduced",
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    window=128,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128),
    remat="none",
)
