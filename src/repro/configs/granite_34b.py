"""granite-34b — IBM Granite 34B code model [arXiv:2405.04324].

Llama-style dense decoder with multi-query attention (kv=1).
88L, d_model=6144, 48 heads, d_ff=24576, vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    rope_theta=10_000.0,
    source="arXiv:2405.04324",
)

REDUCED = CONFIG.replace(
    name="granite-34b-reduced",
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=1,
    d_ff=512,
    vocab_size=512,
    remat="none",
)
