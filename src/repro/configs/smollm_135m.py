"""smollm-135m — SmolLM 135M [hf:HuggingFaceTB/SmolLM-135M].

Llama-style small dense decoder: 30L, d_model=576, 9 heads, GQA kv=3,
d_ff=1536, vocab=49152. Default ACAR probe model in this framework.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)

REDUCED = CONFIG.replace(
    name="smollm-135m-reduced",
    num_layers=2,
    d_model=192,
    num_heads=3,
    num_kv_heads=1,
    d_ff=512,
    vocab_size=512,
    remat="none",
)
