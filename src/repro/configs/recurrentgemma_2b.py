"""recurrentgemma-2b — Griffin-style hybrid [arXiv:2402.19427].

26L, d_model=2560, 10 heads (MQA kv=1, head_dim 256), d_ff=7680,
vocab=256000. Temporal mixing pattern 2 RG-LRU : 1 local attention
(window 2048).
"""
from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    window=2048,                       # local attention window
    layer_pattern=("rglru", "rglru", "attn"),
    rglru=RGLRUConfig(lru_width=2560, conv_width=4, chunk=256),
    rope_theta=10_000.0,
    source="arXiv:2402.19427",
)

REDUCED = CONFIG.replace(
    name="recurrentgemma-2b-reduced",
    num_layers=3,                      # one full pattern period
    d_model=256,
    num_heads=4,
    num_kv_heads=1,
    d_ff=512,
    vocab_size=512,
    window=64,
    rglru=RGLRUConfig(lru_width=256, conv_width=4, chunk=32),
    remat="none",
)
