"""llama3-8b — Llama 3 8B dense decoder [arXiv:2407.21783].

32L, d_model=4096, 32 heads, GQA kv=8, d_ff=14336, vocab=128256,
rope_theta=500000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
    source="arXiv:2407.21783",
)

REDUCED = CONFIG.replace(
    name="llama3-8b-reduced",
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    remat="none",
)
