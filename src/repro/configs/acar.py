"""ACAR orchestration configuration (paper §3.2, §4).

The paper's deployment uses Gemini 2.0 Flash as the probe and
{Claude Sonnet 4, GPT-4o, Gemini 2.0 Flash} as the ensemble. In this
framework the ensemble members are architectures from the zoo; the
default mirrors the paper's "one fast probe + three diverse members"
shape with smollm-135m as the fast probe.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ACARConfig:
    n_probe_samples: int = 3                  # paper: N=3
    probe_temperature: float = 0.7            # probe sampling temperature
    ensemble_temperature: float = 0.0         # paper: temperature 0
    probe_model: str = "smollm-135m"
    ensemble_models: Tuple[str, ...] = (
        "llama3-8b", "deepseek-7b", "mixtral-8x22b")
    # retrieval (ACAR-UJ / "Jungler")
    retrieval_enabled: bool = False
    retrieval_threshold: float = 0.0          # paper's (bad) default
    retrieval_top_k: int = 1
    # arena_lite uses the first two ensemble members (paper: Claude+GPT-4o)
    arena_lite_size: int = 2
    seed: int = 0


ACAR_U = ACARConfig()
ACAR_UJ = ACARConfig(retrieval_enabled=True, retrieval_threshold=0.0)
# the paper's §6.1 recommendation
ACAR_UJ_ALIGNED = ACARConfig(retrieval_enabled=True, retrieval_threshold=0.7)
