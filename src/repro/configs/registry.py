"""Architecture registry: ``--arch <id>`` -> ModelConfig.

Full-size CONFIGs are exercised only via the dry-run (ShapeDtypeStruct,
no allocation); REDUCED variants run on CPU in smoke tests and examples.
"""
from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.configs.base import ModelConfig

_ARCH_MODULES = {
    "granite-34b": "repro.configs.granite_34b",
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
    "whisper-medium": "repro.configs.whisper_medium",
    "llama3-8b": "repro.configs.llama3_8b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "smollm-135m": "repro.configs.smollm_135m",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
}

ARCH_IDS: Tuple[str, ...] = tuple(_ARCH_MODULES)


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(
            f"unknown arch {arch!r}; available: {', '.join(ARCH_IDS)}")
    mod = importlib.import_module(_ARCH_MODULES[arch])
    return mod.REDUCED if reduced else mod.CONFIG


def all_configs(reduced: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, reduced) for a in ARCH_IDS}
