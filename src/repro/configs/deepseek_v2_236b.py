"""deepseek-v2-236b — DeepSeek-V2 MoE with MLA [arXiv:2405.04434].

60L, d_model=5120, 128 heads, MLA (kv_lora_rank=512, q_lora_rank=1536,
qk_nope=128, qk_rope=64, v=128). MoE: 160 routed experts top-6 +
2 shared experts, expert d_ff=1536; layer 0 keeps a dense FFN (d_ff=12288).
vocab=102400.
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,     # MLA: per-head latent expansion; kv grouping n/a
    d_ff=12288,           # dense FFN used for layer 0
    vocab_size=102400,
    attn_kind="mla",
    head_dim=128,
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=160,
        top_k=6,
        d_ff_expert=1536,
        num_shared_experts=2,
        d_ff_shared=1536,
        capacity_factor=1.25,
        first_moe_layer=1,
    ),
    rope_theta=10_000.0,
    source="arXiv:2405.04434",
)

REDUCED = CONFIG.replace(
    name="deepseek-v2-236b-reduced",
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=4,
    d_ff=512,
    vocab_size=512,
    head_dim=32,
    mla=MLAConfig(
        kv_lora_rank=64,
        q_lora_rank=96,
        qk_nope_head_dim=32,
        qk_rope_head_dim=16,
        v_head_dim=32,
    ),
    moe=MoEConfig(
        num_experts=4,
        top_k=2,
        d_ff_expert=128,
        num_shared_experts=1,
        d_ff_shared=128,
        first_moe_layer=1,
    ),
    remat="none",
)
