"""falcon-mamba-7b — attention-free Mamba-1 SSM [arXiv:2410.05355].

64L, d_model=4096, d_inner=8192 (expand=2), ssm_state=16, vocab=65024.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=1,          # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,
    vocab_size=65024,
    attn_kind="none",
    use_rope=False,
    ssm=SSMConfig(state_dim=16, conv_width=4, expand=2, chunk=256),
    tie_embeddings=False,
    source="arXiv:2410.05355",
)

REDUCED = CONFIG.replace(
    name="falcon-mamba-7b-reduced",
    num_layers=2,
    d_model=256,
    vocab_size=512,
    ssm=SSMConfig(state_dim=8, conv_width=4, expand=2, chunk=64),
    remat="none",
)
