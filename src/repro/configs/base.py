"""Model / shape / mesh configuration dataclasses.

Every assigned architecture gets one module in this package that exports a
``CONFIG`` (full-size, exercised only via the dry-run) and a ``REDUCED``
variant (2 layers, d_model <= 512, <= 4 experts) used by CPU smoke tests
and the runnable examples.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration (routed + optional shared)."""

    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # "tp": experts tensor-sharded over model axis (no all-to-all).
    # "ep": experts sharded over model axis with all-to-all dispatch.
    # "gather": capacity-free per-token top-k gather dispatch
    # (models.moe.moe_ffn_gather) — batch-composition invariant, so
    # the serving engine may compact/page MoE members; denser compute.
    impl: str = "tp"
    # Layer index of the first MoE layer (earlier layers use dense FFN,
    # deepseek-v2 keeps layer 0 dense).
    first_moe_layer: int = 0


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2)."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 selective-state-space block configuration."""

    state_dim: int = 16
    conv_width: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)
    chunk: int = 256  # chunked-scan block length


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU block configuration."""

    lru_width: int = 0        # 0 -> d_model
    conv_width: int = 4
    expand: int = 3           # width multiple of the gated MLP branch
    chunk: int = 256


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for encoder-decoder models (whisper)."""

    num_layers: int
    num_frames: int           # frontend output length (e.g. 1500 mel frames)
    d_frontend: int           # frontend embedding dim (== d_model for stub)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0         # 0 -> d_model // num_heads
    # attention
    attn_kind: str = "gqa"    # gqa | mla | none
    window: Optional[int] = None          # sliding-window size (SWA / local attn)
    rope_theta: float = 10_000.0
    use_rope: bool = True                 # whisper uses learned positions
    max_position: int = 1 << 20
    # per-layer pattern for hybrid models, e.g. ("rglru", "rglru", "attn");
    # tiled cyclically over num_layers.
    layer_pattern: Optional[Tuple[str, ...]] = None
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[str] = None        # None | "audio" | "vision"
    num_patches: int = 0                  # vision frontend: image token prefix
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # remat policy for training: "none" | "layer"
    remat: str = "layer"
    # scan over stacked layer params (bounded HLO). False = unrolled
    # python loop — used by the dry-run's cost-exact compiles, since
    # XLA cost analysis counts a while body once (models/scan_flags.py).
    scan_layers: bool = True
    use_pallas: bool = False              # TPU deployment flag (kernels/)
    # int8 KV cache (symmetric per-vector quant over head_dim): halves
    # decode's cache-read traffic and storage (EXPERIMENTS.md §Perf C2).
    kv_quant: bool = False
    source: str = ""                      # citation for the config

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.attn_kind == "none"

    @property
    def supports_long_context(self) -> bool:
        """True if decode memory/time per step is sub-linear in history.

        SSM / hybrid (bounded local window) / SWA architectures qualify;
        pure full-attention architectures do not.
        """
        if self.family in ("ssm", "hybrid"):
            return True
        return self.window is not None

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind tuple of length num_layers."""
        if self.layer_pattern is None:
            if self.family == "ssm":
                return ("ssm",) * self.num_layers
            return ("attn",) * self.num_layers
        pat = self.layer_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter accounting (used by roofline + tests) ----------------
    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, v = self.d_model, self.vocab_size
        total = v * d                      # input embedding
        if not self.tie_embeddings:
            total += v * d                 # lm head
        hd = self.resolved_head_dim
        for idx, kind in enumerate(self.layer_kinds):
            total += 2 * d                 # pre-norms (attn/mlp) approx
            if kind == "attn":
                if self.attn_kind == "mla":
                    m = self.mla
                    assert m is not None
                    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
                    total += d * m.q_lora_rank
                    total += m.q_lora_rank * self.num_heads * qk_dim
                    total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    total += m.kv_lora_rank * self.num_heads * (
                        m.qk_nope_head_dim + m.v_head_dim)
                    total += self.num_heads * m.v_head_dim * d
                else:
                    total += d * self.num_heads * hd          # Q
                    total += 2 * d * self.num_kv_heads * hd   # K, V
                    total += self.num_heads * hd * d          # O
            elif kind == "ssm":
                s = self.ssm
                assert s is not None
                d_in = s.expand * d
                dt_rank = s.dt_rank or -(-d // 16)
                total += d * 2 * d_in                 # in_proj (x, z)
                total += d_in * s.conv_width          # conv
                total += d_in * (dt_rank + 2 * s.state_dim)  # x_proj
                total += dt_rank * d_in + d_in        # dt_proj
                total += d_in * s.state_dim           # A_log
                total += d_in                         # D
                total += d_in * d                     # out_proj
            elif kind == "rglru":
                r = self.rglru
                assert r is not None
                w = r.lru_width or d
                total += 2 * d * w                    # in (x, gate branch)
                total += w * r.conv_width
                total += 3 * w                        # a param + gates (diag-ish)
                total += 2 * w * w                    # input/recurrence gates
                total += w * d                        # out
            if kind != "ssm":
                # MLP (mamba blocks have no separate MLP)
                total += self._mlp_params(idx)
        # encoder stack
        if self.encoder is not None:
            e = self.encoder
            for _ in range(e.num_layers):
                total += 2 * d
                total += 4 * d * self.num_heads * hd      # MHA
                total += 3 * d * self.d_ff                # swiglu-ish
            total += e.num_frames * d                     # learned positions
        return total

    def _mlp_params(self, layer_idx: int) -> int:
        d = self.d_model
        if self.moe is not None and layer_idx >= self.moe.first_moe_layer:
            m = self.moe
            routed = m.num_experts * 3 * d * m.d_ff_expert
            shared = m.num_shared_experts * 3 * d * m.d_ff_shared
            router = d * m.num_experts
            return routed + shared + router
        return 3 * d * self.d_ff

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        d = self.d_model
        total = self.param_count()
        # subtract inactive routed experts
        n_moe_layers = sum(
            1 for i, k in enumerate(self.layer_kinds)
            if k == "attn" and i >= m.first_moe_layer)
        inactive = (m.num_experts - m.top_k) * 3 * d * m.d_ff_expert
        return total - n_moe_layers * inactive


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0
