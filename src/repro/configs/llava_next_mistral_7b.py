"""llava-next-mistral-7b — LLaVA-NeXT on a Mistral-7B backbone
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

The TRANSFORMER BACKBONE only (Mistral-7B: 32L, d_model=4096, 32 heads,
GQA kv=8, d_ff=14336, vocab=32000, native sliding window 4096). The
ViT/SigLIP vision encoder + projector are a STUB: input_specs() provides
precomputed patch embeddings (anyres tiling -> num_patches prefix tokens).
"""
from repro.configs.base import ModelConfig

# anyres: base 576 patches + 4 tiles x 576 = 2880 max; we use a 1152-token
# prefix (2 tiles) so train_4k keeps a meaningful text budget.
NUM_PATCHES = 1152

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    window=4096,            # Mistral native SWA
    rope_theta=1_000_000.0,
    frontend="vision",
    num_patches=NUM_PATCHES,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)

REDUCED = CONFIG.replace(
    name="llava-next-mistral-7b-reduced",
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    window=128,
    num_patches=16,
    remat="none",
)
