# Pallas TPU kernels for the serving hot-spots (decode attention, the
# two recurrent scans, fused SwiGLU) + ops.py dispatch + ref.py oracles.
# Selected at deployment via ModelConfig.use_pallas; validated on CPU in
# interpret mode (tests/test_kernels.py).
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
