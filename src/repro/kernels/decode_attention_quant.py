"""Pallas TPU kernel: flash-decode over an int8-quantised KV cache.

Deployment kernel for the §Perf C2 optimisation: the cache stores int8
codes + f32 per-vector scales; blocks stream through VMEM at half the
HBM traffic of bf16. The scales fold into the attention math exactly as
in the jnp path (models/attention.py::decode_attention_quant):

    scores_s = (q . k_codes_s) * k_scale_s
    out      = sum_s (p_s * v_scale_s) * v_codes_s

Same grid/scratch structure as decode_attention.py; the int8->f32
widen happens on the VPU after the VMEM load, so the MXU contraction
runs on the widened block while HBM only ever sees int8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 512


def _decode_attn_quant_kernel(len_ref, q_ref, k_ref, ks_ref, v_ref,
                              vs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                              block_s: int, scale: float):
    s_idx = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, Dk)
    k = k_ref[0, :, 0].astype(jnp.float32)               # (BLK, Dk) int8
    kscale = ks_ref[0, :, 0].astype(jnp.float32)         # (BLK,)
    v = v_ref[0, :, 0].astype(jnp.float32)
    vscale = vs_ref[0, :, 0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    s = s * kscale[None, :]                              # fold k scales
    positions = s_idx * block_s + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_s), 1)
    valid = positions < len_ref[0]
    s = jnp.where(valid, s, -jnp.inf)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(jnp.isfinite(m_new), p, 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), alpha, 0.0)

    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    pv = p * vscale[None, :]                             # fold v scales
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        pv, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s_idx == n_s - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention_quant(q: jax.Array, k_codes: jax.Array,
                           k_scale: jax.Array, v_codes: jax.Array,
                           v_scale: jax.Array, length: jax.Array,
                           *, block_s: int = DEFAULT_BLOCK_S,
                           interpret: bool = False) -> jax.Array:
    """q: (B, H, Dk); k_codes/v_codes: (B, S, KV, D) int8;
    k_scale/v_scale: (B, S, KV) f32; length: scalar int32."""
    b, h, dk = q.shape
    s, kv = k_codes.shape[1], k_codes.shape[2]
    dv = v_codes.shape[-1]
    g = h // kv
    if s % block_s != 0:
        block_s = s
    n_s = s // block_s
    scale = 1.0 / (dk ** 0.5)

    qg = q.reshape(b, kv, g, dk)
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (1,))

    out = pl.pallas_call(
        functools.partial(_decode_attn_quant_kernel, block_s=block_s,
                          scale=scale),
        grid=(b, kv, n_s),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((1, 1, g, dk), lambda bi, ki, si: (bi, ki, 0, 0)),
            pl.BlockSpec((1, block_s, 1, dk),
                         lambda bi, ki, si: (bi, si, ki, 0)),
            pl.BlockSpec((1, block_s, 1),
                         lambda bi, ki, si: (bi, si, ki)),
            pl.BlockSpec((1, block_s, 1, dv),
                         lambda bi, ki, si: (bi, si, ki, 0)),
            pl.BlockSpec((1, block_s, 1),
                         lambda bi, ki, si: (bi, si, ki)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dv),
                               lambda bi, ki, si: (bi, ki, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dv), jnp.float32),
        ],
        interpret=interpret,
    )(length, qg, k_codes, k_scale, v_codes, v_scale)
    return out.reshape(b, h, dv)
