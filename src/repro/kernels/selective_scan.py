"""Pallas TPU kernel: Mamba-1 selective scan (chunked, channel-blocked).

TPU adaptation of the CUDA selective-scan kernel (DESIGN.md §3): the
warp-parallel recurrence becomes a channel-blocked chunk walk. Grid =
(B, D // BLOCK_D, S // CHUNK); for each (batch, channel block) the
kernel walks chunks sequentially, carrying the (BLOCK_D, N) state in
VMEM scratch, and runs the recurrence inside the chunk with a
``fori_loop`` whose body is pure VPU work on (BLOCK_D, N) tiles —
decay-and-accumulate plus the C-projection reduce.

All math f32 (matching the deployed jnp path); inputs may be bf16.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_D = 512
DEFAULT_CHUNK = 256


def _selective_scan_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref,
                           y_ref, hout_ref, h_ref, *, chunk: int):
    c_idx = pl.program_id(2)
    n_c = pl.num_programs(2)

    @pl.when(c_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = -jnp.exp(alog_ref[...].astype(jnp.float32))     # (BD, N)

    def step(t, h):
        xt = x_ref[0, t].astype(jnp.float32)            # (BD,)
        dtt = dt_ref[0, t].astype(jnp.float32)          # (BD,)
        bt = b_ref[0, t].astype(jnp.float32)            # (N,)
        ct = c_ref[0, t].astype(jnp.float32)            # (N,)
        dta = jnp.exp(dtt[:, None] * a)                 # (BD, N)
        u = (dtt * xt)[:, None] * bt[None, :]
        h = dta * h + u
        y_ref[0, t] = jnp.sum(h * ct[None, :],
                              axis=-1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(c_idx == n_c - 1)
    def _emit_state():
        hout_ref[0] = h_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("block_d", "chunk", "interpret"))
def selective_scan(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                   b_in: jax.Array, c_in: jax.Array, *,
                   block_d: int = DEFAULT_BLOCK_D,
                   chunk: int = DEFAULT_CHUNK,
                   interpret: bool = False):
    """x, dt: (B, S, D); a_log: (D, N); b_in, c_in: (B, S, N).

    Returns (y (B, S, D), h_final (B, D, N) f32).
    """
    bsz, s, d = x.shape
    n = a_log.shape[1]
    if d % block_d != 0:
        block_d = d
    if s % chunk != 0:
        chunk = s
    grid = (bsz, d // block_d, s // chunk)

    y, h_final = pl.pallas_call(
        functools.partial(_selective_scan_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d),
                         lambda bi, di, ci: (bi, ci, di)),   # x
            pl.BlockSpec((1, chunk, block_d),
                         lambda bi, di, ci: (bi, ci, di)),   # dt
            pl.BlockSpec((block_d, n),
                         lambda bi, di, ci: (di, 0)),        # a_log
            pl.BlockSpec((1, chunk, n),
                         lambda bi, di, ci: (bi, ci, 0)),    # B
            pl.BlockSpec((1, chunk, n),
                         lambda bi, di, ci: (bi, ci, 0)),    # C
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d),
                         lambda bi, di, ci: (bi, ci, di)),   # y
            pl.BlockSpec((1, block_d, n),
                         lambda bi, di, ci: (bi, di, 0)),    # h_final
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, d), x.dtype),
            jax.ShapeDtypeStruct((bsz, d, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a_log, b_in, c_in)
    return y, h_final
