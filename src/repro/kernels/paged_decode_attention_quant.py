"""Pallas TPU kernel: block-table (paged) flash-decode over an
int8-quantised KV cache.

kernels/paged_decode_attention.py re-derived for the quant page
layout (serving/kv_pool.py ``layout == "quant"``): pages hold int8
codes plus f32 per-vector scale planes, so HBM reads per position are
Dh + 4 bytes instead of 2*Dh — roughly 2x the rows per device at the
same pool bytes. The grid walks one page per step per
(batch, kv-head); the page id comes from the scalar-prefetched block
table (DMA for page ``n+1`` issues while page ``n`` computes); the
online-softmax state (m, l, acc) rides in VMEM scratch. The scales
fold into the attention math exactly as in the dense quant kernel
(kernels/decode_attention_quant.py):

    scores_s = (q . k_codes_s) * k_scale_s
    out      = sum_s (p_s * v_scale_s) * v_codes_s

The int8->f32 widen happens on the VPU after the VMEM load, so the
MXU contraction runs on the widened page while HBM only ever sees
int8 codes + one f32 scale per vector.

Grid: (B, KV, NB) — page axis innermost so the scratch carries across
one row's pages. Rows shorter than NB pages mask by ``lengths[b]``;
spare block-table slots must hold *valid* page ids (the pool
guarantees this), the data being fully masked.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _paged_decode_quant_kernel(bt_ref, len_ref, q_ref, k_ref, ks_ref,
                               v_ref, vs_ref, o_ref, m_ref, l_ref,
                               acc_ref, *, page_size: int,
                               scale: float):
    bi = pl.program_id(0)
    ni = pl.program_id(2)
    n_b = pl.num_programs(2)

    @pl.when(ni == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # (G, Dk)
    k = k_ref[0, :, 0].astype(jnp.float32)             # (page, Dk) int8
    kscale = ks_ref[0, :, 0].astype(jnp.float32)       # (page,)
    v = v_ref[0, :, 0].astype(jnp.float32)
    vscale = vs_ref[0, :, 0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    s = s * kscale[None, :]                            # fold k scales
    positions = ni * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    valid = positions < len_ref[bi]
    s = jnp.where(valid, s, -jnp.inf)

    m_prev = m_ref[...]                                # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(jnp.isfinite(m_new), p, 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), alpha, 0.0)

    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    pv = p * vscale[None, :]                           # fold v scales
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        pv, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ni == n_b - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_quant(q: jax.Array, k_pages: jax.Array,
                                 k_scale_pages: jax.Array,
                                 v_pages: jax.Array,
                                 v_scale_pages: jax.Array,
                                 block_table: jax.Array,
                                 lengths: jax.Array, *,
                                 interpret: bool = False) -> jax.Array:
    """q: (B, H, Dk); k_pages/v_pages: (P, page_size, KV, Dk/Dv) int8;
    k_scale_pages/v_scale_pages: (P, page_size, KV) f32;
    block_table: (B, NB) int32 page ids; lengths: (B,) int32 valid
    positions per row. Returns (B, H, Dv)."""
    b, h, dk = q.shape
    page_size, kv = k_pages.shape[1], k_pages.shape[2]
    dv = v_pages.shape[-1]
    nb = block_table.shape[1]
    g = h // kv
    scale = 1.0 / (dk ** 0.5)

    qg = q.reshape(b, kv, g, dk)
    block_table = block_table.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,           # block_table, lengths
        grid=(b, kv, nb),
        in_specs=[
            pl.BlockSpec((1, 1, g, dk),
                         lambda bi, ki, ni, bt, ln: (bi, ki, 0, 0)),
            pl.BlockSpec((1, page_size, 1, dk),
                         lambda bi, ki, ni, bt, ln:
                         (bt[bi, ni], 0, ki, 0)),
            pl.BlockSpec((1, page_size, 1),
                         lambda bi, ki, ni, bt, ln:
                         (bt[bi, ni], 0, ki)),
            pl.BlockSpec((1, page_size, 1, dv),
                         lambda bi, ki, ni, bt, ln:
                         (bt[bi, ni], 0, ki, 0)),
            pl.BlockSpec((1, page_size, 1),
                         lambda bi, ki, ni, bt, ln:
                         (bt[bi, ni], 0, ki)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dv),
                               lambda bi, ki, ni, bt, ln:
                               (bi, ki, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),    # running max m
            pltpu.VMEM((g, 1), jnp.float32),    # running sum l
            pltpu.VMEM((g, dv), jnp.float32),   # output accumulator
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_decode_quant_kernel,
                          page_size=page_size, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, dv), q.dtype),
        interpret=interpret,
    )(block_table, lengths, qg, k_pages, k_scale_pages, v_pages,
      v_scale_pages)
    return out.reshape(b, h, dv)
