"""Pallas TPU kernel: block-table (paged) chunked-prefill attention.

The step-level serving loop feeds long prompts through the paged KV
pool one fixed-size chunk at a time (serving/step_loop.py): each chunk
writes its K/V into pool pages, then its queries attend causally over
everything written so far. This kernel is the paged flash-decode of
kernels/paged_decode_attention.py widened to a query *chunk*: the grid
walks one page per step per (batch, kv-head), page ids come from the
scalar-prefetched block table (the DMA for page ``n+1`` issues while
page ``n`` computes), and the online-softmax state (m, l, acc) — now
carried per (chunk position, group head) — rides in VMEM scratch.

Masking is two-sided: a key at absolute position ``kp`` is valid for
the chunk query at absolute position ``qp`` iff ``kp <= qp`` (causal)
— which also masks every slot past the chunk's own writes, so stale
bytes in recycled pages never reach the softmax.

Layout notes: as in the decode kernel, a page is a ``(page_size,
head_dim)`` VMEM tile per kv-head; the chunk adds a ``(C, G, Dk)`` q
tile. ``C * G`` should be a multiple of 8 sublanes for f32 — the
serving default (chunk 8, G >= 1) satisfies this; smaller chunks still
compile, just with padded tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _chunk_prefill_kernel(bt_ref, qpos_ref, q_ref, k_ref, v_ref,
                          o_ref, m_ref, l_ref, acc_ref, *,
                          page_size: int, scale: float):
    bi = pl.program_id(0)
    ni = pl.program_id(2)
    n_b = pl.num_programs(2)

    @pl.when(ni == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0].astype(jnp.float32) * scale     # (C, G, Dk)
    k = k_ref[0, :, 0].astype(jnp.float32)             # (page, Dk)
    v = v_ref[0, :, 0].astype(jnp.float32)             # (page, Dv)
    c, g = q.shape[0], q.shape[1]

    s = jnp.einsum("cgd,pd->cgp", q, k,
                   preferred_element_type=jnp.float32)  # (C, G, page)
    key_pos = ni * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, page_size), 2)
    q_pos = qpos_ref[bi].reshape(c, 1, 1)
    s = jnp.where(key_pos <= q_pos, s, -jnp.inf)

    m_prev = m_ref[...].reshape(c, g, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(jnp.isfinite(m_new), p, 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), alpha, 0.0)

    l_ref[...] = (l_ref[...].reshape(c, g, 1) * alpha
                  + p.sum(axis=-1, keepdims=True)).reshape(c, g)
    acc_ref[...] = (acc_ref[...].reshape(c, g, -1) * alpha
                    + jnp.einsum("cgp,pd->cgd", p, v,
                                 preferred_element_type=jnp.float32)
                    ).reshape(c, g, -1)
    m_ref[...] = m_new.reshape(c, g)

    @pl.when(ni == n_b - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...].reshape(c, g, 1), 1e-30)
        o_ref[0, :, 0] = (acc_ref[...].reshape(c, g, -1)
                          / l).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("prompt_len", "interpret"))
def chunked_prefill_attention(q: jax.Array, k_pages: jax.Array,
                              v_pages: jax.Array,
                              block_table: jax.Array,
                              q_positions: jax.Array, *,
                              prompt_len: int,
                              interpret: bool = False) -> jax.Array:
    """q: (B, C, H, Dk) chunk queries; k_pages/v_pages: (P, page_size,
    KV, Dk/Dv); block_table: (B, NB) int32 page ids; q_positions:
    (B, C) int32 absolute positions of each row's chunk (rows may sit
    at different prefill depths); prompt_len: static total prompt
    length (pages past it are never touched). The chunk's own K/V
    must already be written into the pages. Returns (B, C, H, Dv)."""
    b, c, h, dk = q.shape
    page_size, kv = k_pages.shape[1], k_pages.shape[2]
    dv = v_pages.shape[-1]
    g = h // kv
    nb_used = -(-prompt_len // page_size)
    scale = 1.0 / (dk ** 0.5)

    qk = q.reshape(b, c, kv, g, dk)                    # (B, C, KV, G, Dk)
    block_table = block_table.astype(jnp.int32)
    q_positions = q_positions.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,           # block_table, q_positions
        grid=(b, kv, nb_used),
        in_specs=[
            pl.BlockSpec((1, c, 1, g, dk),
                         lambda bi, ki, ni, bt, qp: (bi, 0, ki, 0, 0)),
            pl.BlockSpec((1, page_size, 1, dk),
                         lambda bi, ki, ni, bt, qp:
                         (bt[bi, ni], 0, ki, 0)),
            pl.BlockSpec((1, page_size, 1, dv),
                         lambda bi, ki, ni, bt, qp:
                         (bt[bi, ni], 0, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, 1, g, dv),
                               lambda bi, ki, ni, bt, qp:
                               (bi, 0, ki, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((c, g), jnp.float32),       # running max m
            pltpu.VMEM((c, g), jnp.float32),       # running sum l
            pltpu.VMEM((c, g, dv), jnp.float32),   # output accumulator
        ],
    )
    out = pl.pallas_call(
        functools.partial(_chunk_prefill_kernel, page_size=page_size,
                          scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, c, kv, g, dv), q.dtype),
        interpret=interpret,
    )(block_table, q_positions, qk, k_pages, v_pages)
    return out.reshape(b, c, h, dv)
