"""Pallas TPU kernel: RG-LRU linear recurrence h_t = a_t * h_{t-1} + u_t.

Same chunk-walk structure as the selective scan, but the state is a
plain (BLOCK_W,) channel vector — RecurrentGemma's gated recurrence has
no SSM state dimension. Grid = (B, W // BLOCK_W, S // CHUNK); the
channel block rides the lane axis so each fori step is one VPU
multiply-add over the block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_W = 512
DEFAULT_CHUNK = 256


def _rglru_kernel(a_ref, u_ref, y_ref, hout_ref, h_ref, *, chunk: int):
    c_idx = pl.program_id(2)
    n_c = pl.num_programs(2)

    @pl.when(c_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    def step(t, h):
        at = a_ref[0, t].astype(jnp.float32)
        ut = u_ref[0, t].astype(jnp.float32)
        h = at * h + ut
        y_ref[0, t] = h.astype(y_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])

    @pl.when(c_idx == n_c - 1)
    def _emit_state():
        hout_ref[0] = h_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("block_w", "chunk", "interpret"))
def rglru_scan(a: jax.Array, u: jax.Array, *,
               block_w: int = DEFAULT_BLOCK_W,
               chunk: int = DEFAULT_CHUNK,
               interpret: bool = False):
    """a, u: (B, S, W). Returns (hs (B, S, W) f32, h_final (B, W) f32)."""
    bsz, s, w = a.shape
    if w % block_w != 0:
        block_w = w
    if s % chunk != 0:
        chunk = s
    grid = (bsz, w // block_w, s // chunk)

    hs, h_final = pl.pallas_call(
        functools.partial(_rglru_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_w),
                         lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((1, chunk, block_w),
                         lambda bi, wi, ci: (bi, ci, wi)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_w),
                         lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((1, block_w),
                         lambda bi, wi, ci: (bi, wi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, w), jnp.float32),
            jax.ShapeDtypeStruct((bsz, w), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        interpret=interpret,
    )(a, u)
    return hs, h_final
