"""Jit'd dispatch wrappers for the Pallas kernels.

Each op picks the Pallas kernel on TPU, the interpret-mode kernel when
``interpret=True`` (CPU validation), and the pure-jnp oracle otherwise.
``ModelConfig.use_pallas`` routes the model code here for TPU
deployment; the default CPU path stays pure JAX.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.kernels import chunked_prefill_attention as _cpa
from repro.kernels import decode_attention as _da
from repro.kernels import decode_attention_quant as _daq
from repro.kernels import fused_swiglu as _fs
from repro.kernels import paged_decode_attention as _pda
from repro.kernels import paged_decode_attention_quant as _pdaq
from repro.kernels import rglru_scan as _rg
from repro.kernels import ref
from repro.kernels import selective_scan as _ss


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def decode_attention(q, k_cache, v_cache, length, *,
                     block_s: int = _da.DEFAULT_BLOCK_S,
                     interpret: Optional[bool] = None):
    if interpret is None and not _on_tpu():
        return ref.decode_attention_ref(q, k_cache, v_cache, length)
    return _da.decode_attention(q, k_cache, v_cache, length,
                                block_s=block_s,
                                interpret=bool(interpret))


def paged_decode_attention(q, k_pages, v_pages, block_table, lengths,
                           *, interpret: Optional[bool] = None):
    if interpret is None and not _on_tpu():
        return ref.paged_decode_attention_ref(q, k_pages, v_pages,
                                              block_table, lengths)
    return _pda.paged_decode_attention(q, k_pages, v_pages,
                                       block_table, lengths,
                                       interpret=bool(interpret))


def chunked_prefill_attention(q, k_pages, v_pages, block_table,
                              q_positions, *, prompt_len: int,
                              interpret: Optional[bool] = None):
    if interpret is None and not _on_tpu():
        return ref.chunked_prefill_attention_ref(
            q, k_pages, v_pages, block_table, q_positions,
            prompt_len=prompt_len)
    return _cpa.chunked_prefill_attention(
        q, k_pages, v_pages, block_table, q_positions,
        prompt_len=prompt_len, interpret=bool(interpret))


def paged_decode_attention_quant(q, k_pages, k_scale_pages, v_pages,
                                 v_scale_pages, block_table, lengths,
                                 *, interpret: Optional[bool] = None):
    if interpret is None and not _on_tpu():
        return ref.paged_decode_attention_quant_ref(
            q, k_pages, k_scale_pages, v_pages, v_scale_pages,
            block_table, lengths)
    return _pdaq.paged_decode_attention_quant(
        q, k_pages, k_scale_pages, v_pages, v_scale_pages,
        block_table, lengths, interpret=bool(interpret))


def decode_attention_quant(q, k_codes, k_scale, v_codes, v_scale,
                           length, *,
                           block_s: int = _daq.DEFAULT_BLOCK_S,
                           interpret: Optional[bool] = None):
    if interpret is None and not _on_tpu():
        from repro.models.attention import (
            decode_attention_quant as _jnp_quant)
        kpos = jax.numpy.arange(k_codes.shape[1])
        return _jnp_quant(q, k_codes, k_scale, v_codes, v_scale,
                          kpos, length - 1)
    return _daq.decode_attention_quant(
        q, k_codes, k_scale, v_codes, v_scale, length,
        block_s=block_s, interpret=bool(interpret))


def selective_scan(x, dt, a_log, b_in, c_in, *,
                   block_d: int = _ss.DEFAULT_BLOCK_D,
                   chunk: int = _ss.DEFAULT_CHUNK,
                   interpret: Optional[bool] = None):
    if interpret is None and not _on_tpu():
        return ref.selective_scan_ref(x, dt, a_log, b_in, c_in)
    return _ss.selective_scan(x, dt, a_log, b_in, c_in,
                              block_d=block_d, chunk=chunk,
                              interpret=bool(interpret))


def rglru_scan(a, u, *, block_w: int = _rg.DEFAULT_BLOCK_W,
               chunk: int = _rg.DEFAULT_CHUNK,
               interpret: Optional[bool] = None):
    if interpret is None and not _on_tpu():
        return ref.rglru_scan_ref(a, u)
    return _rg.rglru_scan(a, u, block_w=block_w, chunk=chunk,
                          interpret=bool(interpret))


def fused_swiglu(x, w_gate, w_up, w_down, *,
                 block_t: int = _fs.DEFAULT_BLOCK_T,
                 block_f: int = _fs.DEFAULT_BLOCK_F,
                 interpret: Optional[bool] = None):
    if interpret is None and not _on_tpu():
        return ref.fused_swiglu_ref(x, w_gate, w_up, w_down)
    return _fs.fused_swiglu(x, w_gate, w_up, w_down, block_t=block_t,
                            block_f=block_f, interpret=bool(interpret))
