"""Pallas TPU kernel: single-token GQA decode attention (flash-decode).

The serving hot-spot: one query token per request attending over a long
padded KV cache. TPU adaptation of GPU flash-decode: instead of one
warp per row, the cache is tiled into (BLOCK_S, head_dim) VMEM blocks
and the grid walks them sequentially per (batch, kv-head), carrying the
online-softmax state (m, l, acc) in VMEM scratch. The q-group dim (G =
H / KV) rides the sublane axis; head_dim (128 for every assigned arch)
fills the lane axis, so the score/PV contractions are MXU-shaped.

Grid: (B, KV, S // BLOCK_S) — the S axis must iterate innermost so the
scratch carries across cache blocks of the same (b, kv).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 512


def _decode_attn_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                        m_ref, l_ref, acc_ref, *, block_s: int,
                        scale: float):
    s_idx = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # (G, Dk)
    k = k_ref[0, :, 0].astype(jnp.float32)             # (BLK, Dk)
    v = v_ref[0, :, 0].astype(jnp.float32)             # (BLK, Dv)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (G, BLK)
    positions = s_idx * block_s + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_s), 1)
    valid = positions < len_ref[0]
    s = jnp.where(valid, s, -jnp.inf)

    m_prev = m_ref[...]                                # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                             # (G, BLK)
    # masked-out columns produced exp(-inf - m) = 0 already, but guard
    # the all-masked block case where m_new stays -inf:
    p = jnp.where(jnp.isfinite(m_new), p, 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), alpha, 0.0)

    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s_idx == n_s - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(q: jax.Array, k_cache: jax.Array,
                     v_cache: jax.Array, length: jax.Array,
                     *, block_s: int = DEFAULT_BLOCK_S,
                     interpret: bool = False) -> jax.Array:
    """q: (B, H, Dk); k_cache/v_cache: (B, S, KV, Dk/Dv);
    length: scalar int32 (valid cache prefix). Returns (B, H, Dv)."""
    b, h, dk = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    dv = v_cache.shape[-1]
    g = h // kv
    if s < block_s:
        block_s = s
    elif s % block_s != 0:
        # pad the cache to the next block multiple instead of
        # collapsing to one giant (s, head_dim) VMEM tile — the padded
        # positions sit past ``length`` and are masked like any other
        # invalid slot. The model path allocates caches on the block
        # grid (transformer._attn_cache_len), so this copy only runs
        # for direct off-grid callers.
        pad = block_s - s % block_s
        widths = [(0, 0), (0, pad), (0, 0), (0, 0)]
        k_cache = jnp.pad(k_cache, widths)
        v_cache = jnp.pad(v_cache, widths)
        s = s + pad
    n_s = s // block_s
    scale = 1.0 / (dk ** 0.5)

    qg = q.reshape(b, kv, g, dk)
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (1,))

    out = pl.pallas_call(
        functools.partial(_decode_attn_kernel, block_s=block_s,
                          scale=scale),
        grid=(b, kv, n_s),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),     # length (prefetch-ish)
            pl.BlockSpec((1, 1, g, dk), lambda bi, ki, si: (bi, ki, 0, 0)),
            pl.BlockSpec((1, block_s, 1, dk),
                         lambda bi, ki, si: (bi, si, ki, 0)),
            pl.BlockSpec((1, block_s, 1, dv),
                         lambda bi, ki, si: (bi, si, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dv),
                               lambda bi, ki, si: (bi, ki, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),    # running max m
            pltpu.VMEM((g, 1), jnp.float32),    # running sum l
            pltpu.VMEM((g, dv), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(length, qg, k_cache, v_cache)
    return out.reshape(b, h, dv)
