"""Pallas TPU kernel: fused SwiGLU MLP — silu(x Wg) * (x Wu) @ Wd.

The three matmuls + gate fuse into one VMEM-resident pipeline: grid =
(T // BLOCK_T, F // BLOCK_F) with the F axis innermost. For each token
block the kernel walks hidden blocks, computing the gate/up projections
on the MXU, the silu gate on the VPU, and accumulating the down
projection into an f32 VMEM scratch — the (T, F) hidden activation is
never materialised in HBM. Block sizes default to MXU-aligned 256/512.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_T = 256
DEFAULT_BLOCK_F = 512


def _swiglu_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref):
    f_idx = pl.program_id(1)
    n_f = pl.num_programs(1)

    @pl.when(f_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                     # (BT, D)
    g = jnp.dot(x, wg_ref[...],
                preferred_element_type=jnp.float32)    # (BT, BF)
    u = jnp.dot(x, wu_ref[...],
                preferred_element_type=jnp.float32)
    h = (g * jax.nn.sigmoid(g)) * u                    # silu gate, f32
    acc_ref[...] += jnp.dot(h.astype(x.dtype), wd_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(f_idx == n_f - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_t", "block_f", "interpret"))
def fused_swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                 w_down: jax.Array, *,
                 block_t: int = DEFAULT_BLOCK_T,
                 block_f: int = DEFAULT_BLOCK_F,
                 interpret: bool = False) -> jax.Array:
    """x: (T, D); w_gate/w_up: (D, F); w_down: (F, D) -> (T, D)."""
    t, d = x.shape
    f = w_gate.shape[1]
    if t % block_t != 0:
        block_t = t
    if f % block_f != 0:
        block_f = f
    grid = (t // block_t, f // block_f)

    return pl.pallas_call(
        _swiglu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d), lambda ti, fi: (ti, 0)),
            pl.BlockSpec((d, block_f), lambda ti, fi: (0, fi)),
            pl.BlockSpec((d, block_f), lambda ti, fi: (0, fi)),
            pl.BlockSpec((block_f, d), lambda ti, fi: (fi, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, d), lambda ti, fi: (ti, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_t, d), jnp.float32)],
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
