"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics the kernels must match (tests sweep shapes and
dtypes and assert allclose against these). They are also the fallback
implementation on backends without Pallas TPU support.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, length: jax.Array
                         ) -> jax.Array:
    """Single-token GQA decode attention over a padded KV cache.

    q: (B, H, Dk); k_cache/v_cache: (B, S, KV, Dk/Dv);
    length: scalar int32 — number of valid cache positions.
    Returns (B, H, Dv), computed in f32.
    """
    b, h, dk = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    scale = 1.0 / jnp.sqrt(jnp.float32(dk))
    qr = q.reshape(b, kv, g, dk).astype(jnp.float32) * scale
    scores = jnp.einsum("bkgd,bskd->bkgs", qr,
                        k_cache.astype(jnp.float32))
    valid = jnp.arange(s)[None] < length
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs,
                     v_cache.astype(jnp.float32))
    return out.reshape(b, h, v_cache.shape[-1]).astype(q.dtype)


def paged_decode_attention_ref(q: jax.Array, k_pages: jax.Array,
                               v_pages: jax.Array,
                               block_table: jax.Array,
                               lengths: jax.Array) -> jax.Array:
    """Single-token GQA decode attention over a paged KV cache.

    q: (B, H, Dk); k_pages/v_pages: (P, page_size, KV, Dk/Dv);
    block_table: (B, NB) int32 page ids per row; lengths: (B,) int32
    valid positions per row. Gathers each row's pages into a
    contiguous view and attends over the valid prefix; math in f32.
    Returns (B, H, Dv).
    """
    b, h, dk = q.shape
    page_size, kv = k_pages.shape[1], k_pages.shape[2]
    nb = block_table.shape[1]
    g = h // kv
    k_cache = k_pages[block_table].reshape(b, nb * page_size, kv, dk)
    v_cache = v_pages[block_table].reshape(b, nb * page_size, kv,
                                           v_pages.shape[-1])
    scale = 1.0 / jnp.sqrt(jnp.float32(dk))
    qr = q.reshape(b, kv, g, dk).astype(jnp.float32) * scale
    scores = jnp.einsum("bkgd,bskd->bkgs", qr,
                        k_cache.astype(jnp.float32))
    valid = jnp.arange(nb * page_size)[None] < lengths[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs,
                     v_cache.astype(jnp.float32))
    return out.reshape(b, h, v_cache.shape[-1]).astype(q.dtype)


def paged_decode_attention_quant_ref(q: jax.Array, k_pages: jax.Array,
                                     k_scale_pages: jax.Array,
                                     v_pages: jax.Array,
                                     v_scale_pages: jax.Array,
                                     block_table: jax.Array,
                                     lengths: jax.Array) -> jax.Array:
    """Single-token GQA decode over int8-quantised paged KV.

    q: (B, H, Dk); k_pages/v_pages: (P, page_size, KV, Dk/Dv) int8
    codes; k_scale_pages/v_scale_pages: (P, page_size, KV) f32
    per-vector scales; block_table: (B, NB) int32 page ids; lengths:
    (B,) int32 valid positions per row. Scales fold into the
    attention math exactly as in
    ``models.attention.decode_attention_quant``:
        scores_s = (q . k_codes_s) * k_scale_s
        out      = sum_s (p_s * v_scale_s) * v_codes_s
    Math in f32; returns (B, H, Dv).
    """
    b, h, dk = q.shape
    page_size, kv = k_pages.shape[1], k_pages.shape[2]
    nb = block_table.shape[1]
    g = h // kv
    k_cache = k_pages[block_table].reshape(b, nb * page_size, kv, dk)
    v_cache = v_pages[block_table].reshape(b, nb * page_size, kv,
                                           v_pages.shape[-1])
    k_scale = k_scale_pages[block_table].reshape(b, nb * page_size, kv)
    v_scale = v_scale_pages[block_table].reshape(b, nb * page_size, kv)
    scale = 1.0 / jnp.sqrt(jnp.float32(dk))
    qr = q.reshape(b, kv, g, dk).astype(jnp.float32) * scale
    scores = jnp.einsum("bkgd,bskd->bkgs", qr,
                        k_cache.astype(jnp.float32))
    scores = scores * k_scale.transpose(0, 2, 1)[:, :, None, :]
    valid = jnp.arange(nb * page_size)[None] < lengths[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    pv = probs * v_scale.transpose(0, 2, 1)[:, :, None, :]
    out = jnp.einsum("bkgs,bskd->bkgd", pv,
                     v_cache.astype(jnp.float32))
    return out.reshape(b, h, v_cache.shape[-1]).astype(q.dtype)


def chunked_prefill_attention_ref(q: jax.Array, k_pages: jax.Array,
                                  v_pages: jax.Array,
                                  block_table: jax.Array,
                                  q_positions: jax.Array, *,
                                  prompt_len: int) -> jax.Array:
    """Chunked-prefill GQA attention over a paged KV cache.

    q: (B, C, H, Dk) chunk queries at absolute positions
    ``q_positions`` (B, C) — rows may sit at different prefill
    depths; k_pages/v_pages: (P, page_size, KV, Dk/Dv); block_table:
    (B, NB) int32 page ids. The chunk's own K/V must already be
    written into the pages. Gathers each row's pages to the static
    ``prompt_len`` and attends causally (key position <= query
    position); math in f32. Returns (B, C, H, Dv).
    """
    b, c, h, dk = q.shape
    page_size, kv = k_pages.shape[1], k_pages.shape[2]
    nb = block_table.shape[1]
    g = h // kv
    k_cache = k_pages[block_table].reshape(
        b, nb * page_size, kv, dk)[:, :prompt_len]
    v_cache = v_pages[block_table].reshape(
        b, nb * page_size, kv, v_pages.shape[-1])[:, :prompt_len]
    scale = 1.0 / jnp.sqrt(jnp.float32(dk))
    qr = q.reshape(b, c, kv, g, dk).astype(jnp.float32) * scale
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qr,
                        k_cache.astype(jnp.float32))
    valid = q_positions[:, :, None] >= \
        jnp.arange(prompt_len)[None, None]                 # (B, C, S)
    scores = jnp.where(valid[:, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs,
                     v_cache.astype(jnp.float32))
    return out.reshape(b, c, h, v_cache.shape[-1]).astype(q.dtype)


def selective_scan_ref(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                       b_in: jax.Array, c_in: jax.Array,
                       h0: Optional[jax.Array] = None
                       ) -> Tuple[jax.Array, jax.Array]:
    """Sequential Mamba-1 selective scan (the definitional oracle).

    x, dt: (B, S, D); a_log: (D, N); b_in, c_in: (B, S, N).
    Returns (y (B, S, D), h_final (B, D, N)); math in f32.
    """
    bsz, s, d = x.shape
    n = a_log.shape[1]
    a = -jnp.exp(a_log.astype(jnp.float32))

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = b_in.astype(jnp.float32)
    cf = c_in.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((bsz, d, n), jnp.float32)

    def step(h, t):
        xt, dtt, bt, ct = t
        dta = jnp.exp(dtt[..., None] * a)              # (B, D, N)
        u = (dtt * xt)[..., None] * bt[:, None, :]
        h = dta * h + u
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    ts = (xf.swapaxes(0, 1), dtf.swapaxes(0, 1),
          bf.swapaxes(0, 1), cf.swapaxes(0, 1))
    h_final, ys = jax.lax.scan(step, h0, ts)
    return ys.swapaxes(0, 1).astype(x.dtype), h_final


def rglru_scan_ref(a: jax.Array, u: jax.Array,
                   h0: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Sequential linear recurrence h_t = a_t * h_{t-1} + u_t.

    a, u: (B, S, W) f32 gates/inputs. Returns (hs (B,S,W), h_final).
    """
    bsz, s, w = a.shape
    if h0 is None:
        h0 = jnp.zeros((bsz, w), jnp.float32)

    def step(h, t):
        at, ut = t
        h = at * h + ut
        return h, h

    h_final, hs = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (a.astype(jnp.float32).swapaxes(0, 1),
         u.astype(jnp.float32).swapaxes(0, 1)))
    return hs.swapaxes(0, 1), h_final


def fused_swiglu_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                     w_down: jax.Array) -> jax.Array:
    """SwiGLU MLP: silu(x @ w_gate) * (x @ w_up) @ w_down.

    x: (T, D); w_gate/w_up: (D, F); w_down: (F, D).
    """
    g = jnp.einsum("td,df->tf", x, w_gate)
    u = jnp.einsum("td,df->tf", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("tf,fd->td", h, w_down)
