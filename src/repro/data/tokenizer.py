"""Deterministic char-level tokenizer for the runnable examples.

Vocabulary covers the arithmetic task surface ("3 + 4 = -7") plus BOS/
EOS/PAD. Fixed, code-defined vocab keeps the substrate deterministic
(no learned tokenizer artifacts to fingerprint).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

PAD, BOS, EOS = 0, 1, 2
_CHARS = "0123456789+-*= ."
CHAR_TO_ID = {c: i + 3 for i, c in enumerate(_CHARS)}
ID_TO_CHAR = {i: c for c, i in CHAR_TO_ID.items()}
VOCAB_SIZE = 3 + len(_CHARS)


def encode(text: str, add_bos: bool = True,
           add_eos: bool = False) -> List[int]:
    ids = [CHAR_TO_ID[c] for c in text if c in CHAR_TO_ID]
    if add_bos:
        ids = [BOS] + ids
    if add_eos:
        ids = ids + [EOS]
    return ids


def decode(ids: Sequence[int]) -> str:
    out = []
    for i in ids:
        i = int(i)
        if i == EOS:
            break
        if i in (PAD, BOS):
            continue
        out.append(ID_TO_CHAR.get(i, ""))
    return "".join(out)


def encode_batch(texts: Sequence[str], length: int) -> np.ndarray:
    """Right-pad each encoded text to ``length`` (PAD)."""
    out = np.full((len(texts), length), PAD, np.int32)
    for r, t in enumerate(texts):
        ids = encode(t)[:length]
        out[r, :len(ids)] = ids
    return out


def encode_aligned(texts: Sequence[str]) -> np.ndarray:
    """Encode prompts for GENERATION: uniform length, no padding.

    Right-padding a prompt before decoding puts PAD tokens between the
    prompt and the model's continuation — a train/serve mismatch that
    wrecks generation. The arithmetic task surface is naturally uniform
    ("d op d = "); this asserts that and appends the trailing space the
    training corpus used before the answer span.
    """
    rows = [encode(t if t.endswith(" ") else t + " ") for t in texts]
    length = len(rows[0])
    assert all(len(r) == length for r in rows),         "generation prompts must be uniform length (got mixed lengths)"
    return np.asarray(rows, np.int32)
