from repro.data.tasks import (
    BENCHMARKS, PAPER_MIX, Task, arithmetic_suite, paper_suite,
    split_by_benchmark)

__all__ = [
    "BENCHMARKS", "PAPER_MIX", "Task", "arithmetic_suite", "paper_suite",
    "split_by_benchmark",
]
