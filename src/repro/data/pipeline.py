"""Training data pipelines.

Two sources, one interface (an iterator of ``Batch``):

* ``arithmetic_batches`` — genuinely learnable char-level arithmetic
  ("a + b = c<eos>"), loss-masked to the answer span. The example
  drivers train the reduced zoo models on this so the end-to-end ACAR
  serving path runs over models that actually know something.
* ``synthetic_lm_batches`` — deterministic Zipf-distributed token
  stream with local n-gram structure, for throughput-style training
  runs at arbitrary (batch, seq, vocab). Purely seeded; no files.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Tuple

import numpy as np

from repro.data import tokenizer as tok


@dataclass(frozen=True)
class Batch:
    tokens: np.ndarray       # (B, S) int32 — model input
    labels: np.ndarray       # (B, S) int32 — next-token targets
    loss_mask: np.ndarray    # (B, S) float32


def _arith_example(rng: np.random.Generator, max_operand: int
                   ) -> Tuple[str, str]:
    a = int(rng.integers(0, max_operand + 1))
    b = int(rng.integers(0, max_operand + 1))
    op = "+" if rng.random() < 0.5 else "-"
    res = a + b if op == "+" else a - b
    return f"{a} {op} {b} = ", str(res)


def arithmetic_batches(batch_size: int, seq_len: int, *,
                       seed: int = 0, max_operand: int = 9
                       ) -> Iterator[Batch]:
    """Infinite stream of fixed-shape arithmetic batches."""
    rng = np.random.default_rng(seed)
    while True:
        tokens = np.full((batch_size, seq_len), tok.PAD, np.int32)
        mask = np.zeros((batch_size, seq_len), np.float32)
        for r in range(batch_size):
            prompt, answer = _arith_example(rng, max_operand)
            ids = tok.encode(prompt) + tok.encode(
                answer, add_bos=False, add_eos=True)
            ids = ids[:seq_len]
            tokens[r, :len(ids)] = ids
            ans_start = len(tok.encode(prompt))
            # loss on predicting the answer span (incl. EOS):
            # position i predicts token i+1.
            mask[r, max(ans_start - 1, 0):len(ids) - 1] = 1.0
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = tok.PAD
        yield Batch(tokens=tokens, labels=labels, loss_mask=mask)


def synthetic_lm_batches(batch_size: int, seq_len: int, vocab: int, *,
                         seed: int = 0, zipf_a: float = 1.2
                         ) -> Iterator[Batch]:
    """Deterministic structured token stream (Zipf unigrams + a cyclic
    bigram tendency so there is signal to learn)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = ranks ** (-zipf_a)
    probs /= probs.sum()
    while True:
        base = rng.choice(vocab, size=(batch_size, seq_len), p=probs)
        # bigram structure: with p=0.35 a token is (prev*7+3) % vocab
        follow = (np.roll(base, 1, axis=1) * 7 + 3) % vocab
        pick = rng.random((batch_size, seq_len)) < 0.35
        tokens = np.where(pick, follow, base).astype(np.int32)
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = 0
        mask = np.ones((batch_size, seq_len), np.float32)
        mask[:, -1] = 0.0
        yield Batch(tokens=tokens, labels=labels, loss_mask=mask)
