"""Synthetic task suites.

Two suites:

* ``paper_suite`` — 1,510 tasks mirroring the paper's benchmark mix
  (MathArena 60 / Reasoning Gym 250 / LiveCodeBench 200 / SuperGPQA
  1,000) with latent difficulty distributions per benchmark. Used with
  the calibrated SyntheticBackend to regenerate the paper's tables.
* ``arithmetic_suite`` — genuinely solvable few-token arithmetic tasks
  used with real (tiny) JAX models in the runnable examples, so the
  full probe -> sigma -> route -> ensemble path executes end to end.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

BENCHMARKS = ("matharena", "reasoning_gym", "livecodebench", "supergpqa")
PAPER_MIX = {
    "matharena": 60,
    "reasoning_gym": 250,
    "livecodebench": 200,
    "supergpqa": 1000,
}
BENCH_KIND = {
    "matharena": "math",
    "reasoning_gym": "reasoning",
    "livecodebench": "code",
    "supergpqa": "mcq",
}

# latent difficulty ~ N(mu, sd), higher = harder. Tuned so that the
# calibrated model-skill profile reproduces the paper's per-benchmark
# accuracies (see benchmarks/table1_overall.py).
# difficulty is a BIMODAL mixture (paper Fig. 1: bimodality is what
# makes routing effective): (p_easy, mu_easy, sd_easy, mu_hard, sd_hard)
BENCH_DIFFICULTY = {
    "matharena": (0.05, -0.5, 0.4, 2.2, 0.7),
    "reasoning_gym": (0.18, -1.2, 0.5, 1.15, 0.8),
    "livecodebench": (0.15, -1.0, 0.5, 0.8, 0.8),
    "supergpqa": (0.33, -1.5, 0.5, 1.2, 0.7),
}
# size of the per-task wrong-answer pool and its concentration: a small,
# concentrated pool yields correlated errors -> agreement-but-wrong.
BENCH_CONFUSION = {
    "matharena": (45, 0.98),    # diverse wrong numbers -> sigma=1 (93%)
    "reasoning_gym": (20, 0.95),
    "livecodebench": (8, 0.6),
    "supergpqa": (9, 0.65),     # 10-option MCQ (SuperGPQA)
}


@dataclass(frozen=True)
class Task:
    task_id: str
    benchmark: str
    kind: str                  # math | reasoning | code | mcq
    text: str
    gold: str
    difficulty: float          # latent, synthetic-backend only
    wrong_pool: Tuple[str, ...] = ()
    wrong_weights: Tuple[float, ...] = ()


def _mk_wrong_pool(rng: np.random.Generator, kind: str, gold: str,
                   size: int, conc: float):
    if kind == "mcq":
        pool = [c for c in "ABCDEFGHIJ" if c != gold][:size]
    elif kind == "math":
        base = int(float(gold)) if gold.lstrip("-").isdigit() else 0
        deltas = rng.choice(np.arange(1, 50), size=size, replace=False)
        signs = rng.choice([-1, 1], size=size)
        pool = [str(base + int(d) * int(s))
                for d, s in zip(deltas, signs)]
    else:
        pool = [f"alt_{i}_{rng.integers(1 << 30)}" for i in range(size)]
    w = np.array([conc ** i for i in range(len(pool))], np.float64)
    w /= w.sum()
    return tuple(pool), tuple(float(x) for x in w)


def paper_suite(seed: int = 0) -> List[Task]:
    """1,510 tasks mirroring the paper's benchmark mix."""
    rng = np.random.default_rng(seed)
    tasks: List[Task] = []
    for bench in BENCHMARKS:
        n = PAPER_MIX[bench]
        kind = BENCH_KIND[bench]
        p_easy, mu_e, sd_e, mu_h, sd_h = BENCH_DIFFICULTY[bench]
        pool_size, conc = BENCH_CONFUSION[bench]
        for i in range(n):
            if rng.random() < p_easy:
                d = float(rng.normal(mu_e, sd_e))
            else:
                d = float(rng.normal(mu_h, sd_h))
            if kind == "mcq":
                gold = "ABCDEFGHIJ"[rng.integers(10)]
            elif kind == "math":
                gold = str(int(rng.integers(-500, 500)))
            elif kind == "code":
                gold = f"impl_{rng.integers(1 << 30)}"
            else:
                gold = f"concl_{rng.integers(1 << 30)}"
            pool, w = _mk_wrong_pool(rng, kind, gold, pool_size, conc)
            tasks.append(Task(
                task_id=f"{bench}-{i:04d}",
                benchmark=bench,
                kind=kind,
                # diverse token surface -> realistic low cross-task
                # retrieval similarity (the paper's 0.167 median regime)
                text=" ".join(
                    f"w{rng.integers(300_000)}" for _ in range(16)),
                gold=gold,
                difficulty=d,
                wrong_pool=pool,
                wrong_weights=w,
            ))
    return tasks


# ----------------------------------------------------------------------
# genuinely solvable arithmetic tasks for the JAX-model examples
# ----------------------------------------------------------------------
def arithmetic_suite(n: int = 64, seed: int = 0,
                     max_operand: int = 9) -> List[Task]:
    rng = np.random.default_rng(seed)
    tasks = []
    for i in range(n):
        a = int(rng.integers(0, max_operand + 1))
        b = int(rng.integers(0, max_operand + 1))
        op = rng.choice(["+", "-"])
        gold = a + b if op == "+" else a - b
        tasks.append(Task(
            task_id=f"arith-{i:04d}",
            benchmark="arithmetic",
            kind="math",
            text=f"{a} {op} {b} =",
            gold=str(gold),
            difficulty=0.0,
        ))
    return tasks


def split_by_benchmark(tasks: List[Task]) -> Dict[str, List[Task]]:
    out: Dict[str, List[Task]] = {}
    for t in tasks:
        out.setdefault(t.benchmark, []).append(t)
    return out
