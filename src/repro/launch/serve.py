"""ACAR serving driver — end-to-end over real JAX models.

Trains (or loads) a probe + ensemble of reduced zoo models on the
arithmetic corpus, then serves a task batch through the batched ACAR
engine: (B x N) probe decode -> EXTRACT -> on-device sigma/routing ->
masked ensemble decodes -> vectorised judge. Prints accuracy, routing
distribution, and ensemble calls saved.

    PYTHONPATH=src python -m repro.launch.serve --tasks 32 \
        --train-steps 300

Fleet members are registry arch names, optionally with a page-layout
variant suffix: ``arch:quant`` serves from int8-quantised KV pages,
``arch:swaN`` serves with an N-token sliding window (ring pages in
the stepped engine). Variants share the base arch's training — the
cache layout only changes how the member serves. ``--hetero-fleet``
is the paper's headline mix in one flag (Mamba probe, quant + SWA
members, a full-attention arena member):

    PYTHONPATH=src python -m repro.launch.serve --hetero-fleet \
        --step-loop --tasks 32
"""
from __future__ import annotations

import argparse
import collections
from typing import Dict, List, Optional, Sequence

import jax

from repro.checkpoint import restore_checkpoint
from repro.configs.acar import ACARConfig
from repro.configs.registry import ARCH_IDS
from repro.core.extract import extract
from repro.core.sigma import MODE_NAMES
from repro.data.tasks import Task, arithmetic_suite
from repro.launch.train import reduced_for_data, train
from repro.models import params as params_lib
from repro.serving import BatchedACAREngine, ZooModel

DEFAULT_PROBE = "smollm-135m"
DEFAULT_ENSEMBLE = ("llama3-8b", "deepseek-7b", "recurrentgemma-2b")
# the paper's headline heterogeneous mix: a cheap recurrent probe, a
# quant-KV member and a sliding-window member beside a full-attention
# arena member — all four page layouts in one stepped fleet
HETERO_PROBE = "falcon-mamba-7b"
HETERO_ENSEMBLE = ("smollm-135m:quant", "smollm-135m:swa16",
                   "llama3-8b")


def parse_member(spec: str):
    """``arch[:quant|:swaN]`` -> (base arch, cfg variant applier).

    The variant changes the member's serving cache layout only (int8
    KV pages / ring pages); training always runs on the base arch."""
    arch, _, var = spec.partition(":")
    if arch not in ARCH_IDS:
        raise SystemExit(
            f"unknown arch {arch!r} (choose from {sorted(ARCH_IDS)})")
    if not var:
        return arch, lambda cfg: cfg
    if var == "quant":
        return arch, lambda cfg: cfg.replace(kv_quant=True)
    if var.startswith("swa"):
        window = int(var[3:] or 16)
        return arch, lambda cfg: cfg.replace(window=window)
    raise SystemExit(
        f"unknown member variant {spec!r} "
        "(use arch, arch:quant, or arch:swaN)")


def build_zoo(archs: Sequence[str], train_steps: int, seed: int = 0,
              ckpts: Optional[Dict[str, str]] = None,
              verbose: bool = True) -> List[ZooModel]:
    """Train (or restore) reduced arithmetic models for each member
    spec (``arch`` or ``arch:variant``)."""
    zoo = []
    for i, spec in enumerate(archs):
        arch, variant = parse_member(spec)
        cfg = variant(reduced_for_data(arch, "arithmetic"))
        if ckpts and spec in ckpts:
            template = params_lib.init_params(
                cfg, jax.random.PRNGKey(seed + i))
            prm = restore_checkpoint(ckpts[spec], template)
        else:
            if verbose:
                print(f"-- training {spec} ({train_steps} steps)")
            _, prm, _ = train(arch=arch, data="arithmetic",
                              steps=train_steps, batch=64, seq=24,
                              lr=2e-3, seed=seed + i, verbose=False)
        zoo.append(ZooModel(name=spec, cfg=cfg, params=prm))
    return zoo


def serve(tasks: Sequence[Task], probe: ZooModel,
          ensemble: Sequence[ZooModel], acfg: ACARConfig,
          verbose: bool = True,
          scheduler: bool = False,
          step_loop: bool = False,
          batch_size: int = 8,
          data_shards: Optional[int] = None,
          megastep: int = 1,
          trace_path: Optional[str] = None,
          lineage_task: Optional[str] = None) -> dict:
    """Serve tasks through the batched engine. With ``scheduler=True``
    the request stream flows through the admission queue and is served
    as micro-batches of at most ``batch_size`` (continuous-batching
    path); with ``step_loop=True`` it runs the step-level loop
    (streaming admission + chunked prefill + mixed-phase decode
    steps — requires a paged-capable probe); ``data_shards`` runs that
    loop on a sharded serving mesh (per-shard paged KV pools, needs
    that many visible devices); ``megastep`` fuses up to that many
    decode ticks into one device launch (bit-identical outputs, fewer
    host round-trips); otherwise the whole suite runs as one batch.

    ``trace_path`` arms the deterministic span tracer and flushes the
    hash-chained span JSONL there; ``lineage_task`` (implies tracing)
    walks the PROV graph backwards from that task's final answer and
    prints the verified lineage."""
    tracer = None
    if trace_path is not None or lineage_task is not None:
        from repro.serving.tracing import SpanTracer
        tracer = SpanTracer(trace_path)
    engine = BatchedACAREngine(acfg, probe, ensemble)
    if verbose:
        from repro.models.transformer import resolve_layout
        layouts = {m.name: (resolve_layout(m.cfg) or "dense*")
                   for m in [probe] + list(ensemble)}
        print("fleet layouts     : " + ", ".join(
            f"{n}={l}" for n, l in layouts.items()))
    if step_loop or data_shards is not None or megastep > 1:
        from repro.serving.queue import MicroBatchPolicy
        res = engine.run_stepped(
            list(tasks), MicroBatchPolicy(max_batch_size=batch_size),
            data_shards=data_shards, megastep=megastep,
            tracer=tracer)
        scheduler = True          # report the queued-shape extras
    elif scheduler or tracer is not None:
        from repro.serving.queue import MicroBatchPolicy
        res = engine.run_queued(
            list(tasks), MicroBatchPolicy(max_batch_size=batch_size),
            tracer=tracer)
        scheduler = True
    else:
        res = engine.run_batch(list(tasks))
    correct = sum(
        1 for t, a in zip(tasks, res.final_answers)
        if extract(a, t.kind) == t.gold or a == t.gold)
    dist = collections.Counter(
        MODE_NAMES[m] for m in res.modes)
    out = {
        "accuracy": correct / len(tasks),
        "mode_distribution": dict(dist),
        "ensemble_calls_saved": res.ensemble_calls_saved,
        "wall_ms": res.wall_ms,
        "sigma_mean": float(res.sigma.mean()),
    }
    cs = res.compaction
    if cs is not None:
        out["ensemble_decode_tokens"] = cs.ensemble_decode_tokens
        out["ensemble_decode_tokens_saved"] = \
            cs.ensemble_decode_tokens_saved
        out["ensemble_decode_token_reduction"] = \
            cs.ensemble_decode_token_reduction
        out["probe_prefill_reduction"] = cs.probe_prefill_reduction
    if scheduler:
        out["batch_sizes"] = res.batch_sizes
    if verbose:
        print(f"served {len(tasks)} tasks in {res.wall_ms:.0f} ms")
        print(f"accuracy          : {out['accuracy']:.3f}")
        print(f"mode distribution : {out['mode_distribution']}")
        print(f"calls saved       : {out['ensemble_calls_saved']} "
              f"of {3 * len(tasks)}")
        if cs is not None:
            print(f"compaction        : "
                  f"{cs.ensemble_decode_tokens} ensemble decode tokens "
                  f"({cs.ensemble_decode_tokens_saved} saved, "
                  f"{out['ensemble_decode_token_reduction']:.2f}x), "
                  f"probe prefill "
                  f"{out['probe_prefill_reduction']:.2f}x fewer tokens")
        if scheduler:
            print(f"micro-batches     : {res.batch_sizes}")
            if getattr(res, "step", None) is not None:
                print(f"step loop         : {res.step.ticks} ticks, "
                      f"{res.step.invocations} program launches, "
                      f"{res.step.prefill_chunks} prefill chunks")
            print(res.metrics.render())
    if tracer is not None and getattr(res, "spans", None) is not None:
        out["spans"] = len(res.spans)
        out["span_head"] = res.span_head
        if verbose:
            print(f"spans             : {len(res.spans)} "
                  f"(head {res.span_head[:16]}...)"
                  + (f" -> {trace_path}" if trace_path else ""))
        if lineage_task is not None:
            from repro.teamllm.prov import lineage
            lin = lineage(res.spans, lineage_task)
            out["lineage_ok"] = lin["ok"]
            out["lineage_verified"] = lin["verified"]
            if verbose:
                print(f"lineage           : task {lineage_task} "
                      f"trace {lin['trace']} — "
                      f"{lin['verified']} span hashes verified, "
                      f"{'OK' if lin['ok'] else 'FAILED'}")
                for rec in lin["records"]:
                    if rec["kind"] == "entity":
                        print(f"  entity   {rec['id']}")
                    elif rec["kind"] == "wasDerivedFrom":
                        via = f" via {rec['via']}" if "via" in rec \
                            else ""
                        print(f"  derived  {rec['entity']} <- "
                              f"{rec['source']}{via}")
                    elif rec["kind"] == "wasGeneratedBy":
                        print(f"  genBy    {rec['entity']} <- "
                              f"{rec['activity']}")
                for f in lin["hash_failures"]:
                    print(f"  FAIL     {f}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tasks", type=int, default=32)
    ap.add_argument("--train-steps", type=int, default=300)
    ap.add_argument("--probe", default=DEFAULT_PROBE,
                    help="probe member spec: a registry arch name, "
                         "optionally with a page-layout variant "
                         "suffix (arch, arch:quant, arch:swaN)")
    ap.add_argument("--ensemble", nargs="+",
                    default=list(DEFAULT_ENSEMBLE),
                    help="ensemble member specs (same syntax as "
                         "--probe)")
    ap.add_argument("--hetero-fleet", action="store_true",
                    help="serve the paper's heterogeneous mix "
                         f"(probe {HETERO_PROBE}, ensemble "
                         f"{', '.join(HETERO_ENSEMBLE)}) — overrides "
                         "--probe/--ensemble")
    ap.add_argument("--probe-temperature", type=float, default=0.7)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scheduler", action="store_true",
                    help="serve via the admission queue as "
                         "micro-batches (continuous batching)")
    ap.add_argument("--step-loop", action="store_true",
                    help="serve via the step-level loop (streaming "
                         "admission, chunked prefill, mixed-phase "
                         "decode steps; needs a paged-capable probe)")
    ap.add_argument("--shards", type=int, default=None,
                    help="run the step loop on a data-sharded serving "
                         "mesh with this many shards (implies "
                         "--step-loop; needs that many devices — on "
                         "CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count)")
    ap.add_argument("--megastep", type=int, default=1,
                    help="fuse up to K decode ticks per device launch "
                         "in the step loop (implies --step-loop; "
                         "bit-identical outputs at any K)")
    ap.add_argument("--batch-size", type=int, default=8,
                    help="micro-batch size budget for --scheduler")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="arm the deterministic span tracer and flush "
                         "the hash-chained span JSONL here (span "
                         "structure is bit-identical run to run; "
                         "wall-times ride the non-hashed side channel)")
    ap.add_argument("--lineage", default=None, metavar="TASK_ID",
                    help="after serving, walk the PROV lineage of this "
                         "task's final answer (answer -> judge -> "
                         "members -> route -> probe samples, plus KV "
                         "page-reuse derivations) and verify every "
                         "span hash on the walk (implies tracing)")
    args = ap.parse_args(argv)

    if args.hetero_fleet:
        args.probe = HETERO_PROBE
        args.ensemble = list(HETERO_ENSEMBLE)
    zoo = build_zoo([args.probe] + list(args.ensemble),
                    args.train_steps, seed=args.seed)
    probe, ensemble = zoo[0], zoo[1:]
    acfg = ACARConfig(probe_model=args.probe,
                      ensemble_models=tuple(args.ensemble),
                      probe_temperature=args.probe_temperature,
                      seed=args.seed)
    tasks = arithmetic_suite(args.tasks, seed=args.seed + 99)
    serve(tasks, probe, ensemble, acfg,
          scheduler=args.scheduler, step_loop=args.step_loop,
          batch_size=args.batch_size, data_shards=args.shards,
          megastep=args.megastep, trace_path=args.trace,
          lineage_task=args.lineage)


if __name__ == "__main__":
    main()
