"""Step builders + abstract input specs shared by train.py / serve.py /
dryrun.py.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model
input of a given (arch, input-shape) — weak-type-correct, shardable, no
device allocation. ``make_train_step`` / ``make_serve_step`` /
``make_prefill_step`` build the jittable step functions; the sharding
helpers map every leaf (params, optimizer state, batch, KV/state cache)
to a NamedSharding on the production mesh.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig, TrainConfig
from repro.models import params as params_lib
from repro.models import transformer as T
from repro.models.frontends import audio_frame_shape, vision_patch_shape
from repro.optim import AdamWState, softmax_cross_entropy, update
from repro.sharding import mesh_axis_size

PyTree = Any


# ----------------------------------------------------------------------
# step builders
# ----------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    remat = cfg.remat == "layer"

    def train_step(params: PyTree, opt_state: AdamWState,
                   batch: Dict[str, jax.Array]):
        moe_shards = mesh_axis_size("batch")

        def loss_fn(p):
            logits, aux = T.forward(
                cfg, p, batch["tokens"],
                batch.get("frontend_embeds"),
                remat=remat, moe_shards=moe_shards)
            loss, met = softmax_cross_entropy(
                logits, batch["labels"], batch.get("loss_mask"))
            total = loss
            if cfg.moe is not None:
                total = total + cfg.moe.router_aux_weight * aux
            met = dict(met, aux_loss=aux)
            return total, met

        (total, met), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_params, new_state, om = update(params, grads, opt_state, tc)
        return new_params, new_state, {**met, **om, "total_loss": total}

    return train_step


def make_prefill_step(cfg: ModelConfig):
    """(params, tokens[, frontend]) -> (last-pos logits, decode cache)."""

    def prefill_step(params, tokens, frontend_embeds=None):
        return T.prefill(cfg, params, tokens, frontend_embeds)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One decode step: (params, cache, token, pos) -> (logits, cache)."""

    def serve_step(params, cache, token, pos):
        return T.decode_step(cfg, params, cache, token, pos)

    return serve_step


# ----------------------------------------------------------------------
# abstract input specs (no allocation)
# ----------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _frontend_sds(cfg: ModelConfig, batch: int):
    if cfg.frontend == "audio":
        return _sds(audio_frame_shape(cfg, batch), cfg.dtype)
    if cfg.frontend == "vision":
        return _sds(vision_patch_shape(cfg, batch), cfg.dtype)
    return None


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one step's model inputs.

    train   -> {tokens, labels, loss_mask[, frontend_embeds]}
    prefill -> {tokens[, frontend_embeds]}
    decode  -> {cache, token, pos}
    """
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        out = {
            "tokens": _sds((b, s), jnp.int32),
            "labels": _sds((b, s), jnp.int32),
            "loss_mask": _sds((b, s), jnp.float32),
        }
        fe = _frontend_sds(cfg, b)
        if fe is not None:
            out["frontend_embeds"] = fe
        return out
    if shape.kind == "prefill":
        out = {"tokens": _sds((b, s), jnp.int32)}
        fe = _frontend_sds(cfg, b)
        if fe is not None:
            out["frontend_embeds"] = fe
        return out
    assert shape.kind == "decode"
    cache = jax.eval_shape(lambda: T.init_cache(cfg, b, s))
    return {
        "cache": cache,
        "token": _sds((b,), jnp.int32),
        "pos": _sds((), jnp.int32),
    }


# ----------------------------------------------------------------------
# sharding specs
# ----------------------------------------------------------------------
def batch_pspec(rules: dict) -> P:
    return P(rules["batch"])


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def train_input_pspecs(cfg: ModelConfig, specs: Dict[str, Any],
                       rules: dict) -> Dict[str, P]:
    ba = rules["batch"]
    out = {}
    for k, v in specs.items():
        out[k] = P(ba, *([None] * (v.ndim - 1)))
    return out


def sanitize_pspec(shape: Tuple[int, ...], spec: P, mesh: Mesh) -> P:
    """Drop mesh axes whose size does not divide the dim — explicit
    pjit in/out shardings require exact divisibility (unlike
    with_sharding_constraint, which pads)."""
    dims = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for d, ax in zip(shape, dims):
        if ax is not None and d % _axis_size(mesh, ax) != 0:
            ax = None
        out.append(ax)
    return P(*out)


def sanitize_tree(sds_tree: PyTree, pspec_tree: PyTree,
                  mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda sds, spec: sanitize_pspec(sds.shape, spec, mesh),
        sds_tree, pspec_tree,
        is_leaf=lambda x: isinstance(x, P))


def cache_pspecs(cfg: ModelConfig, cache_sds: PyTree, mesh: Mesh,
                 rules: dict) -> PyTree:
    """PartitionSpec tree for a decode cache.

    Attention KV caches shard by KV head when the head count divides the
    model axis; MQA / small-KV caches shard along the *sequence* axis
    instead (Pope-style MQA decode sharding). SSM / RG-LRU state shards
    along the channel dim; MLA latent caches shard along sequence.
    """
    ba = rules["batch"]
    model_n = mesh.shape.get("model", 1)

    def spec_for(path, leaf):
        keys = [getattr(p, "key", None) for p in path]
        keys = [k for k in keys if k is not None]
        name = keys[-1] if keys else ""
        stacked = any(k in ("layers", "dec_layers", "cross")
                      for k in keys)
        off = 1 if stacked else 0
        dims = [None] * leaf.ndim
        if leaf.ndim > off:
            dims[off] = ba
        if name in ("k", "v", "k_scale", "v_scale"):
            # (.., B, S, KV, hd) / scales (.., B, S, KV)
            kv = leaf.shape[off + 2]
            seq = leaf.shape[off + 1]
            if kv % model_n == 0:
                dims[off + 2] = "model"
            elif seq % model_n == 0:
                dims[off + 1] = "model"
        elif name in ("c_kv", "k_rope"):
            seq = leaf.shape[off + 1]
            if seq % model_n == 0:
                dims[off + 1] = "model"
        elif name == "conv":
            # (.., B, w-1, d_in)
            if leaf.shape[off + 2] % model_n == 0:
                dims[off + 2] = "model"
        elif name == "h":
            # ssm: (.., B, d_in, n); rglru: (.., B, w)
            if leaf.shape[off + 1] % model_n == 0:
                dims[off + 1] = "model"
        return P(*dims)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_sds)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in flat])


def opt_state_pspecs(param_specs: PyTree) -> AdamWState:
    return AdamWState(step=P(), mu=param_specs, nu=param_specs)


def to_shardings(mesh: Mesh, pspecs: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P))


# ----------------------------------------------------------------------
# full per-(arch, shape) lowering spec
# ----------------------------------------------------------------------
def abstract_opt_state(abs_params: PyTree) -> AdamWState:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree.map(f32, abs_params),
        nu=jax.tree.map(f32, abs_params),
    )


def build_lowering(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                   rules: dict, tc: Optional[TrainConfig] = None):
    """Returns (jitted_fn, example_args) ready for ``.lower(*args)``.

    All array arguments are ShapeDtypeStructs carrying NamedShardings —
    nothing is allocated.
    """
    abs_params = params_lib.abstract_params(cfg)
    pspecs = sanitize_tree(abs_params,
                           params_lib.param_specs(cfg, rules), mesh)
    p_shard = to_shardings(mesh, pspecs)
    specs = input_specs(cfg, shape)

    def with_sharding(sds, sharding):
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                    sharding=sharding)

    abs_params = jax.tree.map(with_sharding, abs_params, p_shard)

    if shape.kind == "train":
        tc = tc or TrainConfig()
        step = make_train_step(cfg, tc)
        in_pspecs = sanitize_tree(
            specs, train_input_pspecs(cfg, specs, rules), mesh)
        in_shard = to_shardings(mesh, in_pspecs)
        batch = jax.tree.map(with_sharding, specs, in_shard)
        o_shard = opt_state_pspecs(pspecs)
        opt_sds = abstract_opt_state(abs_params)
        opt_sds = jax.tree.map(
            with_sharding, opt_sds,
            to_shardings(mesh, o_shard))
        jitted = jax.jit(
            step,
            out_shardings=(p_shard, to_shardings(mesh, o_shard), None))
        return jitted, (abs_params, opt_sds, batch)

    if shape.kind == "prefill":
        step = make_prefill_step(cfg)
        in_pspecs = sanitize_tree(
            specs, train_input_pspecs(cfg, specs, rules), mesh)
        in_shard = to_shardings(mesh, in_pspecs)
        args = [abs_params,
                with_sharding(specs["tokens"], in_shard["tokens"])]
        if "frontend_embeds" in specs:
            args.append(with_sharding(specs["frontend_embeds"],
                                      in_shard["frontend_embeds"]))
        jitted = jax.jit(step)
        return jitted, tuple(args)

    # decode
    step = make_serve_step(cfg)
    c_pspecs = sanitize_tree(
        specs["cache"], cache_pspecs(cfg, specs["cache"], mesh, rules),
        mesh)
    c_shard = to_shardings(mesh, c_pspecs)
    cache = jax.tree.map(with_sharding, specs["cache"], c_shard)
    token = with_sharding(
        specs["token"],
        NamedSharding(mesh, sanitize_pspec(
            specs["token"].shape, P(rules["batch"]), mesh)))
    pos = with_sharding(specs["pos"], NamedSharding(mesh, P()))
    jitted = jax.jit(step, out_shardings=(None, c_shard))
    return jitted, (abs_params, cache, token, pos)
