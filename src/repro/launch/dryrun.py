from repro.xla_flags import force_host_device_count
force_host_device_count(512)
# The two lines above MUST run before any jax-touching import: jax
# locks the device count on first initialisation, and the production
# meshes below need 512 placeholder host devices. The helper *merges*
# into any user-exported XLA_FLAGS (preserving their other flags and
# their own device-count override) instead of clobbering the variable.
# Smoke tests and benches see 1 CPU — nothing else sets this flag.
"""Multi-pod dry-run: lower + compile every (architecture x input
shape) on the production meshes and extract roofline inputs.

Per combo this emits ``experiments/dryrun/<arch>__<shape>__<mesh>.json``
with: HLO FLOPs + bytes (``compiled.cost_analysis()``), per-device
memory (``compiled.memory_analysis()``), and collective bytes parsed
from the post-SPMD HLO (sum of operand sizes over all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute).

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch llama3-8b --shape train_4k --mesh single
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path
from typing import Dict, Optional

import jax

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.launch.mesh import (
    make_production_mesh, mesh_chip_count, rules_for)
from repro.sharding import rule_set
from repro.launch.steps import build_lowering
from repro.sharding import axis_rules

DEFAULT_OUT = Path("experiments/dryrun")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
    "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(
    r"\b(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|f64|c64|c128)"
    r"\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter",
                "all-to-all", "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_NAME_RE = re.compile(r"%([\w.\-]+)")


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective op kind.

    Post-optimisation HLO prints operands as bare ``%name`` references,
    so first build a name -> output-bytes map from every instruction
    definition, then resolve the operand lists of collective calls.
    NOTE: inside a ``while`` body instructions print once — the dry-run
    extrapolates scan-body collectives via the unrolled correction
    compiles (see run_combo).
    """
    defs: Dict[str, int] = {}
    lines = hlo_text.splitlines()
    for line in lines:
        ls = line.strip()
        if not ls.startswith(("%", "ROOT %")) or " = " not in ls:
            continue
        name_part, rhs = ls.split(" = ", 1)
        m = _NAME_RE.search(name_part)
        if not m:
            continue
        # output type(s): everything before the op-call token "name("
        call = re.search(r"[a-z][\w\-]*\(", rhs)
        type_str = rhs[:call.start()] if call else rhs
        defs[m.group(1)] = sum(_shape_bytes(d, s)
                               for d, s in _SHAPE_RE.findall(type_str))
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in lines:
        if " = " not in line:
            continue
        rhs = line.split(" = ", 1)[1]
        for op in _COLLECTIVES:
            if f" {op}(" not in f" {rhs}" \
                    and f" {op}-start(" not in f" {rhs}":
                continue
            idx = rhs.find(op + "(")
            if idx < 0:
                idx = rhs.find(op + "-start(")
            operands = rhs[rhs.index("(", idx):]
            depth = 0
            for j, ch in enumerate(operands):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        operands = operands[:j + 1]
                        break
            inline = sum(_shape_bytes(d, s)
                         for d, s in _SHAPE_RE.findall(operands))
            if inline == 0:
                inline = sum(defs.get(n, 0)
                             for n in _NAME_RE.findall(operands))
            out[op] += inline
            counts[op] += 1
            break
    out["counts"] = counts
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """Useful-compute reference: 6*N*D train, 2*N*D forward-only."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch   # one token / request


def should_skip(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("pure full-attention architecture: 524k decode requires "
                "sub-quadratic attention (DESIGN.md §4)")
    return None


# ----------------------------------------------------------------------
# cost-exact correction compiles
# ----------------------------------------------------------------------
def _layer_period(cfg: ModelConfig) -> int:
    return len(cfg.layer_pattern) if cfg.layer_pattern else 1


def _n_unrolled(cfg: ModelConfig) -> int:
    if cfg.moe is not None and cfg.moe.first_moe_layer > 0:
        return cfg.moe.first_moe_layer
    return 0


def correction_configs(cfg: ModelConfig):
    """Two small fully-unrolled variants whose cost difference is the
    exact per-layer-period cost (XLA counts while bodies once)."""
    import dataclasses as _dc
    period = _layer_period(cfg)
    base = _n_unrolled(cfg)
    k1, k2 = base + period, base + 2 * period

    def shrink(k):
        c = cfg.replace(num_layers=k, scan_layers=False)
        if cfg.encoder is not None:
            c = c.replace(encoder=_dc.replace(cfg.encoder, num_layers=k))
        return c

    return shrink(k1), shrink(k2), k1, k2, period


_COST_KEYS = ("hlo_flops", "hlo_bytes", "hlo_transcendentals")


def extract_costs(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return {
        "hlo_flops": float(cost.get("flops", 0.0)),
        "hlo_bytes": float(cost.get("bytes accessed", 0.0)),
        "hlo_transcendentals": float(cost.get("transcendentals", 0.0)),
        "collective": coll,
    }


def extrapolate_costs(c1: dict, c2: dict, num_layers: int, k1: int,
                      k2: int, period: int) -> dict:
    """corrected = cost(k2) + (L-k2)/period * (cost(k2) - cost(k1))."""
    f = (num_layers - k2) / period
    out = {}
    for k in _COST_KEYS:
        out[k] = c2[k] + f * (c2[k] - c1[k])
    coll = {}
    for k in _COLLECTIVES:
        coll[k] = max(c2["collective"][k]
                      + f * (c2["collective"][k] - c1["collective"][k]),
                      0.0)
    coll["total"] = sum(coll.values())
    out["collective"] = coll
    return out


def _compile(cfg: ModelConfig, shape: InputShape, mesh, rules,
             unrolled: bool = False):
    from repro.models.scan_flags import unrolled_costs
    import contextlib
    ctx = unrolled_costs() if unrolled else contextlib.nullcontext()
    with mesh, axis_rules(mesh, rules), ctx:
        jitted, args = build_lowering(cfg, shape, mesh, rules)
        return jitted.lower(*args).compile()


def run_combo(arch: str, shape_name: str, mesh_kind: str,
              out_dir: Path, save_hlo: bool = False,
              correct: bool = True, rules_name: str = "default") -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "rules": rules_name}
    skip = should_skip(cfg, shape)
    if skip:
        rec.update(status="skipped", reason=skip)
        _write(out_dir, rec)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = rule_set(rules_name, multi_pod=(mesh_kind == "multi")) \
        if rules_name != "default" else rules_for(mesh)
    t0 = time.perf_counter()
    try:
        # The deliverable compile: full config, scanned layer stacks.
        compiled = _compile(cfg, shape, mesh, rules)
        t_compile = time.perf_counter() - t0
    except Exception as e:  # noqa: BLE001 — record the failure
        rec.update(status="failed", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        _write(out_dir, rec)
        return rec

    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", 0),
        }
    except Exception as e:  # noqa: BLE001
        mem_rec = {"error": str(e)}

    raw = extract_costs(compiled)
    rec.update(
        status="ok",
        chips=mesh_chip_count(mesh),
        compile_s=round(t_compile, 2),
        raw=raw,                      # scan bodies counted once
        memory=mem_rec,
        model_flops=model_flops(cfg, shape),
        params=cfg.param_count(),
        active_params=cfg.active_param_count(),
        num_layers=cfg.num_layers,
    )

    if correct:
        # Cost-exact extrapolation from two small unrolled compiles
        # (XLA counts scan/while bodies once; see models/scan_flags.py).
        try:
            t1 = time.perf_counter()
            cfg1, cfg2, k1, k2, period = correction_configs(cfg)
            c1 = extract_costs(_compile(cfg1, shape, mesh, rules,
                                        unrolled=True))
            c2 = extract_costs(_compile(cfg2, shape, mesh, rules,
                                        unrolled=True))
            rec["corrected"] = extrapolate_costs(
                c1, c2, cfg.num_layers, k1, k2, period)
            rec["correction"] = {
                "k1": k1, "k2": k2, "period": period,
                "compile_s": round(time.perf_counter() - t1, 2)}
        except Exception as e:  # noqa: BLE001
            rec["corrected"] = None
            rec["correction"] = {"error": f"{type(e).__name__}: {e}"}

    if save_hlo:
        (out_dir / f"{arch}__{shape_name}__{mesh_kind}.hlo.txt"
         ).write_text(compiled.as_text())
    _write(out_dir, rec)
    return rec


def _write(out_dir: Path, rec: dict) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = "" if rec.get("rules", "default") == "default" \
        else f"__{rec['rules']}"
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{suffix}.json"
    (out_dir / name).write_text(json.dumps(rec, indent=1))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", action="append", choices=ARCH_IDS)
    ap.add_argument("--shape", action="append",
                    choices=tuple(INPUT_SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=("single", "multi", "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--no-correct", action="store_true",
                    help="skip the cost-exact correction compiles")
    ap.add_argument("--rules", default="default",
                    choices=("default", "dp", "no-kv-shard", "ep"),
                    help="sharding rule-set (perf iterations)")
    args = ap.parse_args(argv)

    archs = list(ARCH_IDS) if (args.all or not args.arch) else args.arch
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else args.shape
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    out_dir = Path(args.out)

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                # cost-exact corrections feed the (single-pod) roofline
                # table; the multi-pod pass proves lowering only.
                rec = run_combo(arch, shape, mesh_kind, out_dir,
                                save_hlo=args.save_hlo,
                                correct=(mesh_kind == "single"
                                         and not args.no_correct),
                                rules_name=args.rules)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    cc = rec.get("corrected") or rec["raw"]
                    extra = (f"flops {cc['hlo_flops']:.3e} "
                             f"coll {cc['collective']['total']:.3e}B "
                             f"compile {rec['compile_s']}s")
                elif status == "failed":
                    extra = rec["error"][:120]
                    n_fail += 1
                print(f"[{status:7s}] {arch:24s} {shape:12s} "
                      f"{mesh_kind:6s} {extra}", flush=True)
    if n_fail:
        raise SystemExit(f"{n_fail} combos failed")


if __name__ == "__main__":
    main()
