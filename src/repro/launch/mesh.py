"""Production meshes.

Defined as FUNCTIONS (not module-level constants) so importing this
module never touches jax device state — the dry-run sets
``--xla_force_host_platform_device_count`` before first jax init, and
smoke tests must keep seeing one device.

Target hardware: TPU v5e-like pods — 256 chips/pod, 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI. Single pod is a (16, 16) ("data",
"model") mesh; multi-pod prepends a "pod" axis that extends data
parallelism (gradient all-reduce crosses pods in training; pure request
parallelism in serving).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.sharding import MULTI_POD_RULES, SINGLE_POD_RULES


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — run "
            "under launch/dryrun.py, which forces 512 host devices")
    dev = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev, axes)


def make_serving_mesh(data: Optional[int] = None,
                      model: int = 1) -> Mesh:
    """Request-parallel mesh for the sharded serving subsystem: each
    "data" shard owns a slice of the paged KV pool and decodes its
    resident rows. ``model > 1`` adds a second ("model",) axis so each
    data shard runs ensemble members tensor-parallel across ``model``
    devices (heads/kv_heads/ff shard per ``sharding/partitioning.py``);
    ``model=1`` keeps the 1-D ("data",) mesh byte-compatible with the
    pre-2-D subsystem. ``data=None`` takes every visible device (divided
    by ``model``). On CPU, run under
    ``--xla_force_host_platform_device_count=N`` (see
    ``repro.xla_flags.force_host_device_count``) to get N devices."""
    devices = jax.devices()
    m = int(model)
    if m < 1:
        raise ValueError("serving mesh needs model >= 1")
    n = (len(devices) // m) if data is None else int(data)
    if n < 1:
        raise ValueError("serving mesh needs at least one shard")
    need = n * m
    if len(devices) < need:
        raise RuntimeError(
            f"serving mesh data={n} model={m} needs {need} devices, "
            f"have {len(devices)} — on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need} before jax "
            "initialises (repro.xla_flags.force_host_device_count)")
    if m == 1:
        return Mesh(np.asarray(devices[:n]), ("data",))
    dev = np.asarray(devices[:need]).reshape(n, m)
    return Mesh(dev, ("data", "model"))


def make_smoke_mesh(model: int = 1) -> Mesh:
    """1xN mesh over however many devices exist (tests/examples)."""
    devices = jax.devices()
    n = len(devices)
    assert n % model == 0
    dev = np.asarray(devices).reshape(n // model, model)
    return Mesh(dev, ("data", "model"))


def rules_for(mesh: Mesh) -> dict:
    return MULTI_POD_RULES if "pod" in mesh.axis_names \
        else SINGLE_POD_RULES


def mesh_chip_count(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
