# Launch layer: meshes, step builders, train/serve drivers, dry-run.
# dryrun.py must be imported/run standalone (it sets XLA_FLAGS first).
from repro.launch.mesh import (
    make_production_mesh, make_smoke_mesh, mesh_chip_count, rules_for)
from repro.launch.steps import (
    build_lowering, cache_pspecs, input_specs, make_prefill_step,
    make_serve_step, make_train_step)

__all__ = [
    "build_lowering", "cache_pspecs", "input_specs",
    "make_prefill_step", "make_production_mesh", "make_serve_step",
    "make_smoke_mesh", "make_train_step", "mesh_chip_count",
    "rules_for",
]
