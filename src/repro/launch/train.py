"""Training driver.

Real execution path (CPU examples / TPU deployment alike): build the
config, init params + optimizer, jit the train step with sharded
in/out specs under the active mesh, and run the data pipeline.

CLI (reduced configs; full configs are exercised via dryrun.py):

    PYTHONPATH=src python -m repro.launch.train \
        --arch smollm-135m --data arithmetic --steps 300 \
        --batch 64 --seq 24 --ckpt /tmp/smollm.npz
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs.base import TrainConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.data import tokenizer as tok
from repro.data.pipeline import arithmetic_batches, synthetic_lm_batches
from repro.launch.steps import make_train_step
from repro.models import params as params_lib
from repro.models.frontends import synthetic_frames, synthetic_patches
from repro.optim import init as opt_init


def reduced_for_data(arch: str, data: str):
    """Reduced config adapted to the selected dataset."""
    cfg = get_config(arch, reduced=True)
    if data == "arithmetic":
        cfg = cfg.replace(vocab_size=tok.VOCAB_SIZE, dtype="float32",
                          tie_embeddings=True)
    else:
        cfg = cfg.replace(dtype="float32")
    return cfg


def train(arch: str = "smollm-135m", data: str = "arithmetic",
          steps: int = 300, batch: int = 64, seq: int = 24,
          lr: float = 1e-3, seed: int = 0,
          ckpt: Optional[str] = None, log_every: int = 50,
          reduced: bool = True, verbose: bool = True):
    cfg = reduced_for_data(arch, data) if reduced \
        else get_config(arch)
    tc = TrainConfig(learning_rate=lr, warmup_steps=min(50, steps // 4),
                     total_steps=steps, seed=seed)
    params = params_lib.init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = opt_init(params)
    step_fn = jax.jit(make_train_step(cfg, tc))

    if data == "arithmetic":
        it = arithmetic_batches(batch, seq, seed=seed)
    else:
        it = synthetic_lm_batches(batch, seq, cfg.vocab_size, seed=seed)

    fe = None
    if cfg.frontend == "audio":
        fe = synthetic_frames(cfg, batch, seed)
    elif cfg.frontend == "vision":
        fe = synthetic_patches(cfg, batch, seed)

    t0 = time.perf_counter()
    metrics = {}
    for i in range(steps):
        b = next(it)
        batch_dict = {
            "tokens": jnp.asarray(b.tokens),
            "labels": jnp.asarray(b.labels),
            "loss_mask": jnp.asarray(b.loss_mask),
        }
        if fe is not None:
            batch_dict["frontend_embeds"] = fe
        params, opt_state, metrics = step_fn(params, opt_state,
                                             batch_dict)
        if verbose and (i % log_every == 0 or i == steps - 1):
            print(f"step {i:5d}  loss {float(metrics['loss']):.4f}  "
                  f"tok_acc {float(metrics['token_accuracy']):.3f}  "
                  f"gnorm {float(metrics['grad_norm']):.2f}  "
                  f"lr {float(metrics['lr']):.2e}")
    wall = time.perf_counter() - t0
    if verbose:
        n_params = params_lib.count_params(params)
        print(f"trained {arch} ({n_params / 1e6:.1f}M params) "
              f"{steps} steps in {wall:.1f}s "
              f"({steps / wall:.2f} steps/s)")
    if ckpt:
        save_checkpoint(ckpt, params, step=steps,
                        metadata={"arch": arch, "data": data})
        if verbose:
            print(f"checkpoint -> {ckpt}")
    return cfg, params, metrics


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m", choices=ARCH_IDS)
    ap.add_argument("--data", default="arithmetic",
                    choices=("arithmetic", "synthetic"))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seq", type=int, default=24)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args(argv)
    train(arch=args.arch, data=args.data, steps=args.steps,
          batch=args.batch, seq=args.seq, lr=args.lr, seed=args.seed,
          ckpt=args.ckpt)


if __name__ == "__main__":
    main()
