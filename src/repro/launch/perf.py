from repro.xla_flags import force_host_device_count
force_host_device_count(512)
# Must run before any jax-touching import — see launch/dryrun.py
# (merges into user-set XLA_FLAGS instead of clobbering them).
"""§Perf hillclimb runner: named (arch, shape, rules, config-transform)
variants, lowered on the single-pod production mesh, recorded to
experiments/perf/<variant>.json with the same cost extraction as the
dry-run. EXPERIMENTS.md §Perf documents each hypothesis -> change ->
before -> after cycle.

    PYTHONPATH=src python -m repro.launch.perf --variant A2 [--all]
"""
import argparse
import dataclasses
import json
import time
from pathlib import Path

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import get_config
from repro.launch.dryrun import (
    _compile, correction_configs, extract_costs, extrapolate_costs,
    model_flops)
from repro.launch.mesh import make_production_mesh, mesh_chip_count
from repro.sharding import rule_set

OUT = Path("experiments/perf")


def _moe_cap(cfg, cap):
    return cfg.replace(moe=dataclasses.replace(cfg.moe,
                                               capacity_factor=cap))


# variant -> (arch, shape, rules-name, config transform or None)
VARIANTS = {
    # A: smollm-135m x train_4k — worst useful-FLOPs fraction
    "A0": ("smollm-135m", "train_4k", "default", None),
    "A1": ("smollm-135m", "train_4k", "dp", None),
    "A2": ("smollm-135m", "train_4k", "dp",
           lambda c: c.replace(remat="none")),
    # B: deepseek-v2-236b x prefill_32k — most collective-bound
    "B0": ("deepseek-v2-236b", "prefill_32k", "default", None),
    "B1": ("deepseek-v2-236b", "prefill_32k", "ep", None),
    "B2": ("deepseek-v2-236b", "prefill_32k", "ep",
           lambda c: _moe_cap(c, 1.0)),
    # C: llama3-8b x decode_32k — the ACAR serving step
    "C0": ("llama3-8b", "decode_32k", "default", None),
    "C1": ("llama3-8b", "decode_32k", "no-kv-shard", None),
    # C2: int8 KV cache (symmetric per-vector quant; halves cache
    # storage + decode read traffic; scales fold into attention math)
    "C2": ("llama3-8b", "decode_32k", "default",
           lambda c: c.replace(kv_quant=True)),
    # C3: int8 KV + batch also over the model axis (decode is pure
    # request parallelism for the cache; 128 % 256 != 0 so the batch
    # stays on "data" — kept for the record, falls back to C2 behavior)
    "C2_long": ("granite-34b", "decode_32k", "default",
                lambda c: c.replace(kv_quant=True)),
    # C2 applied to the HBM-overflow case found in SDry-run
    "C2_ds7b": ("deepseek-7b", "decode_32k", "default",
                lambda c: c.replace(kv_quant=True)),
    "C2_mixtral": ("mixtral-8x22b", "decode_32k", "default",
                   lambda c: c.replace(kv_quant=True)),
}


def run_variant(name: str) -> dict:
    arch, shape_name, rules_name, transform = VARIANTS[name]
    cfg = get_config(arch)
    if transform:
        cfg = transform(cfg)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh()
    rules = rule_set(rules_name)
    t0 = time.perf_counter()
    compiled = _compile(cfg, shape, mesh, rules)
    try:
        mem = compiled.memory_analysis()
        mem_rec = {"argument_bytes": mem.argument_size_in_bytes,
                   "temp_bytes": mem.temp_size_in_bytes,
                   "output_bytes": mem.output_size_in_bytes}
    except Exception as e:  # noqa: BLE001
        mem_rec = {"error": str(e)}
    cfg1, cfg2, k1, k2, period = correction_configs(cfg)
    c1 = extract_costs(_compile(cfg1, shape, mesh, rules, unrolled=True))
    c2 = extract_costs(_compile(cfg2, shape, mesh, rules, unrolled=True))
    rec = {
        "variant": name, "arch": arch, "shape": shape_name,
        "rules": rules_name, "status": "ok", "mesh": "single",
        "chips": mesh_chip_count(mesh),
        "compile_s": round(time.perf_counter() - t0, 2),
        "raw": extract_costs(compiled),
        "corrected": extrapolate_costs(c1, c2, cfg.num_layers, k1, k2,
                                       period),
        "memory": mem_rec,
        "model_flops": model_flops(cfg, shape),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / f"{name}.json").write_text(json.dumps(rec, indent=1))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--variant", action="append",
                    choices=tuple(VARIANTS))
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args(argv)
    names = tuple(VARIANTS) if args.all else (args.variant or ())
    import sys
    sys.path.insert(0, ".")
    from benchmarks.roofline import analyse_record
    for name in names:
        rec = run_variant(name)
        r = analyse_record(rec)
        print(f"[{name}] compute {r['compute_s']:.3e} "
              f"memory {r['memory_s']:.3e} "
              f"collective {r['collective_s']:.3e} "
              f"bound={r['bottleneck']} "
              f"useful={r['useful_flops_ratio']:.2%}", flush=True)


if __name__ == "__main__":
    main()
