"""XLA_FLAGS helpers that are safe to run before jax initialises.

Several entry points need ``--xla_force_host_platform_device_count``
set *before* the first jax backend initialisation (the device count
locks then): the dry-run/perf compiles force 512 placeholder host
devices, and the sharded-serving harness forces a small CPU device
mesh. Assigning ``os.environ["XLA_FLAGS"] = ...`` outright clobbers
whatever the user already exported (custom partitioner flags, dump
paths, or their *own* device-count override) — these helpers merge
instead.

This module must stay import-light: no jax, no repro.* imports — the
callers run it as their very first statement.
"""
from __future__ import annotations

import os
import re
import sys
from typing import Optional, Sequence

_COUNT_RE = re.compile(
    r"--xla_force_host_platform_device_count=(\d+)")
_FLAG = "--xla_force_host_platform_device_count"


def host_device_count(flags: Optional[str]) -> Optional[int]:
    """Parse an existing host-device-count override out of a flags
    string; None when the flag is absent."""
    if not flags:
        return None
    m = _COUNT_RE.search(flags)
    return int(m.group(1)) if m else None


def merge_host_device_count(flags: Optional[str], count: int) -> str:
    """Return ``flags`` with the host-device-count flag ensured.

    Every other flag is preserved verbatim, and an *existing*
    ``--xla_force_host_platform_device_count`` wins over ``count`` —
    a user who exported their own override keeps it.
    """
    parts = [p for p in (flags or "").split() if p]
    if any(p.startswith(_FLAG) for p in parts):
        return " ".join(parts)
    parts.append(f"{_FLAG}={count}")
    return " ".join(parts)


def force_host_device_count(count: int, env=None) -> str:
    """Merge the host-device-count flag into ``env['XLA_FLAGS']``
    (default ``os.environ``) and return the resulting flags string.
    Must run before the first jax backend initialisation to have any
    effect — jax locks the device count then."""
    if env is None:
        env = os.environ
    merged = merge_host_device_count(env.get("XLA_FLAGS"), count)
    env["XLA_FLAGS"] = merged
    return merged


def argv_int(argv: Sequence[str], flag: str, default: int) -> int:
    """Read an integer option from an argv slice, accepting both the
    ``--flag N`` and ``--flag=N`` spellings argparse accepts."""
    argv = list(argv)
    for i, a in enumerate(argv):
        if a == flag and i + 1 < len(argv):
            return int(argv[i + 1])
        if a.startswith(flag + "="):
            return int(a.split("=", 1)[1])
    return default


def reexec_with_host_devices(count: int,
                             argv: Sequence[str]) -> None:
    """Re-exec the current interpreter with the host-device-count flag
    merged into XLA_FLAGS — the escape hatch for CLIs that need a
    multi-device CPU mesh but were launched without one (jax locks
    the count at first backend init, so setting it in-process is too
    late once anything touched a device). No-op when the environment
    already carries a count: a user-set override always wins, and the
    downstream mesh constructor raises a clear error if it is too
    small. ``argv`` is the exec argv after the interpreter path."""
    if host_device_count(os.environ.get("XLA_FLAGS")) is not None:
        return
    env = dict(os.environ)
    env["XLA_FLAGS"] = merge_host_device_count(
        env.get("XLA_FLAGS"), count)
    os.execve(sys.executable, [sys.executable] + list(argv), env)
