"""Per-architecture smoke tests (deliverable f): a REDUCED variant of
each assigned family runs one forward + one train step + one
prefill/decode step on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import TrainConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.launch.steps import make_train_step
from repro.models import params as params_lib
from repro.models import transformer as T
from repro.models.frontends import synthetic_frames, synthetic_patches
from repro.optim import init as opt_init

# JIT/compile-heavy: excluded from the fast inner loop (-m 'not slow')
pytestmark = pytest.mark.slow


B, S = 2, 16


def setup_model(arch):
    cfg = get_config(arch, reduced=True)
    params = params_lib.init_params(cfg, jax.random.PRNGKey(0))
    fe = None
    if cfg.frontend == "audio":
        fe = synthetic_frames(cfg, B)
    elif cfg.frontend == "vision":
        fe = synthetic_patches(cfg, B)
    return cfg, params, fe


def assert_finite(name, x):
    assert not bool(jnp.isnan(x.astype(jnp.float32)).any()), \
        f"{name}: NaN"
    assert not bool(jnp.isinf(x.astype(jnp.float32)).any()), \
        f"{name}: Inf"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_constraints(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.num_layers <= 4
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg, params, fe = setup_model(arch)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    logits, aux = T.forward(cfg, params, tokens, fe)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert_finite(arch, logits)
    assert_finite(arch + "/aux", aux)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg, params, fe = setup_model(arch)
    cfg = cfg.replace(dtype="float32")
    params = params_lib.init_params(cfg, jax.random.PRNGKey(0))
    if fe is not None:
        fe = fe.astype(jnp.float32)
    tc = TrainConfig(total_steps=10)
    step = jax.jit(make_train_step(cfg, tc))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                     cfg.vocab_size),
        "loss_mask": jnp.ones((B, S), jnp.float32),
    }
    if fe is not None:
        batch["frontend_embeds"] = fe
    new_params, opt_state, metrics = step(params, opt_init(params),
                                          batch)
    assert_finite(arch + "/loss", metrics["loss"])
    assert float(metrics["loss"]) > 0
    assert int(opt_state.step) == 1
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, new_params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """decode_step(pos=S) after prefill must equal forward logits of the
    extended sequence at the same position (teacher forcing parity).

    MoE capacity is raised so no tokens drop: the full-sequence path
    uses capacity dispatch (drops on overflow), the decode path is
    exact top-k — parity is only defined without drops."""
    import dataclasses
    cfg, params, fe = setup_model(arch)
    cfg = cfg.replace(dtype="float32")
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))
    params = params_lib.init_params(cfg, jax.random.PRNGKey(0))
    if fe is not None:
        fe = fe.astype(jnp.float32)
    key = jax.random.PRNGKey(3)
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    prompt, nxt = tokens[:, :S], tokens[:, S]

    full_logits, _ = T.forward(cfg, params, tokens, fe)
    lp, cache = T.prefill(cfg, params, prompt, fe, cache_len=S + 1)
    # prefill last-position logits == forward logits at S-1
    assert jnp.allclose(lp, full_logits[:, S - 1], atol=2e-2), arch
    ld, _ = T.decode_step(cfg, params, cache, nxt, jnp.int32(S))
    assert jnp.allclose(ld, full_logits[:, S], atol=2e-2), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """Full configs carry the exact assigned hyperparameters."""
    expected = {
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "falcon-mamba-7b": (64, 4096, None, None, 0, 65024),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
    }[arch]
    cfg = get_config(arch)
    L, d, h, kv, ff, v = expected
    assert cfg.num_layers == L and cfg.d_model == d
    assert cfg.vocab_size == v
    if h is not None:
        assert cfg.num_heads == h and cfg.num_kv_heads == kv
    if arch == "deepseek-v2-236b":
        assert cfg.moe.num_experts == 160 and cfg.moe.top_k == 6
        assert cfg.moe.num_shared_experts == 2
        assert cfg.mla.kv_lora_rank == 512
        assert cfg.moe.d_ff_expert == ff
    elif arch == "mixtral-8x22b":
        assert cfg.moe.num_experts == 8 and cfg.moe.top_k == 2
        assert cfg.window is not None          # SWA
        assert cfg.moe.d_ff_expert == ff
    elif arch == "falcon-mamba-7b":
        assert cfg.ssm.state_dim == 16
        assert cfg.is_attention_free
    elif arch == "recurrentgemma-2b":
        kinds = cfg.layer_kinds
        # 1:2 attn:rglru pattern, tiled over 26 layers (26 % 3 != 0)
        assert abs(kinds.count("rglru") - 2 * kinds.count("attn")) <= 2
        assert cfg.d_ff == ff
    else:
        assert cfg.d_ff == ff
