"""Seeded example-driven stand-ins for ``hypothesis``.

The property tests in this repo use a small slice of the hypothesis
API: ``given``, ``settings``, and the ``lists`` / ``sampled_from`` /
``integers`` strategies. When hypothesis is installed the real library
is used (see the try/except at each test module's top); when it is
not, these shims run each property as a deterministic, seeded sweep of
generated examples. No shrinking, no database — just enough coverage
that the properties are genuinely exercised on a bare interpreter.
"""
from __future__ import annotations

import inspect
import random
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence

DEFAULT_MAX_EXAMPLES = 25


class Strategy:
    def example(self, rng: random.Random) -> Any:
        raise NotImplementedError


@dataclass
class _SampledFrom(Strategy):
    options: Sequence[Any]

    def example(self, rng: random.Random) -> Any:
        return self.options[rng.randrange(len(self.options))]


@dataclass
class _Integers(Strategy):
    min_value: int
    max_value: int

    def example(self, rng: random.Random) -> int:
        return rng.randint(self.min_value, self.max_value)


@dataclass
class _Lists(Strategy):
    elements: Strategy
    min_size: int
    max_size: int

    def example(self, rng: random.Random) -> list:
        n = rng.randint(self.min_size, self.max_size)
        return [self.elements.example(rng) for _ in range(n)]


@dataclass
class _Tuples(Strategy):
    parts: Sequence[Strategy]

    def example(self, rng: random.Random) -> tuple:
        return tuple(p.example(rng) for p in self.parts)


class strategies:
    """Namespace mirroring ``hypothesis.strategies``."""

    @staticmethod
    def sampled_from(options: Sequence[Any]) -> Strategy:
        return _SampledFrom(list(options))

    @staticmethod
    def integers(min_value: int = -(1 << 31),
                 max_value: int = (1 << 31) - 1) -> Strategy:
        return _Integers(min_value, max_value)

    @staticmethod
    def lists(elements: Strategy, min_size: int = 0,
              max_size: int = 10) -> Strategy:
        return _Lists(elements, min_size, max_size)

    @staticmethod
    def tuples(*parts: Strategy) -> Strategy:
        return _Tuples(parts)

    @staticmethod
    def booleans() -> Strategy:
        return _SampledFrom([False, True])


def given(*strats: Strategy) -> Callable:
    """Run the wrapped test over a seeded sweep of examples.

    The seed derives from the test's qualified name, so a failing
    example is reproducible run to run.
    """
    def deco(fn: Callable) -> Callable:
        def wrapper() -> None:
            # honour @settings whether applied above @given (attribute
            # lands on the wrapper) or beneath it (on the raw fn)
            n = getattr(wrapper, "_propshim_max_examples",
                        getattr(fn, "_propshim_max_examples",
                                DEFAULT_MAX_EXAMPLES))
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                fn(*(s.example(rng) for s in strats))
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        # zero-arg signature: pytest must not treat the property's
        # generated parameters as fixtures
        wrapper.__signature__ = inspect.Signature()
        wrapper._propshim_given = True
        return wrapper
    return deco


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES,
             deadline: Optional[Any] = None, **_ignored: Any) -> Callable:
    def deco(fn: Callable) -> Callable:
        fn._propshim_max_examples = max_examples
        return fn
    return deco
