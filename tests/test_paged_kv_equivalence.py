"""Paged <-> dense engine equivalence (the tentpole contract).

The paged KV subsystem must be an allocation strategy, not a semantic
change: identical sigma, modes, final answers, per-member answers, and
trace record hashes as the dense tile_cache path — across escalation
rates, bucket-straddling batch sizes, and duplicate-bearing streams
that exercise the prompt prefix cache — while measurably reusing
prefill work through retained pages.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from harness.simulate import run_paged_kv_equivalence

# JIT/compile-heavy: excluded from the fast inner loop (-m 'not slow')
pytestmark = pytest.mark.slow


def forced_route(rate: float):
    def route(sig):
        b = sig.shape[0]
        modes = np.zeros(b, np.int32)
        k = int(round(rate * b))
        for j in range(k):
            modes[j] = 1 + (j % 2)
        return jnp.asarray(modes)
    return route


@pytest.mark.parametrize("batch_size", [6, 8])
@pytest.mark.parametrize("rate", [0.0, 0.5, 1.0])
def test_paged_equivalence_forced_rates(rate, batch_size, tmp_path):
    report = run_paged_kv_equivalence(
        n_tasks=batch_size * 2, batch_size=batch_size,
        route_fn=forced_route(rate),
        workdir=tmp_path / f"r{rate}-b{batch_size}")
    assert report.ok, report.summary()
    if rate > 0.0:
        # escalated rows exist and the arena's third member is the
        # probe model: prefill reuse must engage (probe->ensemble
        # seeding on the compacted subset, or the prefix cache when a
        # member decodes the full batch)
        assert report.prefill_tokens_reused > 0


def test_paged_equivalence_emergent_routing_with_duplicates(tmp_path):
    """Whatever the tiny probe's sigma emerges as, paged and dense
    must agree bit-for-bit across multiple micro-batches; the
    duplicate resubmissions drive prompt prefix-cache hits."""
    report = run_paged_kv_equivalence(
        n_tasks=24, batch_size=5, duplicate_rate=0.4,
        workdir=tmp_path)
    assert report.ok, report.summary()


def test_paged_probe_reuse_at_paper_rate(tmp_path):
    """At the paper's ~45.8% escalation, probe->ensemble prefill
    seeding must be active (nonzero reused tokens through the
    compacted subset path)."""
    report = run_paged_kv_equivalence(
        n_tasks=16, batch_size=8, route_fn=forced_route(0.458),
        workdir=tmp_path)
    assert report.ok, report.summary()
    assert report.prefill_tokens_reused_probe > 0
