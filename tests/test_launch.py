"""Launch layer: lowering specs + a real compile of each step kind on a
1-device smoke mesh (the 256/512-device meshes are dryrun.py-only)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import (
    INPUT_SHAPES, InputShape, TrainConfig)
from repro.configs.registry import ARCH_IDS, get_config
from repro.launch.mesh import make_smoke_mesh, rules_for
from repro.launch.steps import (
    build_lowering, cache_pspecs, input_specs)
from repro.launch.train import train
from repro.sharding import axis_rules

# JIT/compile-heavy: excluded from the fast inner loop (-m 'not slow')
pytestmark = pytest.mark.slow


SMALL_TRAIN = InputShape("train_small", 32, 4, "train")
SMALL_PREFILL = InputShape("prefill_small", 64, 2, "prefill")
SMALL_DECODE = InputShape("decode_small", 64, 4, "decode")


def test_input_specs_shapes():
    cfg = get_config("llama3-8b")
    sp = input_specs(cfg, INPUT_SHAPES["train_4k"])
    assert sp["tokens"].shape == (256, 4096)
    assert sp["labels"].dtype == jnp.int32
    sp = input_specs(cfg, INPUT_SHAPES["decode_32k"])
    assert sp["token"].shape == (128,)
    k = sp["cache"]["layers"]["k"]
    assert k.shape == (32, 128, 32768, 8, 128)


def test_input_specs_frontends():
    llava = get_config("llava-next-mistral-7b")
    sp = input_specs(llava, INPUT_SHAPES["prefill_32k"])
    assert "frontend_embeds" in sp
    assert sp["frontend_embeds"].shape[0] == 32
    whisper = get_config("whisper-medium")
    sp = input_specs(whisper, INPUT_SHAPES["train_4k"])
    assert sp["frontend_embeds"].shape == (
        256, whisper.encoder.num_frames, whisper.d_model)


def test_decode_specs_window_caches():
    mixtral = get_config("mixtral-8x22b")
    sp = input_specs(mixtral, INPUT_SHAPES["long_500k"])
    k = sp["cache"]["layers"]["k"]
    assert k.shape[2] == mixtral.window      # ring cache, not 524288
    mamba = get_config("falcon-mamba-7b")
    sp = input_specs(mamba, INPUT_SHAPES["long_500k"])
    h = sp["cache"]["layers"]["h"]
    assert h.shape == (64, 1, 2 * 4096, 16)  # O(1) state in seq_len


@pytest.mark.parametrize("shape", [SMALL_TRAIN, SMALL_PREFILL,
                                   SMALL_DECODE])
@pytest.mark.parametrize("arch", ["smollm-135m", "mixtral-8x22b",
                                  "falcon-mamba-7b",
                                  "recurrentgemma-2b",
                                  "whisper-medium"])
def test_build_lowering_compiles_reduced(arch, shape):
    """lower+compile each step kind for reduced archs on 1 device."""
    cfg = get_config(arch, reduced=True)
    mesh = make_smoke_mesh()
    rules = rules_for(mesh)
    with mesh, axis_rules(mesh, rules):
        jitted, args = build_lowering(cfg, shape, mesh, rules,
                                      tc=TrainConfig())
        compiled = jitted.lower(*args).compile()
    assert compiled.cost_analysis() is not None


def test_cache_pspecs_structure_matches():
    cfg = get_config("llama3-8b", reduced=True)
    mesh = make_smoke_mesh()
    sp = input_specs(cfg, SMALL_DECODE)
    ps = cache_pspecs(cfg, sp["cache"], mesh, rules_for(mesh))
    jax.tree.map(lambda a, b: None, sp["cache"], ps)  # same structure


def test_train_driver_loss_decreases():
    _, _, metrics = train(arch="smollm-135m", data="arithmetic",
                          steps=40, batch=32, seq=20, lr=2e-3,
                          verbose=False)
    assert float(metrics["loss"]) < 2.5      # from ~3.1 at init
