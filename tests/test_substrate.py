"""Substrate units: optimizer, checkpoint, sampler, data pipelines,
tokenizer, sharding rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import optim
from repro.checkpoint import (
    load_metadata, restore_checkpoint, save_checkpoint)
from repro.configs.base import TrainConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.data import tokenizer as tok
from repro.data.pipeline import arithmetic_batches, synthetic_lm_batches
from repro.launch.mesh import make_smoke_mesh, rules_for
from repro.launch.steps import sanitize_pspec
from repro.models import params as params_lib
from repro.sampling import generate, sample_token
from repro.sharding import SINGLE_POD_RULES, axis_rules, resolve


# JIT/compile-heavy: excluded from the fast inner loop (-m 'not slow')
pytestmark = pytest.mark.slow


# ----------------------------------------------------------------------
# optimizer
# ----------------------------------------------------------------------
def test_adamw_converges_on_quadratic():
    tc = TrainConfig(learning_rate=0.3, warmup_steps=5, total_steps=200,
                     weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = optim.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = optim.update(params, g, state, tc)
    assert float(loss(params)) < 1e-2


def test_cosine_schedule_shape():
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=10,
                     total_steps=100)
    lrs = [float(optim.cosine_schedule(jnp.int32(s), tc))
           for s in range(100)]
    assert lrs[0] < lrs[9]                       # warmup rises
    assert abs(lrs[10] - 1e-3) < 1e-9            # peak
    assert lrs[-1] < 0.2 * 1e-3                  # decays to ~10%
    assert all(l > 0 for l in lrs)


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(optim.global_norm(clipped)) == pytest.approx(1.0, rel=1e-3)


def test_cross_entropy_masking():
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.zeros((1, 4), jnp.int32)
    full, _ = optim.softmax_cross_entropy(logits, labels)
    masked, met = optim.softmax_cross_entropy(
        logits, labels, jnp.asarray([[1.0, 1.0, 0.0, 0.0]]))
    assert float(full) == pytest.approx(float(masked))
    assert float(met["tokens"]) == 2.0


# ----------------------------------------------------------------------
# checkpoint
# ----------------------------------------------------------------------
def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.float32) * 7},
            "c": jnp.int32(3)}
    p = save_checkpoint(tmp_path / "ck.npz", tree, step=42,
                        metadata={"note": "x"})
    out = restore_checkpoint(p, tree)
    for k in ("a", "c"):
        assert jnp.allclose(out[k].astype(jnp.float32),
                            tree[k].astype(jnp.float32))
    assert out["a"].dtype == jnp.bfloat16
    meta = load_metadata(p)
    assert meta["step"] == 42 and meta["user"]["note"] == "x"


def test_checkpoint_shape_mismatch_raises(tmp_path):
    p = save_checkpoint(tmp_path / "ck.npz", {"a": jnp.ones((2,))})
    with pytest.raises(ValueError):
        restore_checkpoint(p, {"a": jnp.ones((3,))})


def test_checkpoint_missing_key_raises(tmp_path):
    p = save_checkpoint(tmp_path / "ck.npz", {"a": jnp.ones((2,))})
    with pytest.raises(KeyError):
        restore_checkpoint(p, {"zz": jnp.ones((2,))})


# ----------------------------------------------------------------------
# tokenizer + pipelines
# ----------------------------------------------------------------------
def test_tokenizer_roundtrip():
    s = "12 + 7 = -3"
    assert tok.decode(tok.encode(s)) == s


def test_tokenizer_batch_padding():
    out = tok.encode_batch(["1 + 1 =", "12 - 7 ="], 16)
    assert out.shape == (2, 16)
    assert (out[:, 0] == tok.BOS).all()
    assert (out[0] == tok.PAD).sum() > 0


def test_arithmetic_batches_learnable_targets():
    b = next(arithmetic_batches(4, 20, seed=3))
    assert b.tokens.shape == b.labels.shape == b.loss_mask.shape
    # labels are tokens shifted left
    np.testing.assert_array_equal(b.labels[:, :-1], b.tokens[:, 1:])
    assert b.loss_mask.sum() > 0


def test_pipeline_determinism():
    a = next(arithmetic_batches(4, 20, seed=5))
    b = next(arithmetic_batches(4, 20, seed=5))
    np.testing.assert_array_equal(a.tokens, b.tokens)
    c = next(synthetic_lm_batches(2, 32, 100, seed=5))
    d = next(synthetic_lm_batches(2, 32, 100, seed=5))
    np.testing.assert_array_equal(c.tokens, d.tokens)


# ----------------------------------------------------------------------
# sampler
# ----------------------------------------------------------------------
def _tiny():
    cfg = get_config("smollm-135m", reduced=True).replace(
        vocab_size=tok.VOCAB_SIZE, dtype="float32",
        tie_embeddings=True)
    return cfg, params_lib.init_params(cfg, jax.random.PRNGKey(0))


def test_generate_greedy_deterministic():
    cfg, prm = _tiny()
    ids = jnp.asarray(tok.encode_batch(["3 + 4 = "], 12))
    o1 = generate(cfg, prm, ids, max_new_tokens=5, temperature=0.0,
                  eos_id=tok.EOS, pad_id=tok.PAD)
    o2 = generate(cfg, prm, ids, max_new_tokens=5, temperature=0.0,
                  eos_id=tok.EOS, pad_id=tok.PAD)
    np.testing.assert_array_equal(o1.tokens, o2.tokens)
    assert o1.tokens.shape == (1, 5)


def test_generate_batch_rows_independent():
    cfg, prm = _tiny()
    one = jnp.asarray(tok.encode_batch(["3 + 4 = "], 12))
    two = jnp.asarray(tok.encode_batch(["3 + 4 = ", "9 - 2 = "], 12))
    o1 = generate(cfg, prm, one, max_new_tokens=5, temperature=0.0,
                  eos_id=tok.EOS, pad_id=tok.PAD)
    o2 = generate(cfg, prm, two, max_new_tokens=5, temperature=0.0,
                  eos_id=tok.EOS, pad_id=tok.PAD)
    np.testing.assert_array_equal(o1.tokens[0], o2.tokens[0])


def test_sample_token_greedy_vs_temperature():
    logits = jnp.asarray([[0.0, 5.0, 0.0]])
    assert int(sample_token(logits, 0.0, jax.random.PRNGKey(0))[0]) == 1
    draws = {int(sample_token(logits * 0, 1.0,
                              jax.random.PRNGKey(i))[0])
             for i in range(20)}
    assert len(draws) > 1      # temperature actually samples


# ----------------------------------------------------------------------
# sharding rules
# ----------------------------------------------------------------------
def test_resolve_outside_context_noop():
    from repro.sharding import shard
    x = jnp.ones((2, 3))
    assert shard(x, "batch", "embed") is x


def test_resolve_rules():
    mesh = make_smoke_mesh()
    with axis_rules(mesh, SINGLE_POD_RULES):
        assert resolve("batch", "seq", "heads") == P("data", None,
                                                     "model")
        # duplicate mesh axis dropped
        assert resolve("heads", "ff") == P("model", None)


def test_sanitize_pspec_drops_nondivisible():
    mesh = make_smoke_mesh()
    spec = sanitize_pspec((3, 8), P("data", "model"), mesh)
    # smoke mesh is 1x1 — everything divides, spec unchanged
    assert spec == P("data", "model")


def test_param_specs_align_with_params():
    for arch in ARCH_IDS:
        cfg = get_config(arch, reduced=True)
        prm = params_lib.init_params(cfg, jax.random.PRNGKey(0))
        specs = params_lib.param_specs(cfg, SINGLE_POD_RULES)
        jax.tree.map(lambda a, s: None, prm, specs)  # structure match
        flat_p = jax.tree.leaves(prm)
        flat_s = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for a, s in zip(flat_p, flat_s):
            assert len(s) <= a.ndim
