"""Batched ACAR serving engine: on-device judge semantics + the full
probe->sigma->route->ensemble path over tiny real JAX models."""
import jax.numpy as jnp
import numpy as np
import pytest

import jax
from repro.configs.acar import ACARConfig
from repro.configs.registry import get_config
from repro.core.judge import judge_select
from repro.core.routing import execution_mode
from repro.core.sigma import sigma as sigma_host
from repro.data import tokenizer as tok
from repro.data.tasks import arithmetic_suite
from repro.models import params as params_lib
from repro.serving import (
    BatchedACAREngine, ZooModel, intern_answers, judge_batch)
from repro.teamllm.trace import ModelResponse


# JIT/compile-heavy: excluded from the fast inner loop (-m 'not slow')
pytestmark = pytest.mark.slow


def test_intern_answers():
    ids = intern_answers(["a", "b", "a", "c", "b"])
    np.testing.assert_array_equal(ids, [0, 1, 0, 2, 1])


def _host_judge(member, probe, mode):
    """Reference semantics for one row of judge_batch."""
    if mode == 0:
        return probe
    if mode == 1:
        if member[0] == member[1] >= 0 and member[0] != probe:
            return member[0]
        return probe
    valid = [m for m in member if m >= 0]
    counts = {m: valid.count(m) for m in valid}
    best = max(counts.values())
    winners = [m for m in valid if counts[m] == best]
    if probe in winners:
        return probe
    # vectorised judge: first valid member with max score wins
    for m in member:
        if m in winners:
            return m
    return probe


@pytest.mark.parametrize("rows", [
    # (member_ids, probe_majority, mode)
    ([(0, 0, 0)], [0], [0]),
    ([(1, 1, -1)], [0], [1]),       # arena-lite override
    ([(1, 2, -1)], [0], [1]),       # disagree -> probe stands
    ([(1, 1, 2)], [2], [2]),        # plurality
    ([(1, 2, 3)], [2], [2]),        # tie -> probe wins
    ([(5, 5, 5)], [9], [2]),
])
def test_judge_batch_semantics(rows):
    member, probe, mode = rows
    got = np.asarray(judge_batch(
        jnp.asarray(member, jnp.int32),
        jnp.asarray(probe, jnp.int32),
        jnp.asarray(mode, jnp.int32)))
    for i in range(len(member)):
        assert got[i] == _host_judge(list(member[i]), probe[i], mode[i])


def test_judge_batch_matches_host_judge_full_arena():
    rng = np.random.default_rng(0)
    member = rng.integers(0, 4, size=(32, 3)).astype(np.int32)
    probe = rng.integers(0, 4, size=32).astype(np.int32)
    modes = np.full(32, 2, np.int32)
    got = np.asarray(judge_batch(jnp.asarray(member),
                                 jnp.asarray(probe),
                                 jnp.asarray(modes)))
    for i in range(32):
        rs = [ModelResponse(f"m{j}", "", str(member[i, j]), 0.0)
              for j in range(3)]
        want = judge_select(rs, f"task-{i}",
                            probe_answer=str(probe[i]))
        # both judges pick a plurality answer; on ties both prefer the
        # probe answer
        counts = {a: list(member[i]).count(a) for a in member[i]}
        best = max(counts.values())
        winners = {a for a in member[i] if counts[a] == best}
        assert got[i] in winners
        assert int(want) in winners
        if int(probe[i]) in winners:
            assert got[i] == probe[i] == int(want)


def _tiny_zoo(names=("probe", "a", "b", "c")):
    zoo = []
    for i, name in enumerate(names):
        cfg = get_config("smollm-135m", reduced=True).replace(
            vocab_size=tok.VOCAB_SIZE, dtype="float32",
            tie_embeddings=True)
        prm = params_lib.init_params(cfg, jax.random.PRNGKey(i))
        zoo.append(ZooModel(name=name, cfg=cfg, params=prm))
    return zoo


def test_engine_runs_end_to_end():
    zoo = _tiny_zoo()
    acfg = ACARConfig(probe_temperature=0.9, seed=0)
    engine = BatchedACAREngine(acfg, zoo[0], zoo[1:],
                               max_new_tokens=4)
    tasks = arithmetic_suite(8, seed=1)
    res = engine.run_batch(tasks)
    assert len(res.final_answers) == 8
    assert res.sigma.shape == (8,)
    assert set(np.unique(res.modes)) <= {0, 1, 2}
    # sigma -> mode mapping holds on-device
    for s, m in zip(res.sigma, res.modes):
        want = {"single_agent": 0, "arena_lite": 1, "full_arena": 2}[
            execution_mode(float(s))]
        assert m == want
    # per-row sigma equals host sigma over the extracted probe answers
    from repro.core.extract import extract
    for i, t in enumerate(tasks):
        answers = [extract(txt, t.kind) for txt in res.probe_texts[i]]
        assert float(res.sigma[i]) == pytest.approx(sigma_host(answers))
    assert 0 <= res.ensemble_calls_saved <= 3 * 8


def test_engine_run_queued_micro_batches():
    """Continuous-batching entry point: admission queue -> micro-batch
    decodes, concatenated in admission order."""
    from repro.serving import MicroBatchPolicy
    zoo = _tiny_zoo()
    acfg = ACARConfig(probe_temperature=0.9, seed=0)
    engine = BatchedACAREngine(acfg, zoo[0], zoo[1:],
                               max_new_tokens=4)
    tasks = arithmetic_suite(10, seed=1)
    res = engine.run_queued(tasks, MicroBatchPolicy(max_batch_size=4))
    assert res.batch_sizes == [4, 4, 2]
    assert len(res.final_answers) == 10
    assert res.modes.shape == (10,)
    assert res.sigma.shape == (10,)
    # queued serve == per-micro-batch run_batch, concatenated
    ref = [a for lo in (0, 4, 8)
           for a in engine.run_batch(tasks[lo:lo + 4]).final_answers]
    assert res.final_answers == ref
    text = res.metrics.render()
    assert "acar_engine_batches_total 3" in text
    assert "acar_engine_tasks_total 10" in text
