"""Page-pool invariants (property tests).

The paged KV subsystem's correctness rests on host-side accounting:
refcounted alloc/retain/release round-trips must never double-free,
pages-in-use must always equal the live sequences' page footprint,
copy-on-write forks must never alias writable pages, and pool
exhaustion must raise a clean typed error instead of corrupting block
tables.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                                    # pragma: no cover
    from _propshim import given, settings
    from _propshim import strategies as st

from repro.serving.kv_pool import (
    PageAccountingError, PagePool, PoolExhausted, pages_for)


def test_pages_for():
    assert pages_for(0, 8) == 0
    assert pages_for(1, 8) == 1
    assert pages_for(8, 8) == 1
    assert pages_for(9, 8) == 2
    assert pages_for(64, 8) == 8
    assert pages_for(65, 8) == 9


def test_alloc_release_roundtrip():
    pool = PagePool(8, 4)
    a = pool.alloc(3)
    assert pool.pages_in_use == 3
    assert sorted(a.tolist()) == [0, 1, 2]
    pool.release(a)
    assert pool.pages_in_use == 0
    # released pages come back (LIFO), deterministically
    b = pool.alloc(3)
    assert pool.pages_in_use == 3
    assert set(b.tolist()) == {0, 1, 2}


def test_refcount_sharing():
    pool = PagePool(4, 4)
    a = pool.alloc(2)
    pool.retain(a)            # a second owner (e.g. sample 2 of 2)
    pool.release(a)           # first owner gone
    assert pool.pages_in_use == 2      # still held by the second
    pool.release(a)
    assert pool.pages_in_use == 0


def test_double_free_raises_typed_error():
    pool = PagePool(4, 4)
    a = pool.alloc(1)
    pool.release(a)
    with pytest.raises(PageAccountingError):
        pool.release(a)
    with pytest.raises(PageAccountingError):
        pool.retain(a)        # use-after-free


def test_exhaustion_clean_and_atomic():
    pool = PagePool(4, 4)
    a = pool.alloc(3)
    before = pool.pages_in_use
    with pytest.raises(PoolExhausted):
        pool.alloc(2)         # only 1 free
    # the failed allocation leaked nothing and corrupted nothing
    assert pool.pages_in_use == before
    b = pool.alloc(1)
    assert pool.pages_in_use == 4
    pool.release(a)
    pool.release(b)
    assert pool.pages_in_use == 0


@settings(max_examples=40)
@given(st.lists(st.integers(min_value=0, max_value=5),
                min_size=1, max_size=30),
       st.integers(min_value=1, max_value=4))
def test_pages_in_use_equals_live_footprint(seq_lens, n_owners):
    """Random sequences of alloc(+share)/release: the pool's
    pages-in-use always equals the page footprint of the live
    sequences, and full teardown returns the pool to empty."""
    pool = PagePool(256, 4)
    live = []                       # (pages, owners_remaining)
    footprint = 0
    for k in seq_lens:
        pages = pool.alloc(k)
        pool.retain(np.tile(pages, n_owners - 1))
        live.append([pages, n_owners])
        footprint += k
        assert pool.pages_in_use == footprint
        # randomly (deterministically: by parity) drop one owner of
        # the oldest sequence
        if len(live) % 2 == 0:
            entry = live[0]
            pool.release(entry[0])
            entry[1] -= 1
            if entry[1] == 0:
                footprint -= entry[0].size
                live.pop(0)
            assert pool.pages_in_use == footprint
    for pages, owners in live:
        for _ in range(owners):
            pool.release(pages)
    assert pool.pages_in_use == 0
    assert pool.highwater <= pool.num_pages


@settings(max_examples=25)
@given(st.integers(min_value=1, max_value=6),
       st.integers(min_value=1, max_value=4),
       st.integers(min_value=1, max_value=24))
def test_cow_fork_never_aliases(n_shared, n_samples, prompt_tail):
    """The shared/fork layout the probe wave builds: shared prompt
    pages are referenced by every sample's table, but each sample's
    writable (tail/decode) pages are private — no two samples may
    alias a writable page, and no writable page may be a shared one."""
    pool = PagePool(256, 8)
    shared = pool.alloc(n_shared)
    pool.retain(np.tile(shared, n_samples - 1))
    tails = [pool.alloc(pages_for(prompt_tail, 8))
             for _ in range(n_samples)]
    writable = np.concatenate(tails)
    # writable pages are pairwise distinct and disjoint from shared
    assert len(set(writable.tolist())) == writable.size
    assert not set(writable.tolist()) & set(shared.tolist())
    # shared pages carry one ref per sample; private pages exactly one
    for p in shared:
        assert pool.refcount(int(p)) == n_samples
    for p in writable:
        assert pool.refcount(int(p)) == 1
    for t in tails:
        pool.release(t)
    for _ in range(n_samples):
        pool.release(shared)
    assert pool.pages_in_use == 0


def test_alloc_is_deterministic():
    """Identical op sequences yield identical page ids — block tables
    must be reproducible for the bit-equivalence harness."""
    def run():
        pool = PagePool(32, 8)
        a = pool.alloc(5)
        pool.release(a[1:3])
        b = pool.alloc(4)
        return a.tolist(), b.tolist()
    assert run() == run()


# ----------------------------------------------------------------------
# cost-aware prefix-cache eviction (tokens-saved-per-page scoring)
# ----------------------------------------------------------------------
def _tiny_server(prefix_entries=8):
    from repro.configs.registry import get_config
    from repro.data import tokenizer as tok
    from repro.serving.kv_pool import PagedKVServer
    cfg = get_config("smollm-135m", reduced=True).replace(
        vocab_size=tok.VOCAB_SIZE, dtype="float32",
        tie_embeddings=True)
    srv = PagedKVServer(cfg, page_size=8,
                        prefix_cache_entries=prefix_entries)
    srv.ensure_capacity_stream(2, 32, 2, 8)
    return srv


def _insert(srv, key, n_pages, tokens, hits=0):
    pages = srv.pool.alloc(n_pages)
    srv._prefix_insert(key, pages, None,
                       np.zeros(4, np.float32), tokens=tokens)
    srv.pool.release(pages)            # cache ref remains
    for _ in range(hits):
        srv._prefix_lookup(key)


def test_eviction_is_cost_aware_not_lru():
    """A recently-inserted low-value entry (few tokens saved per page)
    is evicted before an older, hotter, denser one — the opposite of
    pure LRU."""
    srv = _tiny_server()
    _insert(srv, b"hot-long", 2, tokens=16, hits=3)   # 16*4/2 = 32/page
    _insert(srv, b"cold-wide", 4, tokens=8, hits=0)   # 8*1/4 = 2/page
    assert srv._evict_one()
    assert b"hot-long" in srv._prefix
    assert b"cold-wide" not in srv._prefix
    assert srv.stats.prefix_evictions == 1


def test_eviction_tie_break_deterministic():
    """Equal scores evict in insertion order (oldest first)."""
    srv = _tiny_server()
    _insert(srv, b"a", 2, tokens=16)
    _insert(srv, b"b", 2, tokens=16)
    srv._evict_one()
    assert b"a" not in srv._prefix and b"b" in srv._prefix


def test_evict_prefix_frees_requested_pages():
    srv = _tiny_server()
    free0 = srv.pool.free_pages
    for i in range(4):
        _insert(srv, bytes([i]), 3, tokens=24)
    assert srv.pool.free_pages == free0 - 12
    got = srv.evict_prefix(free0 - 6)
    assert got >= free0 - 6
    assert len(srv._prefix) == 2


def test_alloc_retry_evicts_then_raises_clean():
    """_alloc_retry sheds cache entries on exhaustion and only raises
    once the cache is empty and the pages genuinely do not exist."""
    srv = _tiny_server()
    free0 = srv.pool.free_pages
    # cache holds most of the pool; a big allocation must reclaim it
    for i in range(4):
        _insert(srv, bytes([i]), free0 // 5, tokens=8)
    big = srv._alloc_retry(free0 - 2)
    assert big.size == free0 - 2
    srv.pool.release(big)
    with pytest.raises(PoolExhausted):
        srv._alloc_retry(srv.pool.num_pages + 1)
    # pool intact: scratch only
    assert srv.pool.pages_in_use == srv._scratch.size


def test_evict_prefix_shared_victim_counts_only_freed_pages():
    """Regression: a victim whose pages are still shared (refcount >
    1 — a live row retained the same prompt pages via a cache hit)
    frees nothing when evicted. The freed-page accounting must report
    pages actually returned to the free list, and the no-progress
    round must stop the loop before it shreds every remaining entry."""
    srv = _tiny_server()
    _insert(srv, b"pinned", 3, tokens=4)          # lowest score
    entry = srv._prefix_lookup(b"pinned")
    srv.pool.retain(entry.shared)                 # a live row holds them
    _insert(srv, b"keep-a", 2, tokens=32)
    _insert(srv, b"keep-b", 2, tokens=32)
    before_free = srv.pool.free_pages
    got = srv.evict_prefix(srv.pool.num_pages)    # unsatisfiable demand
    # only pages actually returned to the free list are counted: the
    # shared victim's release freed zero
    assert got == srv.pool.free_pages == before_free
    assert b"pinned" not in srv._prefix
    # the no-progress break preserved the rest of the cache
    assert b"keep-a" in srv._prefix and b"keep-b" in srv._prefix
    srv.pool.release(entry.shared)


def test_alloc_retry_raises_clean_on_no_progress_eviction():
    """Regression: when eviction cannot free pages (the only victim is
    still shared), _alloc_retry must raise PoolExhausted instead of
    spinning or over-reporting reclaimed pages — and leave the pool
    accounting intact."""
    srv = _tiny_server()
    free0 = srv.pool.free_pages
    live = srv.pool.alloc(free0 - 4)              # live rows hold most
    _insert(srv, b"pinned", 2, tokens=4)
    entry = srv._prefix_lookup(b"pinned")
    srv.pool.retain(entry.shared)
    with pytest.raises(PoolExhausted):
        srv._alloc_retry(4)
    # the shared victim was evicted (cache ref released) but its pages
    # stayed with the live holder — free count unchanged
    assert srv.pool.free_pages == 2
    assert b"pinned" not in srv._prefix
    srv.pool.release(entry.shared)
    srv.pool.release(live)
    assert srv.pool.pages_in_use == srv._scratch.size


def test_prefix_insert_capacity_still_bounded():
    """The entry-count bound still holds; overflow evicts by score."""
    srv = _tiny_server(prefix_entries=3)
    _insert(srv, b"dense", 1, tokens=32, hits=2)      # best
    _insert(srv, b"mid", 2, tokens=16)
    _insert(srv, b"sparse", 4, tokens=4)              # worst
    _insert(srv, b"new", 2, tokens=16)
    assert len(srv._prefix) == 3
    assert b"sparse" not in srv._prefix
    assert b"dense" in srv._prefix
