"""PromCounters registry: kind safety, HELP landing, histograms.

Regression coverage for the metric-kind clobbering bug (a name used by
both ``inc`` and ``set_gauge`` silently flipped the rendered TYPE to
gauge and dropped the counter semantics — now a ``ValueError``), plus
the histogram exposition added for span latencies
(``acar_span_duration{phase}``): cumulative ``_bucket`` series with a
``+Inf`` bound, ``_sum``/``_count``, deterministic ordering, and
fixed-per-name bucket bounds.
"""
import pytest

from repro.serving.metrics import DEFAULT_BUCKETS, PromCounters


# ----------------------------------------------------------------------
# metric-kind registry
# ----------------------------------------------------------------------
def test_counter_then_gauge_same_name_raises():
    m = PromCounters()
    m.inc("acar_things_total")
    with pytest.raises(ValueError, match="already registered"):
        m.set_gauge("acar_things_total", 3.0)
    # the counter series is intact after the rejected call
    assert m.get("acar_things_total") == 1.0
    assert "# TYPE acar_things_total counter" in m.render()


def test_gauge_then_counter_same_name_raises():
    m = PromCounters()
    m.set_gauge("acar_depth", 7.0)
    with pytest.raises(ValueError, match="already registered as gauge"):
        m.inc("acar_depth")
    assert m.get("acar_depth") == 7.0


def test_histogram_cross_kind_raises_both_ways():
    m = PromCounters()
    m.observe("acar_lat", 0.1)
    with pytest.raises(ValueError, match="histogram"):
        m.inc("acar_lat")
    m2 = PromCounters()
    m2.inc("acar_lat")
    with pytest.raises(ValueError, match="counter"):
        m2.observe("acar_lat", 0.1)


def test_same_kind_reuse_is_fine():
    m = PromCounters()
    m.inc("acar_ok_total", mode=0)
    m.inc("acar_ok_total", 2.0, mode=1)
    m.set_gauge("acar_fill", 0.5, bucket=4)
    m.set_gauge("acar_fill", 0.9, bucket=4)
    assert m.get("acar_ok_total", mode="1") == 2.0
    assert m.get("acar_fill", bucket="4") == 0.9


def test_late_help_lands_when_first_call_passed_none():
    m = PromCounters()
    m.inc("acar_late_total")                 # no help text yet
    assert "# HELP acar_late_total" not in m.render()
    m.inc("acar_late_total", help="counts late things")
    assert "# HELP acar_late_total counts late things" in m.render()


def test_first_nonempty_help_wins():
    m = PromCounters()
    m.inc("acar_h_total", help="first")
    m.inc("acar_h_total", help="second")
    assert "# HELP acar_h_total first" in m.render()
    assert "second" not in m.render()


# ----------------------------------------------------------------------
# histograms
# ----------------------------------------------------------------------
def test_histogram_renders_cumulative_buckets_sum_count():
    m = PromCounters()
    b = (0.01, 0.1, 1.0)
    for v in (0.005, 0.05, 0.5, 5.0):
        m.observe("acar_span_duration", v, buckets=b, phase="judge",
                  help="per-phase wall seconds")
    text = m.render()
    assert "# TYPE acar_span_duration histogram" in text
    assert "# HELP acar_span_duration per-phase wall seconds" in text
    # cumulative counts: 1 <= 0.01, 2 <= 0.1, 3 <= 1, all 4 <= +Inf
    assert 'acar_span_duration_bucket{phase="judge",le="0.01"} 1' \
        in text
    assert 'acar_span_duration_bucket{phase="judge",le="0.1"} 2' \
        in text
    assert 'acar_span_duration_bucket{phase="judge",le="1"} 3' in text
    assert 'acar_span_duration_bucket{phase="judge",le="+Inf"} 4' \
        in text
    assert 'acar_span_duration_sum{phase="judge"} 5.555' in text
    assert 'acar_span_duration_count{phase="judge"} 4' in text


def test_histogram_unlabelled_series_renders_bare_suffixes():
    m = PromCounters()
    m.observe("acar_d", 0.2, buckets=(1.0,))
    text = m.render()
    assert 'acar_d_bucket{le="1"} 1' in text
    assert "acar_d_sum 0.2" in text
    assert "acar_d_count 1" in text


def test_histogram_bucket_bounds_are_fixed_per_name():
    m = PromCounters()
    m.observe("acar_lat", 0.1, buckets=(0.1, 1.0))
    m.observe("acar_lat", 0.2, buckets=(0.1, 1.0))   # same: fine
    with pytest.raises(ValueError, match="buckets"):
        m.observe("acar_lat", 0.2, buckets=(0.5, 2.0))


def test_get_histogram_sum_count():
    m = PromCounters()
    assert m.get_histogram("acar_missing") == (0.0, 0.0)
    m.observe("acar_lat", 0.25, phase="route")
    m.observe("acar_lat", 0.75, phase="route")
    s, c = m.get_histogram("acar_lat", phase="route")
    assert (s, c) == (1.0, 2.0)
    # other label sets are independent series
    assert m.get_histogram("acar_lat", phase="judge") == (0.0, 0.0)


def test_default_buckets_cover_sub_ms_to_seconds():
    assert DEFAULT_BUCKETS[0] <= 0.001
    assert DEFAULT_BUCKETS[-1] >= 5.0
    assert list(DEFAULT_BUCKETS) == sorted(DEFAULT_BUCKETS)


def test_render_is_deterministic_across_insertion_order():
    a, b = PromCounters(), PromCounters()
    a.inc("acar_x_total", mode=1)
    a.observe("acar_lat", 0.3, phase="judge")
    a.observe("acar_lat", 0.01, phase="route")
    a.inc("acar_x_total", mode=0)
    b.observe("acar_lat", 0.01, phase="route")
    b.inc("acar_x_total", mode=0)
    b.inc("acar_x_total", mode=1)
    b.observe("acar_lat", 0.3, phase="judge")
    assert a.render() == b.render()


def test_histogram_label_values_escaped():
    m = PromCounters()
    m.observe("acar_lat", 0.1, buckets=(1.0,), model='we"ird\nname')
    assert 'model="we\\"ird\\nname"' in m.render()
