"""XLA_FLAGS merging (clobber regression).

``launch/perf.py`` and ``launch/dryrun.py`` used to assign
``os.environ["XLA_FLAGS"] = ...`` unconditionally, silently deleting
whatever the user had exported (dump paths, partitioner options, or
their own ``--xla_force_host_platform_device_count``). They now merge
through ``repro.xla_flags``; these tests pin the merge semantics and
the subprocess behavior of the real entry points.
"""
import os
import subprocess
import sys

import pytest

from repro.xla_flags import (
    argv_int, force_host_device_count, host_device_count,
    merge_host_device_count)

COUNT = "--xla_force_host_platform_device_count"


def test_merge_adds_flag_when_absent():
    assert merge_host_device_count(None, 512) == f"{COUNT}=512"
    assert merge_host_device_count("", 4) == f"{COUNT}=4"


def test_merge_preserves_other_flags():
    flags = "--xla_dump_to=/tmp/d --xla_cpu_enable_fast_math=false"
    merged = merge_host_device_count(flags, 512)
    assert "--xla_dump_to=/tmp/d" in merged
    assert "--xla_cpu_enable_fast_math=false" in merged
    assert f"{COUNT}=512" in merged


def test_merge_existing_count_wins():
    """A user-exported device-count override must survive — the 512
    default must not stomp it."""
    flags = f"--xla_dump_to=/tmp/d {COUNT}=8"
    merged = merge_host_device_count(flags, 512)
    assert f"{COUNT}=8" in merged
    assert "512" not in merged
    assert "--xla_dump_to=/tmp/d" in merged


def test_host_device_count_parse():
    assert host_device_count(None) is None
    assert host_device_count("--xla_dump_to=/tmp/d") is None
    assert host_device_count(f"{COUNT}=16") == 16


def test_force_host_device_count_mutates_env_copy():
    env = {"XLA_FLAGS": "--xla_dump_to=/x"}
    out = force_host_device_count(4, env=env)
    assert env["XLA_FLAGS"] == out
    assert "--xla_dump_to=/x" in out and f"{COUNT}=4" in out


def test_argv_int_both_spellings():
    """The re-exec helpers must honour both option spellings argparse
    accepts — '--shards 6' and '--shards=6'."""
    assert argv_int(["--sharded", "--shards", "6"], "--shards", 4) == 6
    assert argv_int(["--sharded", "--shards=6"], "--shards", 4) == 6
    assert argv_int(["--sharded"], "--shards", 4) == 4
    assert argv_int([], "--shards", 4) == 4


def _import_in_subprocess(module: str, xla_flags: str) -> str:
    env = dict(os.environ, XLA_FLAGS=xla_flags,
               PYTHONPATH="src" + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    return subprocess.check_output(
        [sys.executable, "-c",
         f"import {module}; import os; print(os.environ['XLA_FLAGS'])"],
        env=env, text=True, stderr=subprocess.DEVNULL).strip()


@pytest.mark.slow
def test_dryrun_import_merges_user_flags():
    """Importing the dry-run entry point must preserve user flags and
    their own device-count override (the clobber this PR fixes)."""
    out = _import_in_subprocess(
        "repro.launch.dryrun",
        f"--xla_dump_to=/tmp/acar-dump {COUNT}=8")
    assert "--xla_dump_to=/tmp/acar-dump" in out
    assert f"{COUNT}=8" in out
    assert "512" not in out


@pytest.mark.slow
def test_perf_import_adds_count_without_clobbering():
    """perf.py (which imports dryrun too) appends the 512 default but
    keeps the user's other flags."""
    out = _import_in_subprocess(
        "repro.launch.perf", "--xla_dump_to=/tmp/acar-dump")
    assert "--xla_dump_to=/tmp/acar-dump" in out
    assert f"{COUNT}=512" in out
