"""Ensure the repo root (for ``benchmarks``) is importable regardless
of how pytest is invoked. NOTE: no XLA flags here — smoke tests must
see one CPU device (the 512-device meshes are dryrun.py-only)."""
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
for p in (str(ROOT), str(ROOT / "src"), str(ROOT / "tests")):
    if p not in sys.path:
        sys.path.insert(0, p)
