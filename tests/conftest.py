"""Ensure the repo root (for ``benchmarks``) is importable regardless
of how pytest is invoked. NOTE: no XLA flags here — smoke tests must
see one CPU device (the 512-device meshes are dryrun.py-only, and
multi-device sharded tests run in subprocesses via the
``forced_devices`` fixture below)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
for p in (str(ROOT), str(ROOT / "src"), str(ROOT / "tests")):
    if p not in sys.path:
        sys.path.insert(0, p)


@pytest.fixture
def forced_devices():
    """Run a python snippet in a subprocess under a forced host device
    count (the ``test_stable_seed.py`` subprocess pattern): jax locks
    the device count at first backend init, so multi-device sharded
    tests must not pollute the in-process single-device jax state the
    rest of the suite relies on. XLA_FLAGS is *merged* (never
    clobbered — repro.xla_flags), PYTHONPATH covers src+tests, and the
    snippet's stdout is returned; a non-zero exit raises with the
    subprocess's stderr attached."""
    def run(snippet: str, count: int = 4, timeout: int = 560) -> str:
        sys.path.insert(0, str(ROOT / "src"))
        from repro.xla_flags import merge_host_device_count
        env = dict(os.environ)
        env["XLA_FLAGS"] = merge_host_device_count(
            env.get("XLA_FLAGS"), count)
        env["PYTHONPATH"] = os.pathsep.join(
            [str(ROOT / "src"), str(ROOT / "tests"), str(ROOT)]
            + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
        proc = subprocess.run(
            [sys.executable, "-c", snippet], env=env, text=True,
            capture_output=True, timeout=timeout)
        if proc.returncode != 0:
            raise AssertionError(
                f"forced-device subprocess failed "
                f"(exit {proc.returncode}):\n{proc.stderr[-4000:]}")
        return proc.stdout
    return run


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """Long single-process runs of the full suite accumulate every
    module's compiled XLA executables; on CPU that eventually crashes
    the compiler's JIT allocator mid-suite (observed as a segfault in
    backend_compile around the 300-test mark). Dropping the caches at
    module teardown bounds the live-executable footprint; modules
    recompile their own shapes anyway, so the only cost is losing
    cross-module cache hits."""
    yield
    import jax
    jax.clear_caches()
