"""Paged (block-table) decode attention: Pallas kernel vs oracle, and
the oracle vs the dense decode-attention semantics it must preserve."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.paged_decode_attention import paged_decode_attention

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def tol_for(dtype):
    return TOL[jnp.bfloat16 if dtype == jnp.bfloat16 else jnp.float32]


def _paged_case(key, b, h, kv, dk, ps, nb, n_pages, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, dk), dtype)
    k_pages = jax.random.normal(ks[1], (n_pages, ps, kv, dk), dtype)
    v_pages = jax.random.normal(ks[2], (n_pages, ps, kv, dk), dtype)
    # distinct pages per row: a permutation slice, like the pool yields
    rng = np.random.default_rng(b * nb + h)
    bt = jnp.asarray(rng.permutation(n_pages)[:b * nb].reshape(b, nb),
                     jnp.int32)
    return q, k_pages, v_pages, bt


def test_paged_ref_matches_dense_ref_rowwise():
    """Gathering a row's pages into a contiguous cache and running the
    dense oracle must equal the paged oracle — the semantics paging
    must not change."""
    q, kp, vp, bt = _paged_case(jax.random.PRNGKey(0), 3, 8, 2, 64,
                                8, 6, 32, jnp.float32)
    lengths = jnp.asarray([48, 17, 1], jnp.int32)
    got = ref.paged_decode_attention_ref(q, kp, vp, bt, lengths)
    kc = kp[bt].reshape(3, -1, 2, 64)
    vc = vp[bt].reshape(3, -1, 2, 64)
    for r in range(3):
        want = ref.decode_attention_ref(q[r:r + 1], kc[r:r + 1],
                                        vc[r:r + 1], lengths[r])
        np.testing.assert_allclose(np.asarray(got[r]),
                                   np.asarray(want[0]), atol=1e-6)


def test_paged_ref_ignores_unmapped_pages():
    """Positions past ``lengths`` — including whole trailing pages and
    stale data in recycled pages — must not affect the output."""
    q, kp, vp, bt = _paged_case(jax.random.PRNGKey(1), 2, 4, 1, 64,
                                8, 4, 16, jnp.float32)
    lengths = jnp.asarray([9, 25], jnp.int32)
    out1 = ref.paged_decode_attention_ref(q, kp, vp, bt, lengths)
    # scribble over every page position past each row's length
    kp2 = np.asarray(kp).copy()
    pos = np.arange(4 * 8)
    for r in range(2):
        for j, page in enumerate(np.asarray(bt)[r]):
            mask = pos[j * 8:(j + 1) * 8] >= int(lengths[r])
            kp2[page, mask] = 99.0
    out2 = ref.paged_decode_attention_ref(q, jnp.asarray(kp2), vp, bt,
                                          lengths)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               atol=1e-6)


def test_ops_dispatch_cpu_fallback():
    assert jax.default_backend() != "tpu"
    q, kp, vp, bt = _paged_case(jax.random.PRNGKey(2), 2, 4, 2, 64,
                                8, 3, 12, jnp.float32)
    lengths = jnp.asarray([20, 11], jnp.int32)
    out = ops.paged_decode_attention(q, kp, vp, bt, lengths)
    want = ref.paged_decode_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-6)


# ----------------------------------------------------------------------
# Pallas kernel (interpret mode) — JIT/compile-heavy: slow lane
# ----------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("b,h,kv,dk,ps,nb", [
    (1, 4, 4, 64, 8, 4),        # MHA, serving-default page size
    (2, 8, 2, 128, 8, 6),       # GQA
    (2, 8, 1, 128, 16, 3),      # MQA, bigger pages
    (3, 6, 3, 32, 32, 2),       # few large pages
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_kernel_sweep(b, h, kv, dk, ps, nb, dtype):
    n_pages = 2 * b * nb
    q, kp, vp, bt = _paged_case(
        jax.random.PRNGKey(b * nb + kv), b, h, kv, dk, ps, nb,
        n_pages, dtype)
    rng = np.random.default_rng(7 * b + nb)
    lengths = jnp.asarray(
        rng.integers(1, nb * ps + 1, size=b), jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, lengths,
                                 interpret=True)
    want = ref.paged_decode_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=tol_for(dtype), rtol=tol_for(dtype))


@pytest.mark.slow
def test_paged_kernel_shared_prefix_rows():
    """Rows sharing prefix pages (the probe's N-sample layout) must
    each read the shared pages correctly."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    b, h, kv, dk, ps = 4, 4, 2, 64, 8
    q = jax.random.normal(ks[0], (b, h, dk))
    kp = jax.random.normal(ks[1], (16, ps, kv, dk))
    vp = jax.random.normal(ks[2], (16, ps, kv, dk))
    # all rows share pages [0, 1]; private third page per row
    bt = jnp.asarray([[0, 1, 2 + r] for r in range(b)], jnp.int32)
    lengths = jnp.asarray([17, 18, 19, 20], jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, lengths,
                                 interpret=True)
    want = ref.paged_decode_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
