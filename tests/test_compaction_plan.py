"""Host-side compaction planning: shape buckets, gather/scatter plans,
savings accounting, and the metrics gauge support they feed."""
import numpy as np
import pytest

from repro.serving.compaction import (
    CompactionStats, bucket_size, plan_compaction)
from repro.serving.metrics import PromCounters


@pytest.mark.parametrize("k,want", [
    (0, 0), (1, 1), (2, 2), (3, 4), (4, 4), (5, 8), (7, 8), (8, 8),
    (9, 16), (63, 64),
])
def test_bucket_size_power_of_two(k, want):
    assert bucket_size(k) == want


def test_bucket_size_cap():
    assert bucket_size(5, cap=8) == 8
    assert bucket_size(5, cap=6) == 6     # clipped, still >= k
    assert bucket_size(3, cap=8) == 4
    assert bucket_size(0, cap=8) == 0


def test_plan_compaction_subsets():
    # modes: 3 single_agent, 3 arena_lite, 2 full_arena
    modes = [0, 1, 0, 2, 1, 0, 2, 1]
    plan = plan_compaction(modes, n_members=3, arena_lite_size=2)
    assert plan.escalated_rows == 5
    assert plan.full_arena_rows == 2
    # arena-lite members decode the modes>=1 rows
    np.testing.assert_array_equal(plan.members[0].rows, [1, 3, 4, 6, 7])
    np.testing.assert_array_equal(plan.members[1].rows, [1, 3, 4, 6, 7])
    # the third member only the modes>=2 rows
    np.testing.assert_array_equal(plan.members[2].rows, [3, 6])
    assert plan.members[0].bucket == 8    # 5 -> 8, capped at batch
    assert plan.members[2].bucket == 2


def test_plan_padded_rows_replicate_first():
    plan = plan_compaction([0, 2, 0, 2, 2], 3, 2)
    mp = plan.members[2]
    np.testing.assert_array_equal(mp.rows, [1, 3, 4])
    np.testing.assert_array_equal(mp.padded_rows(), [1, 3, 4, 1])
    assert mp.occupancy == 3 / 4


def test_plan_accounting_half_escalation():
    # batch 8, half escalated (2 lite + 2 full) — the regime where
    # compaction pays
    modes = [0, 1, 0, 2, 0, 1, 0, 2]
    plan = plan_compaction(modes, 3, 2)
    # members 0/1 decode bucket(4)=4 rows, member 2 bucket(2)=2
    assert plan.compacted_decode_rows == 4 + 4 + 2
    # masked path: all three members decode the full batch
    assert plan.masked_decode_rows == 3 * 8
    assert plan.decode_rows_saved == 24 - 10
    assert plan.decode_tokens(8) == 10 * 8


def test_plan_no_escalation_skips_everything():
    plan = plan_compaction([0, 0, 0, 0], 3, 2)
    assert plan.compacted_decode_rows == 0
    assert plan.masked_decode_rows == 0
    assert all(m.bucket == 0 for m in plan.members)


def test_plan_full_escalation_saves_nothing():
    plan = plan_compaction([2, 2, 2, 2], 3, 2)
    assert plan.compacted_decode_rows == 12
    assert plan.masked_decode_rows == 12
    assert plan.decode_rows_saved == 0


def test_compaction_stats_merge_and_reductions():
    a = CompactionStats(batch=8, escalated_rows=4,
                        ensemble_decode_tokens=80,
                        ensemble_decode_tokens_saved=112,
                        probe_prefill_tokens=72,
                        probe_prefill_tokens_saved=144)
    b = CompactionStats(batch=8, escalated_rows=3,
                        ensemble_decode_tokens=48,
                        ensemble_decode_tokens_saved=144)
    a.merge(b)
    assert a.batch == 16 and a.escalated_rows == 7
    assert a.ensemble_decode_tokens == 128
    assert a.ensemble_decode_tokens_saved == 256
    assert a.ensemble_decode_token_reduction == pytest.approx(3.0)
    assert a.probe_prefill_reduction == pytest.approx(3.0)


def test_prom_gauge_renders_and_overwrites():
    m = PromCounters()
    m.inc("waves_total", help="waves")
    m.set_gauge("occupancy", 0.5, help="fill", bucket="4")
    m.set_gauge("occupancy", 0.75, bucket="4")
    m.set_gauge("occupancy", 1.0, bucket="8")
    text = m.render()
    assert "# TYPE occupancy gauge" in text
    assert '# TYPE waves_total counter' in text
    assert 'occupancy{bucket="4"} 0.75' in text
    assert 'occupancy{bucket="8"} 1' in text
    assert m.get("occupancy", bucket="4") == 0.75
