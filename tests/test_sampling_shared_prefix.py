"""Shared-prefix N-sample generation + decode-length accounting.

``generate_samples`` must emit tokens bit-identical to ``generate``
over an ``np.repeat``-expanded prompt batch — it elides the redundant
prefills, nothing else. ``GenerateOutput.lengths`` must count emitted
tokens via the done mask, not by counting non-pad tokens (a model may
legitimately sample the pad token before EOS).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.data import tokenizer as tok
from repro.models import params as params_lib
from repro.sampling import batch_invariant, generate, generate_samples

# JIT/compile-heavy: excluded from the fast inner loop (-m 'not slow')
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("smollm-135m", reduced=True).replace(
        vocab_size=tok.VOCAB_SIZE, dtype="float32",
        tie_embeddings=True)
    prm = params_lib.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, prm


def _prompts():
    return tok.encode_aligned(
        ["3 + 4 = ", "2 * 3 = ", "9 - 5 = ", "1 + 1 = "])


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_generate_samples_bit_equals_tiled_generate(tiny_model,
                                                    temperature):
    cfg, prm = tiny_model
    ids = _prompts()
    n, key = 3, jax.random.PRNGKey(7)
    tiled = generate(cfg, prm, jnp.asarray(np.repeat(ids, n, axis=0)),
                     max_new_tokens=6, temperature=temperature,
                     key=key, eos_id=tok.EOS, pad_id=tok.PAD)
    shared = generate_samples(cfg, prm, jnp.asarray(ids), n,
                              max_new_tokens=6, temperature=temperature,
                              key=key, eos_id=tok.EOS, pad_id=tok.PAD)
    np.testing.assert_array_equal(np.asarray(tiled.tokens),
                                  np.asarray(shared.tokens))
    np.testing.assert_array_equal(np.asarray(tiled.logprobs),
                                  np.asarray(shared.logprobs))
    np.testing.assert_array_equal(np.asarray(tiled.lengths),
                                  np.asarray(shared.lengths))


def test_lengths_count_sampled_pad_tokens(tiny_model):
    """With EOS unreachable every row emits max_new real tokens; rows
    that sample the pad-valued token mid-stream must not be
    undercounted."""
    cfg, prm = tiny_model
    ids = _prompts()
    out = generate(cfg, prm, jnp.asarray(ids), max_new_tokens=6,
                   temperature=0.9, key=jax.random.PRNGKey(7),
                   eos_id=-999, pad_id=tok.PAD)
    toks = np.asarray(out.tokens)
    assert (np.asarray(out.lengths) == 6).all()
    # the regression scenario actually occurs: some row sampled the
    # pad id before the end (the old formula would have undercounted)
    assert (toks == tok.PAD).any()


def test_lengths_include_eos_and_stop_counting_after(tiny_model):
    """Pick a row's first emitted token as the EOS id and rerun: that
    row must report length 1 (EOS inclusive), and pre-EOS emissions
    never count as padding."""
    cfg, prm = tiny_model
    ids = _prompts()
    key = jax.random.PRNGKey(3)
    base = generate(cfg, prm, jnp.asarray(ids), max_new_tokens=6,
                    temperature=0.0, key=key, eos_id=-999,
                    pad_id=tok.PAD)
    first = int(np.asarray(base.tokens)[0, 0])
    out = generate(cfg, prm, jnp.asarray(ids), max_new_tokens=6,
                   temperature=0.0, key=key, eos_id=first,
                   pad_id=tok.PAD)
    toks = np.asarray(out.tokens)
    lengths = np.asarray(out.lengths)
    assert lengths[0] == 1
    for r in range(toks.shape[0]):
        hits = np.nonzero(toks[r] == first)[0]
        want = int(hits[0]) + 1 if hits.size else 6
        assert lengths[r] == want


def test_batch_invariant_gate():
    dense = get_config("smollm-135m", reduced=True)
    assert batch_invariant(dense)
    moe = get_config("mixtral-8x22b", reduced=True)
    assert moe.moe is not None and not batch_invariant(moe)
