"""EXTRACT canonicalisation + JudgeSelect / arena_verify."""
import pytest
try:
    from hypothesis import given
    from hypothesis import strategies as st
except ImportError:                          # seeded fallback shim
    from _propshim import given
    from _propshim import strategies as st

from repro.core.extract import (
    extract, extract_code, extract_math, extract_mcq, extract_reasoning)
from repro.core.judge import arena_verify, judge_select
from repro.teamllm.trace import ModelResponse


def mr(model, answer, response=None):
    return ModelResponse(model=model, response=response or answer,
                         answer=answer, cost=0.0)


# ----------------------------------------------------------------------
# extract
# ----------------------------------------------------------------------
def test_extract_math_last_number():
    assert extract_math("first 3 then answer: 42") == "42"
    assert extract_math("x = -17.0") == "-17"
    assert extract_math("2e3 apples") == "2000"


def test_extract_math_no_number():
    assert extract_math("I do not know") == "i do not know"


@given(st.integers(-10**9, 10**9))
def test_extract_math_roundtrip(n):
    assert extract_math(f"the answer: {n}") == str(n)


def test_extract_mcq():
    assert extract_mcq("Answer: B") == "B"
    assert extract_mcq("I choose (C) because...") == "C"
    assert extract_mcq("Answer: option D is right") == "D"


def test_extract_reasoning_normalises():
    a = extract_reasoning("Answer:   THE   cat SAT")
    assert a == "the cat sat"


def test_extract_code_canonicalisation_knob():
    r1 = "def f():  # variant 1\n    return 7"
    r2 = "def f():   # variant 2\n    return  7"
    # raw comparison (paper's setting): distinct
    assert extract(r1, "code") != extract(r2, "code")
    # canonicalised: identical
    assert extract(r1, "code", canonicalize_code=True) == \
        extract(r2, "code", canonicalize_code=True)


def test_extract_dispatch():
    assert extract("answer: 5", "math") == "5"
    assert extract("Answer: A", "mcq") == "A"
    assert extract("answer: yes", "unknown-kind") == "yes"


# ----------------------------------------------------------------------
# judge
# ----------------------------------------------------------------------
def test_judge_plurality():
    rs = [mr("a", "x"), mr("b", "x"), mr("c", "y")]
    assert judge_select(rs, "t1") == "x"


def test_judge_tie_prefers_probe():
    rs = [mr("a", "x"), mr("b", "y")]
    assert judge_select(rs, "t1", probe_answer="y") == "y"


def test_judge_tie_deterministic_coin():
    rs = [mr("a", "x"), mr("b", "y"), mr("c", "z")]
    first = judge_select(rs, "some-task")
    for _ in range(5):
        assert judge_select(rs, "some-task") == first
    assert first in ("x", "y", "z")


def test_judge_model_order_stable():
    rs1 = [mr("a", "x"), mr("b", "y")]
    rs2 = [mr("b", "y"), mr("a", "x")]
    assert judge_select(rs1, "t") == judge_select(rs2, "t")


def test_arena_verify_upholds_probe():
    # members disagree with each other -> probe stands
    rs = [mr("a", "p"), mr("b", "q")]
    assert arena_verify("m", rs, "t") == "m"


def test_arena_verify_unanimous_override():
    rs = [mr("a", "q"), mr("b", "q")]
    assert arena_verify("m", rs, "t") == "q"
    # unanimous agreement WITH the probe keeps it
    rs2 = [mr("a", "m"), mr("b", "m")]
    assert arena_verify("m", rs2, "t") == "m"
