"""Trace auditability under batching (paper §3.1 invariants, extended
to the continuous-batching scheduler): the hash chain stays valid, the
``schedule`` provenance rides a non-hashed side channel, and
``logical_time`` is a total order consistent with admission order even
when micro-batches interleave through the two-stage pipeline."""
import json

from repro.configs.acar import ACARConfig
from repro.core.backends import paper_backends
from repro.data.tasks import paper_suite
from repro.serving.queue import MicroBatchPolicy
from repro.serving.scheduler import ContinuousBatchingScheduler
from repro.teamllm.artifacts import ArtifactStore
from repro.teamllm.trace import ModelResponse, ProbeSample, TraceRecord

ACFG = ACARConfig()
PROBE = "gemini-2.0-flash"


def make_sched(store=None, batch_size=4, overlap=True, run_id="audit"):
    backs = paper_backends()
    return ContinuousBatchingScheduler(
        ACFG, backs[PROBE], backs, store=store, run_id=run_id,
        policy=MicroBatchPolicy(max_batch_size=batch_size),
        overlap=overlap)


# ----------------------------------------------------------------------
# schedule metadata is auditable but non-hashed
# ----------------------------------------------------------------------
def mk_trace(schedule=None, logical_time=0):
    return TraceRecord(
        run_id="r", task_id="t", benchmark="b", prompt_hash="ph",
        seed=0, sigma=0.5, mode="arena_lite",
        probe_samples=(ProbeSample("resp", "a", 0.01),),
        responses=(ModelResponse("m", "resp", "a", 0.02),),
        final_answer="a", correct=True, cost=0.03,
        logical_time=logical_time, schedule=schedule)


def test_schedule_metadata_not_hashed():
    t1 = mk_trace(schedule=None)
    t2 = mk_trace(schedule={"arrival": 0, "admitted": 0, "batch_id": 7})
    assert t1.record_hash() == t2.record_hash()
    assert "schedule" not in t1.hashed_view()


def test_schedule_metadata_persisted(tmp_path):
    p = tmp_path / "runs.jsonl"
    store = ArtifactStore(p)
    store.append(mk_trace(schedule={"arrival": 3, "admitted": 0,
                                    "batch_id": 1}))
    store.append(mk_trace(schedule=None, logical_time=1))
    rows = [json.loads(l) for l in p.read_text().splitlines()]
    assert rows[0]["schedule"] == {"arrival": 3, "admitted": 0,
                                   "batch_id": 1}
    assert "schedule" not in rows[0]["record"]
    assert "schedule" not in rows[1]
    # side channel does not perturb the chain
    assert ArtifactStore(p).audit()["ok"]


# ----------------------------------------------------------------------
# scheduler runs: chain validity + admission-order logical time
# ----------------------------------------------------------------------
def test_chain_valid_under_batching(tmp_path):
    p = tmp_path / "sched.jsonl"
    sched = make_sched(ArtifactStore(p), batch_size=4)
    tasks = paper_suite(seed=2)[:20]
    sched.serve(tasks)
    audit = ArtifactStore(p).audit()
    assert audit["ok"] and audit["records"] == 20
    assert audit["parse_errors"] == 0


def test_logical_time_is_admission_total_order(tmp_path):
    """Batches interleave through the pipeline (overlap=True), yet
    logical_time must be 0..n-1 in admission order."""
    p = tmp_path / "sched.jsonl"
    sched = make_sched(ArtifactStore(p), batch_size=3, overlap=True)
    tasks = paper_suite(seed=2)[:20]
    reqs = sched.submit_many(tasks)
    outcomes = sched.run_until_idle()

    lts = [o.trace.logical_time for o in outcomes]
    assert lts == list(range(len(tasks)))
    # consistent with the admission order the queue assigned
    assert [r.admission_index for r in reqs] == lts
    # and with FIFO arrival order
    arrivals = [o.trace.schedule["arrival"] for o in outcomes]
    assert arrivals == sorted(arrivals)
    # persisted records carry the same order
    recs = ArtifactStore(p).read_all()
    assert [r["logical_time"] for r in recs] == lts
    assert [r["task_id"] for r in recs] == [t.task_id for t in tasks]


def test_schedule_provenance_fields(tmp_path):
    p = tmp_path / "sched.jsonl"
    sched = make_sched(ArtifactStore(p), batch_size=4)
    sched.serve(paper_suite(seed=2)[:10])
    rows = [json.loads(l) for l in p.read_text().splitlines()]
    for i, row in enumerate(rows):
        s = row["schedule"]
        assert s["admitted"] == i == row["record"]["logical_time"]
        assert isinstance(s["batch_id"], int)
        assert isinstance(s["probe_cache_hit"], bool)
    # batch ids are non-decreasing in admission order, 4 tasks max each
    batch_ids = [json.loads(l)["schedule"]["batch_id"] for l in
                 p.read_text().splitlines()]
    assert batch_ids == sorted(batch_ids)
    assert max(batch_ids) >= 2          # really was micro-batched


def test_sequential_and_batched_chain_heads_match(tmp_path):
    """Strongest audit property: same workload, same run_id => the two
    hash chains end at the same head."""
    from repro.core.orchestrator import ACAROrchestrator
    tasks = paper_suite(seed=9)[:15]
    backs = paper_backends()
    seq_store = ArtifactStore(tmp_path / "seq.jsonl")
    ACAROrchestrator(ACFG, backs[PROBE], backs, store=seq_store,
                     run_id="head").run_suite(tasks)
    sched_store = ArtifactStore(tmp_path / "sched.jsonl")
    sched = make_sched(sched_store, batch_size=5, run_id="head")
    sched.serve(tasks)
    assert seq_store.head == sched_store.head
    assert len(seq_store) == len(sched_store) == 15


def test_cache_hits_do_not_break_audit(tmp_path):
    """Duplicate submissions served from the probe cache still append
    well-formed, chain-valid records with fresh logical times."""
    p = tmp_path / "sched.jsonl"
    sched = make_sched(ArtifactStore(p), batch_size=4)
    tasks = paper_suite(seed=2)[:6]
    sched.serve(tasks + tasks)           # second half hits the cache
    assert sched.cache.hits == 6
    audit = ArtifactStore(p).audit()
    assert audit["ok"] and audit["records"] == 12
    recs = ArtifactStore(p).read_all()
    # same task, two admissions: identical content hash except time
    assert recs[0]["task_id"] == recs[6]["task_id"]
    assert recs[0]["final_answer"] == recs[6]["final_answer"]
    assert recs[0]["logical_time"] != recs[6]["logical_time"]
