"""Chunk-boundary prefill: chunked composition must be bit-identical
to one-shot ``prefill_paged``.

The step-level serving loop streams long prompts through the paged KV
pool in fixed-size chunks (``sampler.prefill_chunk_paged``). The
bit-equivalence contract (see ``models.transformer.prefill_chunk_paged``)
is that for ANY chunk schedule — size 1, a ragged size straddling page
boundaries, exactly one page, or the whole prompt at once — the
written KV pages and the final-position logits match the one-shot
paged prefill bit for bit, even when the pages start out holding stale
garbage. Property tests sweep prompt lengths and chunk sizes through
``tests/_propshim.py`` (hypothesis when available).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                                   # pragma: no cover
    from _propshim import given, settings
    from _propshim import strategies as st

from repro.configs.registry import get_config
from repro.data import tokenizer as tok
from repro.models import params as params_lib
from repro.models import transformer as T
from repro.sampling import prefill_chunk_paged

# JIT/compile-heavy: excluded from the fast inner loop (-m 'not slow')
pytestmark = pytest.mark.slow

PAGE = 8


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("smollm-135m", reduced=True).replace(
        vocab_size=tok.VOCAB_SIZE, dtype="float32",
        tie_embeddings=True)
    prm = params_lib.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, prm


def _paged_setup(cfg, batch: int, prompt_len: int, garbage_seed=None):
    """Pages + per-row block tables; optionally garbage-initialised
    (recycled pages must not leak into chunked prefill output)."""
    nbp = -(-prompt_len // PAGE)
    n_pages = batch * nbp + 2
    shape = (cfg.num_layers, n_pages, PAGE, cfg.num_kv_heads,
             cfg.resolved_head_dim)
    if garbage_seed is None:
        k = jnp.zeros(shape, jnp.float32)
        v = jnp.zeros(shape, jnp.float32)
    else:
        rng = np.random.default_rng(garbage_seed)
        k = jnp.asarray(rng.normal(size=shape), jnp.float32)
        v = jnp.asarray(rng.normal(size=shape), jnp.float32)
    table = np.arange(batch * nbp, dtype=np.int32).reshape(batch, nbp)
    return k, v, table, nbp


def _prompts(batch: int, length: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    ids = rng.integers(3, tok.VOCAB_SIZE,
                       size=(batch, length)).astype(np.int32)
    ids[:, 0] = tok.BOS
    return ids


def _oneshot(cfg, prm, ids, table):
    k, v, _, _ = _paged_setup(cfg, ids.shape[0], ids.shape[1])
    fn = jax.jit(T.prefill_paged,
                 static_argnames=("cfg", "cache_len"))
    lg, pages = fn(cfg, prm, jnp.asarray(ids), {"k": k, "v": v},
                   jnp.asarray(table))
    return (np.asarray(lg), np.asarray(pages["k"]),
            np.asarray(pages["v"]))


def _chunked(cfg, prm, ids, table, chunk: int, garbage_seed=1):
    b, s = ids.shape
    k, v, _, _ = _paged_setup(cfg, b, s, garbage_seed=garbage_seed)
    pages = {"k": k, "v": v}
    logits = np.zeros((b, cfg.vocab_size), np.float32)
    start = 0
    while start < s:
        c = min(chunk, s - start)
        starts = jnp.full((b,), start, jnp.int32)
        lg, pages = prefill_chunk_paged(
            cfg, prm, jnp.asarray(ids[:, start:start + c]), pages,
            jnp.asarray(table), starts, prompt_len=s)
        start += c
    logits[:] = np.asarray(lg)
    return (logits, np.asarray(pages["k"]), np.asarray(pages["v"]))


def _written_kv(pages, table, prompt_len, cfg):
    """The prompt-covering slots (the tail page's dead slots past the
    prompt are never read — decode overwrites them position by
    position before attending)."""
    gathered = pages[:, table]          # (L, B, NBp, PAGE, KV, Dh)
    layers, b = gathered.shape[0], gathered.shape[1]
    return gathered.reshape(layers, b, -1, cfg.num_kv_heads,
                            cfg.resolved_head_dim)[:, :, :prompt_len]


@pytest.mark.parametrize("prompt_len", [9, 16, 23])
@pytest.mark.parametrize("chunk", [1, 7, PAGE])
def test_chunk_sizes_bit_identical(tiny_model, prompt_len, chunk):
    """Chunk sizes {1, 7, page_size} across page-aligned and
    straddling prompt lengths: pages and logits match one-shot."""
    cfg, prm = tiny_model
    ids = _prompts(3, prompt_len)
    table = _paged_setup(cfg, 3, prompt_len)[2]
    lg1, k1, _ = _oneshot(cfg, prm, ids, table)
    lg2, k2, _ = _chunked(cfg, prm, ids, table, chunk)
    np.testing.assert_array_equal(lg1, lg2)
    np.testing.assert_array_equal(
        _written_kv(k1, table, prompt_len, cfg),
        _written_kv(k2, table, prompt_len, cfg))


def test_whole_prompt_chunk_bit_identical(tiny_model):
    """chunk == L: one chunked call is the one-shot prefill."""
    cfg, prm = tiny_model
    ids = _prompts(2, 21)
    table = _paged_setup(cfg, 2, 21)[2]
    lg1, k1, _ = _oneshot(cfg, prm, ids, table)
    lg2, k2, _ = _chunked(cfg, prm, ids, table, chunk=21)
    np.testing.assert_array_equal(lg1, lg2)
    np.testing.assert_array_equal(
        _written_kv(k1, table, 21, cfg),
        _written_kv(k2, table, 21, cfg))


@settings(max_examples=6)
@given(st.integers(min_value=9, max_value=33),
       st.integers(min_value=1, max_value=11),
       st.integers(min_value=0, max_value=1 << 20))
def test_chunked_prefill_property(prompt_len, chunk, seed):
    """Any (prompt length, chunk size) pair composes bit-identically,
    from garbage-initialised pages."""
    cfg = get_config("smollm-135m", reduced=True).replace(
        vocab_size=tok.VOCAB_SIZE, dtype="float32",
        tie_embeddings=True)
    prm = params_lib.init_params(cfg, jax.random.PRNGKey(0))
    ids = _prompts(2, prompt_len, seed=seed % 1000)
    table = _paged_setup(cfg, 2, prompt_len)[2]
    lg1, k1, _ = _oneshot(cfg, prm, ids, table)
    lg2, k2, _ = _chunked(cfg, prm, ids, table, chunk,
                          garbage_seed=seed % 997)
    np.testing.assert_array_equal(lg1, lg2)
    np.testing.assert_array_equal(
        _written_kv(k1, table, prompt_len, cfg),
        _written_kv(k2, table, prompt_len, cfg))


def test_mixed_depth_rows_share_one_program(tiny_model):
    """Rows at different prefill depths batched into one call (traced
    per-row starts) produce the same bits as rows advanced alone."""
    cfg, prm = tiny_model
    s, c = 16, 4
    ids = _prompts(2, s)
    k, v, table, _ = _paged_setup(cfg, 2, s, garbage_seed=3)
    # row 0 advances alone to depth 4; then both rows step together,
    # row 1 lagging row 0 by one chunk
    lg = None
    pos = np.array([0, 0], np.int32)
    pages = {"k": k, "v": v}
    _, pages = prefill_chunk_paged(
        cfg, prm, jnp.asarray(ids[:1, 0:c]), pages,
        jnp.asarray(table[:1]), jnp.asarray([0], jnp.int32),
        prompt_len=s)
    pos[0] = c
    while pos.min() < s:
        rows = [r for r in range(2) if pos[r] < s]
        toks = np.stack([ids[r, pos[r]:pos[r] + c] for r in rows])
        lg, pages = prefill_chunk_paged(
            cfg, prm, jnp.asarray(toks), pages,
            jnp.asarray(table[rows]),
            jnp.asarray(pos[rows], jnp.int32), prompt_len=s)
        for r in rows:
            pos[r] += c
    lg1, k1, _ = _oneshot(cfg, prm, ids, table)
    np.testing.assert_array_equal(
        _written_kv(k1, table, s, cfg),
        _written_kv(np.asarray(pages["k"]), table, s, cfg))


def test_chunk_kernel_matches_oracle():
    """The Pallas chunked-prefill kernel (interpret mode) matches the
    jnp oracle on mixed-depth rows."""
    from repro.kernels.chunked_prefill_attention import (
        chunked_prefill_attention)
    from repro.kernels.ref import chunked_prefill_attention_ref
    rng = np.random.default_rng(0)
    b, c, h, kv, dk, ps, nb = 2, 4, 4, 2, 16, 8, 3
    prompt_len = 21
    q = jnp.asarray(rng.normal(size=(b, c, h, dk)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(b * nb + 1, ps, kv, dk)),
                     jnp.float32)
    vp = jnp.asarray(rng.normal(size=(b * nb + 1, ps, kv, dk)),
                     jnp.float32)
    table = jnp.asarray(
        np.arange(b * nb, dtype=np.int32).reshape(b, nb))
    qpos = jnp.asarray(np.stack([np.arange(4, 8), np.arange(12, 16)])
                       .astype(np.int32))
    want = chunked_prefill_attention_ref(q, kp, vp, table, qpos,
                                         prompt_len=prompt_len)
    got = chunked_prefill_attention(q, kp, vp, table, qpos,
                                    prompt_len=prompt_len,
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
