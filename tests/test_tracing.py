"""Span tracing + PROV lineage unit tests (host-only, fast).

Pins the observability substrate's contracts without touching models:
span records are deterministic hashable dicts (wall time rides the
non-hashed side channel, so two runs with different clocks share one
chain head), ``SpanLog.flush`` writes ``ArtifactStore``-verifiable
JSONL, ``SpanTracer`` assigns per-trace ordinal span ids with implicit
stream parenting (row lifecycle vs forked member/probe streams) and
first-writer-wins KV provenance, and ``teamllm.prov`` materializes a
walkable PROV graph whose lineage walk re-verifies span hashes —
including catching a tampered span.
"""
import copy

import pytest

from repro.serving.tracing import NullTracer, SpanTracer
from repro.teamllm.artifacts import ArtifactStore
from repro.teamllm.prov import lineage, prov_records, verify_span_file
from repro.teamllm.spans import SpanLog, make_trace_id, span_record
from repro.teamllm.trace import content_hash


# ----------------------------------------------------------------------
# span records + SpanLog
# ----------------------------------------------------------------------
def test_make_trace_id():
    assert make_trace_id("req-3", 7) == "req-3#7"


def test_span_record_sorts_fields_and_drops_none():
    r = span_record("route", "t#0", "t#0/2", 5, parent="t#0/1",
                    sigma=0.5, mode=1, aborted=None)
    assert list(r) == ["event", "phase", "trace", "span", "tick",
                      "parent", "mode", "sigma"]
    assert "aborted" not in r
    # same fields, different kwarg order -> same hash
    r2 = span_record("route", "t#0", "t#0/2", 5, parent="t#0/1",
                     mode=1, sigma=0.5)
    assert content_hash(r) == content_hash(r2)


def test_spanlog_wall_time_is_outside_the_hash():
    a, b = SpanLog(), SpanLog()
    rec = span_record("admit", "t#0", "t#0/0", 0)
    a.append(rec, wall_time=1.0)
    b.append(rec, wall_time=999.0)
    assert a.head == b.head
    assert a.rows[0]["wall_time"] != b.rows[0]["wall_time"]


def test_spanlog_flush_is_artifact_store_compatible(tmp_path):
    log = SpanLog()
    for i in range(5):
        log.append(span_record("admit", f"t#{i}", f"t#{i}/0", i),
                   wall_time=float(i))
    p = tmp_path / "spans.jsonl"
    head = log.flush(p)
    assert head == log.head
    audit = ArtifactStore(p).audit()
    assert audit["ok"] and audit["records"] == 5
    assert audit["head"] == head
    assert verify_span_file(p)["ok"]


# ----------------------------------------------------------------------
# SpanTracer
# ----------------------------------------------------------------------
def test_null_tracer_is_disarmed_and_inert():
    t = NullTracer()
    assert t.armed is False
    assert t.span("admit", "t#0", 0) is None
    assert t.kv_insert("m", "h", "t#0", "t#0/0") is None
    assert t.kv_source("m", "h") is None
    assert t.records() == [] and t.flush() is None


def test_span_ids_are_per_trace_ordinals():
    t = SpanTracer()
    assert t.span("admit", "a#0", 0) == "a#0/0"
    assert t.span("admit", "b#1", 0) == "b#1/0"
    assert t.span("route", "a#0", 1) == "a#0/1"
    assert t.span("route", "b#1", 1) == "b#1/1"


def test_implicit_parenting_row_stream_and_forks():
    t = SpanTracer()
    s0 = t.span("admit", "a#0", 0)
    s1 = t.span("route", "a#0", 1)
    # forked member stream: first span parents on the row stream...
    m0 = t.span("member_launch", "a#0", 1, key=("m", 0))
    # ...later spans chain within the fork, not the row stream
    m1 = t.span("member_decode", "a#0", 2, key=("m", 0))
    # a second fork also parents on the row stream's latest span
    p0 = t.span("member_launch", "a#0", 1, key=("m", 1))
    # the row stream keeps chaining through its own last span
    s2 = t.span("retire", "a#0", 3)
    recs = {r["span"]: r for r in t.records()}
    assert "parent" not in recs[s0]
    assert recs[s1]["parent"] == s0
    assert recs[m0]["parent"] == s1
    assert recs[m1]["parent"] == m0
    assert recs[p0]["parent"] == s1
    assert recs[s2]["parent"] == s1


def test_explicit_parent_overrides():
    t = SpanTracer()
    s0 = t.span("admit", "a#0", 0)
    t.span("route", "a#0", 1)
    s2 = t.span("requeued", "a#0", 2, parent=s0)
    assert [r for r in t.records()
            if r["span"] == s2][0]["parent"] == s0


def test_kv_insert_first_writer_wins():
    t = SpanTracer()
    t.kv_insert("model-a", "hash1", "a#0", "a#0/3")
    t.kv_insert("model-a", "hash1", "b#1", "b#1/3")   # duplicate
    assert t.kv_source("model-a", "hash1") == ("a#0", "a#0/3")
    assert t.kv_source("model-b", "hash1") is None


def test_memory_only_flush_returns_head(tmp_path):
    t = SpanTracer()                      # path=None
    t.span("admit", "a#0", 0)
    assert t.flush() == t.head
    td = SpanTracer(tmp_path / "s.jsonl")
    td.span("admit", "a#0", 0)
    assert td.flush() == td.head
    assert ArtifactStore(tmp_path / "s.jsonl").audit()["ok"]


def test_identical_span_streams_share_one_head():
    def _run():
        t = SpanTracer()
        t.span("admit", "a#0", 0, prompt_tokens=9)
        t.span("route", "a#0", 1, sigma=0.25, mode=2)
        t.span("retire", "a#0", 2, task_id="q1", final_answer="42")
        return t.head
    assert _run() == _run()


# ----------------------------------------------------------------------
# PROV lineage
# ----------------------------------------------------------------------
def _lifecycle(t, trace, task_id, *, mode=2, members=(0, 1),
               answer="42", kv_source=None):
    """Emit one task's full span lifecycle on ``t``."""
    t.span("admit", trace, 0, prompt_tokens=9)
    t.span("probe_decode", trace, 1, model="probe", n_samples=3,
           key=("p", 0))
    t.span("route", trace, 1, sigma=0.4, mode=mode, n_samples=3)
    for mi in members:
        t.span("member_launch", trace, 1, key=("m", mi), member=mi,
               model=f"member-{mi}", reuse=0)
        if kv_source is not None:
            t.span("kv_reuse", trace, 1, key=("m", mi), kind="prefix",
                   model=f"member-{mi}", source=kv_source)
        t.span("member_decode", trace, 2, key=("m", mi), member=mi,
               model=f"member-{mi}", done=1)
    t.span("judge", trace, 3, mode=mode, members=list(members))
    t.span("retire", trace, 3, task_id=task_id, final_answer=answer,
           sigma=0.4, mode=mode)


def test_prov_graph_and_lineage_walk_verifies_hashes():
    t = SpanTracer()
    _lifecycle(t, "a#0", "q1")
    recs = prov_records(t.records())
    kinds = {}
    for r in recs:
        kinds.setdefault(r["kind"], []).append(r)
    ids = {r["id"] for r in kinds["entity"]}
    assert {"probe:a#0", "route:a#0", "member:a#0/0", "member:a#0/1",
            "answer:a#0"} <= ids
    assert {r["id"] for r in kinds["agent"]} == \
        {"model:probe", "model:member-0", "model:member-1"}
    assert any(r["entity"] == "member:a#0/0"
               and r["agent"] == "model:member-0"
               for r in kinds["wasAttributedTo"])

    lin = lineage(t.records(), "q1")
    assert lin["ok"] and lin["trace"] == "a#0"
    assert lin["verified"] > 0 and not lin["hash_failures"]
    walked = {r.get("id") for r in lin["records"]}
    assert {"answer:a#0", "route:a#0", "probe:a#0"} <= walked


def test_lineage_crosses_kv_reuse_between_traces():
    t = SpanTracer()
    _lifecycle(t, "a#0", "q1")
    _lifecycle(t, "b#1", "q2", kv_source="a#0")   # prefix donated by a#0
    lin = lineage(t.records(), "q2")
    assert lin["ok"]
    # the walk crossed the wasDerivedFrom edge into the donor trace
    assert "answer:a#0" in {r.get("id") for r in lin["records"]}
    assert any(r.get("kind") == "wasDerivedFrom"
               and r.get("source") == "answer:a#0"
               and r.get("kv") == "prefix"
               for r in lin["records"])


def test_lineage_detects_span_tampered_after_prov_build():
    """The PROV graph captures each span's content hash at build time;
    a lineage walk against that graph catches a span mutated since."""
    t = SpanTracer()
    _lifecycle(t, "a#0", "q1")
    recs = prov_records(t.records())      # materialized pre-tamper
    spans = copy.deepcopy(t.records())
    for s in spans:
        if s["phase"] == "route":
            s["mode"] = 99                # tamper the hashed record
    assert lineage(spans, "q1")["ok"]     # rebuilt graph: self-consistent
    lin = lineage(spans, "q1", records=recs)
    assert not lin["ok"]
    assert any("hash mismatch" in f for f in lin["hash_failures"])


def test_lineage_unknown_task_reports_cleanly():
    t = SpanTracer()
    _lifecycle(t, "a#0", "q1")
    lin = lineage(t.records(), "nope")
    assert not lin["ok"] and lin["trace"] is None
    assert lin["verified"] == 0


def test_latest_admission_wins_for_duplicate_task_ids():
    t = SpanTracer()
    _lifecycle(t, "a#0", "q1", answer="first")
    _lifecycle(t, "a#5", "q1", answer="second")
    lin = lineage(t.records(), "q1")
    assert lin["trace"] == "a#5"
    answers = [r.get("answer") for r in lin["records"]
               if r.get("id") == "answer:a#5"]
    assert answers == ["second"]


def test_mode0_answer_derives_from_probe():
    t = SpanTracer()
    _lifecycle(t, "a#0", "q1", mode=0, members=())
    lin = lineage(t.records(), "q1")
    assert lin["ok"]
    assert any(r.get("kind") == "wasDerivedFrom"
               and r.get("entity") == "answer:a#0"
               and r.get("source") == "probe:a#0"
               for r in lin["records"])
