"""Paged KV-cache generation: bit-equivalence with the dense paths.

The paged subsystem is an allocation strategy, not a semantic change:
``PagedKVServer.probe_wave`` must emit tokens bit-identical to
``generate_samples`` (which tiles the prefill cache N times), and both
``reuse_decode`` (prefill skipped, seeded from retained probe pages)
and ``generate`` must match the dense ``generate`` — same tokens, same
logprobs, same lengths, at greedy and sampled temperatures.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.data import tokenizer as tok
from repro.models import params as params_lib
from repro.models.transformer import paged_supported
from repro.sampling import generate, generate_samples
from repro.serving.kv_pool import (
    PagedKVServer, PoolExhausted, dense_tile_slots, pages_for)

# JIT/compile-heavy: excluded from the fast inner loop (-m 'not slow')
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("smollm-135m", reduced=True).replace(
        vocab_size=tok.VOCAB_SIZE, dtype="float32",
        tie_embeddings=True)
    prm = params_lib.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, prm


def _prompts(length=None):
    texts = ["3 + 4 = ", "2 * 3 = ", "9 - 5 = ", "1 + 1 = "]
    ids = tok.encode_aligned(texts)
    if length is not None:
        reps = -(-length // ids.shape[1])
        ids = np.tile(ids, (1, reps))[:, :length]
    return ids


@pytest.mark.parametrize("temperature", [0.0, 0.7])
@pytest.mark.parametrize("prompt_len", [9, 16, 21])
def test_probe_wave_bit_equals_generate_samples(tiny_model,
                                                temperature,
                                                prompt_len):
    """Across page-aligned (16) and straddling (9, 21) prompt lengths
    the shared-prefix paged probe matches the tiled dense probe
    bit-for-bit."""
    cfg, prm = tiny_model
    ids = _prompts(prompt_len)
    n, m, key = 3, 6, jax.random.PRNGKey(7)
    dense = generate_samples(cfg, prm, jnp.asarray(ids), n,
                             max_new_tokens=m, temperature=temperature,
                             key=key, eos_id=tok.EOS, pad_id=tok.PAD)
    srv = PagedKVServer(cfg, page_size=8, prefix_cache_entries=8)
    out, handle = srv.probe_wave(prm, ids, n, max_new_tokens=m,
                                 temperature=temperature, key=key,
                                 eos_id=tok.EOS, pad_id=tok.PAD)
    handle.close()
    np.testing.assert_array_equal(np.asarray(dense.tokens), out.tokens)
    np.testing.assert_array_equal(np.asarray(dense.logprobs),
                                  out.logprobs)
    np.testing.assert_array_equal(np.asarray(dense.lengths),
                                  out.lengths)


def test_reuse_decode_bit_equals_generate(tiny_model):
    """An ensemble member sharing the probe's params decodes from the
    retained probe pages — no prefill — and must match the dense
    ``generate`` over the same rows (duplicates included, as bucket
    padding produces them)."""
    cfg, prm = tiny_model
    ids = _prompts()
    key = jax.random.PRNGKey(3)
    srv = PagedKVServer(cfg, page_size=8, prefix_cache_entries=8)
    _, handle = srv.probe_wave(prm, ids, 3, max_new_tokens=6,
                               temperature=0.9, key=key,
                               eos_id=tok.EOS, pad_id=tok.PAD)
    rows = [2, 0, 2]
    mkey = jax.random.fold_in(key, 1001)
    want = generate(cfg, prm, jnp.asarray(ids[rows]), max_new_tokens=6,
                    temperature=0.0, key=mkey, eos_id=tok.EOS,
                    pad_id=tok.PAD)
    got = srv.reuse_decode(prm, handle, rows, max_new_tokens=6,
                           temperature=0.0, key=mkey, eos_id=tok.EOS,
                           pad_id=tok.PAD)
    np.testing.assert_array_equal(np.asarray(want.tokens), got.tokens)
    assert srv.stats.prefill_tokens_reused_probe == \
        len(rows) * ids.shape[1]
    handle.close()


def test_resolve_frees_pages_and_blocks_reuse(tiny_model):
    """resolve() frees non-kept rows immediately; reusing a resolved
    row is an accounting error, not silent corruption."""
    from repro.serving.kv_pool import PageAccountingError
    cfg, prm = tiny_model
    ids = _prompts()
    srv = PagedKVServer(cfg, page_size=8, prefix_cache_entries=0)
    _, handle = srv.probe_wave(prm, ids, 3, max_new_tokens=6,
                               temperature=0.9,
                               key=jax.random.PRNGKey(0),
                               eos_id=tok.EOS, pad_id=tok.PAD)
    in_use = srv.pool.pages_in_use
    handle.resolve([1])
    assert srv.pool.pages_in_use < in_use
    with pytest.raises(PageAccountingError):
        srv.reuse_decode(prm, handle, [0], max_new_tokens=6,
                         temperature=0.0, key=jax.random.PRNGKey(1),
                         eos_id=tok.EOS, pad_id=tok.PAD)
    handle.close()
    # only the permanent scratch pages remain
    assert srv.pool.pages_in_use == srv._scratch.size


def test_prefix_cache_hits_skip_prefill_bitwise(tiny_model):
    """A second wave over the same prompts must hit the prefix cache
    (no prefill tokens computed) and still emit identical bits."""
    cfg, prm = tiny_model
    ids = _prompts()
    key = jax.random.PRNGKey(11)
    srv = PagedKVServer(cfg, page_size=8, prefix_cache_entries=8)
    out1, h1 = srv.probe_wave(prm, ids, 3, max_new_tokens=6,
                              temperature=0.9, key=key,
                              eos_id=tok.EOS, pad_id=tok.PAD)
    h1.close()
    computed = srv.stats.prefill_tokens_computed
    out2, h2 = srv.probe_wave(prm, ids, 3, max_new_tokens=6,
                              temperature=0.9, key=key,
                              eos_id=tok.EOS, pad_id=tok.PAD)
    h2.close()
    assert srv.stats.prefill_tokens_computed == computed
    assert srv.stats.prefill_tokens_reused_prefix == \
        ids.shape[0] * ids.shape[1]
    np.testing.assert_array_equal(out1.tokens, out2.tokens)


def test_probe_memory_highwater_beats_tile_cache(tiny_model):
    """With prompts long relative to decode, the shared-prefix paged
    working set must be >= 2x smaller than tile_cache's B*N*(S+M)."""
    cfg, prm = tiny_model
    ids = _prompts(64)
    b, s = ids.shape
    n, m = 3, 8
    srv = PagedKVServer(cfg, page_size=8, prefix_cache_entries=0)
    _, handle = srv.probe_wave(prm, ids, n, max_new_tokens=m,
                               temperature=0.0,
                               key=jax.random.PRNGKey(0),
                               eos_id=tok.EOS, pad_id=tok.PAD)
    handle.close()
    paged_slots = srv.stats.probe_pages_highwater * srv.page_size
    assert paged_slots * 2 <= dense_tile_slots(b, n, s, m)


def test_pool_exhaustion_is_typed_and_clean(tiny_model):
    """Driving a server against a deliberately tiny pool raises
    PoolExhausted; the pool accounting survives intact."""
    cfg, prm = tiny_model
    ids = _prompts()
    srv = PagedKVServer(cfg, page_size=8, prefix_cache_entries=0)
    # shrink the pool under the wave's worst case
    srv._ensure_capacity(ids.shape[0], ids.shape[1], 3, 6)
    srv._rebuild(4, pages_for(ids.shape[1], 8), srv._capacity_key)
    before = srv.pool.pages_in_use
    with pytest.raises(PoolExhausted):
        srv.probe_wave(prm, ids, 3, max_new_tokens=6, temperature=0.0,
                       key=jax.random.PRNGKey(0), eos_id=tok.EOS,
                       pad_id=tok.PAD)
    # the failed wave released everything it had accumulated: the
    # pool is exactly as before (scratch only), not wedged
    assert srv.pool.pages_in_use == before


def test_paged_supported_gates():
    from repro.models.transformer import resolve_layout
    assert paged_supported(get_config("smollm-135m", reduced=True))
    assert not paged_supported(get_config("mixtral-8x22b",
                                          reduced=True))     # MoE
    # layout descriptors: SSM members page their recurrent state as
    # lanes; sliding-window members get ring pages; kv_quant gets
    # int8 code pages; hybrids (RG-LRU + attention) stay dense-only
    assert resolve_layout(
        get_config("smollm-135m", reduced=True)) == "dense"
    assert resolve_layout(
        get_config("falcon-mamba-7b", reduced=True)) == "lanes"
    assert resolve_layout(
        get_config("smollm-135m", reduced=True).replace(
            window=16)) == "ring"
    assert resolve_layout(
        get_config("smollm-135m", reduced=True).replace(
            kv_quant=True)) == "quant"
    assert resolve_layout(
        get_config("recurrentgemma-2b", reduced=True)) is None
