"""Sequential <-> batched equivalence (the scheduler's core contract).

The continuous-batching scheduler must be an *execution strategy*, not
a semantic change: identical routing modes, final answers, and trace
record hashes as the sequential ACAROrchestrator, for any batch shape.
"""
import pytest

from harness.simulate import (
    ScriptedBackend, WorkloadConfig, generate_workload, run_equivalence,
    scripted_task)
from repro.configs.acar import ACARConfig
from repro.core.backends import paper_backends
from repro.core.orchestrator import ACAROrchestrator
from repro.core.routing import ARENA_LITE, FULL_ARENA, SINGLE_AGENT
from repro.data.tasks import paper_suite
from repro.serving.queue import MicroBatchPolicy
from repro.serving.scheduler import ContinuousBatchingScheduler

ACFG = ACARConfig()
PROBE = "gemini-2.0-flash"


def run_both_scripted(probe_answers, member_answers, gold="a"):
    """Drive one scripted task through both paths; returns
    (sequential outcome, scheduler outcome)."""
    task = scripted_task("t0", gold=gold)
    probe_script = {("t0", i): a for i, a in enumerate(probe_answers)}
    ens_names = [f"m{i + 1}" for i in range(len(member_answers))]

    def mk_backends():
        probe = ScriptedBackend("probe", dict(probe_script))
        ens = {n: ScriptedBackend(n, {("t0", 0): a})
               for n, a in zip(ens_names, member_answers)}
        return probe, ens

    p1, e1 = mk_backends()
    seq = ACAROrchestrator(ACFG, p1, e1, run_id="s").run_task(task)
    p2, e2 = mk_backends()
    sched = ContinuousBatchingScheduler(ACFG, p2, e2, run_id="s")
    bat = sched.serve([task])[0]
    return seq, bat


# ----------------------------------------------------------------------
# sigma edge cases (Def. 1 / Def. 2 boundaries)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("probe_answers,members,want_mode", [
    # all-agree -> sigma=0 -> single_agent, probe consensus is final
    (("a", "a", "a"), ("x", "y", "z"), SINGLE_AGENT),
    # 2-of-3 agreement -> sigma=0.5 -> arena_lite
    (("a", "a", "b"), ("a", "a", "z"), ARENA_LITE),
    # 2-of-3, majority arrives late (tie-break to first seen)
    (("b", "a", "b"), ("b", "b", "z"), ARENA_LITE),
    # arena_lite unanimous override: members agree on a != probe answer
    (("a", "a", "b"), ("q", "q", "z"), ARENA_LITE),
    # all-disagree -> sigma=1 -> full_arena, judge aggregates
    (("a", "b", "c"), ("a", "b", "b"), FULL_ARENA),
    # full_arena with all members distinct (judge coin tie-break)
    (("a", "b", "c"), ("x", "y", "z"), FULL_ARENA),
])
def test_sigma_edge_case_equivalence(probe_answers, members, want_mode):
    seq, bat = run_both_scripted(probe_answers, members)
    assert seq.trace.mode == want_mode
    assert bat.trace.mode == seq.trace.mode
    assert bat.trace.final_answer == seq.trace.final_answer
    assert bat.trace.sigma == seq.trace.sigma
    assert bat.trace.record_hash() == seq.trace.record_hash()
    assert bat.semantic_answer == seq.semantic_answer
    assert bat.correct == seq.correct


def test_arena_lite_override_picks_member_answer():
    seq, bat = run_both_scripted(("a", "a", "b"), ("q", "q", "z"))
    # members m1,m2 unanimously contradict the probe majority
    assert seq.trace.final_answer == "q"
    assert bat.trace.final_answer == "q"


# ----------------------------------------------------------------------
# calibrated-backend equivalence over batch shapes
# ----------------------------------------------------------------------
@pytest.mark.parametrize("batch_size", [1, 3, 8, 64])
def test_equivalence_across_batch_shapes(batch_size, tmp_path):
    tasks = paper_suite(seed=3)[:48]
    report, _, _ = run_equivalence(
        tasks, acfg=ACFG,
        policy=MicroBatchPolicy(max_batch_size=batch_size),
        workdir=tmp_path / f"b{batch_size}")
    assert report.ok, report.summary()


def test_equivalence_without_overlap(tmp_path):
    tasks = paper_suite(seed=5)[:24]
    report, _, _ = run_equivalence(
        tasks, acfg=ACFG, policy=MicroBatchPolicy(max_batch_size=4),
        workdir=tmp_path, overlap=False)
    assert report.ok, report.summary()


def test_equivalence_with_retrieval(tmp_path):
    """ACAR-UJ path: retrieval metadata must survive batching too."""
    from repro.configs.acar import ACAR_UJ_ALIGNED
    from repro.core.retrieval import Experience, ExperienceStore
    from repro.teamllm.artifacts import ArtifactStore

    tasks = paper_suite(seed=1)[:16]
    exp = ExperienceStore()
    for i, t in enumerate(tasks[:8]):
        exp.add(Experience(t.text, t.gold, True, t.benchmark))

    backs = paper_backends()
    seq_store = ArtifactStore(tmp_path / "seq.jsonl")
    seq = ACAROrchestrator(ACAR_UJ_ALIGNED, backs[PROBE], backs,
                           store=seq_store, experience=exp,
                           run_id="uj").run_suite(tasks)
    backs2 = paper_backends()
    sched_store = ArtifactStore(tmp_path / "sched.jsonl")
    sched = ContinuousBatchingScheduler(
        ACAR_UJ_ALIGNED, backs2[PROBE], backs2, store=sched_store,
        experience=exp, run_id="uj",
        policy=MicroBatchPolicy(max_batch_size=4))
    bat = sched.serve(tasks)
    assert [o.trace.record_hash() for o in seq] == \
        [o.trace.record_hash() for o in bat]
    assert seq_store.head == sched_store.head


def test_scheduler_rerun_is_deterministic():
    tasks = paper_suite(seed=7)[:32]

    def one_run():
        backs = paper_backends()
        sched = ContinuousBatchingScheduler(
            ACFG, backs[PROBE], backs, run_id="det",
            policy=MicroBatchPolicy(max_batch_size=8))
        return [o.trace.record_hash() for o in sched.serve(tasks)]

    assert one_run() == one_run()


# ----------------------------------------------------------------------
# the acceptance-criteria simulation: >= 200 seeded synthetic tasks
# ----------------------------------------------------------------------
def test_simulation_200_tasks_bit_identical(tmp_path):
    stream = generate_workload(WorkloadConfig(
        n_tasks=200, seed=0, duplicate_rate=0.15))
    assert len(stream) == 200
    report, seq, bat = run_equivalence(
        stream, acfg=ACFG, policy=MicroBatchPolicy(max_batch_size=8),
        workdir=tmp_path)
    assert report.ok, report.summary()
    # duplicates in the stream hit the probe cache
    assert report.probe_cache_hits > 0
    # batching + pipelining beats the sequential virtual makespan >= 2x
    assert report.speedup_vs_sequential >= 2.0
    # the modes really are bit-identical, per task
    assert [o.trace.mode for o in seq] == [o.trace.mode for o in bat]
    assert [o.trace.final_answer for o in seq] == \
        [o.trace.final_answer for o in bat]


def test_streaming_drains_accumulate_makespan():
    """Repeated submit/drain cycles must keep the virtual-clock stats
    honest: both sides of the speedup ratio accumulate."""
    tasks = paper_suite(seed=11)[:16]
    backs = paper_backends()
    sched = ContinuousBatchingScheduler(
        ACFG, backs[PROBE], backs, run_id="stream",
        policy=MicroBatchPolicy(max_batch_size=4))
    sched.serve(tasks[:8])
    pipe1 = sched.stats.pipeline_makespan_ms
    seq1 = sched.stats.sequential_makespan_ms
    speedup1 = sched.stats.speedup_vs_sequential
    sched.serve(tasks[8:])
    assert sched.stats.pipeline_makespan_ms > pipe1
    assert sched.stats.sequential_makespan_ms > seq1
    # the ratio stays in the same regime instead of doubling per drain
    assert sched.stats.speedup_vs_sequential < 2 * speedup1


def test_workload_generator_is_seeded():
    cfg = WorkloadConfig(n_tasks=50, seed=4, duplicate_rate=0.2)
    a = [t.task_id for t in generate_workload(cfg)]
    b = [t.task_id for t in generate_workload(cfg)]
    assert a == b
    c = [t.task_id for t in generate_workload(
        WorkloadConfig(n_tasks=50, seed=5, duplicate_rate=0.2))]
    assert a != c
