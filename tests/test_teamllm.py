"""TEAMLLM substrate invariants (paper §3.1): determinism, immutable
artifacts, forward-only state machine."""
import json

import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                          # seeded fallback shim
    from _propshim import given, settings
    from _propshim import strategies as st

from repro.teamllm.artifacts import ArtifactStore, ChainCorruption, GENESIS
from repro.teamllm.fingerprint import (
    capture_environment, prompt_hash, render_prompt)
from repro.teamllm.state_machine import (
    IllegalTransition, RunState, RunStateMachine)
from repro.teamllm.trace import (
    ModelResponse, ProbeSample, TraceRecord, content_hash)


def make_trace(i=0, **kw):
    base = dict(
        run_id="r", task_id=f"t{i}", benchmark="b", prompt_hash="ph",
        seed=0, sigma=0.5, mode="arena_lite",
        probe_samples=(ProbeSample("resp", "a", 0.01),),
        responses=(ModelResponse("m", "resp", "a", 0.02),),
        final_answer="a", correct=True, cost=0.03,
        logical_time=i, wall_time=123.0)
    base.update(kw)
    return TraceRecord(**base)


# ----------------------------------------------------------------------
# invariant 3: forward-only state machine
# ----------------------------------------------------------------------
def test_happy_path():
    sm = RunStateMachine("r1")
    for s in (RunState.EXECUTING, RunState.VERIFYING,
              RunState.COMPLETED):
        sm.advance(s)
    assert sm.terminal
    assert sm.history == [
        ("PENDING", "EXECUTING"), ("EXECUTING", "VERIFYING"),
        ("VERIFYING", "COMPLETED")]


@pytest.mark.parametrize("start,bad", [
    (RunState.PENDING, RunState.VERIFYING),
    (RunState.PENDING, RunState.COMPLETED),
    (RunState.EXECUTING, RunState.PENDING),
    (RunState.VERIFYING, RunState.EXECUTING),
    (RunState.COMPLETED, RunState.PENDING),
    (RunState.COMPLETED, RunState.FAILED),
    (RunState.FAILED, RunState.EXECUTING),
])
def test_no_rollback_or_skip(start, bad):
    sm = RunStateMachine("r", state=start)
    with pytest.raises(IllegalTransition):
        sm.advance(bad)


@given(st.lists(st.sampled_from(list(RunState)), max_size=6))
@settings(deadline=None)
def test_state_machine_never_goes_backward(path):
    order = {RunState.PENDING: 0, RunState.EXECUTING: 1,
             RunState.VERIFYING: 2, RunState.COMPLETED: 3,
             RunState.FAILED: 99}
    sm = RunStateMachine("r")
    prev = sm.state
    for s in path:
        try:
            sm.advance(s)
        except IllegalTransition:
            continue
        assert order[sm.state] > order[prev]
        prev = sm.state


# ----------------------------------------------------------------------
# invariant 2: immutable hash-chained artifacts
# ----------------------------------------------------------------------
def test_append_and_reopen(tmp_path):
    p = tmp_path / "runs.jsonl"
    store = ArtifactStore(p)
    assert store.head == GENESIS
    h1 = store.append(make_trace(0))
    h2 = store.append(make_trace(1))
    assert h1 != h2
    reopened = ArtifactStore(p)
    assert reopened.head == h2
    assert len(reopened) == 2
    assert reopened.audit()["ok"]


def test_tamper_detection(tmp_path):
    p = tmp_path / "runs.jsonl"
    store = ArtifactStore(p)
    store.append(make_trace(0))
    store.append(make_trace(1))
    rows = p.read_text().splitlines()
    row = json.loads(rows[0])
    row["record"]["final_answer"] = "tampered"
    rows[0] = json.dumps(row)
    p.write_text("\n".join(rows) + "\n")
    with pytest.raises(ChainCorruption):
        ArtifactStore(p)


def test_chain_depends_on_order(tmp_path):
    s1 = ArtifactStore(tmp_path / "a.jsonl")
    s1.append(make_trace(0))
    s1.append(make_trace(1))
    s2 = ArtifactStore(tmp_path / "b.jsonl")
    s2.append(make_trace(1))
    s2.append(make_trace(0))
    assert s1.head != s2.head


# ----------------------------------------------------------------------
# invariant 1: deterministic hashing; wall time excluded
# ----------------------------------------------------------------------
def test_trace_hash_ignores_wall_time():
    t1 = make_trace(0, wall_time=1.0)
    t2 = make_trace(0, wall_time=9999.0)
    assert t1.record_hash() == t2.record_hash()


def test_trace_hash_covers_content():
    assert make_trace(0).record_hash() != \
        make_trace(0, final_answer="z").record_hash()
    assert make_trace(0).record_hash() != \
        make_trace(0, sigma=1.0).record_hash()


def test_content_hash_stable_across_key_order():
    assert content_hash({"a": 1, "b": 2}) == content_hash({"b": 2, "a": 1})


def test_environment_fingerprint():
    f = capture_environment()
    assert f.digest() == capture_environment().digest()
    assert f.rubric_version


def test_prompt_rendering():
    p0 = render_prompt("2 + 2 =")
    assert "2 + 2 =" in p0
    p1 = render_prompt("2 + 2 =", exemplar="1 + 1 = -> 2")
    assert "Similar past example" in p1 and "2 + 2 =" in p1
    assert prompt_hash(p0) != prompt_hash(p1)
