"""int8 KV-cache quantization (§Perf C2): math + end-to-end parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import params as params_lib
from repro.models import transformer as T
from repro.models.attention import (
    decode_attention, decode_attention_quant, quantize_kv)


# JIT/compile-heavy: excluded from the fast inner loop (-m 'not slow')
pytestmark = pytest.mark.slow


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64, 8, 128))
    codes, scale = quantize_kv(x)
    assert codes.dtype == jnp.int8
    deq = codes.astype(jnp.float32) * scale[..., None]
    err = jnp.max(jnp.abs(deq - x))
    # per-vector symmetric quant: max error <= scale/2 <= max|x|/254
    assert float(err) <= float(jnp.max(jnp.abs(x))) / 254.0 + 1e-6


def test_quantize_zero_vector_safe():
    codes, scale = quantize_kv(jnp.zeros((2, 3, 4)))
    assert not np.isnan(np.asarray(scale)).any()
    assert (np.asarray(codes) == 0).all()


def test_quant_decode_attention_close_to_exact():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 8, 64))
    k = jax.random.normal(ks[1], (2, 128, 2, 64))
    v = jax.random.normal(ks[2], (2, 128, 2, 64))
    pos = jnp.int32(100)
    kpos = jnp.arange(128)
    exact = decode_attention(q, k, v, kpos, pos)
    kq, kscale = quantize_kv(k)
    vq, vscale = quantize_kv(v)
    quant = decode_attention_quant(q, kq, kscale, vq, vscale, kpos, pos)
    np.testing.assert_allclose(np.asarray(quant), np.asarray(exact),
                               atol=0.05, rtol=0.05)


@pytest.mark.parametrize("arch", ["llama3-8b", "granite-34b",
                                  "mixtral-8x22b"])
def test_end_to_end_parity_with_quant_cache(arch):
    cfg = get_config(arch, reduced=True).replace(dtype="float32",
                                                 kv_quant=True)
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))
    params = params_lib.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S + 1), 0,
                              cfg.vocab_size)
    full, _ = T.forward(cfg, params, toks)
    _, cache = T.prefill(cfg, params, toks[:, :S], cache_len=S + 1)
    assert cache["layers"]["k"].dtype == jnp.int8
    ld, new_cache = T.decode_step(cfg, params, cache, toks[:, S],
                                  jnp.int32(S))
    assert jnp.allclose(ld, full[:, S], atol=5e-2), arch
    assert new_cache["layers"]["k"].dtype == jnp.int8


def test_quant_cache_is_half_the_bytes():
    cfg = get_config("llama3-8b", reduced=True)
    plain = jax.eval_shape(lambda: T.init_cache(cfg, 4, 256))
    quant = jax.eval_shape(
        lambda: T.init_cache(cfg.replace(kv_quant=True), 4, 256))
    nbytes = lambda t: sum(np.prod(l.shape) * l.dtype.itemsize
                           for l in jax.tree.leaves(t))
    # int8 codes (0.5x) + f32 scales (~1/2hd overhead)
    assert nbytes(quant) < 0.6 * nbytes(plain)


# ----------------------------------------------------------------------
# Pallas int8 flash-decode kernel (deployment path for C2)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("b,h,kv,dk,s,blk", [
    (2, 8, 2, 128, 1024, 256),
    (1, 4, 1, 64, 512, 512),       # MQA
    (2, 16, 8, 64, 768, 256),
])
def test_pallas_quant_decode_matches_jnp(b, h, kv, dk, s, blk):
    from repro.kernels.decode_attention_quant import (
        decode_attention_quant as kernel)
    from repro.models.attention import (
        decode_attention_quant as jnp_quant, quantize_kv)
    ks = jax.random.split(jax.random.PRNGKey(b * s), 3)
    q = jax.random.normal(ks[0], (b, h, dk))
    k = jax.random.normal(ks[1], (b, s, kv, dk))
    v = jax.random.normal(ks[2], (b, s, kv, dk))
    length = jnp.int32(s - s // 3)
    kq, kscale = quantize_kv(k)
    vq, vscale = quantize_kv(v)
    out = kernel(q, kq, kscale, vq, vscale, length, block_s=blk,
                 interpret=True)
    want = jnp_quant(q, kq, kscale, vq, vscale, jnp.arange(s),
                     length - 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
