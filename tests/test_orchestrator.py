"""ACAR orchestrator end-to-end behaviour over the synthetic backends
(Alg. 1, the baselines, determinism, the trace artifact flow)."""
import pytest

from repro.configs.acar import ACAR_U, ACAR_UJ, ACARConfig
from repro.core.backends import paper_backends
from repro.core.orchestrator import ACAROrchestrator, run_fixed_mode
from repro.core.retrieval import Experience, ExperienceStore
from repro.core.routing import ARENA_LITE, FULL_ARENA, SINGLE_AGENT
from repro.data.tasks import paper_suite
from repro.teamllm.artifacts import ArtifactStore

TASKS = paper_suite(seed=0)[:60]


def make_orch(tmp_path=None, acfg=ACAR_U, experience=None,
              run_id="t"):
    backs = paper_backends()
    store = ArtifactStore(tmp_path / "runs.jsonl") if tmp_path else None
    return ACAROrchestrator(
        acfg, backs["gemini-2.0-flash"], backs, store=store,
        experience=experience, run_id=run_id)


def test_mode_matches_sigma():
    orch = make_orch()
    for t in TASKS[:30]:
        out = orch.run_task(t)
        tr = out.trace
        want = {0.0: SINGLE_AGENT, 0.5: ARENA_LITE, 1.0: FULL_ARENA}[
            tr.sigma]
        assert tr.mode == want
        n = {SINGLE_AGENT: 0, ARENA_LITE: 2, FULL_ARENA: 3}[tr.mode]
        assert len(tr.responses) == n
        assert len(tr.probe_samples) == 3


def test_deterministic_reexecution(tmp_path):
    h1 = [o.trace.record_hash()
          for o in make_orch(tmp_path / "a").run_suite(TASKS[:20])]
    h2 = [o.trace.record_hash()
          for o in make_orch(tmp_path / "b").run_suite(TASKS[:20])]
    assert h1 == h2


def test_seed_changes_traces():
    a = make_orch(acfg=ACARConfig(seed=0)).run_suite(TASKS[:20])
    b = make_orch(acfg=ACARConfig(seed=1)).run_suite(TASKS[:20])
    assert [o.trace.record_hash() for o in a] != \
        [o.trace.record_hash() for o in b]


def test_artifact_store_written(tmp_path):
    orch = make_orch(tmp_path)
    orch.run_suite(TASKS[:10])
    store = ArtifactStore(tmp_path / "runs.jsonl")
    assert len(store) == 10
    recs = store.read_all()
    assert all(r["benchmark"] == "matharena" for r in recs)
    assert store.audit()["parse_errors"] == 0


def test_cost_accounting():
    orch = make_orch()
    out = orch.run_task(TASKS[0])
    tr = out.trace
    expect = sum(p.cost for p in tr.probe_samples) \
        + sum(r.cost for r in tr.responses)
    if len(tr.responses) > 1:
        from repro.core.orchestrator import COORDINATION_COST
        expect += COORDINATION_COST
    assert tr.cost == pytest.approx(expect)


def test_retrieval_toggles_traces(tmp_path):
    exp = ExperienceStore()
    for i in range(20):
        exp.add(Experience(f"[matharena] synthetic task {i} (topic 1)",
                           str(i), True, "matharena"))
    uj = make_orch(acfg=ACAR_UJ, experience=exp)
    out = uj.run_task(TASKS[0])
    assert out.trace.retrieval is not None
    assert "hit" in out.trace.retrieval
    u = make_orch(acfg=ACAR_U, experience=exp)
    assert u.run_task(TASKS[0]).trace.retrieval is None


def test_fixed_mode_baselines():
    backs = paper_backends()
    single = run_fixed_mode(TASKS[:20], backs, ["claude-sonnet-4"])
    assert all(len(o.trace.responses) == 1 for o in single)
    assert all(o.trace.mode == SINGLE_AGENT for o in single)
    arena3 = run_fixed_mode(TASKS[:20], backs, list(backs))
    assert all(len(o.trace.responses) == 3 for o in arena3)
    # arena-3 cost strictly higher than single (3 calls + coordination)
    assert sum(o.trace.cost for o in arena3) > \
        sum(o.trace.cost for o in single)


def test_single_agent_uses_probe_consensus():
    orch = make_orch()
    for t in TASKS[:40]:
        out = orch.run_task(t)
        if out.trace.mode == SINGLE_AGENT:
            answers = {p.answer for p in out.trace.probe_samples}
            assert len(answers) == 1
            assert out.trace.final_answer in answers
            break
    else:
        pytest.skip("no sigma=0 task in sample")


def test_agreement_but_wrong_is_unrecoverable():
    """sigma=0 + wrong consensus -> ACAR cannot recover (paper §6.2)."""
    orch = make_orch()
    found = False
    for t in paper_suite(seed=0)[:300]:
        out = orch.run_task(t)
        if out.trace.mode == SINGLE_AGENT and not out.correct:
            assert len(out.trace.responses) == 0   # nothing to rescue it
            found = True
            break
    assert found, "expected at least one agreement-but-wrong case"
