"""Admission queue, micro-batch formation, probe cache, and the
Prometheus-style counter registry."""
import pytest

from repro.configs.acar import ACARConfig
from repro.data.tasks import Task, arithmetic_suite
from repro.serving.queue import (
    AdmissionQueue, MicroBatchPolicy, estimate_tokens)
from repro.serving.scheduler import ProbeCache, PromCounters, \
    _ProbeEntry


def mk_task(i, text="1 + 1 ="):
    return Task(task_id=f"q-{i:03d}", benchmark="arithmetic",
                kind="math", text=text, gold="2", difficulty=0.0)


# ----------------------------------------------------------------------
# admission + batch formation
# ----------------------------------------------------------------------
def test_fifo_admission_and_batch_size_budget():
    q = AdmissionQueue(MicroBatchPolicy(max_batch_size=4))
    for i in range(10):
        q.submit(mk_task(i))
    batches = q.drain_batches()
    assert [len(b) for b in batches] == [4, 4, 2]
    assert [b.batch_id for b in batches] == [0, 1, 2]
    flat = [r for b in batches for r in b.requests]
    assert [r.task.task_id for r in flat] == \
        [f"q-{i:03d}" for i in range(10)]
    assert [r.admission_index for r in flat] == list(range(10))
    assert len(q) == 0


def test_token_budget_closes_batch():
    q = AdmissionQueue(MicroBatchPolicy(max_batch_size=16,
                                        max_batch_tokens=10))
    for i in range(4):
        q.submit(mk_task(i, text="w " * 4))     # 4 tokens each
    batches = q.drain_batches()
    assert [len(b) for b in batches] == [2, 2]
    assert all(b.total_tokens <= 10 for b in batches)


def test_oversized_request_admitted_alone():
    q = AdmissionQueue(MicroBatchPolicy(max_batch_size=8,
                                        max_batch_tokens=4))
    q.submit(mk_task(0, text="w " * 50))        # alone exceeds budget
    q.submit(mk_task(1))
    batches = q.drain_batches()
    assert [len(b) for b in batches] == [1, 1]


def test_arrival_times_monotone():
    q = AdmissionQueue()
    q.submit(mk_task(0), arrival_time=5)
    with pytest.raises(ValueError):
        q.submit(mk_task(1), arrival_time=3)
    r = q.submit(mk_task(2))                    # auto tick continues
    assert r.arrival_time > 5


def test_arrival_watermark_survives_batch_formation():
    """Monotonicity is enforced against the last arrival ever seen,
    not just the current pending tail."""
    q = AdmissionQueue()
    q.submit(mk_task(0), arrival_time=10)
    q.form_batch()                              # drains the deque
    with pytest.raises(ValueError):
        q.submit(mk_task(1), arrival_time=3)


def test_ready_fill_or_timeout():
    q = AdmissionQueue(MicroBatchPolicy(max_batch_size=4,
                                        max_wait_ticks=10))
    assert not q.ready()
    q.submit(mk_task(0), arrival_time=0)
    assert not q.ready(now=5)               # not full, not timed out
    assert q.ready(now=10)                  # oldest waited max_wait
    for i in range(1, 4):
        q.submit(mk_task(i))                # arrive at ticks 1..3
    # only *arrived* requests count toward the fill trigger: at now=1
    # just two of four have landed, so the batch must not close early
    assert not q.ready(now=1)
    assert q.ready(now=3)                   # size budget filled


def test_ready_ignores_unarrived_pending():
    """Regression: ready() counted future arrivals toward the fill
    trigger, so a head request plus a burst landing later fired the
    trigger at the head's arrival — admitting the head alone and the
    burst as a second batch."""
    q = AdmissionQueue(MicroBatchPolicy(max_batch_size=4,
                                        max_wait_ticks=10))
    q.submit(mk_task(0), arrival_time=0)
    for i in range(1, 4):
        q.submit(mk_task(i), arrival_time=10)
    assert not q.ready(now=0)
    assert not q.ready(now=9)
    assert q.ready(now=10)
    batch = q.form_batch(now=10)
    assert len(batch) == 4                  # one batch, not two


def test_burst_at_fill_equals_timeout_forms_one_batch():
    """The prescribed boundary: a burst whose last member arrives
    exactly when the head's wait budget expires (fill == timeout) —
    both triggers coincide, and drain admits the whole burst as a
    single batch at that instant."""
    pol = MicroBatchPolicy(max_batch_size=4, max_wait_ticks=10)
    q = AdmissionQueue(pol)
    q.submit(mk_task(0), arrival_time=0)
    for i in range(1, 4):
        q.submit(mk_task(i), arrival_time=10)   # fill == timeout == 10
    assert q.next_ready_at() == 10
    # streaming view: not a tick before 10, the whole burst at 10
    assert not q.ready(now=9)
    assert q.ready(now=10)
    batches = q.drain_batches()
    assert [len(b) for b in batches] == [4]


def test_next_ready_at_boundaries():
    """Empty queue: None (no meaningful instant after a drain).
    Exactly-full queue: the min of the fill and timeout instants."""
    pol = MicroBatchPolicy(max_batch_size=3, max_wait_ticks=10)
    q = AdmissionQueue(pol)
    assert q.next_ready_at() is None
    q.submit(mk_task(0), arrival_time=2)
    assert q.next_ready_at() == 12          # under-full: timeout only
    q.submit(mk_task(1), arrival_time=4)
    q.submit(mk_task(2), arrival_time=6)    # exactly full
    assert q.next_ready_at() == 6           # fill (6) < timeout (12)
    q.drain_batches()
    assert q.next_ready_at() is None        # drained: meaningless again


def test_policy_validation():
    with pytest.raises(ValueError):
        MicroBatchPolicy(max_batch_size=0)
    with pytest.raises(ValueError):
        MicroBatchPolicy(max_batch_tokens=0)


def test_estimate_tokens():
    assert estimate_tokens("a b c") == 3
    assert estimate_tokens("") == 1


# ----------------------------------------------------------------------
# probe cache
# ----------------------------------------------------------------------
def entry():
    return _ProbeEntry([], [], 1.0)


def test_probe_cache_hit_miss_counting():
    c = ProbeCache(capacity=4)
    k = ProbeCache.key(mk_task(0), "prompt", ACARConfig())
    assert c.lookup(k) is None
    c.insert(k, entry())
    assert c.lookup(k) is not None
    assert (c.hits, c.misses) == (1, 1)


def test_probe_cache_key_covers_generation_identity():
    t = mk_task(0)
    base = ProbeCache.key(t, "p", ACARConfig())
    assert ProbeCache.key(t, "p2", ACARConfig()) != base
    assert ProbeCache.key(t, "p", ACARConfig(seed=1)) != base
    assert ProbeCache.key(t, "p", ACARConfig(
        probe_temperature=0.1)) != base
    assert ProbeCache.key(mk_task(1), "p", ACARConfig()) != base


def test_probe_cache_lru_eviction():
    c = ProbeCache(capacity=2)
    ks = [ProbeCache.key(mk_task(i), "p", ACARConfig())
          for i in range(3)]
    c.insert(ks[0], entry())
    c.insert(ks[1], entry())
    assert c.lookup(ks[0]) is not None      # refresh 0 -> 1 is LRU
    c.insert(ks[2], entry())                # evicts 1
    assert c.lookup(ks[1]) is None
    assert c.lookup(ks[0]) is not None
    assert len(c) == 2


def test_probe_cache_zero_capacity_disables():
    c = ProbeCache(capacity=0)
    k = ProbeCache.key(mk_task(0), "p", ACARConfig())
    c.insert(k, entry())
    assert c.lookup(k) is None


# ----------------------------------------------------------------------
# Prometheus-style counters
# ----------------------------------------------------------------------
def test_counters_accumulate_and_render():
    m = PromCounters()
    m.inc("acar_x_total", help="an x counter")
    m.inc("acar_x_total", 2.0)
    m.inc("acar_y_total", 1.0, mode="full_arena")
    m.inc("acar_y_total", 1.0, mode="single_agent")
    assert m.get("acar_x_total") == 3.0
    assert m.get("acar_y_total", mode="full_arena") == 1.0
    text = m.render()
    assert "# HELP acar_x_total an x counter" in text
    assert "# TYPE acar_x_total counter" in text
    assert "acar_x_total 3" in text
    assert 'acar_y_total{mode="full_arena"} 1' in text
    assert text.endswith("\n")


def test_counters_render_escapes_hostile_labels():
    """Regression: label values rendered unescaped, so a model name
    containing a quote, backslash or newline produced invalid
    Prometheus exposition text."""
    m = PromCounters()
    m.inc("acar_h_total", 1.0, model='ev"il\\mo\ndel',
          help="hostile\nhelp \\text")
    text = m.render()
    # label value: \ -> \\, " -> \", newline -> \n (two characters)
    assert 'acar_h_total{model="ev\\"il\\\\mo\\ndel"} 1' in text
    # HELP text: backslash and newline escaped
    assert "# HELP acar_h_total hostile\\nhelp \\\\text" in text
    # the sample must survive as exactly one exposition line — a raw
    # newline in the label would have split it in two
    sample = [ln for ln in text.splitlines()
              if ln.startswith("acar_h_total{")]
    assert len(sample) == 1
    # benign labels render byte-identically to before
    m2 = PromCounters()
    m2.inc("acar_y_total", 1.0, mode="full_arena")
    assert 'acar_y_total{mode="full_arena"} 1' in m2.render()


def test_counters_render_deterministic():
    def build():
        m = PromCounters()
        m.inc("b_total", mode="z")
        m.inc("a_total")
        m.inc("b_total", mode="a")
        return m.render()
    assert build() == build()
    assert build().index("a_total") < build().index("b_total")


# ----------------------------------------------------------------------
# engine wiring: queued serve over the real-model engine is exercised
# in test_serving_engine.py-adjacent speed; here we only check the
# micro-batch split logic is reachable through run_queued's queue use
# ----------------------------------------------------------------------
def test_arithmetic_queue_split():
    q = AdmissionQueue(MicroBatchPolicy(max_batch_size=8))
    for t in arithmetic_suite(20, seed=0):
        q.submit(t)
    assert [len(b) for b in q.drain_batches()] == [8, 8, 4]
